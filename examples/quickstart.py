"""Quickstart: the paper in five minutes.

Builds a Graph500-style R-MAT graph, runs the self-stabilizing SSSP
kernel three ways — (1) the literal Algorithm 1 synchronous sweep,
(2) the logical AGM (Definition 3 semantics), (3) the distributed
EAGM engine behind the repro.api facade — then uses the two serving
features the facade adds: batched sources and self-stabilizing warm
restarts.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import numpy as np

from repro.api import Problem, SingleSource, Solver
from repro.core import (
    dijkstra_reference, make_ordering, model_time_s, run_logical,
    sssp_agm,
)
from repro.core.selfstab import synchronous_sweep
from repro.graph import rmat1


def agrees(ref, d):
    return np.allclose(np.where(np.isinf(ref), -1, ref),
                       np.where(np.isinf(d), -1, d))


def main():
    g = rmat1(11, seed=0)
    print(f"graph: {g.name}  |V|={g.n}  |E|={g.m}")
    ref = dijkstra_reference(g, 0)
    reach = int(np.isfinite(ref).sum())
    print(f"oracle: {reach}/{g.n} vertices reachable from 0\n")

    # 1. the self-stabilizing kernel itself (Algorithm 1), started
    #    from a CORRUPTED state — it still stabilizes.
    rng = np.random.default_rng(0)
    d0 = rng.uniform(0, 100, g.n).astype(np.float32)
    d = synchronous_sweep(g, 0, d0, iters=600)
    print(f"[1] self-stabilizing sweep from random state: "
          f"{'stabilized correctly' if agrees(ref, d) else 'FAILED'}")

    # 2. the logical AGM: ordering => equivalence classes => less work
    print("\n[2] logical AGM (Definition 3): ordering vs work")
    for spec in ["chaotic", "delta:20", "dijkstra"]:
        dist, m = run_logical(sssp_agm(g, 0, make_ordering(spec)))
        assert agrees(ref, dist)
        print(f"    {spec:9s} classes={m.classes:5d} "
              f"relaxations={m.relaxations:8d} commits={m.commits}")

    # 3. the distributed EAGM engine through the facade: one spec
    #    string per family member, compiled once per shape
    print("\n[3] distributed EAGM engine (repro.api)")
    for spec in ["delta:20+buffer", "chaotic+threadq"]:
        solver = Solver(spec + "/a2a")
        sol = solver.solve(Problem(g, SingleSource(0)))
        assert agrees(ref, sol.state)
        m = sol.metrics
        print(f"    {spec:16s} supersteps={m.supersteps:4d} "
              f"relax={m.relaxations:8d} "
              f"cost-model(256 chips)={model_time_s(m, 256)*1e3:6.2f} ms")

    # 4. serving features: batched sources share one engine call,
    #    and a warm restart stabilizes a perturbed graph in a few
    #    supersteps (paper §II — the kernel converges from any state
    #    the perturbation left valid)
    print("\n[4] serving: batched sources + warm restart")
    solver = Solver("chaotic+threadq/a2a")
    sols = solver.solve_batch(
        [Problem(g, SingleSource(v)) for v in (0, 17, 99)]
    )
    print(f"    batch of 3 sources: supersteps="
          f"{[s.metrics.supersteps for s in sols]}")

    g2 = dataclasses.replace(g, weight=g.weight.copy(), name="cheaper")
    g2.weight[rng.integers(0, g2.m, 50)] *= 0.25  # some edges cheapen
    warm = solver.resolve(sols[0], graph=g2)
    cold = solver.solve(Problem(g2, SingleSource(0)))
    assert agrees(cold.state, warm.state)
    print(f"    warm restart after perturbation: "
          f"{warm.metrics.supersteps} supersteps "
          f"(cold solve: {cold.metrics.supersteps})")

    print("\nall layers agree with Dijkstra — see DESIGN.md for how "
          "the EAGM hierarchy maps to a TPU pod")


if __name__ == "__main__":
    main()
