"""Quickstart: the paper in five minutes.

Builds a Graph500-style R-MAT graph, runs the self-stabilizing SSSP
kernel three ways — (1) the literal Algorithm 1 synchronous sweep,
(2) the logical AGM (Definition 3 semantics), (3) the distributed
EAGM engine — and shows that orderings trade work for synchronization
exactly as the paper claims.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    EngineConfig, dijkstra_reference, make_ordering, make_policy,
    model_time_s, run_distributed, run_logical, sssp_agm, sssp_sources,
)
from repro.core.selfstab import synchronous_sweep
from repro.graph import partition_1d, rmat1
from repro.launch.mesh import make_cpu_topology


def main():
    g = rmat1(11, seed=0)
    print(f"graph: {g.name}  |V|={g.n}  |E|={g.m}")
    ref = dijkstra_reference(g, 0)
    reach = int(np.isfinite(ref).sum())
    print(f"oracle: {reach}/{g.n} vertices reachable from 0\n")

    # 1. the self-stabilizing kernel itself (Algorithm 1), started
    #    from a CORRUPTED state — it still stabilizes.
    rng = np.random.default_rng(0)
    d0 = rng.uniform(0, 100, g.n).astype(np.float32)
    d = synchronous_sweep(g, 0, d0, iters=600)
    ok = np.allclose(np.where(np.isinf(ref), -1, ref),
                     np.where(np.isinf(d), -1, d))
    print(f"[1] self-stabilizing sweep from random state: "
          f"{'stabilized correctly' if ok else 'FAILED'}")

    # 2. the logical AGM: ordering => equivalence classes => less work
    print("\n[2] logical AGM (Definition 3): ordering vs work")
    for spec in ["chaotic", "delta:20", "dijkstra"]:
        dist, m = run_logical(sssp_agm(g, 0, make_ordering(spec)))
        assert np.allclose(np.where(np.isinf(ref), -1, ref),
                           np.where(np.isinf(dist), -1, dist))
        print(f"    {spec:9s} classes={m.classes:5d} "
              f"relaxations={m.relaxations:8d} commits={m.commits}")

    # 3. the distributed EAGM engine (same code the 512-chip dry-run
    #    lowers), with the paper's best variant
    print("\n[3] distributed EAGM engine")
    topo = make_cpu_topology()
    pg = partition_1d(g, topo.n_devices)
    for root, variant in [("delta:20", "buffer"),
                          ("chaotic", "threadq")]:
        cfg = EngineConfig(policy=make_policy(root, variant,
                                              chunk_size=512))
        dist, m = run_distributed(pg, topo.mesh, cfg, sssp_sources(0))
        assert np.allclose(np.where(np.isinf(ref), -1, ref),
                           np.where(np.isinf(dist), -1, dist))
        print(f"    {root:9s}+{variant:8s} supersteps={m.supersteps:4d} "
              f"relax={m.relaxations:8d} "
              f"cost-model(256 chips)={model_time_s(m, 256)*1e3:6.2f} ms")
    print("\nall three layers agree with Dijkstra — see DESIGN.md "
          "for how the EAGM hierarchy maps to a TPU pod")


if __name__ == "__main__":
    main()
