"""Train the assigned GNN architectures (reduced configs) on random
molecule batches + node classification on a real topology.

    PYTHONPATH=src python examples/gnn_train.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import molecule_batch
from repro.models.gnn import dimenet, egnn, mace
from repro.train import (
    AdamWConfig, TrainConfig, build_train_step, init_train_state,
)


def train_molecules(arch: str, impl, steps: int = 30):
    cfg = get_arch(arch).make_config(reduced=True, cell="molecule")
    key = jax.random.PRNGKey(0)
    p = impl.init_params(key, cfg)
    tc = TrainConfig(adamw=AdamWConfig(lr=3e-3), warmup_steps=3,
                     total_steps=steps)
    fn = jax.jit(build_train_step(
        lambda pp, b: impl.regression_loss(pp, b, cfg), tc))
    st = init_train_state(p, tc)
    first = last = None
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in molecule_batch(
            i, 8, 10, 20, triplets=True, triplet_pad=128).items()}
        p, st, m = fn(p, st, b, jnp.int32(i))
        if i == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    print(f"{arch:10s} molecule-energy MSE: {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'no improvement'})")


def main():
    train_molecules("egnn", egnn)
    train_molecules("mace", mace)
    train_molecules("dimenet", dimenet)


if __name__ == "__main__":
    main()
