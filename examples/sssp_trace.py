"""Flight-record one SSSP solve and watch it converge.

A ``/trace`` spec runs the solve through the segment engine purely to
publish per-superstep metrics windows — by self-stabilization the
schedule reordering cannot move the fixpoint, so the traced solve is
bit-identical (state AND WorkMetrics) to the untraced one, which this
example verifies before printing the per-superstep convergence table
and exporting a Perfetto-loadable trace.

    PYTHONPATH=src python examples/sssp_trace.py
    # then load /tmp/sssp_trace.json at https://ui.perfetto.dev
"""

import numpy as np

from repro.api import Problem, SingleSource, Solver
from repro.graph import rmat1
from repro.obs import Tracer, use_tracer, write_chrome_trace


def main():
    g = rmat1(10, seed=0)
    prob = Problem(g, SingleSource(0))
    spec = "delta:5/sparse"
    print(f"graph {g.name}: n={g.n} m={g.m}, spec {spec!r}")

    # 1. the untraced reference
    base = Solver(spec).solve(prob)

    # 2. the same solve, flight-recorded under a span tracer
    tracer = Tracer()
    with use_tracer(tracer):
        traced = Solver(spec + "/trace").solve(prob)

    # 3. observation without intervention, machine-checked
    assert np.array_equal(base.state, traced.state)
    assert base.metrics == traced.metrics
    tr = traced.trace
    tr.reconcile(traced.metrics)  # per-superstep sums == aggregates
    print(f"traced solve bit-identical to untraced: {traced.metrics}\n")

    # 4. the paper's work-vs-ordering narrative, superstep by superstep
    print(tr.table())

    # 5. where the wall-clock went (span tree)
    solve = tracer.find("solver.solve")[0]
    print(f"\nsolver.solve {solve.duration_s * 1e3:.1f}ms across "
          f"{len(tracer.find('tune.segment'))} segments:")
    for seg in tracer.find("tune.segment"):
        print(f"  segment {seg.attrs['segment']}: "
              f"{seg.attrs['supersteps']} supersteps, "
              f"pending {seg.attrs['pending']}, "
              f"{seg.duration_s * 1e3:.1f}ms")

    out = "/tmp/sssp_trace.json"
    write_chrome_trace(out, tracer, [tr])
    print(f"\nwrote {out} — load it at https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
