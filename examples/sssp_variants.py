"""The paper's full evaluation grid (Figures 5-7) at laptop scale:
every (AGM root ordering × EAGM spatial variant), verified against
Dijkstra, with the work/sync metrics the paper's timings decompose
into.

    PYTHONPATH=src python examples/sssp_variants.py [--scale 10]
"""

import argparse

import numpy as np

from repro.core import (
    EngineConfig, dijkstra_reference, model_time_s, paper_variant_grid,
    run_distributed, sssp_sources,
)
from repro.graph import partition_1d, rmat2
from repro.launch.mesh import make_cpu_topology


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=10)
    args = ap.parse_args()

    g = rmat2(args.scale, seed=3)
    topo = make_cpu_topology()
    pg = partition_1d(g, topo.n_devices)
    ref = dijkstra_reference(g, 0)
    print(f"graph {g.name}: |V|={g.n} |E|={g.m}\n")
    print(f"{'variant':22s} {'steps':>6s} {'relax':>9s} {'commits':>8s} "
          f"{'xchg MB':>8s} {'model ms':>9s}")

    best = None
    for pol in paper_variant_grid(deltas=(5,), ks=(1, 2)):
        cfg = EngineConfig(policy=pol, exchange="a2a")
        dist, m = run_distributed(pg, topo.mesh, cfg, sssp_sources(0))
        ok = np.allclose(np.where(np.isinf(ref), -1, ref),
                         np.where(np.isinf(dist), -1, dist))
        assert ok, pol.name
        ms = model_time_s(m, 256) * 1e3
        if best is None or ms < best[1]:
            best = (pol.name, ms)
        print(f"{pol.name:22s} {m.supersteps:6d} {m.relaxations:9d} "
              f"{m.commits:8d} {m.exchange_bytes/1e6:8.1f} {ms:9.2f}")
    print(f"\nfastest under the pod cost model: {best[0]} "
          f"({best[1]:.2f} ms)")


if __name__ == "__main__":
    main()
