"""The paper's full evaluation grid (Figures 5-7) at laptop scale —
every (AGM root ordering × EAGM spatial variant) — plus composed
multi-level hierarchies the one-slot variant API could not express,
all verified against Dijkstra, with the work/sync metrics the paper's
timings decompose into.  Each family member is one repro.api spec
string (legacy ``root+variant`` or hierarchy ``root > level:ordering
> ...``).

    PYTHONPATH=src python examples/sssp_variants.py [--scale 10]
"""

import argparse

import numpy as np

from repro.api import Problem, SingleSource, Solver, SolverConfig
from repro.core import dijkstra_reference, model_time_s, paper_variant_specs
from repro.graph import rmat2

# beyond-paper family points: several levels annotated simultaneously
COMPOSED = [
    "delta:5 > pod:dijkstra > chunk:delta:1",
    "delta:5 > pod:delta:2 > device:dijkstra > chunk:topk:256",
    "chaotic > device:dijkstra > chunk:topk:128",
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=10)
    args = ap.parse_args()

    g = rmat2(args.scale, seed=3)
    ref = dijkstra_reference(g, 0)
    print(f"graph {g.name}: |V|={g.n} |E|={g.m}\n")
    print(f"{'family member':44s} {'steps':>6s} {'relax':>9s} "
          f"{'commits':>8s} {'xchg MB':>8s} {'model ms':>9s}")

    best = None
    specs = paper_variant_specs(deltas=(5,), ks=(1, 2)) + COMPOSED
    for spec in specs:
        solver = Solver(SolverConfig.from_spec(spec, chunk_size=1024))
        sol = solver.solve(Problem(g, SingleSource(0)))
        ok = np.allclose(np.where(np.isinf(ref), -1, ref),
                         np.where(np.isinf(sol.state), -1, sol.state))
        assert ok, spec
        m = sol.metrics
        ms = model_time_s(m, 256) * 1e3
        if best is None or ms < best[1]:
            best = (spec, ms)
        label = spec if len(spec) <= 44 else spec.replace(" ", "")
        print(f"{label:44s} {m.supersteps:6d} {m.relaxations:9d} "
              f"{m.commits:8d} {m.exchange_bytes/1e6:8.1f} {ms:9.2f}")
    print(f"\nfastest under the pod cost model: {best[0]} "
          f"({best[1]:.2f} ms)")


if __name__ == "__main__":
    main()
