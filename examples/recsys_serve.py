"""MIND *model*-serving example: train briefly on synthetic
interactions, then serve batched retrieval requests (the
retrieval_cand cell's compute pattern at laptop scale).

    PYTHONPATH=src python examples/recsys_serve.py

This is the recommender demo.  The *graph* query-serving demo — the
`repro.serve` Router/SolutionCache/LandmarkIndex stack over the SSSP
solver — lives in examples/sssp_serve.py.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import mind_batch
from repro.models import mind
from repro.train import (
    AdamWConfig, TrainConfig, build_train_step, init_train_state,
)


def main():
    cfg = get_arch("mind").make_config(reduced=True)
    key = jax.random.PRNGKey(0)
    p = mind.init_params(key, cfg)
    tc = TrainConfig(adamw=AdamWConfig(lr=1e-2), warmup_steps=5,
                     total_steps=60)
    fn = jax.jit(build_train_step(
        lambda pp, b: mind.sampled_softmax_loss(pp, b, cfg), tc))
    st = init_train_state(p, tc)
    for i in range(60):
        b = {k: jnp.asarray(v) for k, v in mind_batch(i, 64, cfg).items()}
        p, st, m = fn(p, st, b, jnp.int32(i))
        if i % 20 == 0:
            print(f"train step {i:3d} loss={float(m['loss']):.4f}")

    # batched retrieval serving: score every item for a request batch
    serve = jax.jit(lambda pp, b, c: mind.retrieval_scores(pp, b, c, cfg))
    cand = jnp.arange(cfg.n_items, dtype=jnp.int32)
    reqs = {k: jnp.asarray(v) for k, v in mind_batch(999, 32, cfg).items()}
    t0 = time.perf_counter()
    scores = serve(p, reqs, cand)
    scores.block_until_ready()
    dt = time.perf_counter() - t0
    top = jnp.argsort(-scores, axis=1)[:, :5]
    print(f"\nserved 32 requests x {cfg.n_items} candidates in "
          f"{dt*1e3:.1f} ms (incl. compile)")
    print("top-5 items for first 3 users:")
    for u in range(3):
        print(f"  user {u}: {np.asarray(top[u])}")


if __name__ == "__main__":
    main()
