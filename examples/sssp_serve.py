"""Graph-serving end-to-end demo: the persistent SSSP query service.

Walks the whole `repro.serve` stack at laptop scale:

  1. one long-lived Solver (compile-once engine cache);
  2. a LandmarkIndex hub tier (one batched solve over K hubs);
  3. a Router admitting a skewed query mix into fixed-shape batches,
     backed by a byte-budgeted LRU SolutionCache;
  4. an UpdateFeed streaming edge updates: improving ones keep cached
     answers fresh via self-stabilizing warm restarts (exact, a few
     supersteps), non-improving ones invalidate + cold-solve.

    PYTHONPATH=src python examples/sssp_serve.py

(The MIND recommender-serving demo lives in examples/recsys_serve.py;
this file is the *graph* serving demo.)
"""

import time

import numpy as np

from repro.api import Solver
from repro.graph import rmat1
from repro.serve import (
    EdgeUpdate, LandmarkIndex, Query, Router, SolutionCache, UpdateFeed,
    serve_latency_stats,
)


def main():
    g = rmat1(10, seed=0)
    solver = Solver("delta:5+threadq/a2a")
    print(f"graph {g.name}: n={g.n} m={g.m}")

    # landmark tier: K hub sources, one batched solve
    t0 = time.perf_counter()
    lm = LandmarkIndex(solver, g, k=4, symmetric=True)
    print(f"landmarks {lm.landmarks} built in "
          f"{time.perf_counter() - t0:.2f}s")

    cache = SolutionCache(byte_budget=64 << 20)
    router = Router(solver, g, cache=cache, landmarks=lm, max_batch=4)

    # a skewed mix: hot sources repeat, some point-to-point, one
    # estimate; hot set drawn from well-connected vertices so the demo
    # prints finite distances
    rng = np.random.default_rng(0)
    deg_order = np.argsort(-np.bincount(g.src, minlength=g.n))
    hot = [int(v) for v in rng.choice(deg_order[:50], size=3,
                                      replace=False)]
    queries = (
        [Query(hot[0]), Query(hot[1], target=7), Query(hot[2])]
        + [Query(hot[0], target=int(t)) for t in rng.integers(0, g.n, 4)]
        + [Query(hot[1], target=9, exact=False)]   # landmark estimate
    )
    answers = router.serve(queries)
    lat = serve_latency_stats(answers)
    for a in answers[:5]:
        what = (f"d({a.query.source},{a.query.target})={a.distance:.3f}"
                if a.query.target is not None
                else f"state({a.query.source})")
        print(f"  {what:28s} via {a.served_by}")
    print(f"served {len(answers)} queries: {lat}")

    # the hot set is now resident: a second round is all cache hits
    again = router.serve([Query(v) for v in hot])
    print(f"second round served by "
          f"{sorted({a.served_by for a in again})}; cache {cache.stats}")

    # stream an improving update: cached answers refresh via warm
    # restart — exact by self-stabilization, a few supersteps
    feed = UpdateFeed(g, solver, cache=cache, landmarks=lm)
    e = int(rng.integers(0, g.m))
    res = feed.apply(EdgeUpdate(int(g.src[e]), int(g.dst[e]),
                                float(g.weight[e]) * 0.25))
    print(f"improving update: {res.warm_refreshes} entries warm-refreshed "
          f"in {res.warm_supersteps} total supersteps")

    # and a non-improving one: stale answers detected, cold-solved
    e = int(rng.integers(0, g.m))
    res = feed.apply(EdgeUpdate(int(g.src[e]), int(g.dst[e]),
                                float(g.weight[e]) * 10.0))
    print(f"non-improving update: {res.invalidated} invalidated, "
          f"{res.cold_refreshes} cold-refreshed")

    # the hot source is still served from cache, and still correct
    a = router.serve([Query(hot[0])])[0]
    print(f"post-update query via {a.served_by}; "
          f"reached {int(np.isfinite(a.solution.state).sum())}/{g.n}")


if __name__ == "__main__":
    main()
