"""End-to-end LM training driver example: trains a reduced phi3-mini
on the deterministic synthetic stream for a few hundred steps with
checkpointing, then resumes from the checkpoint to show idempotent
recovery.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import sys
import tempfile

sys.argv0 = sys.argv[0]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    from repro.launch import train as train_mod

    with tempfile.TemporaryDirectory() as ckdir:
        argv = [
            "--arch", "phi3-mini-3.8b", "--reduced",
            "--steps", str(args.steps), "--batch", "8", "--seq", "64",
            "--microbatches", "2", "--ckpt-dir", ckdir,
            "--ckpt-every", "50", "--lr", "3e-3",
        ]
        sys.argv = ["train"] + argv
        train_mod.main()
        # resume from the checkpoint (simulated restart)
        print("\n--- simulated restart: resuming from checkpoint ---")
        sys.argv = ["train"] + argv + ["--steps", str(args.steps + 20)]
        train_mod.main()


if __name__ == "__main__":
    main()
