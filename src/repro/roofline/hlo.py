"""HLO-text analysis: collective-traffic extraction.

``cost_analysis()`` does not report collective bytes, so we parse the
compiled (post-SPMD-partitioning, per-device) HLO module text: first a
pass over instruction definitions builds name → result-shape-bytes,
then every collective op's operand names are resolved through that
map and summed.  (Operand shapes are not inlined in modern HLO dumps,
hence the two passes.)
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
    "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "token": 0, "u1": 1, "s1": 1,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# definition: `  %name = SHAPE opcode(args...`  (SHAPE may be a tuple)
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
    r"(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"([\w\-]+)\((.*)$"
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _shape_bytes(shape_expr: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_expr):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str, within=None) -> dict:
    """Returns per-device collective operand traffic:
    {'bytes': {op: B}, 'counts': {op: n}, 'total_bytes': B}.

    ``within`` (optional set of computation names) restricts which
    computations' collectives are charged — e.g. the transitive while
    body from :func:`while_body_computations` to get per-superstep
    rather than per-solve traffic.  Operand sizes still resolve
    module-wide."""
    sizes: dict[str, int] = {}
    pending: list[tuple[str, str, str]] = []  # (opcode, args, name)

    cur_comp = None
    for line in hlo_text.splitlines():
        cm = _COMP_RE.match(line)
        if cm:
            cur_comp = cm.group(1)
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, shape_expr, opcode, rest = m.groups()
        sizes[name] = _shape_bytes(shape_expr)
        if within is not None and cur_comp not in within:
            continue
        base = opcode.replace("-start", "")
        if base in COLLECTIVE_OPS and not opcode.endswith("-done"):
            # operand list = text up to the matching close paren
            depth, end = 1, len(rest)
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            pending.append((base, rest[:end], name))

    out: dict = defaultdict(int)
    counts: dict = defaultdict(int)
    for base, args, name in pending:
        b = 0
        for om in _OPERAND_RE.finditer(args):
            b += sizes.get(om.group(1), 0)
        if b == 0:
            # operand resolution failed; fall back to result size
            b = sizes.get(name, 0)
        out[base] += b
        counts[base] += 1
    return {
        "bytes": dict(out),
        "counts": dict(counts),
        "total_bytes": int(sum(out.values())),
    }


def flops_and_bytes(cost: dict) -> tuple[float, float]:
    """Extract (flops, bytes accessed) from compiled.cost_analysis()."""
    if cost is None:
        return 0.0, 0.0
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    return flops, byts


# ------------------------------------------------------------------ #
# refined HBM-traffic model
#
# cost_analysis()'s "bytes accessed" on the CPU backend counts every
# instruction of every computation — including the *internals* of
# fused computations (whose parameters/slices/bitcasts never touch
# HBM).  This analyzer walks the HLO text computation-by-computation,
# skips computations that are only ever called by `fusion` ops, skips
# free ops, and charges each remaining instruction output-bytes plus
# operand-bytes — a standard post-fusion HBM traffic model.

_FREE_OPS = {
    "parameter", "bitcast", "tuple", "get-tuple-element", "constant",
    "after-all", "partition-id", "replica-id", "iota",
}

# computation header: `%name (params...) -> result {`.  Params may be
# tuple-typed (nested parens), so match greedily up to the `)` that
# precedes the arrow rather than the first close-paren.
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->")

#: attributes whose value names a called computation; branch lists
#: appear as `branch_computations={%a, %b}`
_CALL_KEY_RE = re.compile(
    r"(?:calls|to_apply|body|condition|true_computation|"
    r"false_computation|branch_computations)="
    r"(\{[^}]*\}|%?[\w\.\-]+)"
)


def _called_comps(rest: str) -> list:
    """Computation names an instruction's attribute text calls into —
    covers calls/to_apply/body/condition and conditional branches
    (both the true/false pair and the `{...}` indexed-branch list)."""
    names: list = []
    for m in _CALL_KEY_RE.finditer(rest):
        v = m.group(1)
        if v.startswith("{"):
            names += re.findall(r"%?([\w\.\-]+)", v)
        else:
            names.append(v.lstrip("%"))
    return names


def while_body_computations(hlo_text: str) -> set:
    """Names of every computation reachable from a ``while`` op's body
    — the per-superstep program, transitively through calls, fusions,
    reducers and conditional branches.  Use as ``within=`` for
    :func:`hbm_traffic` / :func:`collective_bytes` to isolate hot-loop
    traffic from one-time setup."""
    edges: dict = defaultdict(set)
    roots: set = set()
    cur_comp = None
    for ln in hlo_text.splitlines():
        cm = _COMP_RE.match(ln)
        if cm:
            cur_comp = cm.group(1)
            continue
        m = _DEF_RE.match(ln)
        if not m:
            continue
        _, _, opcode, rest = m.groups()
        called = _called_comps(rest)
        if cur_comp is not None:
            edges[cur_comp].update(called)
        if opcode == "while":
            bm = re.search(r"body=%?([\w\.\-]+)", rest)
            if bm:
                roots.add(bm.group(1))
    out: set = set()
    stack = list(roots)
    while stack:
        c = stack.pop()
        if c in out:
            continue
        out.add(c)
        stack.extend(edges.get(c, ()))
    return out


def hbm_traffic(hlo_text: str, within=None, top: int = 8) -> dict:
    """Estimate executed HBM bytes: sum over non-free instructions in
    non-fused computations of (output + operand) bytes.  While bodies
    count once (callers scale by trip count externally).

    Fusion ops are charged at their boundary (operands + output — the
    internals stay in registers/VMEM) and labeled
    ``fusion(<root-opcode>)`` after the fused computation's ROOT, so
    a profile can say *which* fusion dominates.  ``within`` (a set of
    computation names, e.g. from :func:`while_body_computations`)
    restricts the charge to those computations; ``top`` caps the
    per-op breakdown length."""
    # pass 1: computations referenced by fusion ops (+ reducers), and
    # each computation's ROOT opcode (for fusion labels)
    fused: set = set()
    reducers: set = set()
    comp_root: dict = {}
    lines = hlo_text.splitlines()
    cur_comp = None
    for ln in lines:
        cm = _COMP_RE.match(ln)
        if cm:
            cur_comp = cm.group(1)
            continue
        m = _DEF_RE.match(ln)
        if not m:
            continue
        _, _, opcode, rest = m.groups()
        if ln.lstrip().startswith("ROOT") and cur_comp is not None:
            comp_root[cur_comp] = opcode
        called = _called_comps(rest)
        if opcode == "fusion":
            fused.update(called)
        elif opcode in ("reduce", "all-reduce", "reduce-scatter",
                        "scatter", "reduce-window", "sort",
                        "all-reduce-start"):
            reducers.update(called)

    sizes: dict[str, int] = {}
    cur_comp = None
    skip = False
    total = 0
    per_op: dict = defaultdict(int)
    for ln in lines:
        cm = _COMP_RE.match(ln)
        if cm:
            cur_comp = cm.group(1)
            skip = cur_comp in fused or cur_comp in reducers
            continue
        m = _DEF_RE.match(ln)
        if not m:
            continue
        name, shape_expr, opcode, rest = m.groups()
        out_b = _shape_bytes(shape_expr)
        sizes[name] = out_b
        if skip or opcode in _FREE_OPS:
            continue
        if within is not None and cur_comp not in within:
            continue
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_b = sum(
            sizes.get(om.group(1), 0)
            for om in _OPERAND_RE.finditer(rest[:end])
        )
        label = opcode
        if opcode == "fusion":
            called = _called_comps(rest)
            root = comp_root.get(called[0]) if called else None
            label = f"fusion({root})" if root else "fusion"
        total += out_b + operand_b
        per_op[label] += out_b + operand_b
    topd = dict(sorted(per_op.items(), key=lambda kv: -kv[1])[:top])
    return {"total_bytes": int(total), "by_op": topd}
