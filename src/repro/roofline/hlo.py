"""HLO-text analysis: collective-traffic extraction.

``cost_analysis()`` does not report collective bytes, so we parse the
compiled (post-SPMD-partitioning, per-device) HLO module text: first a
pass over instruction definitions builds name → result-shape-bytes,
then every collective op's operand names are resolved through that
map and summed.  (Operand shapes are not inlined in modern HLO dumps,
hence the two passes.)
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
    "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "token": 0, "u1": 1, "s1": 1,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# definition: `  %name = SHAPE opcode(args...`  (SHAPE may be a tuple)
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
    r"(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"([\w\-]+)\((.*)$"
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _shape_bytes(shape_expr: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_expr):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Returns per-device collective operand traffic:
    {'bytes': {op: B}, 'counts': {op: n}, 'total_bytes': B}."""
    sizes: dict[str, int] = {}
    pending: list[tuple[str, str, str]] = []  # (opcode, args, name)

    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, shape_expr, opcode, rest = m.groups()
        sizes[name] = _shape_bytes(shape_expr)
        base = opcode.replace("-start", "")
        if base in COLLECTIVE_OPS and not opcode.endswith("-done"):
            # operand list = text up to the matching close paren
            depth, end = 1, len(rest)
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            pending.append((base, rest[:end], name))

    out: dict = defaultdict(int)
    counts: dict = defaultdict(int)
    for base, args, name in pending:
        b = 0
        for om in _OPERAND_RE.finditer(args):
            b += sizes.get(om.group(1), 0)
        if b == 0:
            # operand resolution failed; fall back to result size
            b = sizes.get(name, 0)
        out[base] += b
        counts[base] += 1
    return {
        "bytes": dict(out),
        "counts": dict(counts),
        "total_bytes": int(sum(out.values())),
    }


def flops_and_bytes(cost: dict) -> tuple[float, float]:
    """Extract (flops, bytes accessed) from compiled.cost_analysis()."""
    if cost is None:
        return 0.0, 0.0
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    return flops, byts


# ------------------------------------------------------------------ #
# refined HBM-traffic model
#
# cost_analysis()'s "bytes accessed" on the CPU backend counts every
# instruction of every computation — including the *internals* of
# fused computations (whose parameters/slices/bitcasts never touch
# HBM).  This analyzer walks the HLO text computation-by-computation,
# skips computations that are only ever called by `fusion` ops, skips
# free ops, and charges each remaining instruction output-bytes plus
# operand-bytes — a standard post-fusion HBM traffic model.

_FREE_OPS = {
    "parameter", "bitcast", "tuple", "get-tuple-element", "constant",
    "after-all", "partition-id", "replica-id", "iota",
}

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\([^)]*\)\s*->")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")


def hbm_traffic(hlo_text: str) -> dict:
    """Estimate executed HBM bytes: sum over non-free instructions in
    non-fused computations of (output + operand) bytes.  While bodies
    count once (callers scale by trip count externally)."""
    # pass 1: find computations referenced by fusion ops (+ reducers)
    fused: set = set()
    reducers: set = set()
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        _, _, opcode, rest = m.groups()
        for cm in _CALLS_RE.finditer(rest):
            if opcode == "fusion":
                fused.add(cm.group(1))
            elif opcode in ("reduce", "all-reduce", "reduce-scatter",
                            "scatter", "reduce-window", "sort",
                            "all-reduce-start"):
                reducers.add(cm.group(1))

    sizes: dict[str, int] = {}
    cur_comp = None
    skip = False
    total = 0
    per_op: dict = defaultdict(int)
    for ln in lines:
        cm = _COMP_RE.match(ln)
        if cm:
            cur_comp = cm.group(1)
            skip = cur_comp in fused or cur_comp in reducers
            continue
        m = _DEF_RE.match(ln)
        if not m:
            continue
        name, shape_expr, opcode, rest = m.groups()
        out_b = _shape_bytes(shape_expr)
        sizes[name] = out_b
        if skip or opcode in _FREE_OPS:
            continue
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_b = sum(
            sizes.get(om.group(1), 0)
            for om in _OPERAND_RE.finditer(rest[:end])
        )
        total += out_b + operand_b
        per_op[opcode] += out_b + operand_b
    top = dict(sorted(per_op.items(), key=lambda kv: -kv[1])[:8])
    return {"total_bytes": int(total), "by_op": top}
