from repro.roofline.hlo import collective_bytes, flops_and_bytes, hbm_traffic
from repro.roofline.model import (
    Roofline, from_record, PEAK_FLOPS, HBM_BW, LINK_BW,
)

__all__ = [
    "collective_bytes", "flops_and_bytes", "hbm_traffic",
    "Roofline", "from_record",
    "PEAK_FLOPS", "HBM_BW", "LINK_BW",
]
