from repro.roofline.hlo import (
    collective_bytes,
    flops_and_bytes,
    hbm_traffic,
    while_body_computations,
)
from repro.roofline.model import (
    Roofline, from_record, PEAK_FLOPS, HBM_BW, LINK_BW,
)
from repro.roofline.superstep import (
    engine_step_hlo,
    fused_kernel_bytes,
    relax_region_bytes,
    superstep_profile,
)

__all__ = [
    "collective_bytes", "flops_and_bytes", "hbm_traffic",
    "while_body_computations",
    "Roofline", "from_record",
    "PEAK_FLOPS", "HBM_BW", "LINK_BW",
    "engine_step_hlo", "fused_kernel_bytes", "relax_region_bytes",
    "superstep_profile",
]
