"""Op-wise per-superstep roofline profile of the compiled engine.

The roofline model (:mod:`repro.roofline.model`) works per *solve*;
perf work on the superstep kernel needs the per-*superstep* view:
which ops inside the while body move the HBM bytes, and what the
fused gather+relax+scatter kernel saves.  This module compiles the
engine program, isolates the hot loop with
:func:`repro.roofline.hlo.while_body_computations`, and charges HBM
traffic op-by-op (fusions labeled by their ROOT opcode).

Fused-kernel accounting: Pallas kernels compile to opaque
custom-calls whose internals the HLO walk cannot see (and on the CPU
backend they run interpreted, which is not the program the roofline
targets).  So a fused config is profiled as

    ref while-body traffic
      - measured standalone relax-region traffic (gather/relax/scatter
        microprogram at the same shapes)
      + closed-form fused-kernel traffic (each tile crosses HBM once)

which is exactly the fusion's value proposition: the (F, W) candidate
matrix and its scatter intermediates never round-trip through HBM.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import EngineConfig, make_engine
from repro.core.frontier import frontier_caps, payload_plane_words
from repro.roofline.hlo import (
    collective_bytes,
    hbm_traffic,
    while_body_computations,
)

#: default abstract partition shape, mirrors analyze's StepShape
#: (roofline cannot import it — analyze imports roofline)
DEFAULT_SHAPE = {"n_local": 64, "rows": 80, "width": 8}


def engine_step_hlo(
    ecfg: EngineConfig,
    shape: Optional[dict] = None,
    mesh=None,
) -> tuple[str, int]:
    """Compiled per-device HLO text of the solve program for ``ecfg``
    at ``shape`` ({'n_local', 'rows', 'width'}).  Returns
    (hlo_text, n_parts)."""
    sh = dict(DEFAULT_SHAPE, **(shape or {}))
    if mesh is None:
        mesh = jax.make_mesh((jax.device_count(),), ("data",))
    n_parts = int(np.prod(tuple(mesh.devices.shape)))
    if ecfg.adapt_window:
        ecfg = dataclasses.replace(ecfg, adapt_window=0)
    fn = make_engine(
        {"n_parts": n_parts, "n_local": sh["n_local"]}, mesh, ecfg
    )
    s = jax.ShapeDtypeStruct
    args = (
        s((n_parts, sh["rows"]), jnp.int32),
        s((n_parts, sh["rows"], sh["width"]), jnp.int32),
        s((n_parts, sh["rows"], sh["width"]), jnp.float32),
        s((n_parts, sh["n_local"] + 1), jnp.float32),
        s((n_parts, sh["n_local"] + 1), jnp.float32),
        s((n_parts, sh["n_local"] + 1), jnp.float32),
    )
    return fn.lower(*args).compile().as_text(), n_parts


def _relax_region(D, f_idx, row_src, col, wgt, n_pad: int):
    """The unfused push-mode relax region at engine shapes: gather the
    F eligible rows, form min-plus candidates, scatter-min into a
    padded buffer — the ops the fused kernel replaces."""
    n_local = D.shape[0] - 1
    colg = jnp.take(col, f_idx, axis=0, mode="fill", fill_value=n_pad)
    srcg = jnp.take(row_src, f_idx, mode="fill", fill_value=n_local)
    wgtg = jnp.take(wgt, f_idx, axis=0, mode="fill", fill_value=jnp.inf)
    cand = D[srcg][:, None] + wgtg
    buf = jnp.full((n_pad + 1,), jnp.inf, jnp.float32)
    return buf.at[colg.reshape(-1)].min(cand.reshape(-1))[:n_pad]


def relax_region_bytes(
    ecfg: EngineConfig,
    shape: Optional[dict] = None,
    n_parts: int = 1,
) -> int:
    """Measured HBM bytes of the standalone relax-region microprogram
    at ``ecfg``'s frontier shapes (compiled, fusion-aware walk)."""
    sh = dict(DEFAULT_SHAPE, **(shape or {}))
    row_cap, _ = frontier_caps(
        sh["rows"], sh["width"], sh["n_local"], n_parts,
        ecfg.frontier_cap,
    )
    n_pad = n_parts * sh["n_local"]
    s = jax.ShapeDtypeStruct
    fn = jax.jit(_relax_region, static_argnums=(5,))
    text = fn.lower(
        s((sh["n_local"] + 1,), jnp.float32),
        s((row_cap,), jnp.int32),
        s((sh["rows"],), jnp.int32),
        s((sh["rows"], sh["width"]), jnp.int32),
        s((sh["rows"], sh["width"]), jnp.float32),
        n_pad,
    ).compile().as_text()
    return int(hbm_traffic(text)["total_bytes"])


def fused_kernel_bytes(
    row_cap: int, width: int, n_local: int, n_pad: int
) -> int:
    """Closed-form HBM bytes of one fused-kernel launch: every tile
    crosses HBM exactly once (col + wgt tiles per grid step, one
    row_src word per gather, the resident distance block in, the
    scatter block out, plus the scalar-prefetch plane)."""
    words = (
        row_cap * width * 2   # col + wgt tiles
        + row_cap             # row_src gathers
        + (n_local + 1)       # resident distance block, read once
        + (n_pad + 1)         # output block, one writeback
        + row_cap + 1         # scalar-prefetch idx plane + count
    )
    return 4 * words


def superstep_profile(
    ecfg: EngineConfig,
    shape: Optional[dict] = None,
    mesh=None,
) -> dict:
    """Op-wise per-superstep HBM/collective profile for ``ecfg``.

    Compiles the engine (the ref variant for fused configs — see the
    module docstring), restricts the traffic walk to the while body,
    and reports bytes per superstep plus the fused-kernel adjustment
    when ``ecfg.relax_impl`` requests fusion."""
    sh = dict(DEFAULT_SHAPE, **(shape or {}))
    fused = ecfg.relax_impl.startswith("fused")
    base = dataclasses.replace(ecfg, relax_impl="ref") if fused else ecfg
    text, n_parts = engine_step_hlo(base, sh, mesh)
    within = while_body_computations(text) or None
    hbm = hbm_traffic(text, within=within)
    coll = collective_bytes(text, within=within)
    row_cap, slot_cap = frontier_caps(
        sh["rows"], sh["width"], sh["n_local"], n_parts,
        ecfg.frontier_cap,
    )
    use_level = ecfg.hierarchy.needs_level
    xwords = payload_plane_words(slot_cap, use_level, ecfg.payload)
    prof = {
        "relax_impl": ecfg.relax_impl,
        "payload": ecfg.payload,
        "n_parts": n_parts,
        "shape": sh,
        "hbm_bytes_per_superstep": int(hbm["total_bytes"]),
        "hbm_by_op": hbm["by_op"],
        "collective_bytes_per_superstep": int(coll["total_bytes"]),
        "collective_counts": coll["counts"],
        "exchange_payload_bytes_per_superstep":
            4 * max(n_parts - 1, 0) * xwords,
    }
    if fused:
        rbytes = relax_region_bytes(ecfg, sh, n_parts)
        kbytes = fused_kernel_bytes(
            row_cap, sh["width"], sh["n_local"], n_parts * sh["n_local"]
        )
        prof["hbm_bytes_unfused"] = int(hbm["total_bytes"])
        prof["relax_region_bytes"] = rbytes
        prof["fused_kernel_bytes"] = kbytes
        prof["hbm_bytes_per_superstep"] = (
            max(0, int(hbm["total_bytes"]) - rbytes) + kbytes
        )
        prof["hbm_by_op"] = dict(
            hbm["by_op"], **{"fused_kernel(closed-form)": kbytes}
        )
    return prof
