"""Three-term roofline model for TPU v5e (the TARGET hardware).

    compute term    = HLO_FLOPs / peak_FLOP/s            (per chip)
    memory term     = HLO_bytes / HBM_bw                 (per chip)
    collective term = collective_bytes / link_bw         (per chip)

HLO_FLOPs / HLO_bytes come from the compiled (per-device, SPMD)
module's cost analysis; collective bytes from the HLO-text parser.
The dominant term is the bottleneck; MODEL_FLOPS/HLO_FLOPs measures
how much of the compiled compute is 'useful'.
"""

from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12       # bf16 / chip (TPU v5e)
HBM_BW = 819e9            # bytes/s / chip
LINK_BW = 50e9            # bytes/s / ICI link (~per-chip effective)


@dataclasses.dataclass
class Roofline:
    arch: str
    cell: str
    mesh: str
    chips: int
    hlo_flops: float          # per chip
    hlo_bytes: float          # per chip
    coll_bytes: float         # per chip
    model_flops: float        # useful FLOPs for the whole step (global)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=lambda k: terms[k])

    @property
    def t_bound(self) -> float:
        """Roofline-limited step time (no overlap assumption)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (chips · HLO_FLOPs): remat/redundancy waste."""
        total_hlo = self.hlo_flops * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful FLOPs over what the chips could do in the bound time
        (the §Perf score: MFU against the dominant bottleneck)."""
        if self.t_bound <= 0:
            return 0.0
        return self.model_flops / (
            self.chips * PEAK_FLOPS * self.t_bound
        )

    def row(self) -> dict:
        return {
            "arch": self.arch, "cell": self.cell, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def from_record(rec: dict) -> Roofline:
    flops = rec["cost"].get("flops", 0.0)
    # prefer the refined HBM-traffic model (fusion-aware HLO walk)
    # over the raw backend "bytes accessed" when available
    if rec.get("traffic"):
        byts = rec["traffic"]["total_bytes"]
        bkey = "traffic_bytes"
    else:
        byts = rec["cost"].get("bytes accessed", 0.0)
        bkey = "bytes"
    coll = rec["collectives"]["total_bytes"]
    probes = rec.get("probes")
    if probes:
        # layer-scan correction: XLA counts the scan body once, so
        # reconstruct totals from the depth-1/depth-2 probes.
        L = probes["n_layers"]
        p1, p2 = probes["L1"], probes["L2"]
        if bkey not in p1:
            bkey = "bytes"
        flops = p1["flops"] + (L - 1) * (p2["flops"] - p1["flops"])
        byts = p1[bkey] + (L - 1) * (p2[bkey] - p1[bkey])
        coll = p1["collective_bytes"] + (L - 1) * (
            p2["collective_bytes"] - p1["collective_bytes"]
        )
    return Roofline(
        arch=rec["arch"], cell=rec["cell"], mesh=rec["mesh"],
        chips=rec["chips"],
        hlo_flops=flops,
        hlo_bytes=byts,
        coll_bytes=coll,
        model_flops=rec["model_flops"],
    )
