"""Controller policies: metrics window -> next segment's tunables.

A policy is the runtime half of the paper's "generate the algorithm
for the target architecture" thesis (arXiv 1706.05760 §VII): instead
of freezing delta / frontier_cap / exchange per solve, the segmented
engine publishes a :class:`repro.core.metrics.SuperstepWindow` every
``adapt_window`` supersteps and the policy answers with a
:class:`Decision`.  Self-stabilization makes any answer *safe* — the
kernel's fixpoint is unique and every retuning only reorders the
schedule — so policies optimize cost, never correctness.

Policies are plain Python objects (one fresh instance per solve, so
they may carry state) registered by name; the spec grammar's
``/adapt:<policy>`` resolves here via :func:`make_tune_policy`.
``<policy>`` may carry one ``:<arg>`` suffix, passed to the factory
as a string (e.g. ``rho:0.05`` sets RhoPolicy's target fraction).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Protocol

from repro.core.metrics import SuperstepWindow
from repro.core.ordering import suggest


@dataclasses.dataclass(frozen=True)
class Tunables:
    """The knobs live at a segment boundary (what the engine will use
    next unless the policy's Decision overrides them)."""

    delta: Optional[float]       # root bucket width; None if the root
    #                              ordering is not delta-stepping
    frontier_cap: Optional[int]  # current sparse row capacity; None in
    #                              plain dense exchange modes
    exchange_force: int          # 0 = mode default, 1 = force sparse
    #                              (capacity veto still applies),
    #                              2 = force dense


@dataclasses.dataclass(frozen=True)
class Decision:
    """Policy output; ``None`` fields keep the current value.  The
    driver clamps ``frontier_cap`` to the per-device row count and
    counts a retrace when it lands on a capacity this solve has not
    compiled yet."""

    delta: Optional[float] = None
    frontier_cap: Optional[int] = None
    exchange_force: Optional[int] = None


class TunePolicy(Protocol):
    """Structural interface every controller policy implements."""

    def decide(
        self, window: SuperstepWindow, tunables: Tunables
    ) -> Decision:
        ...


class StaticPolicy:
    """Never changes anything — the adaptive engine with the static
    schedule.  The bit-identity equivalence tests pin the segmented
    engine against the classic loop through this policy."""

    def decide(
        self, window: SuperstepWindow, tunables: Tunables
    ) -> Decision:
        return Decision()


class ScheduledPolicy:
    """Replays an explicit list of Decisions, one per segment (then
    holds).  The hypothesis retuning-safety tests drive arbitrary
    schedules through this to machine-check the self-stabilization
    argument: any schedule, same fixpoint."""

    def __init__(self, schedule):
        self._schedule = list(schedule)
        self._i = 0

    def decide(
        self, window: SuperstepWindow, tunables: Tunables
    ) -> Decision:
        if self._i < len(self._schedule):
            d = self._schedule[self._i]
            self._i += 1
            return d
        return Decision()


class RhoPolicy:
    """rho-stepping-style self-tuning (SNIPPETS.md Snippet 2): sample
    the live frontier each segment and

    * double ``frontier_cap`` after >= 2 consecutive overflow
      supersteps (grow capacity instead of falling back dense),
    * retune ``delta`` toward a target eligible-class size — widen
      when the class is starved (too little parallelism per
      superstep), narrow when it floods (too much wasted work) —
      bounded to [1/64, 64]x the spec's delta so one noisy window
      cannot wedge the schedule,
    * pick the exchange from measured pending occupancy instead of
      the static ``auto`` threshold: force dense while more than half
      the graph is pending, force sparse otherwise.
    """

    def __init__(self, target_frac: float = 1.0 / 16.0):
        if not 0.0 < target_frac <= 1.0:
            raise ValueError(
                f"rho target_frac must be in (0, 1]: {target_frac}"
            )
        self.target_frac = float(target_frac)
        self._delta0: Optional[float] = None

    def decide(
        self, window: SuperstepWindow, tunables: Tunables
    ) -> Decision:
        delta: Optional[float] = None
        cap: Optional[int] = None
        force: Optional[int] = None
        if (
            tunables.frontier_cap is not None
            and window.overflow_streak >= 2
        ):
            cap = tunables.frontier_cap * 2
        if tunables.delta is not None and window.eligible:
            if self._delta0 is None:
                self._delta0 = tunables.delta
            base = self._delta0
            target = max(1.0, self.target_frac * window.n)
            avg = window.mean_eligible()
            if avg < target / 4.0:
                delta = min(tunables.delta * 2.0, base * 64.0)
            elif avg > target * 4.0:
                delta = max(tunables.delta / 2.0, base / 64.0)
        if window.sparse_capable and window.pending:
            frac = window.last_pending() / max(1, window.n)
            force = 2 if frac > 0.5 else 1
        return Decision(
            delta=delta, frontier_cap=cap, exchange_force=force
        )


# ---------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------

#: name -> (factory(arg: str | None) -> policy, traits dict)
_POLICIES: dict = {}


def register_tune_policy(
    name: str,
    factory: Callable[[Optional[str]], TunePolicy],
    *,
    grows_cap: bool = False,
    retunes_delta: bool = False,
) -> None:
    """Register a controller policy under ``name`` (usable as
    ``/adapt:<name>`` in specs).  ``factory`` receives the optional
    ``:<arg>`` suffix (string) and must return a fresh policy
    instance per call.  Re-registering a name replaces it.  The trait
    flags feed the spec_check lint (e.g. ``adapt-no-cap-growth``)."""
    if not name or ":" in name or "/" in name or "@" in name:
        raise ValueError(f"invalid policy name {name!r}")
    _POLICIES[name] = (
        factory,
        dict(grows_cap=grows_cap, retunes_delta=retunes_delta),
    )


def _split(spec: str) -> tuple[str, Optional[str]]:
    spec = str(spec).strip()
    if ":" in spec:
        name, arg = spec.split(":", 1)
        return name.strip(), arg.strip()
    return spec, None


def _lookup(spec: str):
    name, arg = _split(spec)
    entry = _POLICIES.get(name)
    if entry is None:
        raise ValueError(
            f"unknown adapt policy {name!r}; registered policies: "
            f"{tuple(sorted(_POLICIES))}"
            f"{suggest(name, tuple(_POLICIES))}"
        )
    return name, arg, entry


def canonical_policy(spec: str) -> str:
    """Validate a ``/adapt:<policy>`` spec and return its canonical
    form (constructs the policy once, so bad args fail at parse time
    with the factory's message)."""
    name, arg, (factory, _) = _lookup(spec)
    factory(arg)  # arg validation
    return name if arg is None else f"{name}:{arg}"


def make_tune_policy(spec: str) -> TunePolicy:
    """A fresh policy instance for one solve."""
    _, arg, (factory, _) = _lookup(spec)
    return factory(arg)


def policy_traits(spec: str) -> dict:
    """The registered trait flags for a policy spec (spec_check uses
    these to warn on e.g. /adapt + /sparse without cap growth)."""
    _, _, (_, traits) = _lookup(spec)
    return dict(traits)


def _rho_factory(arg: Optional[str]) -> RhoPolicy:
    if arg is None:
        return RhoPolicy()
    try:
        frac = float(arg)
    except ValueError:
        raise ValueError(
            f"rho policy arg must be a float target fraction: {arg!r}"
        ) from None
    return RhoPolicy(target_frac=frac)


def _static_factory(arg: Optional[str]) -> StaticPolicy:
    if arg is not None:
        raise ValueError(
            f"static policy takes no argument, got {arg!r}"
        )
    return StaticPolicy()


register_tune_policy(
    "rho", _rho_factory, grows_cap=True, retunes_delta=True
)
register_tune_policy("static", _static_factory)
