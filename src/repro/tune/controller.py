"""Adaptive execution driver: segments + controller policy.

:func:`run_adaptive` is the host side of the ``EngineConfig.
adapt_window`` seam.  It repeatedly invokes the compiled *segment*
engine (at most ``adapt_window`` supersteps per call, full (D, T, L)
state threaded through device-side), turns each segment's on-device
metrics window into a :class:`repro.core.metrics.SuperstepWindow`,
and lets the policy retune the next segment's tunables:

* ``delta`` and the exchange force are *dynamic scalars* — retuning
  them reuses the compiled segment bit-for-bit (no retrace),
* ``frontier_cap`` is a static shape (compaction capacity), so a cap
  the solve has not used yet costs one engine build — counted per
  solve, surfaced via ``Solution.metrics.retraces`` and
  ``Solver.stats()``, and amortized by the process-wide engine cache
  (a repeat solve with the same decision sequence retraces nothing).

Exactness: the kernel is self-stabilizing, so retuning the ordering
mid-solve reorders the schedule but cannot move the fixpoint — the
final distances are bit-identical to any static spec of the same
semiring (machine-checked in tests/test_tune_property.py).  Byte
accounting stays exact across cap changes because each segment's
words are computed with that segment's capacities
(api.solver.exchange_words).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.core.engine import EngineConfig
from repro.core.frontier import frontier_caps
from repro.core.metrics import SuperstepWindow, WorkMetrics
from repro.core.ordering import DeltaStepping
from repro.obs import trace as obs
from repro.tune.policies import Decision, TunePolicy, Tunables


@dataclasses.dataclass
class AdaptReport:
    """What the controller did during one adaptive solve."""

    segments: int = 0
    retraces: int = 0      # distinct frontier_cap shapes this solve
    #                        compiled beyond the first
    cap_growths: int = 0   # cap-change decisions applied
    decisions: list = dataclasses.field(default_factory=list)
    final_delta: Optional[float] = None
    final_frontier_cap: Optional[int] = None


def run_adaptive(
    mesh,
    ecfg: EngineConfig,
    pg,
    policy: TunePolicy,
    D0,
    T0,
    L0,
    on_window: Optional[Callable[[SuperstepWindow, dict], None]] = None,
) -> tuple[np.ndarray, WorkMetrics, AdaptReport]:
    """Drive the segmented engine to convergence (or ``max_iters``)
    under ``policy``.  Returns the padded (P, n_local) committed
    state, exact WorkMetrics, and the controller's AdaptReport.

    ``on_window`` is the flight-recorder tap: when given, it is
    invoked once per segment — *including the final one, before the
    policy is consulted* — with the segment's
    :class:`~repro.core.metrics.SuperstepWindow` and a segment-info
    dict (``supersteps``, wall ``t0``/``t1`` from the tracer clock,
    the tunables in force, ``fallbacks``).  Without ``on_window`` the
    final segment's window is never materialized (it has no policy
    consumer), matching the pre-recorder behavior.
    """
    from repro.api import solver as fac  # lazy: avoids import cycles

    if ecfg.adapt_window <= 0:
        raise ValueError("run_adaptive needs an adaptive EngineConfig "
                         f"(adapt_window > 0): {ecfg.adapt_window}")
    p = ecfg.processing
    Wn = ecfg.adapt_window
    sparse_capable = ecfg.exchange in ("sparse", "auto")
    P_, nl = pg.n_parts, pg.n_local
    n = P_ * nl

    root = ecfg.hierarchy.root
    delta = float(root.delta) if isinstance(root, DeltaStepping) else None
    if sparse_capable:
        cap, _ = frontier_caps(
            pg.rows_per_rank, pg.width, nl, P_, ecfg.frontier_cap
        )
    else:
        cap = None
    force = 0

    D, T, L = D0, T0, L0
    active = int(np.sum(np.asarray(p.better(T0, D0))))
    last_key = np.float32(np.nan)
    streak = 0

    it_total = 0
    commits = relax = classes = fallbacks = 0
    words = 0
    rounds = 0
    max_streak = 0
    caps_seen = {cap}
    report = AdaptReport()

    while active > 0 and it_total < ecfg.max_iters:
        with obs.span(
            "tune.segment", segment=report.segments,
            delta=delta, frontier_cap=cap, force=force,
        ) as sp:
            if sparse_capable:
                ecfg_seg = dataclasses.replace(ecfg, frontier_cap=cap)
            else:
                ecfg_seg = ecfg
            fn = fac.compiled_engine(mesh, ecfg_seg, P_, nl)
            limit = min(Wn, ecfg.max_iters - it_total)
            t0_seg = obs.now()
            out = fn(
                pg.row_src, pg.col, pg.wgt, D, T, L,
                np.int32(active), np.float32(last_key), np.int32(streak),
                np.int32(limit),
                np.float32(delta if delta is not None else np.nan),
                np.int32(force),
            )
            (D, T, L, it_a, c_a, r_a, k_a, active_a, fb_a, lk_a,
             streak_a, mstreak_a, pend_w, elig_w, rows_w, sparse_w) = out
            it = int(it_a)
            if it == 0:
                # can't happen while active > 0 and limit >= 1, but never
                # spin on a no-progress segment
                break
            fb = int(fb_a)
            it_total += it
            commits += int(c_a)
            relax += int(r_a)
            classes += int(k_a)
            fallbacks += fb
            active = int(active_a)
            last_key = np.float32(lk_a)
            streak = int(streak_a)
            max_streak = max(max_streak, int(mstreak_a))
            words += fac.exchange_words(pg, ecfg_seg, it, fb)
            rounds += it * (3 + (1 if sparse_capable else 0))
            report.segments += 1
            t1_seg = obs.now()
            sp.set(supersteps=it, pending=active, fallbacks=fb)

            done = active == 0 or it_total >= ecfg.max_iters
            if on_window is None and done:
                break

            # host-side per-step byte costs from the sparse/dense choice
            # and THIS segment's static capacities
            sparse_steps = np.asarray(sparse_w)[:it]
            dense_b = fac.exchange_words(pg, ecfg_seg, 1, 1) * 4 * P_
            sparse_b = fac.exchange_words(pg, ecfg_seg, 1, 0) * 4 * P_
            window = SuperstepWindow(
                pending=[int(x) for x in np.asarray(pend_w)[:it]],
                eligible=[int(x) for x in np.asarray(elig_w)[:it]],
                rows=[int(x) for x in np.asarray(rows_w)[:it]],
                sparse_used=[int(x) for x in sparse_steps],
                bytes_moved=[
                    sparse_b if int(s) else dense_b for s in sparse_steps
                ],
                overflow_streak=streak,
                supersteps_total=it_total,
                n=n,
                rows_per_rank=pg.rows_per_rank,
                sparse_capable=sparse_capable,
            )
            if on_window is not None:
                on_window(window, {
                    "supersteps": it, "t0": t0_seg, "t1": t1_seg,
                    "delta": delta, "frontier_cap": cap, "force": force,
                    "fallbacks": fb,
                })
            if done:
                break
            decision = policy.decide(
                window, Tunables(delta, cap, force)
            )
            if not isinstance(decision, Decision):
                raise TypeError(
                    f"policy {type(policy).__name__} returned "
                    f"{type(decision).__name__}, expected Decision"
                )
            report.decisions.append(decision)
            sp.set(
                decision_delta=decision.delta,
                decision_frontier_cap=decision.frontier_cap,
                decision_force=decision.exchange_force,
            )
            if decision.delta is not None and delta is not None:
                d = float(decision.delta)
                if not (d > 0.0 and np.isfinite(d)):
                    raise ValueError(
                        f"policy proposed non-positive delta {d}"
                    )
                delta = d
            if decision.exchange_force is not None:
                f = int(decision.exchange_force)
                if f not in (0, 1, 2):
                    raise ValueError(
                        f"policy proposed exchange_force {f}, expected 0|1|2"
                    )
                force = f
            if decision.frontier_cap is not None and sparse_capable:
                new_cap = min(pg.rows_per_rank,
                              max(1, int(decision.frontier_cap)))
                if new_cap != cap:
                    cap = new_cap
                    report.cap_growths += 1
                    if cap not in caps_seen:
                        caps_seen.add(cap)
                        report.retraces += 1
                        fac.note_adapt_retrace()
                        obs.event("adapt_retrace", frontier_cap=cap,
                                  segment=report.segments)

    report.final_delta = delta
    report.final_frontier_cap = cap

    m = WorkMetrics(
        classes=classes,
        commits=commits,
        relaxations=relax,
        supersteps=it_total,
        workitems=commits,
        converged=(active == 0),
        sparse_fallbacks=fallbacks,
        overflow_streak=max_streak,
        retraces=report.retraces,
    )
    m.exchange_bytes = words * 4 * P_
    m.collective_rounds = rounds
    fac._warn_metrics(m, ecfg, pg, active)

    padded = np.asarray(D)[:, :nl]
    return padded, m, report
