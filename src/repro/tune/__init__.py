"""repro.tune — adaptive execution controller + offline spec auto-tuner.

The runtime half of the paper's "generate the algorithm for the
target architecture" story (arXiv 1706.05760 §VII), made safe by
self-stabilization: retuning the ordering mid-solve reorders the
schedule but cannot move the kernel's fixpoint.

* **Runtime controller** (``/adapt[:policy]`` in the spec grammar):
  the engine runs in segments (``EngineConfig.adapt_window``
  supersteps per jitted call) and publishes a per-superstep metrics
  window; a :mod:`policy <repro.tune.policies>` maps the window to
  the next segment's delta bucket width, frontier capacity
  (rho-stepping growth on overflow) and sparse/dense exchange choice.
  Delta and the exchange choice are dynamic scalars (no retrace);
  only a never-seen ``frontier_cap`` compiles a new segment engine
  (counted in ``Solution.metrics.retraces``).

* **Offline auto-tuner** (:class:`AutoTuner`): coordinate-descent
  search over ordering x exchange x partitioner scored by pilot
  solves, winner cached in a :class:`TunedSpecCache` keyed by graph
  fingerprint (hash-chain aware, so streamed updates re-tune).
  ``repro.serve.Router`` consults the cache on admission;
  ``launch/tune.py`` is the CLI.
"""

from repro.tune.policies import (
    Decision,
    RhoPolicy,
    ScheduledPolicy,
    StaticPolicy,
    Tunables,
    TunePolicy,
    canonical_policy,
    make_tune_policy,
    policy_traits,
    register_tune_policy,
)
from repro.tune.controller import AdaptReport, run_adaptive
from repro.tune.autotune import (
    OBJECTIVES,
    AutoTuner,
    TunedRecord,
    TunedSpecCache,
)

__all__ = [
    "Decision", "RhoPolicy", "ScheduledPolicy", "StaticPolicy",
    "Tunables", "TunePolicy", "canonical_policy", "make_tune_policy",
    "policy_traits", "register_tune_policy",
    "AdaptReport", "run_adaptive",
    "OBJECTIVES", "AutoTuner", "TunedRecord", "TunedSpecCache",
]
