"""Offline spec auto-tuner: search the grammar, cache the winner.

The paper's §VII promise — *generate the algorithm for the target
architecture* — as a search: :class:`AutoTuner` walks the spec
grammar (ordering × exchange × partitioner) by coordinate descent,
scores each candidate with a pilot solve on the actual graph, and
records the winner in a :class:`TunedSpecCache` keyed by graph
fingerprint.  ``graph_fingerprint`` returns the hash-chain token when
the graph came through ``chain_fingerprint`` streamed updates, so a
mutated graph misses the cache and re-tunes instead of serving a
stale spec.

``repro.serve.Router`` consults the cache on admission (tuned spec
wins over the router's default config); ``launch/tune.py`` drives
search / inspect / export from the command line.
"""

from __future__ import annotations

import dataclasses
import json
import time
import warnings
from typing import Iterable, Optional

import numpy as np

from repro.core.metrics import model_time_s

#: scoring objectives: cost-model seconds (default), raw superstep
#: count, exchanged bytes, or measured wall seconds of a warm solve
OBJECTIVES = ("model", "supersteps", "bytes", "wall")

_FULL_ORDERINGS = ("delta:3", "delta:5", "delta:10", "dijkstra")
_FULL_EXCHANGES = ("a2a", "sparse")
_FULL_PARTITIONS = ("block", "ebal")
_QUICK_ORDERINGS = ("delta:5", "dijkstra")


@dataclasses.dataclass
class TunedRecord:
    """One graph's tuning result: the winning spec plus the scored
    leaderboard it beat (for ``launch/tune --inspect``)."""

    spec: str
    objective: str
    score: float
    fingerprint: tuple
    leaderboard: list = dataclasses.field(default_factory=list)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["fingerprint"] = list(self.fingerprint)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "TunedRecord":
        return cls(
            spec=str(d["spec"]),
            objective=str(d["objective"]),
            score=float(d["score"]),
            fingerprint=tuple(d["fingerprint"]),
            leaderboard=list(d.get("leaderboard", [])),
        )


def _fp_key(fp) -> tuple:
    return tuple(fp)


class TunedSpecCache:
    """fingerprint -> :class:`TunedRecord`, JSON-persistable.

    Keys are whatever :func:`repro.graph.formats.graph_fingerprint`
    returns — the CRC tuple for plain graphs, the hash-chain token for
    graphs advanced through ``chain_fingerprint`` — so streamed
    updates invalidate by construction: the mutated graph's
    fingerprint simply never matches a stale record."""

    def __init__(self) -> None:
        self._records: dict = {}

    def get(self, fingerprint) -> Optional[TunedRecord]:
        return self._records.get(_fp_key(fingerprint))

    def put(self, record: TunedRecord) -> None:
        self._records[_fp_key(record.fingerprint)] = record

    def invalidate(self, fingerprint) -> bool:
        return self._records.pop(_fp_key(fingerprint), None) is not None

    def records(self) -> list:
        return list(self._records.values())

    def __len__(self) -> int:
        return len(self._records)

    def __contains__(self, fingerprint) -> bool:
        return _fp_key(fingerprint) in self._records

    def to_json(self) -> list:
        return [r.as_dict() for r in self._records.values()]

    @classmethod
    def from_json(cls, rows: Iterable[dict]) -> "TunedSpecCache":
        cache = cls()
        for row in rows:
            cache.put(TunedRecord.from_dict(row))
        return cache

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)

    @classmethod
    def load(cls, path: str) -> "TunedSpecCache":
        with open(path) as f:
            return cls.from_json(json.load(f))


class AutoTuner:
    """Coordinate-descent search over the spec grammar.

    Stages: (1) orderings at the default exchange/partition, (2)
    exchanges at the best ordering, (3) partitioners at the best of
    both — ``len(orderings) + len(exchanges) + len(partitions) - 2``
    pilot solves instead of the full cross product.  Pilot solves run
    on the *actual* graph capped at ``pilot_iters`` supersteps; a
    truncated pilot's score is inflated by its inverse progress so an
    unfinished cheap-looking candidate cannot win."""

    def __init__(
        self,
        mesh=None,
        *,
        objective: str = "model",
        cache: Optional[TunedSpecCache] = None,
        quick: bool = False,
        pilot_iters: int = 2000,
        pilot_source: int = 0,
        orderings: Optional[tuple] = None,
        exchanges: Optional[tuple] = None,
        partitions: Optional[tuple] = None,
    ) -> None:
        if objective not in OBJECTIVES:
            from repro.core.ordering import suggest

            raise ValueError(
                f"objective must be one of {OBJECTIVES}, got "
                f"{objective!r}{suggest(str(objective), OBJECTIVES)}"
            )
        self.mesh = mesh
        self.objective = objective
        self.cache = cache if cache is not None else TunedSpecCache()
        self.pilot_iters = int(pilot_iters)
        self.pilot_source = int(pilot_source)
        self.orderings = tuple(
            orderings
            if orderings is not None
            else (_QUICK_ORDERINGS if quick else _FULL_ORDERINGS)
        )
        self.exchanges = tuple(
            exchanges if exchanges is not None else _FULL_EXCHANGES
        )
        self.partitions = tuple(
            partitions
            if partitions is not None
            else (("block",) if quick else _FULL_PARTITIONS)
        )
        self.pilots_run = 0

    # -- scoring -------------------------------------------------------

    def _pilot(self, graph, spec: str) -> dict:
        from repro.api import Problem, SingleSource, Solver, SolverConfig

        cfg = SolverConfig.from_spec(spec, max_iters=self.pilot_iters)
        solver = Solver(cfg, mesh=self.mesh)
        problem = Problem(graph, SingleSource(self.pilot_source))
        with warnings.catch_warnings():
            # pilot truncation is by design; don't spam the caller
            warnings.simplefilter("ignore", RuntimeWarning)
            sol = solver.solve(problem)
            wall = 0.0
            if self.objective == "wall":
                t0 = time.perf_counter()
                sol = solver.solve(problem)
                wall = time.perf_counter() - t0
        m = sol.metrics
        n_chips = sol.pg.n_parts if sol.pg is not None else 1
        if self.objective == "supersteps":
            score = float(m.supersteps)
        elif self.objective == "bytes":
            score = float(m.exchange_bytes)
        elif self.objective == "wall":
            score = float(wall)
        else:
            score = model_time_s(m, n_chips=n_chips)
        if not m.converged:
            # inflate by inverse progress: committed / n vertices
            n = int(np.asarray(sol.state).shape[0])
            done = int(np.sum(np.isfinite(np.asarray(sol.state))))
            score *= n / max(1, done)
        self.pilots_run += 1
        return dict(
            spec=spec,
            score=float(score),
            supersteps=int(m.supersteps),
            exchange_bytes=int(m.exchange_bytes),
            bytes_per_superstep=(
                int(m.exchange_bytes // max(1, m.supersteps))
            ),
            sparse_fallbacks=int(m.sparse_fallbacks),
            converged=bool(m.converged),
        )

    # -- search --------------------------------------------------------

    @staticmethod
    def _spec(ordering: str, exchange: str, partition: str) -> str:
        s = f"{ordering}/{exchange}"
        if partition != "block":
            s += f"@{partition}"
        return s

    def search(self, graph) -> TunedRecord:
        """Run the coordinate-descent search and cache the winner."""
        from repro.graph.formats import graph_fingerprint

        board: list = []

        def best(specs):
            rows = [self._pilot(graph, s) for s in specs]
            board.extend(rows)
            return min(rows, key=lambda r: r["score"])

        ex0, part0 = self.exchanges[0], self.partitions[0]
        w = best([self._spec(o, ex0, part0) for o in self.orderings])
        ordering = w["spec"].split("/", 1)[0]
        if len(self.exchanges) > 1:
            w2 = best([
                self._spec(ordering, ex, part0)
                for ex in self.exchanges[1:]
            ])
            if w2["score"] < w["score"]:
                w = w2
        exchange = w["spec"].split("/", 1)[1].split("@", 1)[0]
        if len(self.partitions) > 1:
            w3 = best([
                self._spec(ordering, exchange, pt)
                for pt in self.partitions[1:]
            ])
            if w3["score"] < w["score"]:
                w = w3
        board.sort(key=lambda r: r["score"])
        record = TunedRecord(
            spec=w["spec"],
            objective=self.objective,
            score=w["score"],
            fingerprint=_fp_key(graph_fingerprint(graph)),
            leaderboard=board,
        )
        self.cache.put(record)
        return record

    def tune(self, graph):
        """The tuned :class:`SolverConfig` for ``graph`` — cache hit
        if its fingerprint was searched before, one search otherwise.
        The returned config carries production ``max_iters``, not the
        pilot cap."""
        from repro.api import SolverConfig
        from repro.graph.formats import graph_fingerprint

        rec = self.cache.get(graph_fingerprint(graph))
        if rec is None:
            rec = self.search(graph)
        return SolverConfig.from_spec(rec.spec)
