"""Version compatibility shims.

``jax.shard_map`` only became a top-level export in jax 0.5.x; the
pinned 0.4.37 ships it as ``jax.experimental.shard_map.shard_map``.
Everything in this repo imports :func:`shard_map` from here so the
same code runs on both sides of the rename.

The experimental version also has no replication rule for ``while``
(our engine's superstep loop) and needs ``check_rep=False``; the
top-level version dropped that kwarg.  The wrapper passes it exactly
when the underlying function accepts it.
"""

from __future__ import annotations

import functools
import inspect

import jax

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_ACCEPTS_CHECK_REP = "check_rep" in inspect.signature(_shard_map).parameters


@functools.wraps(_shard_map)
def shard_map(f, **kwargs):
    if _ACCEPTS_CHECK_REP:
        kwargs.setdefault("check_rep", False)
    else:
        kwargs.pop("check_rep", None)
    return _shard_map(f, **kwargs)


__all__ = ["shard_map"]
