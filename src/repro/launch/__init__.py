"""Launchers: mesh construction, the multi-pod dry-run, the training
driver and the SSSP driver.  (dryrun must be run as a module so its
XLA device-count flag precedes jax initialization.)"""

from repro.launch.mesh import (
    make_cpu_topology, make_production_mesh, make_topology,
)

__all__ = ["make_cpu_topology", "make_production_mesh", "make_topology"]
