"""Observability driver: record a traced solve, export flight records,
summarize convergence.

    # record: traced vs untraced solve, bit-identity + overhead gate,
    # Perfetto + JSONL + Prometheus artifacts (CI's obs job)
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.obs record \
        --graph rmat1 --scale 9 --spec "delta:5/sparse" \
        --trace-json TRACE_solve.json --jsonl FLIGHT_solve.jsonl \
        --metrics OBS_metrics.txt --gate 1.15

    # export: JSONL flight record -> Chrome-trace/Perfetto JSON
    PYTHONPATH=src python -m repro.launch.obs export \
        FLIGHT_solve.jsonl --out TRACE_solve.json

    # summarize: per-superstep convergence table from a flight record
    PYTHONPATH=src python -m repro.launch.obs summarize FLIGHT_solve.jsonl

``record`` solves the same problem twice — once untraced, once with
``/trace`` — and machine-checks the tentpole claims: final state and
``WorkMetrics`` bit-identical, per-superstep sums reconciling exactly
with the aggregate metrics, and traced wall time within ``--gate``
(default 1.15x) of untraced (min over ``--repeats``, compile warmed
out of both sides).  Load the Perfetto JSON at https://ui.perfetto.dev
or chrome://tracing.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time


def _load_flight(path: str):
    """Rebuild (Tracer, [SolveTrace]) from a JSONL flight record."""
    from repro.obs import SolveTrace, Tracer
    from repro.obs.trace import Event, Span

    tracer = Tracer()
    traces: dict[str, SolveTrace] = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            kind = rec.pop("kind")
            if kind == "span":
                tracer.spans.append(Span(**rec))
            elif kind == "event":
                tracer.events.append(Event(**rec))
            elif kind == "solve":
                tr = SolveTrace(**rec)
                traces[tr.config_name] = tr
            # superstep rows are redundant with the solve header (they
            # exist for line-oriented tooling); skip on reload
    return tracer, list(traces.values())


def cmd_record(args) -> int:
    import numpy as np

    from repro.api import Problem, SingleSource, Solver
    from repro.launch.mesh import make_cpu_topology
    from repro.launch.sssp import build_graph
    from repro.obs import (
        MetricsRegistry, Tracer, use_tracer,
        write_chrome_trace, write_flight_jsonl,
    )

    g = build_graph(args.graph, args.scale, args.seed)
    topo = make_cpu_topology()
    base = Solver(args.spec, mesh=topo.mesh)
    if base.config.trace:
        print("error: pass the UNTRACED spec; record adds /trace itself",
              file=sys.stderr)
        return 2
    traced_cfg = dataclasses.replace(
        base.config, trace=True, adapt_window=args.window
    )
    traced = Solver(traced_cfg, mesh=base.mesh)
    prob = Problem(g, SingleSource(args.source))
    print(f"[obs] {g.name}: n={g.n} m={g.m} spec={base.config.name} "
          f"devices={base.n_devices} window={args.window}")

    def timed(solver):
        best, sol = float("inf"), None
        for _ in range(max(1, args.repeats)):
            t0 = time.perf_counter()
            sol = solver.solve(prob)
            best = min(best, time.perf_counter() - t0)
        return best, sol

    # warm both engines (compile + partition) outside the timed window
    base.solve(prob)
    traced.solve(prob)

    wall_base, sol_base = timed(base)
    registry = MetricsRegistry()
    tracer = Tracer(registry=registry)
    with use_tracer(tracer):
        wall_traced, sol_traced = timed(traced)

    # -- the tentpole claims, machine-checked -------------------------
    assert np.array_equal(sol_base.state, sol_traced.state), \
        "traced solve diverged from untraced state"
    assert sol_base.metrics == sol_traced.metrics, (
        f"traced metrics differ:\n  untraced {sol_base.metrics}\n"
        f"  traced   {sol_traced.metrics}")
    tr = sol_traced.trace
    assert tr is not None
    tr.reconcile(sol_traced.metrics)
    print("[obs] bit-identity: state EQUAL, metrics EQUAL, "
          "trace sums reconcile")
    print(f"[obs] untraced {sol_base.metrics}")

    ratio = wall_traced / wall_base if wall_base > 0 else 1.0
    print(f"[obs] wall: untraced {wall_base*1e3:.1f}ms, traced "
          f"{wall_traced*1e3:.1f}ms ({ratio:.2f}x, gate {args.gate}x, "
          f"min of {args.repeats})")

    if args.table:
        print(tr.table())
    if args.trace_json:
        write_chrome_trace(args.trace_json, tracer, [tr])
        print(f"[obs] wrote Perfetto trace: {args.trace_json} "
              f"({len(tracer.spans)} spans, {len(tracer.events)} events)")
    if args.jsonl:
        write_flight_jsonl(args.jsonl, tracer, [tr])
        print(f"[obs] wrote flight record: {args.jsonl}")
    if args.metrics:
        with open(args.metrics, "w") as f:
            f.write(registry.expose())
        print(f"[obs] wrote exposition: {args.metrics}")

    if args.gate and ratio > args.gate:
        print(f"[obs] FAIL: traced/untraced {ratio:.2f}x exceeds the "
              f"{args.gate}x overhead gate", file=sys.stderr)
        return 1
    return 0


def cmd_export(args) -> int:
    from repro.obs import write_chrome_trace

    tracer, traces = _load_flight(args.record)
    write_chrome_trace(args.out, tracer, traces)
    print(f"[obs] {args.record} -> {args.out} ({len(tracer.spans)} "
          f"spans, {len(tracer.events)} events, {len(traces)} solves)")
    return 0


def cmd_summarize(args) -> int:
    _, traces = _load_flight(args.record)
    if not traces:
        print("no solve traces in record", file=sys.stderr)
        return 1
    for tr in traces:
        print(f"[obs] {tr.config_name}: n={tr.n}")
        print(tr.table())
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    rec = sub.add_parser("record", help="traced vs untraced solve with "
                         "bit-identity assertions and overhead gate")
    rec.add_argument("--graph", default="rmat1",
                     choices=["rmat1", "rmat2", "road", "smallworld"])
    rec.add_argument("--scale", type=int, default=9)
    rec.add_argument("--spec", default="delta:5/sparse")
    rec.add_argument("--source", type=int, default=0)
    rec.add_argument("--seed", type=int, default=0)
    rec.add_argument("--window", type=int, default=8,
                     help="supersteps per recorder segment (larger = "
                          "fewer host syncs = lower overhead)")
    rec.add_argument("--repeats", type=int, default=3,
                     help="timing repeats; the gate compares minima")
    rec.add_argument("--gate", type=float, default=1.15,
                     help="max traced/untraced wall ratio (0 disables)")
    rec.add_argument("--trace-json", default=None,
                     help="write Chrome-trace/Perfetto JSON here")
    rec.add_argument("--jsonl", default=None,
                     help="write the JSONL flight record here")
    rec.add_argument("--metrics", default=None,
                     help="write Prometheus text exposition here")
    rec.add_argument("--table", action="store_true",
                     help="print the per-superstep convergence table")
    rec.set_defaults(fn=cmd_record)

    exp = sub.add_parser("export", help="JSONL flight record -> "
                         "Chrome-trace/Perfetto JSON")
    exp.add_argument("record", help="JSONL flight record path")
    exp.add_argument("--out", default="TRACE_solve.json")
    exp.set_defaults(fn=cmd_export)

    summ = sub.add_parser("summarize", help="per-superstep table from "
                          "a JSONL flight record")
    summ.add_argument("record", help="JSONL flight record path")
    summ.set_defaults(fn=cmd_summarize)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    # must be set before jax initializes; harmless if already set
    os.environ.setdefault("XLA_FLAGS", "")
    sys.exit(main())
