"""SSSP driver: solve on a generated graph with any (ordering × EAGM
variant × exchange), verify against Dijkstra, report work/sync
metrics and cost-model time.

    PYTHONPATH=src python -m repro.launch.sssp --graph rmat1 --scale 14 \
        --root delta:5 --variant threadq --exchange a2a
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def build_graph(kind: str, scale: int, seed: int):
    from repro.graph import (
        grid_road_graph, rmat1, rmat2, small_world_graph,
    )

    if kind == "rmat1":
        return rmat1(scale, seed)
    if kind == "rmat2":
        return rmat2(scale, seed)
    if kind == "road":
        return grid_road_graph(int(2 ** (scale / 2)), seed)
    if kind == "smallworld":
        return small_world_graph(1 << scale, seed=seed)
    raise SystemExit(f"unknown graph kind {kind}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat1",
                    choices=["rmat1", "rmat2", "road", "smallworld"])
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--root", default="delta:5")
    ap.add_argument("--variant", default="buffer",
                    choices=["buffer", "threadq", "nodeq", "numaq"])
    ap.add_argument("--exchange", default="a2a",
                    choices=["a2a", "pmin"])
    ap.add_argument("--chunk", type=int, default=1024)
    ap.add_argument("--source", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", action="store_true")
    ap.add_argument("--problem", default="sssp",
                    choices=["sssp", "bfs", "cc", "sswp"],
                    help="processing function (all share the engine)")
    args = ap.parse_args()

    from repro.core import (
        BFS, CC, SSSP, SSWP, EngineConfig, cc_sources,
        dijkstra_reference, make_policy, model_time_s,
        run_distributed, sssp_sources,
    )
    from repro.graph import partition_1d
    from repro.launch.mesh import make_cpu_topology

    g = build_graph(args.graph, args.scale, args.seed)
    topo = make_cpu_topology()
    P = topo.n_devices
    pg = partition_1d(g, P)
    print(f"[sssp] {pg.describe()}")

    processing = {"sssp": SSSP, "bfs": BFS, "cc": CC, "sswp": SSWP}[
        args.problem
    ]
    if args.problem == "cc":
        sources = cc_sources(g.n)
    elif args.problem == "sswp":
        sources = [(args.source, float("inf"), 0)]
    else:
        sources = sssp_sources(args.source)

    pol = make_policy(args.root, args.variant, chunk_size=args.chunk)
    cfg = EngineConfig(policy=pol, exchange=args.exchange,
                       processing=processing)
    t0 = time.time()
    dist, m = run_distributed(pg, topo.mesh, cfg, sources)
    wall = time.time() - t0
    print(f"[sssp] policy={pol.name} exchange={args.exchange}")
    print(f"[sssp] {m}")
    print(f"[sssp] cpu_wall={wall:.2f}s "
          f"cost_model(256 chips)={model_time_s(m, 256)*1e3:.2f}ms "
          f"reached={int(np.isfinite(dist).sum())}/{g.n}")

    if args.verify and args.problem == "sssp":
        ref = dijkstra_reference(g, args.source)
        ok = np.allclose(
            np.where(np.isinf(ref), -1, ref),
            np.where(np.isinf(dist), -1, dist),
        )
        print(f"[sssp] verify vs Dijkstra: {'OK' if ok else 'MISMATCH'}")
        if not ok:
            raise SystemExit(1)
    elif args.verify:
        print("[sssp] --verify oracle only wired for --problem sssp "
              "(BFS/CC/SSWP oracles live in tests/test_engine.py)")


if __name__ == "__main__":
    main()
