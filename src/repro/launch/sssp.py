"""SSSP driver on the repro.api facade: solve on a generated graph
with any (ordering × EAGM variant × exchange) family member, verify
against Dijkstra, report work/sync metrics and cost-model time.

    PYTHONPATH=src python -m repro.launch.sssp --graph rmat1 --scale 14 \
        --spec delta:5+threadq/a2a
    # a composed per-level hierarchy (grammar v2):
    PYTHONPATH=src python -m repro.launch.sssp \
        --spec "delta:5 > pod:dijkstra > chunk:delta:1 /sparse"
    # batched query serving (one engine invocation for all sources):
    PYTHONPATH=src python -m repro.launch.sssp --sources 0 7 42
    # the family space at a glance:
    PYTHONPATH=src python -m repro.launch.sssp --list-variants

The old --root/--variant/--exchange flags still work and are folded
into the spec.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.engine import EXCHANGE_MODES


def build_graph(kind: str, scale: int, seed: int):
    from repro.graph import (
        grid_road_graph, rmat1, rmat2, small_world_graph,
    )

    if kind == "rmat1":
        return rmat1(scale, seed)
    if kind == "rmat2":
        return rmat2(scale, seed)
    if kind == "road":
        return grid_road_graph(int(2 ** (scale / 2)), seed)
    if kind == "smallworld":
        return small_world_graph(1 << scale, seed=seed)
    raise SystemExit(f"unknown graph kind {kind}")


#: example beyond-paper hierarchies shown by --list-variants
EXAMPLE_HIERARCHIES = [
    "delta:5 > pod:dijkstra",
    "delta:5 > pod:dijkstra > chunk:delta:1",
    "delta:7 > pod:delta:3 > chunk:topk:64",
    "chaotic > device:dijkstra > chunk:topk:32",
    "kla:2 > pod:dijkstra > device:dijkstra",
]


def list_variants_lines() -> list:
    """The preset (paper) grid plus example composed hierarchies, each
    with the collective scope realizing every annotation."""
    from repro.api import SolverConfig
    from repro.core import paper_variant_specs

    lines = ["preset grid (paper Figures 5-7, legacy grammar "
             "root+variant):"]
    for spec in paper_variant_specs():
        cfg = SolverConfig.from_spec(spec)
        lines.append(f"  {cfg.name:26s} {cfg.hierarchy.describe()}")
    lines.append("")
    lines.append("example composed hierarchies (grammar v2: "
                 "'root > level:ordering > ...[/exchange]'):")
    for spec in EXAMPLE_HIERARCHIES:
        cfg = SolverConfig.from_spec(spec)
        lines.append(f"  {spec:44s} {cfg.hierarchy.describe()}")
    lines.append("")
    lines.append("levels: global > pod > device > chunk; orderings: "
                 "chaotic | dijkstra | delta:D | kla:K | topk:B; "
                 "exchange: a2a | pmin | sparse | auto")
    return lines


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat1",
                    choices=["rmat1", "rmat2", "road", "smallworld"])
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--spec", default=None,
                    help="solver spec: legacy 'root[+variant][/exchange]' "
                         "(e.g. delta:5+threadq/a2a) or a hierarchy "
                         "'root > level:ordering > ...[/exchange]' "
                         "(e.g. 'delta:5 > pod:dijkstra > chunk:delta:1"
                         "/sparse')")
    ap.add_argument("--list-variants", action="store_true",
                    help="enumerate the preset grid + example composed "
                         "hierarchies with their collective scopes, "
                         "then exit")
    ap.add_argument("--root", default="delta:5")
    ap.add_argument("--variant", default="buffer",
                    choices=["buffer", "threadq", "nodeq", "numaq"])
    ap.add_argument("--exchange", default="a2a",
                    choices=list(EXCHANGE_MODES))
    ap.add_argument("--partition", default=None, metavar="STRATEGY",
                    help="graph partitioner: block | shuffle[:seed] | "
                         "ebal | degree (also settable via the spec's "
                         "@segment, e.g. 'delta:5/sparse@ebal'; the "
                         "flag wins)")
    ap.add_argument("--chunk", type=int, default=1024)
    ap.add_argument("--sources", type=int, nargs="+", default=[0],
                    help=">1 source solves the batch in one engine call")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", action="store_true")
    ap.add_argument("--problem", default="sssp",
                    choices=["sssp", "bfs", "cc", "sswp"],
                    help="processing function (all share the engine)")
    args = ap.parse_args()

    if args.list_variants:
        for line in list_variants_lines():
            print(line)
        return

    from repro.api import (
        EveryVertex, Problem, SingleSource, Solver, SolverConfig,
    )
    from repro.core import dijkstra_reference, model_time_s
    from repro.launch.mesh import make_cpu_topology

    g = build_graph(args.graph, args.scale, args.seed)
    topo = make_cpu_topology()

    spec = args.spec or f"{args.root}+{args.variant}/{args.exchange}"
    overrides = dict(chunk_size=args.chunk)
    if args.partition is not None:
        overrides["partition"] = args.partition
    cfg = SolverConfig.from_spec(spec, **overrides)
    solver = Solver(cfg, mesh=topo.mesh)
    pg = solver.partition(g)
    st = pg.load_stats()  # one scan, shared with the --verify printout
    print(f"[sssp] {pg.describe(st)}")
    if args.verify:
        print(f"[sssp] load balance ({pg.partitioner}): "
              f"rows/rank={st['rows_per_rank']} (padded to "
              f"{st['max_rows']}) edges/rank={st['edges_per_rank']}")
        print(f"[sssp] straggler ratio: rows={st['straggler_rows']:.3f} "
              f"edges={st['straggler_edges']:.3f} "
              f"ell_occupancy={st['ell_occupancy']:.3f}")

    if args.problem == "cc":
        if args.sources != [0]:
            print("[sssp] note: --sources is ignored for --problem cc "
                  "(CC seeds every vertex)")
        labels = ["all-vertices"]
        problems = [Problem(g, EveryVertex(), processing="cc")]
    else:
        labels = [f"source={v}" for v in args.sources]
        problems = [
            Problem(g, SingleSource(v), processing=args.problem)
            for v in args.sources
        ]

    t0 = time.time()
    if cfg.adapt is not None and len(problems) > 1:
        # solve_batch rejects adaptive specs (one shared controller
        # schedule would steer every lane); solve them one at a time
        sols = [solver.solve(pb) for pb in problems]
    else:
        sols = solver.solve_batch(problems)
    wall = time.time() - t0
    print(f"[sssp] spec={cfg.name} batch={len(problems)}")
    for label, sol in zip(labels, sols):
        m = sol.metrics
        print(f"[sssp] {label} {m}")
        print(f"[sssp] cost_model(256 chips)={model_time_s(m, 256)*1e3:.2f}ms "
              f"reached={int(np.isfinite(sol.state).sum())}/{g.n}")
    print(f"[sssp] cpu_wall={wall:.2f}s total")

    if args.verify and args.problem == "sssp":
        bad = 0
        for src, sol in zip(args.sources, sols):
            ref = dijkstra_reference(g, src)
            ok = np.allclose(
                np.where(np.isinf(ref), -1, ref),
                np.where(np.isinf(sol.state), -1, sol.state),
            )
            print(f"[sssp] source={src} verify vs Dijkstra: "
                  f"{'OK' if ok else 'MISMATCH'}")
            bad += 0 if ok else 1
        if bad:
            raise SystemExit(1)
    elif args.verify:
        print("[sssp] --verify oracle only wired for --problem sssp "
              "(BFS/CC/SSWP oracles live in tests/test_engine.py)")


if __name__ == "__main__":
    main()
