"""Auto-tuner driver: search the spec grammar per graph, persist the
tuned-spec cache, inspect and export it.

    PYTHONPATH=src python -m repro.launch.tune --search --graph rmat1 \
        --scale 10 --objective model --cache TUNE_cache.json
    # 8-device quick search (CI):
    PYTHONPATH=src python -m repro.launch.tune --search --quick \
        --devices 8 --scale 9
    PYTHONPATH=src python -m repro.launch.tune --inspect
    PYTHONPATH=src python -m repro.launch.tune --export tuned.json

``--search`` runs :class:`repro.tune.AutoTuner` coordinate descent
(ordering x exchange x partitioner, pilot-solve scored) on the chosen
graph and merges the winner into ``--cache``; ``--inspect`` prints
every cached record with its scored leaderboard; ``--export`` copies
the cache JSON to a deployment path (``repro.serve.Router`` loads it
via ``TunedSpecCache.load`` and consults it on admission).  Actions
compose: ``--search --inspect --export out.json`` does all three.

``--devices N`` must be processed before jax initializes, which is
why every repro import below is deferred past argument parsing.
"""

from __future__ import annotations

import argparse
import os
from typing import Optional, Sequence


def _print_record(rec, *, top: int = 6) -> None:
    print(f"[tune] fingerprint {rec.fingerprint}: spec {rec.spec!r} "
          f"(objective {rec.objective}, score {rec.score:.3e})")
    for row in rec.leaderboard[:top]:
        mark = "*" if row["spec"] == rec.spec else " "
        print(f"   {mark} {row['spec']:32s} score={row['score']:.3e} "
              f"supersteps={row['supersteps']} "
              f"bytes/superstep={row['bytes_per_superstep']} "
              f"converged={row['converged']}")
    extra = len(rec.leaderboard) - top
    if extra > 0:
        print(f"     ... {extra} more candidates")


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="offline spec auto-tuner (search/inspect/export)"
    )
    ap.add_argument("--search", action="store_true",
                    help="run the coordinate-descent search on --graph "
                         "and merge the winner into --cache (default "
                         "action when none is given)")
    ap.add_argument("--inspect", action="store_true",
                    help="print every cached record + leaderboard")
    ap.add_argument("--export", metavar="PATH",
                    help="write the cache JSON to PATH")
    ap.add_argument("--graph", default="rmat1",
                    choices=["rmat1", "rmat2", "road", "smallworld"])
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--objective", default="model",
                    choices=["model", "supersteps", "bytes", "wall"])
    ap.add_argument("--cache", default="TUNE_cache.json",
                    help="tuned-spec cache file (default %(default)s; "
                         "loaded if it exists, rewritten after "
                         "--search)")
    ap.add_argument("--quick", action="store_true",
                    help="trim the search grid (2 orderings, block "
                         "partition only)")
    ap.add_argument("--pilot-iters", type=int, default=2000,
                    help="superstep cap per pilot solve")
    ap.add_argument("--devices", type=int, default=None, metavar="N",
                    help="force N host platform devices (must precede "
                         "jax init)")
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}"
        ).strip()

    # deferred: XLA_FLAGS must be set before jax initializes
    from repro.tune import AutoTuner, TunedSpecCache

    if not (args.search or args.inspect or args.export):
        args.search = True

    cache = (TunedSpecCache.load(args.cache)
             if os.path.exists(args.cache) else TunedSpecCache())

    if args.search:
        from repro.launch.mesh import make_cpu_topology
        from repro.launch.sssp import build_graph

        g = build_graph(args.graph, args.scale, args.seed)
        topo = make_cpu_topology()
        tuner = AutoTuner(
            topo.mesh,
            objective=args.objective,
            cache=cache,
            quick=args.quick,
            pilot_iters=args.pilot_iters,
        )
        print(f"[tune] searching {g.name}: n={g.n} m={g.m} "
              f"objective={args.objective} "
              f"grid={len(tuner.orderings)}x{len(tuner.exchanges)}"
              f"x{len(tuner.partitions)} (coordinate descent)")
        rec = tuner.search(g)
        _print_record(rec)
        print(f"[tune] {tuner.pilots_run} pilot solves; "
              f"cache -> {args.cache} ({len(cache)} records)")
        cache.save(args.cache)

    if args.inspect:
        if len(cache) == 0:
            print(f"[tune] cache {args.cache}: empty")
        for rec in cache.records():
            _print_record(rec)

    if args.export:
        cache.save(args.export)
        print(f"[tune] exported {len(cache)} records -> {args.export}")


if __name__ == "__main__":
    main()
