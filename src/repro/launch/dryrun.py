import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (architecture ×
input-shape × mesh) cell on 512 placeholder devices.

    PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx-132b \
        --cell train_4k --mesh multi_pod
    PYTHONPATH=src python -m repro.launch.dryrun --all

Per cell it records memory_analysis() (proves it fits),
cost_analysis() (FLOPs/bytes for §Roofline) and the parsed collective
traffic, into experiments/dryrun/<arch>__<cell>__<mesh>.json.

The sssp cells lower the repro.api facade's compiled engine
(configs/cells.py:sssp_cell builds it via Solver.compiled), so what
the dry-run proves fits is exactly what Solver.solve dispatches.
"""

import argparse
import json
import sys
import time
import traceback



def run_cell(arch_id: str, cell: str, multi_pod: bool, out_dir: str,
             force: bool = False, verbose: bool = True) -> dict:
    # imports deferred so the XLA flag is set before jax initializes
    from repro.configs import get_arch
    from repro.launch.mesh import make_topology
    from repro.roofline.hlo import collective_bytes, hbm_traffic

    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, f"{arch_id}__{cell}__{mesh_name}.json".replace("/", "_")
    )
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    topo = make_topology(multi_pod=multi_pod)
    mod = get_arch(arch_id)
    rec = {
        "arch": arch_id, "cell": cell, "mesh": mesh_name,
        "chips": topo.n_devices, "ok": False,
        "family": getattr(mod, "FAMILY", "?"),
    }
    t0 = time.time()
    try:
        with topo.mesh:
            prog = mod.make_cell(cell, topo)
            lowered = prog.lower()
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            mem_rec = {
                k: int(getattr(mem, k))
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "generated_code_size_in_bytes",
                    "alias_size_in_bytes",
                )
                if hasattr(mem, k)
            }
            cost = compiled.cost_analysis() or {}
            cost_rec = {
                k: float(v)
                for k, v in cost.items()
                if isinstance(v, (int, float)) and k in (
                    "flops", "bytes accessed", "transcendentals",
                    "optimal_seconds", "utilization operand 0 {}",
                )
            }
            hlo = compiled.as_text()
            coll = collective_bytes(hlo)
            traffic = hbm_traffic(hlo)

            # --- layer-scan cost correction probes (LM family) ---
            # XLA's cost model counts a lax.scan body once; two depth
            # probes let the roofline reconstruct true per-step totals:
            # total = f(1) + (n_layers-1)·(f(2)-f(1)).
            probes = {}
            if rec["family"] == "lm":
                full_layers = mod.make_config().n_layers
                for L in (1, 2):
                    pp = mod.make_cell(cell, topo, probe_layers=L)
                    pc = pp.lower().compile()
                    pcost = pc.cost_analysis() or {}
                    ptxt = pc.as_text()
                    probes[f"L{L}"] = {
                        "flops": float(pcost.get("flops", 0.0)),
                        "bytes": float(pcost.get("bytes accessed", 0.0)),
                        "traffic_bytes": hbm_traffic(ptxt)[
                            "total_bytes"],
                        "collective_bytes": collective_bytes(ptxt)[
                            "total_bytes"],
                    }
                probes["n_layers"] = full_layers

        rec.update(
            probes=probes if rec["family"] == "lm" else None,
            ok=True,
            t_lower_s=round(t_lower, 2),
            t_compile_s=round(t_compile, 2),
            memory=mem_rec,
            cost=cost_rec,
            collectives=coll,
            traffic=traffic,
            model_flops=prog.model_flops,
            notes=prog.notes,
            hlo_bytes_len=len(hlo),
        )
        if verbose:
            print(compiled.memory_analysis())
            print({k: v for k, v in cost_rec.items()})
            print("collectives:", coll["counts"],
                  f"total={coll['total_bytes']/1e6:.1f} MB/device")
    except Exception as e:  # noqa: BLE001 — record the failure
        rec.update(error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"FAILED {arch_id} {cell} {mesh_name}: {e}",
                  file=sys.stderr)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = "ok" if rec["ok"] else "FAIL"
    print(f"[dryrun] {arch_id:24s} {cell:28s} {mesh_name:10s} {status} "
          f"({time.time()-t0:.1f}s)")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--cell")
    ap.add_argument("--mesh", choices=["single", "multi_pod", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    from repro.configs import all_cells

    meshes = {
        "single": [False], "multi_pod": [True], "both": [False, True]
    }[args.mesh]

    if args.all:
        cells = all_cells()
    else:
        assert args.arch and args.cell, "--arch and --cell or --all"
        cells = [(args.arch, args.cell)]

    failures = 0
    for arch_id, cell in cells:
        for mp in meshes:
            rec = run_cell(arch_id, cell, mp, args.out, args.force)
            failures += 0 if rec.get("ok") else 1
    if failures:
        sys.exit(f"{failures} cell(s) failed")
    print("dry-run complete: all cells lowered + compiled")


if __name__ == "__main__":
    main()
