"""Static-analysis gate: contract verifier + spec/jaxpr/HLO lint.

    PYTHONPATH=src python -m repro.launch.analyze
    PYTHONPATH=src python -m repro.launch.analyze --quick --no-hlo
    PYTHONPATH=src python -m repro.launch.analyze \
        --explain "delta:5 > chunk:delta:1 /sparse"
    PYTHONPATH=src python -m repro.launch.analyze \
        --baseline analyze_baseline.json --json ANALYZE_report.json

Runs every ``repro.analyze`` pass over the paper's full spec grid
(hierarchy × exchange × partitioner): the self-stabilization contract
verifier over every registered processing function, the parse-time
spec cross-checks per grid point, the jaxpr engine lint per distinct
traced engine, and (unless ``--no-hlo``) the compiled-HLO lint over a
representative subset.  Nothing here runs a graph — tracing and AOT
compilation only, so the whole gate is seconds of CPU.

Exit status is the gate: 0 iff every finding of gating severity
(error/warn) is in the checked-in baseline (``--baseline``); info
findings never gate.  ``--write-baseline`` rewrites the baseline file
to accept the current findings (review the diff before committing it).

``--devices N`` forces N host platform devices so collectives survive
into the compiled HLO and the hlo-collective-plan rule has teeth; it
must be processed before jax initializes, which is why every repro
import below is deferred past argument parsing.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser(
        description="static-analysis gate for the AGM engine"
    )
    ap.add_argument(
        "--explain", metavar="SPEC", nargs="+",
        help="print the per-superstep collective plan for SPEC(s) "
             "and exit (no tracing, no compile)",
    )
    ap.add_argument(
        "--quick", action="store_true",
        help="trim the grid to one delta/k per root kind",
    )
    ap.add_argument(
        "--no-hlo", action="store_true",
        help="skip the (compile-heavy) HLO pass",
    )
    ap.add_argument(
        "--json", metavar="PATH", default="ANALYZE_report.json",
        help="where to write the report (default %(default)s; "
             "'-' to skip)",
    )
    ap.add_argument(
        "--baseline", metavar="PATH", default="analyze_baseline.json",
        help="accepted-findings baseline (default %(default)s; "
             "missing file = empty baseline)",
    )
    ap.add_argument(
        "--write-baseline", action="store_true",
        help="rewrite --baseline to accept the current findings",
    )
    ap.add_argument(
        "--devices", type=int, default=None, metavar="N",
        help="force N host platform devices (default: leave XLA "
             "alone) so the HLO pass sees real collectives",
    )
    ap.add_argument(
        "--min-points", type=int, default=0, metavar="N",
        help="fail unless the grid covered at least N spec points "
             "(CI coverage floor)",
    )
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}"
        ).strip()

    # deferred: XLA_FLAGS must be set before jax initializes
    from repro.analyze.findings import baseline_records
    from repro.analyze.report import render_report, run_report
    from repro.analyze.spec_check import explain_config

    if args.explain:
        shape = dict(n_local=64, rows=80, width=8,
                     n_parts=args.devices or 4)
        for i, spec in enumerate(args.explain):
            if i:
                print()
            print(explain_config(spec, shape=shape))
        return

    report = run_report(
        baseline_path=args.baseline,
        quick=args.quick,
        with_hlo=not args.no_hlo,
    )
    if args.json and args.json != "-":
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1)
        print(f"[analyze] report -> {args.json}")
    print(render_report(report))

    if args.write_baseline:
        from repro.analyze.findings import Finding

        gating = [
            Finding(**{k: v for k, v in f.items() if k != "fp"})
            for f in report["findings"] + report["baselined"]
        ]
        with open(args.baseline, "w") as f:
            json.dump(baseline_records(gating), f, indent=1)
        print(f"[analyze] baseline rewritten -> {args.baseline} "
              f"({len(baseline_records(gating))} entries)")
        return

    if args.min_points and report["points"] < args.min_points:
        sys.exit(
            f"coverage floor: linted {report['points']} spec points "
            f"< required {args.min_points}"
        )
    if not report["ok"]:
        sys.exit("analyze gate FAILED: unbaselined findings above")


if __name__ == "__main__":
    main()
