"""Serving driver: run the persistent SSSP query service against a
Zipf-skewed synthetic query mix, with optional streamed edge updates.

    PYTHONPATH=src python -m repro.launch.serve --graph rmat1 --scale 10 \
        --queries 200 --landmarks 8 --updates 4
    # 8-device smoke (CI):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.serve --scale 9 --queries 100

Builds the full serving stack (Router + SolutionCache + LandmarkIndex
+ UpdateFeed) on one long-lived Solver, serves the mix through the
admission batcher, then applies improving updates and verifies that
warm-restart-refreshed answers are bit-identical to cold solves.
Prints queries/sec, p50/p99 latency, and cache hit rate.
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def zipf_sources(n: int, count: int, a: float, rng) -> np.ndarray:
    """Zipf-skewed vertex ids: rank r drawn with p ∝ r^-a, mapped onto
    a fixed random permutation of the vertex ids so the hot set is not
    an artifact of id order."""
    ranks = rng.zipf(a, size=count)
    ranks = np.minimum(ranks - 1, n - 1)
    perm = np.random.default_rng(0).permutation(n)
    return perm[ranks]


def build_query_mix(g, count: int, zipf_a: float, seed: int):
    """70% single-source, 20% point-to-point exact, 10% estimated."""
    from repro.serve import Query

    rng = np.random.default_rng(seed)
    srcs = zipf_sources(g.n, count, zipf_a, rng)
    tgts = rng.integers(0, g.n, size=count)
    kinds = rng.random(count)
    out = []
    for s, t, k in zip(srcs, tgts, kinds):
        if k < 0.7:
            out.append(Query(int(s)))
        elif k < 0.9:
            out.append(Query(int(s), target=int(t)))
        else:
            out.append(Query(int(s), target=int(t), exact=False))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--graph", default="rmat1",
                    choices=["rmat1", "rmat2", "road", "smallworld"])
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--spec", default="delta:5+threadq/a2a")
    ap.add_argument("--queries", type=int, default=200)
    ap.add_argument("--zipf", type=float, default=1.3,
                    help="Zipf exponent of the source skew")
    ap.add_argument("--landmarks", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=10.0)
    ap.add_argument("--cache-mb", type=int, default=256)
    ap.add_argument("--updates", type=int, default=4,
                    help="streamed improving edge updates to apply "
                         "after the query mix (0 disables)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve Prometheus text exposition on "
                         "http://127.0.0.1:PORT/metrics (and a JSON "
                         "/stats) from a daemon thread; 0 picks a "
                         "free port")
    ap.add_argument("--stats-text", action="store_true",
                    help="print the Prometheus text exposition after "
                         "the run (works without --metrics-port)")
    args = ap.parse_args()

    from repro.api import Problem, SingleSource, Solver
    from repro.launch.mesh import make_cpu_topology
    from repro.launch.sssp import build_graph
    from repro.serve import (
        EdgeUpdate, LandmarkIndex, Router, SolutionCache, UpdateFeed,
        serve_latency_stats,
    )

    g = build_graph(args.graph, args.scale, args.seed)
    topo = make_cpu_topology()
    solver = Solver(args.spec, mesh=topo.mesh)
    print(f"[serve] {g.name}: n={g.n} m={g.m} spec={solver.config.name} "
          f"devices={solver.n_devices}")

    # live observability: tracer feeds the registry (span histograms +
    # event counters); --metrics-port exposes it over HTTP
    registry = server = None
    if args.metrics_port is not None or args.stats_text:
        from repro.obs import MetricsRegistry, Tracer, serve_metrics, set_tracer

        registry = MetricsRegistry()
        set_tracer(Tracer(registry=registry))
        if args.metrics_port is not None:
            server = serve_metrics(registry, args.metrics_port)
            print(f"[serve] metrics: http://{server.server_address[0]}:"
                  f"{server.server_address[1]}/metrics (+ /stats)")

    cache = SolutionCache(byte_budget=args.cache_mb << 20)
    t0 = time.perf_counter()
    lm = LandmarkIndex(solver, g, k=args.landmarks, symmetric=True)
    print(f"[serve] landmark tier: K={lm.k} built in "
          f"{time.perf_counter() - t0:.2f}s ({lm.nbytes} bytes)")
    router = Router(
        solver, g, cache=cache, landmarks=lm,
        max_batch=args.max_batch, max_wait_s=args.max_wait_ms / 1e3,
    )
    if registry is not None:
        # callback gauges: the exposition always reads live state
        registry.gauge("repro_router_queries_total",
                       help="queries admitted", fn=lambda: router.stats.queries)
        registry.gauge("repro_router_batches_total",
                       help="admission flushes", fn=lambda: router.stats.batches)
        registry.gauge("repro_router_latency_p99_seconds",
                       help="p99 over the latency ring",
                       fn=lambda: router.latency_stats().p99_s)
        registry.gauge("repro_router_latency_p50_seconds",
                       help="p50 over the latency ring",
                       fn=lambda: router.latency_stats().p50_s)
        registry.gauge("repro_cache_hits_total",
                       help="solution-cache hits", fn=lambda: cache.stats.hits)
        registry.gauge("repro_cache_misses_total",
                       help="solution-cache misses",
                       fn=lambda: cache.stats.misses)
        registry.gauge("repro_engine_traces_total",
                       help="process-wide jit traces",
                       fn=lambda: solver.stats()["engine_cache"]["traces"])

    queries = build_query_mix(g, args.queries, args.zipf, args.seed)
    # warm the compile caches outside the timed window (a real service
    # pre-warms its buckets at deploy time)
    router.serve(queries[: args.max_batch])
    cache.clear()
    cache.stats.hits = cache.stats.misses = 0

    t0 = time.perf_counter()
    tickets = []
    for q in queries:
        tickets.append(router.submit(q))
        router.pump()
    router.flush()
    wall = time.perf_counter() - t0
    answers = [t.result() for t in tickets]

    lat = serve_latency_stats(answers)
    print(f"[serve] {len(answers)} queries in {wall:.2f}s = "
          f"{len(answers) / wall:.1f} q/s")
    print(f"[serve] latency {lat}")
    print(f"[serve] cache {cache.stats}")
    print(f"[serve] router {router.stats.as_dict()}")
    print(f"[serve] solver {solver.stats()}")

    if args.updates:
        feed = UpdateFeed(g, solver, cache=cache, landmarks=lm)
        rng = np.random.default_rng(args.seed + 1)
        warm_total = cold_total = 0
        for _ in range(args.updates):
            e = int(rng.integers(0, g.m))
            res = feed.apply(EdgeUpdate(
                int(g.src[e]), int(g.dst[e]),
                float(g.weight[e]) * 0.25,
            ))
            warm_total += res.warm_supersteps
            cold_total += res.cold_supersteps
        print(f"[serve] applied {args.updates} improving updates: "
              f"{feed.stats.as_dict()}")
        # freshness check: every refreshed entry must equal a cold solve
        from repro.graph import graph_fingerprint

        checked = 0
        for key, sol in cache.entries_for(graph_fingerprint(g))[:3]:
            cold = solver.solve(Problem(g, SingleSource(key[1])))
            assert np.array_equal(sol.state, cold.state), key
            checked += 1
        print(f"[serve] {checked} refreshed entries verified "
              f"bit-identical to cold solves "
              f"(warm supersteps={warm_total})")

    if args.stats_text and registry is not None:
        print("[serve] Prometheus exposition:")
        print(registry.expose())
    if server is not None:
        server.shutdown()


if __name__ == "__main__":
    main()
