"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch phi3-mini-3.8b \
        --reduced --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ck

Runs the real distributed train step (same code the dry-run lowers)
on whatever devices exist, with checkpoint/resume: if the checkpoint
dir holds a step, training resumes from it idempotently (the data
pipeline is a pure function of the step index).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-accum", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_arch
    from repro.data import lm_batch
    from repro.launch.mesh import make_cpu_topology
    from repro.models import lm as lm_mod
    from repro.train import (
        AdamWConfig, Checkpointer, TrainConfig, build_train_step,
        init_train_state,
    )

    mod = get_arch(args.arch)
    if getattr(mod, "FAMILY", "") != "lm":
        raise SystemExit("train driver currently targets the LM family; "
                         "use examples/gnn_train.py for GNNs")
    cfg = mod.make_config(reduced=args.reduced)
    topo = make_cpu_topology()
    tc = TrainConfig(
        adamw=AdamWConfig(lr=args.lr),
        microbatches=args.microbatches,
        compress_accum=args.compress_accum,
        warmup_steps=max(2, args.steps // 10),
        total_steps=args.steps,
    )

    params = lm_mod.init_params(jax.random.PRNGKey(args.seed), cfg)
    opt = init_train_state(params, tc)
    start = 0

    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ck and ck.latest_step() is not None:
        tree, man = ck.restore()
        params, opt, start = tree["params"], tree["opt"], man["step"]
        print(f"[train] resumed from step {start}")

    step_fn = jax.jit(
        build_train_step(lambda p, b: lm_mod.lm_loss(p, b, cfg, topo), tc)
    )

    t0 = time.time()
    for step in range(start, args.steps):
        batch = {
            k: jnp.asarray(v)
            for k, v in lm_batch(
                step, args.batch, args.seq, cfg.vocab, args.seed
            ).items()
        }
        params, opt, m = step_fn(params, opt, batch, jnp.int32(step))
        if step % 5 == 0 or step == args.steps - 1:
            print(
                f"[train] step {step:5d} loss={float(m['loss']):.4f} "
                f"gnorm={float(m['grad_norm']):.3f} "
                f"lr={float(m['lr']):.2e} "
                f"({(time.time()-t0):.1f}s)"
            )
        if ck and (step + 1) % args.ckpt_every == 0:
            ck.save_async(step + 1, {"params": params, "opt": opt})
    if ck:
        ck.save(args.steps, {"params": params, "opt": opt})
        print(f"[train] checkpointed step {args.steps}")


if __name__ == "__main__":
    main()
