"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant)
so importing this module never touches jax device state; the dry-run
sets the 512-placeholder-device XLA flag before first jax init.
"""

from __future__ import annotations

from typing import Optional

import jax

from repro.models.common import Topology


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_topology(*, multi_pod: bool = False) -> Topology:
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = ("pod", "data") if multi_pod else ("data",)
    return Topology(mesh=mesh, dp_axes=dp, tp_axis="model")


def make_cpu_topology(n: Optional[int] = None, tp: int = 1) -> Topology:
    """Small mesh over however many (host) devices exist — used by
    tests and CPU examples."""
    n = n or jax.device_count()
    dp = n // tp
    if tp > 1:
        mesh = jax.make_mesh((dp, tp), ("data", "model"))
        return Topology(mesh=mesh, dp_axes=("data",), tp_axis="model")
    mesh = jax.make_mesh((dp,), ("data",))
    return Topology(mesh=mesh, dp_axes=("data",), tp_axis=None)
