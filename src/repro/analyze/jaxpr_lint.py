"""Engine lint at the jaxpr level: trace, never run.

``lint_engine`` builds the exact shard_map program ``make_engine``
would jit for a given :class:`EngineConfig` and partition shape, traces
it to a jaxpr with abstract inputs (no devices touched, no compile),
and walks the superstep ``while`` body for hazards the type system
does not catch:

  host-callback        a callback/infeed primitive inside the hot loop
                       — serializes every superstep on the host.
  weak-scalar          weak-typed scalar arithmetic in the hot loop: a
                       Python constant whose promotion can silently
                       widen dtypes or fork the jit cache (retrace)
                       when a caller feeds the same value strongly
                       typed.
  f64-promotion        any float64/int64 value anywhere in the step —
                       the engine state is f32/i32 by design; f64
                       doubles exchange bytes silently.
  payload-overflow     an exchange (all_to_all) payload whose dtype
                       cannot represent the vertex-index range or
                       carries fewer mantissa bits than the index
                       needs — the gate ROADMAP item 4's u16/bf16
                       quantized exchange must pass.
  payload-plane        sparse exchange payload whose axis-1 extent is
                       not the expected planes x slot_cap layout — a
                       shape mismatch between the sparse and dense
                       paths' collectives.
  dead-branch          a cond whose predicate is a trace-time literal
                       — one side is dead code that still costs trace
                       time and obscures the spec grid.
  fused-kernel-escape  relax_impl requests the fused superstep kernel
                       but the traced step contains no pallas_call —
                       the engine silently fell back to the reference
                       relax path.

Each finding carries the engine source line (from jaxpr source_info)
when available.  ``lint_grid`` dedupes traces across the spec grid:
partitioners relabel data, not programs, so one trace covers every
partitioner at a given (hierarchy, exchange) point.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.analyze.findings import Finding
from repro.compat import shard_map
from repro.core.engine import EngineConfig, build_step
from repro.core.frontier import frontier_caps, payload_plane_words

#: primitives that force a host round-trip
_HOST_PRIMS = (
    "pure_callback", "io_callback", "debug_callback", "callback",
    "outside_call", "infeed", "outfeed",
)

#: primitives whose weak-typed *output* indicates a Python scalar
#: constant entering hot-loop arithmetic (comparisons excluded — a
#: weak bool is inert; converts excluded — they are the fix)
_WEAK_ARITH_PRIMS = (
    "add", "sub", "mul", "div", "rem", "max", "min", "select_n",
    "floor", "pow", "integer_pow", "neg",
)

#: collective primitives (jaxpr names under shard_map)
_COLLECTIVE_PRIMS = (
    "all_to_all", "psum", "pmin", "pmax", "ppermute", "all_gather",
)


@dataclasses.dataclass(frozen=True)
class StepShape:
    """Abstract partition shape the engine is traced at."""

    n_local: int = 64
    rows: int = 80
    width: int = 8
    n_parts: int = 1

    @property
    def n_pad(self) -> int:
        return self.n_parts * self.n_local


def _source_line(eqn) -> Optional[str]:
    """file:line of the eqn's user frame, best effort."""
    try:
        from jax._src import source_info_util

        frame = source_info_util.user_frame(eqn.source_info)
        if frame is None:
            return None
        fname = frame.file_name.split("/")[-1]
        return f"{fname}:{frame.start_line}"
    except Exception:  # noqa: BLE001 — diagnostics only
        return None


def _walk(jaxpr, visit, path=""):
    """Visit every eqn recursively; ``path`` tracks the enclosing
    higher-order primitives (e.g. '/while/cond')."""
    for eqn in jaxpr.eqns:
        visit(eqn, path)
        name = eqn.primitive.name
        for v in eqn.params.values():
            vals = v if isinstance(v, (list, tuple)) else [v]
            for x in vals:
                inner = getattr(x, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    _walk(inner, visit, path + "/" + name)
                elif inner is not None and hasattr(inner, "jaxpr"):
                    _walk(inner.jaxpr, visit, path + "/" + name)
                elif hasattr(x, "eqns"):
                    _walk(x, visit, path + "/" + name)


def trace_step(
    cfg: EngineConfig,
    shape: StepShape = StepShape(),
    mesh=None,
):
    """The jaxpr ``make_engine`` would jit, traced abstractly.

    Builds the same shard_map-wrapped superstep loop (single-query
    path) and traces it with ShapeDtypeStruct inputs — no device
    buffers, no XLA compile.  Returns the ClosedJaxpr."""
    if mesh is None:
        mesh = jax.make_mesh((1,), ("data",))
    axis_names = tuple(mesh.axis_names)
    mesh_shape = tuple(mesh.devices.shape)
    n_parts = int(np.prod(mesh_shape))
    # the trace is per-program: n_parts enters only through static
    # shapes, so trace at the mesh's true part count
    sh = StepShape(shape.n_local, shape.rows, shape.width, n_parts)
    loop = build_step(cfg, axis_names, mesh_shape, sh.n_local, n_parts)

    def local(row_src, col, wgt, D, T, L):
        out = loop(row_src[0], col[0], wgt[0], D[0], T[0], L[0])
        return (out[0][None],) + out[1:]

    spec = P(axis_names)
    sharded = shard_map(
        local,
        mesh=mesh,
        in_specs=(spec,) * 6,
        out_specs=(spec,) + (P(),) * 7,
    )
    s = jax.ShapeDtypeStruct
    args = (
        s((n_parts, sh.rows), jnp.int32),
        s((n_parts, sh.rows, sh.width), jnp.int32),
        s((n_parts, sh.rows, sh.width), jnp.float32),
        s((n_parts, sh.n_local + 1), jnp.float32),
        s((n_parts, sh.n_local + 1), jnp.float32),
        s((n_parts, sh.n_local + 1), jnp.float32),
    )
    return jax.make_jaxpr(sharded)(*args), sh


#: HLO shape dtype names -> numpy (bf16/f8 handled separately below)
_HLO_DTYPES = {
    "pred": np.bool_, "s8": np.int8, "u8": np.uint8,
    "s16": np.int16, "u16": np.uint16, "s32": np.int32,
    "u32": np.uint32, "s64": np.int64, "u64": np.uint64,
    "f16": np.float16, "f32": np.float32, "f64": np.float64,
}


def payload_index_capacity(dtype) -> int:
    """Largest vertex index a payload plane of ``dtype`` can carry
    exactly (bit-exact for integer planes, contiguous-integer range
    for float planes used arithmetically).  Accepts numpy/jnp dtypes
    and HLO shape names ('u16', 'bf16', 'f8e4m3fn')."""
    if isinstance(dtype, str) and dtype in _HLO_DTYPES:
        dtype = _HLO_DTYPES[dtype]
    elif isinstance(dtype, str) and dtype.startswith(("bf16", "f8")):
        return 1 << 8 if dtype == "bf16" else 1 << 3
    dt = np.dtype(dtype)
    if dt.kind in ("i", "u"):
        return int(np.iinfo(dt).max)
    if dt == np.float64:
        return 1 << 53
    if dt == np.float32:
        return 1 << 24
    if dt == np.float16:
        return 1 << 11
    # bf16 and the f8s — 8- and 3/2-bit mantissas
    name = getattr(dt, "name", str(dtype))
    if "bfloat16" in name or "bf16" in str(dtype):
        return 1 << 8
    return 1 << 3


def lint_engine(
    cfg: EngineConfig,
    shape: StepShape = StepShape(),
    mesh=None,
    subject: Optional[str] = None,
) -> list:
    """Trace ``build_step`` for ``cfg`` and lint the superstep body.
    Returns [Finding]."""
    subject = subject or f"{cfg.hierarchy.name}/{cfg.exchange}"
    try:
        closed, sh = trace_step(cfg, shape, mesh)
    except Exception as e:  # noqa: BLE001 — surface as a finding
        return [Finding(
            pass_name="jaxpr", rule="trace-fails", severity="error",
            subject=subject,
            message=f"build_step does not trace: {e}",
        )]
    out: list = []
    sparse = cfg.exchange in ("sparse", "auto")
    _, slot_cap = frontier_caps(
        sh.rows, sh.width, sh.n_local, sh.n_parts, cfg.frontier_cap
    )
    use_level = cfg.hierarchy.needs_level
    nplanes = 2 if use_level else 1
    expected_a2a_ax1 = {
        payload_plane_words(slot_cap, use_level, cfg.payload),
        sh.n_local,                  # dense reduce-scatter transpose
    }
    saw_pallas = [False]

    def visit(eqn, path):
        prim = eqn.primitive.name
        in_loop = "/while" in path
        src = _source_line(eqn)
        if prim == "pallas_call":
            saw_pallas[0] = True

        if prim in _HOST_PRIMS:
            out.append(Finding(
                "jaxpr", "host-callback",
                "error" if in_loop else "warn", subject,
                f"host primitive {prim!r} "
                f"{'inside the superstep loop' if in_loop else 'in the step'}"
                " — every superstep would synchronize with the host",
                source=src,
            ))

        for ov in eqn.outvars:
            av = getattr(ov, "aval", None)
            dt = getattr(av, "dtype", None)
            if dt is not None and np.dtype(dt).itemsize > 4:
                out.append(Finding(
                    "jaxpr", "f64-promotion", "error", subject,
                    f"{prim} produces {np.dtype(dt).name} — a weak-"
                    "typed Python constant is widening the f32/i32 "
                    "engine state (2x exchange bytes, silent)",
                    source=src,
                ))
            if (
                in_loop
                and prim in _WEAK_ARITH_PRIMS
                and getattr(av, "weak_type", False)
                and getattr(av, "shape", None) == ()
            ):
                out.append(Finding(
                    "jaxpr", "weak-scalar", "warn", subject,
                    f"weak-typed scalar {prim} in the superstep loop "
                    "— a Python constant entered hot-loop arithmetic; "
                    "pin it (jnp.int32/jnp.float32) so dtypes cannot "
                    "drift and the jit cache cannot fork",
                    source=src,
                ))

        if prim == "all_to_all" and in_loop:
            for iv in eqn.invars:
                av = getattr(iv, "aval", None)
                if av is None or not getattr(av, "shape", None):
                    continue
                cap = payload_index_capacity(av.dtype)
                if cap < sh.n_local:
                    out.append(Finding(
                        "jaxpr", "payload-overflow", "error", subject,
                        f"exchange payload dtype {np.dtype(av.dtype).name} "
                        f"can only index {cap} vertices exactly but "
                        f"n_local={sh.n_local} — quantized payloads "
                        "must keep an exact index plane",
                        source=src,
                    ))
                if (
                    sparse
                    and len(av.shape) == 2
                    and av.shape[0] == sh.n_parts
                    and av.shape[1] not in expected_a2a_ax1
                    and av.shape[1] != nplanes * sh.n_local
                ):
                    out.append(Finding(
                        "jaxpr", "payload-plane", "error", subject,
                        f"sparse exchange payload shape {av.shape} "
                        f"does not match the planes x slot_cap layout "
                        f"(expected axis-1 in {sorted(expected_a2a_ax1)} "
                        f"or {nplanes * sh.n_local}) — sparse and "
                        "dense paths would unpack different bytes",
                        source=src,
                    ))

        if prim == "cond":
            pred = eqn.invars[0]
            if not hasattr(pred, "count"):  # a Literal, not a Var
                out.append(Finding(
                    "jaxpr", "dead-branch", "warn", subject,
                    "cond predicate is a trace-time constant "
                    f"({getattr(pred, 'val', '?')}) — one branch is "
                    "dead code; resolve it statically like the auto-"
                    "exchange shortcut does",
                    source=src,
                ))

    _walk(closed.jaxpr, visit)
    if (
        cfg.relax_impl.startswith("fused")
        and sparse
        and not saw_pallas[0]
    ):
        out.append(Finding(
            "jaxpr", "fused-kernel-escape", "warn", subject,
            "relax_impl requests the fused superstep kernel but no "
            "pallas_call appears in the traced step — the engine "
            "silently fell back to the reference relax (non-min-plus "
            "processing or a level-bearing hierarchy); drop '/fused' "
            "or switch to an sssp-shaped spec",
        ))
    return out


def lint_grid(
    configs,
    shape: StepShape = StepShape(),
    mesh=None,
) -> dict:
    """Lint many EngineConfigs, deduping identical traces.  Returns
    {subject: [Finding]} with one entry per distinct (hierarchy,
    exchange, frontier_cap, relax_impl) program."""
    seen: dict = {}
    for cfg in configs:
        key = (cfg.hierarchy, cfg.exchange, cfg.frontier_cap,
               cfg.relax_impl, cfg.collect_metrics, cfg.payload)
        if key in seen:
            continue
        subject = f"{cfg.hierarchy.name}/{cfg.exchange}"
        seen[key] = (subject, lint_engine(cfg, shape, mesh, subject))
    return {subj: fs for subj, fs in seen.values()}
