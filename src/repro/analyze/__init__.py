"""repro.analyze — static verification of the AGM engine and its
processing functions.

The paper's guarantee (any self-stabilizing kernel wrapped by any
AGM/EAGM ordering converges) only holds when the processing function
really is a self-stabilizing kernel and the engine's hot loop really
is the monotone dataflow the proofs assume.  This package checks both
*statically* — nothing here runs a solve:

  contract.py    self-stabilization contract verifier: every
                 registered ProcessingFn is checked against the
                 algebraic laws (idempotent/commutative/selective
                 reduce, inflationary monotone relaxation, top-element
                 identity) by exhaustive small-domain evaluation plus
                 jaxpr inspection; violations name the law and carry a
                 witness input.
  jaxpr_lint.py  engine lint at the jaxpr level: traces ``build_step``
                 across the spec grid without running it and flags
                 host callbacks in the hot loop, weak-typed scalar
                 arithmetic (silent promotion / retrace hazards),
                 exchange-payload dtype overflow, sparse-payload plane
                 mismatches and dead branches.
  hlo_lint.py    the same gate at the compiled-HLO level (reusing the
                 ``roofline.hlo`` parsers): f64 leaks, host
                 custom-calls, collective plan vs the spec's
                 expectation, payload byte accounting.
  spec_check.py  parse-time cross-checks of exchange mode ×
                 frontier_cap × partitioner × hierarchy compatibility,
                 plus ``explain_config`` — the collective plan per
                 spec, no compilation.
  report.py      runs all passes over the full spec grid, applies the
                 checked-in baseline, emits ``ANALYZE_report.json``
                 (the CI ``analyze`` job's gate artifact).

CLI: ``python -m repro.launch.analyze`` (see README "Static analysis").
"""

from repro.analyze.findings import (
    Finding,
    fingerprint,
    load_baseline,
    split_baselined,
)
from repro.analyze.contract import (
    ContractViolation,
    verify_processing,
    verify_registered,
)
from repro.analyze.jaxpr_lint import lint_engine, lint_grid
from repro.analyze.hlo_lint import lint_hlo_text, payload_capacity
from repro.analyze.spec_check import check_config, explain_config
from repro.analyze.report import run_report

__all__ = [
    "Finding", "fingerprint", "load_baseline", "split_baselined",
    "ContractViolation", "verify_processing", "verify_registered",
    "lint_engine", "lint_grid",
    "lint_hlo_text", "payload_capacity",
    "check_config", "explain_config",
    "run_report",
]
