"""Run every analyze pass over the full spec grid and gate.

The grid is the paper's family grid crossed with every exchange mode
and every partitioner — the same space ``bench_variants`` and the
equivalence harness sweep, so the lint gate covers exactly what the
benchmarks run.  The jaxpr pass dedupes by traced program (a
partitioner relabels data, not code); the spec pass runs per point;
the contract pass runs per registered processing function; the HLO
pass compiles a representative subset (compilation is the expensive
part, and the jaxpr pass already covered the whole grid).

``run_report`` returns the JSON-serializable report the CI ``analyze``
job uploads as ``ANALYZE_report.json`` and gates on: any finding of
gating severity (error/warn) that is not in the checked-in baseline
fails the build.
"""

from __future__ import annotations

from typing import Optional, Sequence

from repro.analyze import contract as _contract
from repro.analyze import spec_check as _spec
from repro.analyze.findings import (
    gate_failures,
    load_baseline,
    split_baselined,
)
from repro.analyze.jaxpr_lint import StepShape, lint_grid
from repro.api.config import SolverConfig
from repro.core.eagm import paper_variant_specs

#: exchanges × partitioners spanning the grid
ALL_EXCHANGES = ("a2a", "pmin", "sparse", "auto")
ALL_PARTITIONERS = ("block", "shuffle", "ebal", "degree")

#: representative subset for the (expensive) HLO compile pass: the
#: main dense baseline, the optimized dense, a sparse point and a
#: level-bearing hierarchy
HLO_SPECS = (
    "delta:5+buffer/pmin",
    "delta:5+threadq/a2a",
    "delta:5 > chunk:delta:1 /sparse",
    "kla:2+buffer/auto",
)


def grid_specs(
    exchanges: Sequence[str] = ALL_EXCHANGES,
    partitioners: Sequence[str] = ALL_PARTITIONERS,
    quick: bool = False,
) -> list:
    """The full spec grid as spec strings (hierarchy × exchange ×
    partitioner).  ``quick`` trims to one delta/k per root kind."""
    roots = paper_variant_specs()
    if quick:
        roots = [
            s for s in roots
            if s.split("+")[0] in ("delta:5", "kla:2", "chaotic",
                                   "dijkstra")
        ]
    specs = []
    for root in roots:
        for ex in exchanges:
            for part in partitioners:
                s = f"{root}/{ex}"
                if part != "block":
                    s += f"@{part}"
                specs.append(s)
    return specs


def run_report(
    *,
    baseline_path: Optional[str] = None,
    shape: StepShape = StepShape(),
    mesh=None,
    mesh_axes: Sequence[str] = ("data",),
    quick: bool = False,
    with_hlo: bool = True,
    hlo_specs: Sequence[str] = HLO_SPECS,
    exchanges: Sequence[str] = ALL_EXCHANGES,
    partitioners: Sequence[str] = ALL_PARTITIONERS,
) -> dict:
    """All passes; returns the ANALYZE_report dict (key ``ok`` is the
    gate verdict)."""
    findings: list = []

    # -- contract pass over every registered processing fn -------------
    results = _contract.verify_registered()
    findings += _contract.contract_findings(results)
    contract_summary = {
        name: [str(v) for v in vs] for name, vs in results.items()
    }

    # -- spec + jaxpr passes over the grid ------------------------------
    specs = grid_specs(exchanges, partitioners, quick=quick)
    shape_dict = dict(
        n_local=shape.n_local, rows=shape.rows, width=shape.width,
        n_parts=shape.n_parts,
    )
    # fused-kernel and quantized-payload points ride along so the lint
    # gate covers the '/fused' and '/q:*' spec surface too
    specs = specs + [
        "delta:5/sparse/fused",
        "delta:5/sparse/q:bf16",
        "delta:5/sparse/fused/q:u16",
    ]
    configs = []
    for s in specs:
        cfg = SolverConfig.from_spec(s)
        configs.append(cfg)
        findings += _spec.check_config(
            cfg, shape=shape_dict, mesh_axes=mesh_axes
        )
    engine_cfgs = []
    seen_engines = set()
    for cfg in configs:
        from repro.api.problem import get_processing

        ecfg = cfg.engine_config(get_processing("sssp"))
        key = (ecfg.hierarchy, ecfg.exchange, ecfg.relax_impl,
               ecfg.payload)
        if key not in seen_engines:
            seen_engines.add(key)
            engine_cfgs.append(ecfg)
    jaxpr_results = lint_grid(engine_cfgs, shape, mesh)
    for fs in jaxpr_results.values():
        findings += fs

    # -- HLO pass over the representative subset ------------------------
    hlo_stats: dict = {}
    if with_hlo:
        from repro.analyze.hlo_lint import lint_compiled
        from repro.api.problem import get_processing

        for s in hlo_specs:
            cfg = SolverConfig.from_spec(s)
            ecfg = cfg.engine_config(get_processing("sssp"))
            fs = lint_compiled(ecfg, shape, mesh, subject=cfg.name)
            findings += [f for f in fs if f.severity != "info"]
            hlo_stats[cfg.name] = [
                f.message for f in fs if f.rule == "hlo-payload-bytes"
            ]

    # -- gate ------------------------------------------------------------
    baseline = load_baseline(baseline_path)
    fresh, baselined = split_baselined(findings, baseline)
    failures = gate_failures(fresh)
    counts = {"error": 0, "warn": 0, "info": 0}
    for f in findings:
        counts[f.severity] += 1
    return {
        "ok": not failures,
        "points": len(specs),
        "traced_engines": len(engine_cfgs),
        "processing_checked": sorted(results),
        "contract": contract_summary,
        "hlo": hlo_stats,
        "counts": counts,
        "findings": [f.to_dict() for f in fresh],
        "baselined": [f.to_dict() for f in baselined],
        "shape": shape_dict,
    }


def render_report(report: dict) -> str:
    """Human summary for the CLI."""
    lines = [
        f"analyze: {report['points']} spec-grid points "
        f"({report['traced_engines']} distinct traced engines), "
        f"processing={','.join(report['processing_checked'])}",
        f"findings: {report['counts']['error']} error / "
        f"{report['counts']['warn']} warn / "
        f"{report['counts']['info']} info "
        f"({len(report['baselined'])} baselined)",
    ]
    shown = 0
    for f in report["findings"]:
        if f["severity"] == "info":
            continue
        lines.append(
            f"  {f['severity'].upper():5s} {f['pass_name']}/{f['rule']}"
            f" ({f['subject']}) {f['message']}"
            + (f" witness: {f['witness']}" if f.get("witness") else "")
        )
        shown += 1
        if shown >= 40:
            lines.append("  ... (truncated)")
            break
    lines.append("GATE: " + ("OK" if report["ok"] else "FAIL"))
    return "\n".join(lines)
