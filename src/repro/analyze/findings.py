"""Common finding type + baseline machinery for the analyze passes.

A :class:`Finding` is one diagnostic from any pass.  Severities:

    error   breaks the self-stabilization contract or the engine's
            dataflow assumptions — always gates.
    warn    suspicious but conceivably intentional (e.g. a knob with
            no effect in this mode) — gates unless baselined.
    info    advisory (cost-plan notes) — never gates.

Baselining: a finding's :func:`fingerprint` is a stable hash of its
identity fields (pass, rule, subject, witness) — NOT its message, so
rewording a diagnostic does not invalidate the baseline.  The
checked-in ``analyze_baseline.json`` is a list of
``{"fp": ..., "rule": ..., "subject": ..., "note": ...}`` records;
:func:`split_baselined` partitions a finding list against it.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Iterable, Optional, Sequence

SEVERITIES = ("error", "warn", "info")

#: severities that fail the gate when not baselined
GATING = ("error", "warn")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic from an analyze pass."""

    pass_name: str              # 'contract' | 'jaxpr' | 'hlo' | 'spec'
    rule: str                   # stable rule id, kebab-case
    severity: str               # 'error' | 'warn' | 'info'
    subject: str                # what was analyzed (spec / fn name)
    message: str                # human diagnostic
    witness: Optional[str] = None   # reproducing input, if any
    source: Optional[str] = None    # file:line when known

    def __post_init__(self) -> None:
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity must be one of {SEVERITIES}: {self.severity!r}"
            )

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["fp"] = fingerprint(self)
        return d

    def __str__(self) -> str:
        loc = f" [{self.source}]" if self.source else ""
        wit = f" witness: {self.witness}" if self.witness else ""
        return (
            f"{self.severity.upper():5s} {self.pass_name}/{self.rule} "
            f"({self.subject}){loc}: {self.message}{wit}"
        )


def fingerprint(f: Finding) -> str:
    """Stable identity hash for baselining (message excluded, so
    diagnostics can be reworded without re-baselining)."""
    key = "\x1f".join(
        (f.pass_name, f.rule, f.subject, f.witness or "")
    )
    return hashlib.sha256(key.encode()).hexdigest()[:16]


def load_baseline(path: Optional[str]) -> set:
    """Load the accepted-finding fingerprints from a baseline file
    (missing path or None -> empty baseline)."""
    if path is None:
        return set()
    try:
        with open(path) as fh:
            records = json.load(fh)
    except FileNotFoundError:
        return set()
    if not isinstance(records, list):
        raise ValueError(f"baseline {path}: expected a JSON list")
    fps = set()
    for rec in records:
        if isinstance(rec, str):
            fps.add(rec)
        elif isinstance(rec, dict) and "fp" in rec:
            fps.add(str(rec["fp"]))
        else:
            raise ValueError(f"baseline {path}: bad record {rec!r}")
    return fps


def baseline_records(findings: Sequence[Finding]) -> list:
    """Serializable baseline records for ``--write-baseline``."""
    return [
        {
            "fp": fingerprint(f),
            "rule": f"{f.pass_name}/{f.rule}",
            "subject": f.subject,
            "note": f.message[:120],
        }
        for f in findings
        if f.severity in GATING
    ]


def split_baselined(
    findings: Iterable[Finding], baseline: set
) -> tuple[list, list]:
    """Partition into (fresh, baselined).  Only gating severities are
    ever baselined; info findings always land in ``fresh`` (they don't
    gate anyway)."""
    fresh: list = []
    old: list = []
    for f in findings:
        if f.severity in GATING and fingerprint(f) in baseline:
            old.append(f)
        else:
            fresh.append(f)
    return fresh, old


def gate_failures(findings: Iterable[Finding]) -> list:
    """The findings that fail the CI gate (gating severity)."""
    return [f for f in findings if f.severity in GATING]
