"""Engine lint at the compiled-HLO level.

The jaxpr pass sees what we *asked* XLA for; this pass checks what the
compiler actually emitted, reusing the text parsers the roofline
subsystem already maintains (``repro.roofline.hlo``):

  hlo-f64              an f64 buffer in the compiled module — a
                       promotion that survived to codegen.
  hlo-host-call        infeed/outfeed/host custom-calls — host syncs
                       the jaxpr trace may have hidden inside closed-
                       over callables.
  hlo-collective-plan  the collective opcodes present disagree with
                       the spec's expected plan (e.g. a sparse spec
                       whose while body contains no all-to-all, or a
                       pmin spec that still emits one).
  hlo-payload-bytes    per-superstep collective payload bytes, as
                       parsed by ``roofline.hlo.collective_bytes`` —
                       attached to the report as stats (info), the
                       baseline every quantized-exchange PR diffs
                       against.

Compiling is the expensive part, so callers lint a representative
subset of the grid here (the jaxpr pass covers all of it).
"""

from __future__ import annotations

import re
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analyze.findings import Finding
from repro.analyze.jaxpr_lint import StepShape, payload_index_capacity
from repro.core.engine import EngineConfig, make_engine
from repro.roofline.hlo import collective_bytes, hbm_traffic

_HOST_CALL_RE = re.compile(
    r"\b(infeed|outfeed)\b|custom-call.*custom_call_target="
    r"\"(xla_python_cpu_callback|xla_python_gpu_callback|HostCallback"
    r"[^\"]*|callback[^\"]*)\""
)

_F64_RE = re.compile(r"\bf64\[|\bs64\[|\bu64\[")

#: shapes like u16[...] / bf16[...] on collective lines — candidates
#: for the quantized-exchange capacity check
_NARROW_COLLECTIVE_RE = re.compile(
    r"=\s*\(?((?:u|s)(?:8|16)|bf16|f16|f8\w*)\[([0-9,]*)\][^)]*\)?\s+"
    r"(all-to-all|all-reduce|reduce-scatter|all-gather|collective-permute)"
)


def compile_hlo(
    cfg: EngineConfig,
    shape: StepShape = StepShape(),
    mesh=None,
) -> str:
    """Compile the engine for ``cfg`` at ``shape`` and return the
    optimized per-device HLO text."""
    if mesh is None:
        mesh = jax.make_mesh((jax.device_count(),), ("data",))
    n_parts = int(np.prod(tuple(mesh.devices.shape)))
    fn = make_engine(
        dict(n_parts=n_parts, n_local=shape.n_local), mesh, cfg
    )
    s = jax.ShapeDtypeStruct
    args = (
        s((n_parts, shape.rows), jnp.int32),
        s((n_parts, shape.rows, shape.width), jnp.int32),
        s((n_parts, shape.rows, shape.width), jnp.float32),
        s((n_parts, shape.n_local + 1), jnp.float32),
        s((n_parts, shape.n_local + 1), jnp.float32),
        s((n_parts, shape.n_local + 1), jnp.float32),
    )
    return fn.lower(*args).compile().as_text()


def payload_capacity(dtype, n_local: int) -> tuple[bool, int]:
    """Can an exchange payload plane of ``dtype`` index ``n_local``
    vertices exactly?  Returns (ok, capacity) — the static gate the
    u16/bf16 quantized exchange (ROADMAP item 4) must pass before it
    can land."""
    cap = payload_index_capacity(dtype)
    return cap >= n_local, cap


def expected_collectives(cfg: EngineConfig, n_parts: int) -> dict:
    """The collective plan a spec implies, as {opcode: required}:
    True = must appear, False = must not, None = may appear."""
    if n_parts <= 1:
        # single-device modules legally compile collectives away
        return {}
    plan: dict = {"all-reduce": True}  # termination psum at minimum
    if cfg.exchange in ("a2a", "sparse", "auto"):
        plan["all-to-all"] = True
    elif cfg.exchange == "pmin":
        plan["all-to-all"] = False
    return plan


def lint_hlo_text(
    hlo_text: str,
    subject: str,
    cfg: Optional[EngineConfig] = None,
    shape: Optional[StepShape] = None,
    n_parts: int = 1,
) -> list:
    """Lint compiled HLO text; returns [Finding] including an info
    finding carrying the parsed collective/HBM stats."""
    out: list = []

    m = _F64_RE.search(hlo_text)
    if m:
        line = hlo_text[:m.start()].count("\n") + 1
        out.append(Finding(
            "hlo", "hlo-f64", "error", subject,
            f"64-bit buffer ({m.group(0)}...) in the compiled module "
            "— a weak-typed promotion reached codegen",
            source=f"hlo:{line}",
        ))

    m = _HOST_CALL_RE.search(hlo_text)
    if m:
        line = hlo_text[:m.start()].count("\n") + 1
        out.append(Finding(
            "hlo", "hlo-host-call", "error", subject,
            f"host transfer op in compiled module: {m.group(0)!r}",
            source=f"hlo:{line}",
        ))

    coll = collective_bytes(hlo_text)
    if cfg is not None:
        plan = expected_collectives(cfg, n_parts)
        for op, required in plan.items():
            present = coll["counts"].get(op, 0) > 0
            if required and not present:
                out.append(Finding(
                    "hlo", "hlo-collective-plan", "error", subject,
                    f"spec requires a {op} (exchange={cfg.exchange!r}) "
                    "but the compiled module contains none — the "
                    "collective plan and the spec disagree",
                ))
            elif required is False and present:
                out.append(Finding(
                    "hlo", "hlo-collective-plan", "warn", subject,
                    f"spec implies no {op} (exchange={cfg.exchange!r}) "
                    f"but the compiled module contains "
                    f"{coll['counts'][op]}",
                ))

    if shape is not None:
        for m in _NARROW_COLLECTIVE_RE.finditer(hlo_text):
            dt = m.group(1)
            ok, cap = payload_capacity(dt, shape.n_local)
            if not ok:
                out.append(Finding(
                    "hlo", "hlo-payload-overflow", "error", subject,
                    f"{m.group(3)} moves a {dt} payload but {dt} can "
                    f"only index {cap} < n_local={shape.n_local} "
                    "vertices exactly — quantize values, never "
                    "indices",
                ))

    hbm = hbm_traffic(hlo_text)
    out.append(Finding(
        "hlo", "hlo-payload-bytes", "info", subject,
        f"collectives={coll['counts']} "
        f"collective_bytes={coll['total_bytes']} "
        f"hbm_bytes={hbm['total_bytes']}",
    ))
    return out


def lint_compiled(
    cfg: EngineConfig,
    shape: StepShape = StepShape(),
    mesh=None,
    subject: Optional[str] = None,
) -> list:
    """Compile + lint one spec point."""
    subject = subject or f"{cfg.hierarchy.name}/{cfg.exchange}"
    if mesh is None:
        mesh = jax.make_mesh((jax.device_count(),), ("data",))
    n_parts = int(np.prod(tuple(mesh.devices.shape)))
    try:
        text = compile_hlo(cfg, shape, mesh)
    except Exception as e:  # noqa: BLE001 — surface as a finding
        return [Finding(
            "hlo", "hlo-compile-fails", "error", subject,
            f"engine does not compile: {e}",
        )]
    return lint_hlo_text(text, subject, cfg, shape, n_parts)
