"""Parse-time spec cross-checks + the collective-plan explainer.

``Hierarchy`` validates its own structure (root at GLOBAL, strict
nesting, TopK local-only) at construction; this module extends that
validation *across* the config: exchange mode × frontier_cap ×
partitioner × hierarchy interactions that are individually legal but
jointly useless or hazardous.  Pure spec arithmetic — nothing here
traces or compiles.

``explain_config`` prints the per-superstep collective plan a spec
implies (which collective realizes each annotation, what the exchange
moves, how many synchronization rounds a superstep costs) using the
same closed-form word counts the facade's exact byte accounting uses
(``api.solver._finish_metrics``) — so ``launch/analyze --explain`` can
answer "what will this spec do on the wire" without building an
engine.
"""

from __future__ import annotations

from typing import Optional, Sequence, Union

from repro.analyze.findings import Finding
from repro.api.config import SolverConfig, as_config
from repro.core.eagm import LEVEL_SCOPE, LOCAL_LEVELS
from repro.core.frontier import frontier_caps, payload_plane_words
from repro.core.ordering import DeltaStepping, TopK

#: partitioners whose vertex->rank boundaries depend on the graph's
#: degree structure, so a streamed update can change the layout
GRAPH_DEPENDENT_PARTITIONERS = ("ebal", "degree")


def check_config(
    config: Union[str, SolverConfig],
    *,
    shape: Optional[dict] = None,
    mesh_axes: Sequence[str] = ("data",),
    processing: str = "sssp",
) -> list:
    """Cross-check one spec point; returns [Finding].

    ``shape`` (optional) is ``dict(n_local, rows, width, n_parts)`` —
    when given, capacity rules that need concrete sizes run too.
    ``mesh_axes`` are the launch mesh's axis names (pod-scope rules).
    """
    cfg = as_config(config)
    subject = cfg.name
    out: list = []
    hier = cfg.hierarchy
    sparse = cfg.exchange in ("sparse", "auto")

    if cfg.frontier_cap is not None and not sparse:
        out.append(Finding(
            "spec", "frontier-cap-dense", "warn", subject,
            f"frontier_cap={cfg.frontier_cap} has no effect with the "
            f"dense {cfg.exchange!r} exchange — set /sparse or /auto, "
            "or drop the cap",
        ))

    # kernel relax impls (pallas/fused) silently keep the 'ref' path in
    # configurations the kernel doesn't cover; for the fused kernel
    # that silent escape gets its own rule id so CI can gate on it
    kern = cfg.relax_impl != "ref"
    fused = cfg.relax_impl.startswith("fused")

    if kern and not sparse:
        out.append(Finding(
            "spec",
            "fused-kernel-escape" if fused else "relax-impl-dense",
            "warn", subject,
            f"relax_impl={cfg.relax_impl!r} only drives the sparse "
            f"push path; the dense {cfg.exchange!r} exchange never "
            "invokes it",
        ))

    if kern and processing != "sssp":
        out.append(Finding(
            "spec",
            "fused-kernel-escape" if fused else "relax-impl-processing",
            "warn", subject,
            f"relax_impl={cfg.relax_impl!r} is wired for min-plus "
            f"sssp only; processing {processing!r} silently falls "
            "back to 'ref'",
        ))

    if kern and hier.needs_level:
        out.append(Finding(
            "spec",
            "fused-kernel-escape" if fused else "relax-impl-kla",
            "warn", subject,
            f"relax_impl={cfg.relax_impl!r} does not carry the KLA "
            "level attribute; a level-bearing hierarchy "
            f"({hier.name}) silently falls back to 'ref'",
        ))

    if cfg.payload != "exact" and not sparse:
        out.append(Finding(
            "spec", "payload-quantized-dense", "warn", subject,
            f"payload={cfg.payload!r} only compresses the sparse "
            f"exchange; the dense {cfg.exchange!r} exchange moves "
            "exact f32 planes — /q buys nothing without /sparse or "
            "/auto",
        ))

    if cfg.payload != "exact":
        import jax.numpy as jnp

        from repro.api.problem import get_processing

        if get_processing(processing).reduce is not jnp.minimum:
            out.append(Finding(
                "spec", "payload-processing", "error", subject,
                f"quantized payload {cfg.payload!r} requires a "
                "min-reduce semiring (round-up errors must be "
                f"inflationary); processing {processing!r} is not — "
                "EngineConfig refuses this combination at build time",
            ))

    if hier.at("pod") is not None and "pod" not in mesh_axes:
        out.append(Finding(
            "spec", "pod-scope-flat-mesh", "info", subject,
            "hierarchy annotates the pod level but the mesh "
            f"{tuple(mesh_axes)} has no 'pod' axis — the pod scope "
            "spans every axis, i.e. it degenerates to a second "
            "global decision (more synchronization than the spec "
            "reads as)",
        ))

    chunk = hier.at("chunk")
    if (
        isinstance(chunk, TopK)
        and sparse
        and cfg.frontier_cap is not None
        and chunk.drain > cfg.frontier_cap
    ):
        out.append(Finding(
            "spec", "topk-exceeds-frontier-cap", "warn", subject,
            f"chunk drains top-{chunk.drain} but frontier_cap="
            f"{cfg.frontier_cap} < {chunk.drain} — every full drain "
            "overflows the sparse compaction and falls back dense, "
            "so the cap buys nothing",
        ))

    if cfg.partition in GRAPH_DEPENDENT_PARTITIONERS:
        out.append(Finding(
            "spec", "partition-layout-drift", "info", subject,
            f"partitioner {cfg.partition!r} derives rank boundaries "
            "from the degree structure; streamed graph updates can "
            "move them, and resolve() then refuses the warm restart "
            "(cold-solve fallback) — use 'block' for update-heavy "
            "serving",
        ))

    if cfg.adapt is not None:
        from repro.tune.policies import policy_traits

        traits = policy_traits(cfg.adapt)
        root_delta = isinstance(hier.root, DeltaStepping)
        if sparse and not traits["grows_cap"]:
            out.append(Finding(
                "spec", "adapt-no-cap-growth", "warn", subject,
                f"adapt policy {cfg.adapt!r} never grows frontier_cap, "
                "so a sparse overflow falls back dense every superstep "
                "anyway — use '/adapt:rho' for rho-stepping cap growth "
                "or drop the controller",
            ))
        if not root_delta and not sparse:
            out.append(Finding(
                "spec", "adapt-nothing-to-tune", "warn", subject,
                f"nothing for the controller to tune: root "
                f"{hier.root.spec!r} has no delta bucket width and the "
                f"dense {cfg.exchange!r} exchange has no frontier_cap "
                "or sparse/dense choice — the /adapt segment only "
                "adds per-segment host synchronization",
            ))
        if isinstance(chunk, TopK):
            out.append(Finding(
                "spec", "adapt-topk-drain", "warn", subject,
                f"chunk top-{chunk.drain} drain already rate-limits "
                "per-superstep work device-locally; retuning delta "
                "around it shifts classes the drain then re-truncates "
                "— controller decisions will look ineffective",
            ))

    if cfg.trace:
        out.append(Finding(
            "spec", "trace-no-batch", "warn", subject,
            "/trace solves are unbatchable: the batched engine "
            "publishes no per-lane superstep windows, so "
            "solve_batch (and any Router flush of more than one "
            "distinct source) rejects this spec — trace queries one "
            "at a time, or drop /trace for serving",
        ))
        if cfg.adapt is not None:
            out.append(Finding(
                "spec", "trace-adapt-composition", "warn", subject,
                f"/trace composed with /adapt:{cfg.adapt}: one "
                "segmentation serves both (the recorder taps the "
                "controller's windows), but the flight record then "
                "reflects the RETUNED schedule — per-superstep rows/"
                "bytes will not match a static solve of this spec's "
                "tunables; trace without /adapt for the static record",
            ))
        if not cfg.collect_metrics:
            out.append(Finding(
                "spec", "trace-forces-metrics", "info", subject,
                "collect_metrics=False with /trace: the segment "
                "engine always collects per-superstep counters for "
                "the windows, so the traced WorkMetrics gains the "
                "work terms (and one collective round per superstep) "
                "an untraced collect_metrics=False solve omits — "
                "metrics bit-identity holds only with "
                "collect_metrics=True",
            ))

    if shape is not None:
        nl, R = int(shape["n_local"]), int(shape["rows"])
        W, Pn = int(shape["width"]), int(shape["n_parts"])
        use_level = hier.needs_level
        nplanes = 2 if use_level else 1
        if sparse:
            row_cap, slot_cap = frontier_caps(
                R, W, nl, Pn, cfg.frontier_cap
            )
            if cfg.frontier_cap is not None and cfg.frontier_cap > R:
                out.append(Finding(
                    "spec", "frontier-cap-exceeds-rows", "warn",
                    subject,
                    f"frontier_cap={cfg.frontier_cap} exceeds the "
                    f"{R} ELL rows per rank — clamped to {row_cap}; "
                    "the spec overstates its capacity",
                ))
            pwords = payload_plane_words(slot_cap, use_level, cfg.payload)
            if pwords >= nplanes * nl:
                out.append(Finding(
                    "spec", "sparse-cannot-pay", "info", subject,
                    f"at this shape the sparse payload "
                    f"({pwords} words/segment) never beats the "
                    f"dense reduce-scatter ({nplanes}x{nl} words) — "
                    "'auto' resolves dense at trace time; '/sparse' "
                    "pays the compaction for nothing",
                ))
    return out


def check_grid(
    specs: Sequence[str],
    *,
    shape: Optional[dict] = None,
    mesh_axes: Sequence[str] = ("data",),
) -> dict:
    """``check_config`` over many spec strings: {spec: [Finding]}."""
    return {
        s: check_config(s, shape=shape, mesh_axes=mesh_axes)
        for s in specs
    }


def explain_config(
    config: Union[str, SolverConfig],
    *,
    shape: Optional[dict] = None,
    mesh_axes: Sequence[str] = ("data",),
) -> str:
    """The collective plan a spec implies, one superstep at a time —
    no engine build, no compile."""
    cfg = as_config(config)
    hier = cfg.hierarchy
    use_level = hier.needs_level
    nplanes = 2 if use_level else 1
    lines = [f"spec {cfg.name!r} — per-superstep plan:"]

    lines.append("  ordering decisions (outermost first):")
    for lvl, o in hier.annotations:
        if lvl in LOCAL_LEVELS and isinstance(o, TopK):
            scope = f"device-local top-{o.drain} drain (no collective)"
        elif lvl in LOCAL_LEVELS:
            scope = "device-local minimal class (no collective)"
        elif lvl == "pod" and "pod" not in mesh_axes:
            scope = (f"{LEVEL_SCOPE[lvl]} — NOTE: mesh "
                     f"{tuple(mesh_axes)} has no pod axis, this spans "
                     "all ranks")
        else:
            scope = LEVEL_SCOPE[lvl]
        lines.append(f"    {lvl:7s} {o.spec:16s} {scope}")

    lines.append("  candidate exchange:")
    if shape is not None:
        nl, Pn = int(shape["n_local"]), int(shape["n_parts"])
        R, W = int(shape["rows"]), int(shape["width"])
        dense_words = (Pn - 1) * nl * nplanes
        if cfg.exchange == "pmin":
            lines.append(
                f"    pmin    dense all-reduce combine, "
                f"~{2 * dense_words} words/device/superstep "
                f"(2x the reduce-scatter)"
            )
        elif cfg.exchange == "a2a":
            lines.append(
                f"    a2a     all_to_all transpose + local combine, "
                f"{dense_words} words/device/superstep "
                f"({nplanes} plane{'s' if nplanes > 1 else ''})"
            )
        else:
            row_cap, slot_cap = frontier_caps(
                R, W, nl, Pn, cfg.frontier_cap
            )
            pwords = payload_plane_words(slot_cap, use_level, cfg.payload)
            sparse_words = (Pn - 1) * pwords
            enc = "(idx,val)" if cfg.payload == "exact" else (
                f"(u32 idx, {cfg.payload} Δ)"
            )
            lines.append(
                f"    {cfg.exchange:7s} {enc} all_to_all, "
                f"{sparse_words} words/device on sparse supersteps "
                f"(row_cap={row_cap}, slot_cap={slot_cap}, "
                f"{pwords} words/segment); dense fallback moves "
                f"{dense_words} words"
            )
            if cfg.payload != "exact":
                exact_words = (Pn - 1) * payload_plane_words(
                    slot_cap, use_level, "exact"
                )
                lines.append(
                    f"            quantized payload: {sparse_words} vs "
                    f"{exact_words} exact words — round-up-only codes, "
                    "final state repaired exact by the facade"
                )
            if pwords >= nplanes * nl:
                lines.append(
                    "            NOTE: sparse cannot pay at this "
                    "shape — resolves dense"
                )
    else:
        desc = {
            "pmin": "dense all-reduce combine (paper-faithful, 2x "
                    "reduce-scatter bytes)",
            "a2a": "all_to_all transpose + local combine "
                   "(min-reduce-scatter)",
            "sparse": "frontier-compacted (idx,val) all_to_all, dense "
                      "fallback on capacity overflow",
            "auto": "sparse while the carried pending count is small, "
                    "dense otherwise",
        }[cfg.exchange]
        lines.append(f"    {cfg.exchange:7s} {desc}")

    if cfg.adapt is not None:
        from repro.tune.policies import policy_traits

        traits = policy_traits(cfg.adapt)
        knobs = [
            k for k, on in (
                ("delta", traits["retunes_delta"]
                 and isinstance(hier.root, DeltaStepping)),
                ("frontier_cap", traits["grows_cap"]
                 and cfg.exchange in ("sparse", "auto")),
                ("sparse/dense choice",
                 cfg.exchange in ("sparse", "auto")),
            ) if on
        ]
        lines.append(
            f"  controller: adapt:{cfg.adapt} every "
            f"{cfg.adapt_window} supersteps "
            f"(tunes {', '.join(knobs) if knobs else 'nothing'}; "
            "delta/exchange retunes are dynamic scalars, only a "
            "never-seen frontier_cap retraces)"
        )

    if cfg.trace:
        lines.append(
            f"  recorder: /trace runs {cfg.adapt_window}-superstep "
            "segments purely to publish per-superstep windows "
            "(pending/eligible/rows/bytes) — bit-identical state and "
            "metrics, SolveTrace on Solution.trace"
        )

    rounds = (3 if cfg.collect_metrics else 2) + (
        1 if cfg.exchange in ("sparse", "auto") else 0
    )
    pod_extra = sum(
        1 for lvl, _ in hier.annotations if lvl in ("pod",)
    )
    lines.append(
        f"  synchronization: {rounds + pod_extra} collective rounds "
        f"per superstep ({'with' if cfg.collect_metrics else 'without'}"
        " work metrics; termination psum included)"
    )
    lines.append(f"  partitioner: {cfg.partition} "
                 f"(relabeling only — no effect on the traced program)")
    return "\n".join(lines)
