"""Self-stabilization contract verifier for processing functions.

The engine's correctness argument (paper §II-III) needs the processing
function to be a *self-stabilizing kernel*: the per-vertex combine
must be an idempotent, commutative, selective reduction whose order
agrees with ``better``; relaxation must be inflationary (a candidate
never improves on the state that generated it — min-plus semiring
non-negativity) and monotone; ``worst`` must be the top element (the
reduce identity); and a source's initial value must strictly improve
``worst`` (else the source never becomes pending).  Any function
satisfying these laws can be wrapped by ANY ordering hierarchy and
still converge to the same fixpoint — that is the family theorem this
verifier machine-checks.

Two mechanisms, per Devismes et al.'s observation that stabilization
properties are precise, checkable predicates:

* **Exhaustive small-domain evaluation** — the laws are universally
  quantified over states × weights; we evaluate them over the closure
  of the function's own reachable states (source values + worst,
  closed under ``edge_update``/``reduce`` to depth 2) so there are no
  vacuous passes and no false positives from unreachable states.
  Violations carry the witness input.
* **jaxpr inspection** — ``edge_update``/``better``/``reduce`` are
  traced with f32 scalars and their jaxprs checked for f64 leaks
  (weak-typed Python constants promoting the state dtype), host
  callbacks, and non-pure primitives — hazards evaluation can't see.

``verify_registered`` enumerates :func:`repro.api.problem
.registered_processing` — the registration seam every new family
member passes through, so the CI ``analyze`` job gates them all.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analyze.findings import Finding
from repro.core.processing import ProcessingFn

#: edge weights the laws are quantified over: the min-plus semiring
#: assumes non-negative weights; +inf is the ELL padding weight every
#: real relaxation sweep feeds through ``edge_update``.
DEFAULT_WEIGHTS = (0.0, 0.25, 1.0, 3.0, float("inf"))

#: sample source vertices for ``initial_value``
SAMPLE_VERTICES = (0, 1, 5)

#: jaxpr primitives that break purity / force a host round-trip
_IMPURE_PRIMS = (
    "pure_callback", "io_callback", "debug_callback", "callback",
    "outside_call", "infeed", "outfeed",
)


@dataclasses.dataclass(frozen=True)
class ContractViolation:
    """One broken law, with the witness input that exhibits it."""

    processing: str
    law: str
    witness: tuple
    detail: str

    def __str__(self) -> str:
        return (
            f"{self.processing}: law {self.law!r} violated at witness "
            f"{self.witness}: {self.detail}"
        )

    def to_finding(self) -> Finding:
        return Finding(
            pass_name="contract",
            rule=self.law,
            severity="error",
            subject=self.processing,
            message=self.detail,
            witness=repr(self.witness),
        )


def _f32(x: float) -> np.float32:
    return np.float32(x)


def _eval(fn, *args) -> float:
    """Evaluate a jnp-traceable scalar callable on f32 scalars."""
    out = fn(*(jnp.float32(a) for a in args))
    return float(np.asarray(out))


def _better(p: ProcessingFn, a: float, b: float) -> bool:
    return bool(np.asarray(p.better(jnp.float32(a), jnp.float32(b))))


def _reduce2(p: ProcessingFn, a: float, b: float) -> float:
    return _eval(p.reduce, a, b)


def _reduce_array2(p: ProcessingFn, a: float, b: float) -> float:
    out = p.reduce_array(jnp.asarray([a, b], dtype=jnp.float32), axis=0)
    return float(np.asarray(out))


def reachable_domain(
    p: ProcessingFn,
    weights: Sequence[float] = DEFAULT_WEIGHTS,
    depth: int = 2,
    cap: int = 48,
) -> tuple:
    """States the laws are quantified over: the function's own source
    values and ``worst``, closed under ``edge_update`` (all weights)
    and pairwise ``reduce`` to ``depth``.  Quantifying over *reachable*
    states keeps the check sound without false alarms on states the
    engine can never hold."""
    dom = {float(_f32(p.worst))}
    for v in SAMPLE_VERTICES:
        dom.add(float(_f32(p.initial_value(v))))
    for _ in range(depth):
        new = set()
        for s in dom:
            for w in weights:
                c = _eval(p.edge_update, s, w)
                if not np.isnan(c):
                    new.add(float(_f32(c)))
        for a, b in itertools.combinations(sorted(dom), 2):
            new.add(float(_f32(_reduce2(p, a, b))))
        dom |= new
        if len(dom) > cap:
            break
    # keep the domain small enough that O(n^3) transitivity stays cheap
    return tuple(sorted(dom, key=lambda x: (np.isnan(x), x))[:cap])


# --------------------------------------------------------------------
# the laws
# --------------------------------------------------------------------


def _check_order_laws(p: ProcessingFn, dom, out: list) -> None:
    """``better`` must be a strict order (irreflexive, asymmetric,
    transitive) — otherwise 'pending' is not well-defined."""
    for a in dom:
        if _better(p, a, a):
            out.append(ContractViolation(
                p.name, "better-irreflexive", (a,),
                f"better({a}, {a}) is True — a state must not strictly "
                "improve itself (pending detection would never drain)",
            ))
    for a, b in itertools.permutations(dom, 2):
        if _better(p, a, b) and _better(p, b, a):
            out.append(ContractViolation(
                p.name, "better-asymmetric", (a, b),
                f"better({a}, {b}) and better({b}, {a}) both hold — "
                "the state order is not antisymmetric",
            ))
    for a, b, c in itertools.permutations(dom, 3):
        if (_better(p, a, b) and _better(p, b, c)
                and not _better(p, a, c)):
            out.append(ContractViolation(
                p.name, "better-transitive", (a, b, c),
                f"better({a},{b}) and better({b},{c}) but not "
                f"better({a},{c})",
            ))


def _check_reduce_laws(p: ProcessingFn, dom, out: list) -> None:
    """The combine must be an idempotent commutative selection that
    agrees with ``better`` — the algebraic core that makes the
    scatter-combine atomic-free and the kernel self-stabilizing."""
    for a in dom:
        r = _reduce2(p, a, a)
        if r != a and not (np.isnan(r) and np.isnan(a)):
            out.append(ContractViolation(
                p.name, "reduce-idempotent", (a,),
                f"reduce({a}, {a}) = {r} != {a} — re-delivering a "
                "duplicate workitem changes state, so the lock-free "
                "exchange is unsafe",
            ))
    for a, b in itertools.combinations(dom, 2):
        ab, ba = _reduce2(p, a, b), _reduce2(p, b, a)
        if ab != ba and not (np.isnan(ab) and np.isnan(ba)):
            out.append(ContractViolation(
                p.name, "reduce-commutative", (a, b),
                f"reduce({a},{b}) = {ab} but reduce({b},{a}) = {ba} — "
                "arrival order would change the result",
            ))
        if ab not in (a, b) and not np.isnan(ab):
            out.append(ContractViolation(
                p.name, "reduce-selective", (a, b),
                f"reduce({a},{b}) = {ab}, which is neither input — the "
                "combine must select, not mix (mixing breaks the "
                "monotone convergence argument)",
            ))
        else:
            want = a if _better(p, a, b) else b
            if ab != want:
                out.append(ContractViolation(
                    p.name, "reduce-monotone", (a, b),
                    f"reduce({a},{b}) = {ab} but better() says {want} "
                    "wins — the combine is not monotone non-increasing "
                    "w.r.t. the state order",
                ))
    for a, b, c in itertools.combinations(dom, 3):
        lhs = _reduce2(p, a, _reduce2(p, b, c))
        rhs = _reduce2(p, _reduce2(p, a, b), c)
        if lhs != rhs and not (np.isnan(lhs) and np.isnan(rhs)):
            out.append(ContractViolation(
                p.name, "reduce-associative", (a, b, c),
                f"reduce is not associative: {lhs} != {rhs} — "
                "pre-combining per pod/rank would change the result",
            ))
    # reduce_array (the engine's vectorized path) must agree with the
    # pairwise reduce — ProcessingFn.reduce_array dispatches on
    # `reduce is jnp.minimum`, so a custom reduce silently gets max
    for a, b in itertools.combinations(dom, 2):
        arr, red = _reduce_array2(p, a, b), _reduce2(p, a, b)
        if arr != red and not (np.isnan(arr) and np.isnan(red)):
            out.append(ContractViolation(
                p.name, "reduce-array-consistent", (a, b),
                f"reduce_array([{a},{b}]) = {arr} but reduce({a},{b}) "
                f"= {red} — the dense sweep and the exchange combine "
                "disagree",
            ))
            break  # one witness suffices; this repeats for every pair


def _check_top_laws(p: ProcessingFn, dom, out: list) -> None:
    """``worst`` must be the reduce identity and the top of the state
    order — it is the 'no candidate' element every buffer is filled
    with."""
    worst = float(_f32(p.worst))
    for a in dom:
        r = _reduce2(p, a, worst)
        if r != a and not (np.isnan(r) and np.isnan(a)):
            out.append(ContractViolation(
                p.name, "worst-identity", (a,),
                f"reduce({a}, worst={worst}) = {r} != {a} — worst is "
                "not the reduce identity, so padded slots corrupt "
                "real candidates",
            ))
        if _better(p, worst, a):
            out.append(ContractViolation(
                p.name, "worst-top", (a,),
                f"better(worst={worst}, {a}) — worst must be the top "
                "element (no state is improved by 'no candidate')",
            ))


def _check_relax_laws(
    p: ProcessingFn, dom, weights, out: list
) -> None:
    """Relaxation must be inflationary (a candidate never improves on
    its source state — min-plus non-negativity) and monotone in the
    source state; together with the reduce laws this is exactly what
    makes the chaotic fixpoint order-independent."""
    for s in dom:
        for w in weights:
            c = _eval(p.edge_update, s, w)
            if np.isnan(c):
                continue
            if _better(p, c, s):
                out.append(ContractViolation(
                    p.name, "relax-inflationary", (s, w),
                    f"edge_update({s}, {w}) = {c} strictly improves "
                    "its own source state — relaxation must be "
                    "inflationary under the min-plus semiring or the "
                    "fixpoint is unbounded",
                ))
    for s1, s2 in itertools.permutations(dom, 2):
        if not _better(p, s1, s2):
            continue
        for w in weights:
            c1 = _eval(p.edge_update, s1, w)
            c2 = _eval(p.edge_update, s2, w)
            if np.isnan(c1) or np.isnan(c2):
                continue
            if _better(p, c2, c1):
                out.append(ContractViolation(
                    p.name, "relax-monotone", (s1, s2, w),
                    f"better({s1},{s2}) but edge_update({s2},{w})={c2} "
                    f"improves edge_update({s1},{w})={c1} — a worse "
                    "source must not generate a better candidate "
                    "(monotonicity of the kernel)",
                ))


def _check_source_laws(p: ProcessingFn, out: list) -> None:
    worst = float(_f32(p.worst))
    for v in SAMPLE_VERTICES:
        init = float(_f32(p.initial_value(v)))
        if init != worst and not _better(p, init, worst):
            out.append(ContractViolation(
                p.name, "source-init-improving", (v, init),
                f"initial_value({v}) = {init} does not improve "
                f"worst = {worst} — the source would never become "
                "pending and the solve would return immediately",
            ))


def _walk_jaxpr(jaxpr, visit) -> None:
    for eqn in jaxpr.eqns:
        visit(eqn)
        for v in eqn.params.values():
            vals = v if isinstance(v, (list, tuple)) else [v]
            for x in vals:
                inner = getattr(x, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    _walk_jaxpr(inner, visit)
                elif inner is not None and hasattr(inner, "jaxpr"):
                    _walk_jaxpr(inner.jaxpr, visit)
                elif hasattr(x, "eqns"):
                    _walk_jaxpr(x, visit)


def _check_trace_laws(p: ProcessingFn, out: list) -> None:
    """jaxpr inspection: trace the three callables with f32 scalars
    and flag f64 leaks / impure primitives — hazards that concrete
    evaluation at f32 can't exhibit."""
    s = jax.ShapeDtypeStruct((), jnp.float32)
    traces = {
        "edge_update": (p.edge_update, (s, s)),
        "better": (p.better, (s, s)),
        "reduce": (p.reduce, (s, s)),
    }
    for name, (fn, args) in traces.items():
        try:
            closed = jax.make_jaxpr(fn)(*args)
        except Exception as e:  # noqa: BLE001 — diagnostic, not control
            out.append(ContractViolation(
                p.name, "trace-fails", (name,),
                f"{name} is not jnp-traceable on f32 scalars: {e}",
            ))
            continue

        def visit(eqn, _name=name):
            if eqn.primitive.name in _IMPURE_PRIMS:
                out.append(ContractViolation(
                    p.name, "trace-impure", (_name,),
                    f"{_name} traces a host-callback primitive "
                    f"{eqn.primitive.name!r} — processing functions "
                    "must be pure device code (a callback in the hot "
                    "loop serializes every superstep on the host)",
                ))
            for ov in eqn.outvars:
                dt = getattr(getattr(ov, "aval", None), "dtype", None)
                if dt is not None and np.dtype(dt).itemsize > 4:
                    out.append(ContractViolation(
                        p.name, "trace-f64", (_name,),
                        f"{_name} promotes f32 inputs to {dt} "
                        f"(via {eqn.primitive.name}) — a weak-typed "
                        "Python constant is widening the state dtype; "
                        "the engine state is f32",
                    ))

        _walk_jaxpr(closed.jaxpr, visit)


# --------------------------------------------------------------------
# entry points
# --------------------------------------------------------------------


def verify_processing(
    p: ProcessingFn,
    weights: Sequence[float] = DEFAULT_WEIGHTS,
    max_violations: int = 64,
) -> list:
    """Check every contract law; returns [ContractViolation] (empty =
    the function is a self-stabilizing kernel on its reachable
    domain)."""
    out: list = []
    dom = reachable_domain(p, weights)
    _check_order_laws(p, dom, out)
    _check_reduce_laws(p, dom, out)
    _check_top_laws(p, dom, out)
    _check_relax_laws(p, dom, weights, out)
    _check_source_laws(p, out)
    _check_trace_laws(p, out)
    # a broken law tends to fire on many witnesses; keep a few per law
    # (diagnostics want one, tests may want corroboration) and cap the
    # total
    per_law: dict = {}
    seen: set = set()
    uniq: list = []
    for v in out:
        k = (v.law, v.witness)
        if k in seen or per_law.get(v.law, 0) >= 3:
            continue
        seen.add(k)
        per_law[v.law] = per_law.get(v.law, 0) + 1
        uniq.append(v)
        if len(uniq) >= max_violations:
            break
    return uniq


def verify_registered(
    weights: Sequence[float] = DEFAULT_WEIGHTS,
    registry: Optional[Iterable[ProcessingFn]] = None,
) -> dict:
    """Verify every registered processing function (the
    ``register_processing`` seam); returns {name: [violations]}."""
    if registry is None:
        from repro.api.problem import registered_processing

        fns: Iterable[ProcessingFn] = registered_processing().values()
    else:
        fns = registry
    return {p.name: verify_processing(p, weights) for p in fns}


def contract_findings(results: dict) -> list:
    """Flatten ``verify_registered`` output into Findings."""
    return [
        v.to_finding() for vs in results.values() for v in vs
    ]
