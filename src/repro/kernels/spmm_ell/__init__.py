from repro.kernels.spmm_ell.ops import aggregate_neighbors
from repro.kernels.spmm_ell.ref import spmm_ell_ref

__all__ = ["aggregate_neighbors", "spmm_ell_ref"]
