"""Pure-jnp oracle for spmm_ell."""

from __future__ import annotations

import jax.numpy as jnp


def spmm_ell_ref(x, col, wgt, op: str = "sum"):
    g = jnp.take(x, col, axis=0)  # (R, W, d)
    if op == "sum":
        return jnp.sum(g * wgt[..., None], axis=1)
    if op == "max":
        masked = jnp.where((wgt > 0)[..., None], g, -jnp.inf)
        return jnp.max(masked, axis=1)
    raise ValueError(op)
