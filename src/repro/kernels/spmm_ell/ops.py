"""Public op: ELL SpMM with padding and backend dispatch."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.spmm_ell.kernel import spmm_ell
from repro.kernels.spmm_ell.ref import spmm_ell_ref


def aggregate_neighbors(
    x: jax.Array,
    col: jax.Array,
    wgt: jax.Array,
    *,
    op: str = "sum",
    impl: str = "ref",
    block_rows: int = 128,
    block_feat: int = 128,
) -> jax.Array:
    """reduce_s x[col[r,s]] * wgt[r,s] with shape padding handled."""
    if impl == "ref":
        return spmm_ell_ref(x, col, wgt, op)
    R, W = col.shape
    n_x, d = x.shape
    pad_r = (-R) % block_rows
    pad_d = (-d) % block_feat
    if pad_d:
        x = jnp.pad(x, ((0, 0), (0, pad_d)))
    if pad_r:
        col = jnp.pad(col, ((0, pad_r), (0, 0)), constant_values=n_x - 1)
        wgt = jnp.pad(wgt, ((0, pad_r), (0, 0)))
    out = spmm_ell(
        x, col, wgt, op=op, block_rows=block_rows, block_feat=block_feat,
        interpret=(impl == "pallas_interpret"),
    )
    return out[:R, :d]
