"""Pallas TPU kernel: blocked ELL SpMM (GNN neighbor aggregation).

out[r, :] = reduce_s  X[col[r, s], :] * wgt[r, s]      reduce ∈ {sum, max}

This is the SpMM kernel regime of the assigned GNN architectures
(GIN/EGNN message passing; the paper's graph substrate shares the ELL
layout with the SSSP relax kernel — same tiles, different semiring).

TPU mapping: 2D grid (row blocks × feature blocks).  The feature
matrix is blocked along features only, so a (n_rows_x, BF) strip is
VMEM-resident per step; index/weight tiles are (BR, W).  The gather
produces a (BR, W, BF) VMEM intermediate reduced on the VPU.  BF=128
matches the lane width; BR is tuned so the strip fits VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(op: str):
    def kernel(x_ref, col_ref, wgt_ref, out_ref):
        x = x_ref[...]            # (n_x, BF) feature strip
        col = col_ref[...]        # (BR, W)
        wgt = wgt_ref[...]        # (BR, W)
        g = jnp.take(x, col, axis=0)              # (BR, W, BF)
        if op == "sum":
            out_ref[...] = jnp.sum(g * wgt[..., None], axis=1)
        elif op == "max":
            masked = jnp.where(
                (wgt > 0)[..., None], g, jnp.float32(-jnp.inf)
            )
            out_ref[...] = jnp.max(masked, axis=1)
        else:
            raise ValueError(op)

    return kernel


@functools.partial(
    jax.jit, static_argnames=("op", "block_rows", "block_feat", "interpret")
)
def spmm_ell(
    x: jax.Array,     # (n_x, d) f32 node features; row n_x-1 may be pad-zero
    col: jax.Array,   # (R, W) int32, padded entries -> pad row of x
    wgt: jax.Array,   # (R, W) f32 edge weights, 0 for padding
    *,
    op: str = "sum",
    block_rows: int = 128,
    block_feat: int = 128,
    interpret: bool = False,
) -> jax.Array:
    R, W = col.shape
    n_x, d = x.shape
    assert R % block_rows == 0 and d % block_feat == 0, (R, d)
    grid = (R // block_rows, d // block_feat)
    return pl.pallas_call(
        _make_kernel(op),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_x, block_feat), lambda i, j: (0, j)),
            pl.BlockSpec((block_rows, W), lambda i, j: (i, 0)),
            pl.BlockSpec((block_rows, W), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec(
            (block_rows, block_feat), lambda i, j: (i, j)
        ),
        out_shape=jax.ShapeDtypeStruct((R, d), jnp.float32),
        interpret=interpret,
    )(x, col, wgt)
