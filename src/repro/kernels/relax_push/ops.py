"""Public op: push-mode frontier relaxation with scatter handling.

``relax_push_rows(...)`` relaxes exactly the virtual rows named by a
compacted frontier index list and scatter-mins the candidates into an
(n_out,) buffer.  The Pallas kernel covers the gather/relax half (the
part that scales with F, streamed by scalar-prefetch DMA); the final
scatter-min runs as XLA's native scatter — Mosaic has no vector
scatter primitive, and at F·W elements the scatter is no longer the
hot spot.  ``impl='ref'`` is the pure-jnp oracle the distributed
engine inlines (same math, fusable inside shard_map)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.relax_push.kernel import relax_push_gather
from repro.kernels.relax_push.ref import relax_push_ref


def relax_push_rows(
    dist: jax.Array,
    row_idx: jax.Array,
    row_src: jax.Array,
    col: jax.Array,
    wgt: jax.Array,
    n_out: int,
    *,
    count=None,
    impl: str = "ref",   # 'ref' | 'pallas' | 'pallas_interpret'
) -> jax.Array:
    """(n_out,) scatter-min'd min-plus candidates of the listed rows."""
    if impl == "ref":
        return relax_push_ref(dist, row_idx, row_src, col, wgt, n_out)
    R = row_src.shape[0]
    if count is None:
        count = jnp.sum((row_idx >= 0) & (row_idx < R))
    cand = relax_push_gather(
        dist, row_idx, count, row_src, col, wgt,
        interpret=(impl == "pallas_interpret"),
    )
    colg = jnp.take(col, row_idx, axis=0, mode="fill", fill_value=n_out)
    buf = jnp.full((n_out + 1,), jnp.inf, dtype=jnp.float32)
    return buf.at[colg.reshape(-1)].min(cand.reshape(-1))[:n_out]
