"""Pallas TPU kernel: push-mode frontier relaxation (sparse SSSP hot
loop).

The dense pull kernel (kernels/relax_ell) reads ALL R virtual rows per
superstep; once the engine compacts the eligible class into a
fixed-capacity index list (core/frontier.py), the hot loop only needs
the F listed rows.  This kernel is the gather half of that push step:

    cand[f, :] = dist[row_src[idx[f]]] + wgt[idx[f], :]

TPU mapping (DESIGN.md hardware-adaptation): ``row_idx`` is a
*scalar-prefetched* operand (PrefetchScalarGridSpec, same idiom as
kernels/embedding_bag) — the BlockSpec index maps read ``idx[f]``, so
the DMA engine streams exactly the (1, W) col/wgt strips the frontier
names out of HBM while compute overlaps; rows the frontier does not
touch are never moved.  The distance vector stays VMEM-resident.
Slots past the live count are masked to +inf so the caller's
scatter-min (XLA's native scatter, which Mosaic lacks a vector
primitive for — see ops.relax_push_rows) treats them as padding.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _push_kernel(idx_ref, cnt_ref, dist_ref, src_ref, wgt_ref, out_ref):
    """One grid step: virtual row idx[f].  All tensor refs in VMEM."""
    f = pl.program_id(0)
    d = dist_ref[...]                      # (n_local+1,) resident
    s = d[src_ref[0]]                      # scalar source state
    cand = s + wgt_ref[...]                # (1, W) min-plus product
    out_ref[...] = jnp.where(f < cnt_ref[0], cand, jnp.inf)


@functools.partial(jax.jit, static_argnames=("interpret",))
def relax_push_gather(
    dist: jax.Array,     # (n_local+1,) f32; slot n_local = +inf dummy
    row_idx: jax.Array,  # (F,) int32 row ids (entries past `count` ignored)
    count,               # scalar int32: live prefix length of row_idx
    row_src: jax.Array,  # (R,) int32
    col: jax.Array,      # (R, W) int32 (unused here; shapes the frontier)
    wgt: jax.Array,      # (R, W) f32
    *,
    interpret: bool = False,
) -> jax.Array:
    """Returns (F, W) f32 candidates for the listed rows; masked slots
    carry +inf.  Callers scatter with the correspondingly gathered
    ``col`` rows (padding column annihilates either way)."""
    del col
    F = row_idx.shape[0]
    R, W = wgt.shape
    idx = jnp.clip(row_idx, 0, R - 1)  # fill sentinel R -> in-range block
    cnt = jnp.reshape(jnp.minimum(jnp.int32(count), jnp.int32(F)), (1,))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # row_idx, cnt
        grid=(F,),
        in_specs=[
            pl.BlockSpec(dist.shape, lambda f, idx, cnt: (0,)),  # resident
            pl.BlockSpec((1,), lambda f, idx, cnt: (idx[f],)),
            pl.BlockSpec((1, W), lambda f, idx, cnt: (idx[f], 0)),
        ],
        out_specs=pl.BlockSpec((1, W), lambda f, idx, cnt: (f, 0)),
    )
    return pl.pallas_call(
        _push_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((F, W), jnp.float32),
        interpret=interpret,
    )(idx, cnt, dist, row_src, wgt)
