from repro.kernels.relax_push.kernel import relax_push_gather
from repro.kernels.relax_push.ops import relax_push_rows
from repro.kernels.relax_push.ref import relax_push_ref

__all__ = ["relax_push_gather", "relax_push_rows", "relax_push_ref"]
