"""Pure-jnp oracle for the fused sparse-superstep relaxation."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_superstep_ref(
    dist: jax.Array,     # (n_local+1,) f32 source states; slot n_local = +inf
    row_idx: jax.Array,  # (F,) int32 virtual-row ids; fill sentinel >= R
    row_src: jax.Array,  # (R,) int32 local source per virtual row
    col: jax.Array,      # (R, W) int32 global destination ids (pad: n_out)
    wgt: jax.Array,      # (R, W) f32 weights (+inf padding)
    n_out: int,          # scatter buffer size (n_pad)
) -> jax.Array:
    """Min-plus relax of exactly the virtual rows in ``row_idx``,
    scatter-min'd into an (n_out+1,) candidate buffer — the same
    gather/relax/scatter the kernel fuses, staged through XLA ops.

    Out-of-range entries of ``row_idx`` (the compaction fill) gather
    the dummy source (state +inf) and the padding column n_out, so
    they annihilate in the scatter like padded ELL slots do.
    """
    n_loc = dist.shape[0] - 1
    srcg = jnp.take(row_src, row_idx, mode="fill", fill_value=n_loc)
    colg = jnp.take(col, row_idx, axis=0, mode="fill", fill_value=n_out)
    wgtg = jnp.take(wgt, row_idx, axis=0, mode="fill", fill_value=jnp.inf)
    cand = jnp.take(dist, srcg)[:, None] + wgtg
    buf = jnp.full((n_out + 1,), jnp.inf, dtype=jnp.float32)
    return buf.at[colg.reshape(-1)].min(cand.reshape(-1))
