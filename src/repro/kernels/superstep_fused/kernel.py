"""Pallas TPU kernel: fused sparse superstep relaxation (gather +
min-plus relax + scatter-min in ONE kernel launch).

The unfused sparse path (kernels/relax_push + XLA scatter) pays HBM
round-trips between its stages: the compaction's gathers materialize
(F, W) ``colg``/``srcg``/``wgtg`` buffers, the relax writes an (F, W)
candidate buffer, and a separate XLA scatter reads it all back to
build the (n_pad,) candidate array.  This kernel consumes the
compacted frontier (the eligibility fold + compaction output
``row_idx``/``count``) directly through the scalar-prefetch index
maps and produces the final candidate buffer in one launch:

    out[col[idx[f], w]] = min(out[...], dist[row_src[idx[f]]]
                                        + wgt[idx[f], w])

TPU mapping (DESIGN.md hardware-adaptation): ``row_idx`` and the live
count are scalar-prefetched (PrefetchScalarGridSpec, extending the
kernels/relax_push idiom) so the DMA engine streams exactly the
(1, W) col/wgt strips the frontier names; the distance vector and the
(n_pad+1,) output block stay VMEM-resident across grid steps (the
output BlockSpec index map is constant, so the block is *revisited*,
the standard Pallas accumulation pattern).  The scatter-min itself is
a sequential ``fori_loop`` over the W lane values — Mosaic has no
vector scatter primitive (see relax_push/ops.py), and W is the ELL
width (small by construction), so the serialization is bounded.

Exactness: min is associative and commutative in f32 (no NaNs here —
candidates are sums of non-negative finite values and +inf), so any
accumulation order produces bit-identical results to XLA's
``buf.at[col].min(cand)``; the engine's fused path is bit-identical
to the reference path by construction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fused_kernel(idx_ref, cnt_ref, dist_ref, src_ref, col_ref, wgt_ref,
                  out_ref):
    """One grid step: scatter-min virtual row idx[f] into the resident
    (n_out+1,) candidate block."""
    f = pl.program_id(0)

    @pl.when(f == 0)
    def _init():
        out_ref[...] = jnp.full(out_ref.shape, jnp.inf, jnp.float32)

    d = dist_ref[...]                      # (n_local+1,) resident
    s = d[src_ref[0]]                      # scalar source state
    live = f < cnt_ref[0]
    # slots past the live count carry +inf and annihilate in the min
    cand = jnp.where(live, s + wgt_ref[0, :], jnp.inf)   # (W,)
    cols = col_ref[0, :]                                 # (W,)

    def body(w, acc):
        c = cols[w]
        out_ref[c] = jnp.minimum(out_ref[c], cand[w])
        return acc

    jax.lax.fori_loop(
        jnp.int32(0), jnp.int32(cand.shape[0]), body, jnp.int32(0)
    )


@functools.partial(jax.jit, static_argnames=("n_out", "interpret"))
def fused_superstep(
    dist: jax.Array,     # (n_local+1,) f32; slot n_local = +inf dummy
    row_idx: jax.Array,  # (F,) int32 row ids (entries past `count` ignored)
    count,               # scalar int32: live prefix length of row_idx
    row_src: jax.Array,  # (R,) int32 local source per virtual row
    col: jax.Array,      # (R, W) int32 global destination ids (pad: n_out)
    wgt: jax.Array,      # (R, W) f32 weights (+inf padding)
    n_out: int,          # scatter buffer size (n_pad)
    *,
    interpret: bool = False,
) -> jax.Array:
    """Returns the (n_out+1,) f32 candidate buffer (slot ``n_out``
    swallows ELL padding columns; callers slice ``[:n_out]``).

    Out-of-range entries of ``row_idx`` (the compaction fill sentinel
    R) are clipped to a real block so the DMA index maps stay in
    range; their candidates are masked to +inf by the live count, so
    they contribute nothing — same invariant as relax_push_gather.
    """
    F = row_idx.shape[0]
    R, W = wgt.shape
    idx = jnp.clip(row_idx, 0, R - 1)  # fill sentinel R -> in-range block
    cnt = jnp.reshape(jnp.minimum(jnp.int32(count), jnp.int32(F)), (1,))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # row_idx, cnt
        grid=(F,),
        in_specs=[
            pl.BlockSpec(dist.shape, lambda f, idx, cnt: (0,)),  # resident
            pl.BlockSpec((1,), lambda f, idx, cnt: (idx[f],)),   # row_src
            pl.BlockSpec((1, W), lambda f, idx, cnt: (idx[f], 0)),  # col
            pl.BlockSpec((1, W), lambda f, idx, cnt: (idx[f], 0)),  # wgt
        ],
        # constant index map: the output block is revisited every grid
        # step (accumulation pattern) and written back once at the end
        out_specs=pl.BlockSpec((n_out + 1,), lambda f, idx, cnt: (0,)),
    )
    return pl.pallas_call(
        _fused_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_out + 1,), jnp.float32),
        interpret=interpret,
    )(idx, cnt, dist, row_src, col, wgt)
