from repro.kernels.superstep_fused.kernel import fused_superstep
from repro.kernels.superstep_fused.ref import fused_superstep_ref

__all__ = ["fused_superstep", "fused_superstep_ref"]
