from repro.kernels.relax_ell.ops import relax_rows
from repro.kernels.relax_ell.ref import relax_ell_ref

__all__ = ["relax_rows", "relax_ell_ref"]
