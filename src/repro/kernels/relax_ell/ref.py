"""Pure-jnp oracle for the relax_ell kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def relax_ell_ref(dist: jax.Array, col: jax.Array, wgt: jax.Array):
    """out[r] = min_s dist[col[r, s]] + wgt[r, s]."""
    return jnp.min(jnp.take(dist, col, axis=0) + wgt, axis=1)
