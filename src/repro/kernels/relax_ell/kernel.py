"""Pallas TPU kernel: blocked min-plus ELL relaxation (SSSP hot loop).

This is the vectorized form of the self-stabilizing rule R1 of the
paper's Algorithm 1:

    d(i) := min_{j ∈ N(i)} ( d(j) + w(i, j) )

in pull mode over a padded in-neighbor ELL adjacency.  It is the
per-superstep compute hot spot of the dense (chaotic / synchronous-
demon) sweep and the on-device half of every AGM relax step.

TPU mapping (DESIGN.md hardware-adaptation): rows are blocked to
``block_rows`` so that the (block_rows, width) index/weight tiles and
the gathered distance tile live in VMEM; the distance vector is kept
VMEM-resident as a single block (per-device vertex slices after the
1D partition are ≤ a few hundred thousand vertices — well inside
VMEM).  The gather `d[col]` is a VMEM-local vector gather; the min-
reduction along the width axis runs on the VPU (8x128 lanes), so
`width` should be a multiple of 8 and `block_rows` a multiple of 128
for full-lane utilization.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _relax_kernel(d_ref, col_ref, wgt_ref, out_ref):
    """One grid step: rows [i*BR, (i+1)*BR).  All refs in VMEM."""
    d = d_ref[...]          # (n_pad,)  distance vector (whole, resident)
    col = col_ref[...]      # (BR, W)   neighbor ids (padded -> n_pad)
    wgt = wgt_ref[...]      # (BR, W)   weights (padded -> +inf)
    gathered = jnp.take(d, col, axis=0)       # (BR, W) VMEM gather
    cand = gathered + wgt                      # min-plus product
    out_ref[...] = jnp.min(cand, axis=1)       # (BR,) VPU reduction


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret")
)
def relax_ell(
    dist: jax.Array,       # (n_pad + 1,) f32; slot n_pad = +inf pad target
    col: jax.Array,        # (R, W) int32 in-neighbor ids
    wgt: jax.Array,        # (R, W) f32, +inf padding
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Returns (R,) f32: min-plus reduction per row (no self term —
    callers combine with the current state via jnp.minimum)."""
    R, W = col.shape
    assert R % block_rows == 0, (R, block_rows)
    grid = (R // block_rows,)
    return pl.pallas_call(
        _relax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(dist.shape, lambda i: (0,)),          # resident
            pl.BlockSpec((block_rows, W), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, W), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((R,), jnp.float32),
        interpret=interpret,
    )(dist, col, wgt)
