"""Public op: min-plus ELL relaxation with padding/shape handling.

`relax_rows(...)` pads the row count to the block size, dispatches to
the Pallas kernel (TPU) or the jnp reference (CPU / correctness), and
strips the padding.  Backend selection is explicit so the distributed
engine and the dry-run (which must produce plain-XLA HLO) can choose.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.relax_ell.kernel import relax_ell
from repro.kernels.relax_ell.ref import relax_ell_ref


def relax_rows(
    dist: jax.Array,
    col: jax.Array,
    wgt: jax.Array,
    *,
    impl: str = "ref",        # 'ref' | 'pallas' | 'pallas_interpret'
    block_rows: int = 256,
) -> jax.Array:
    R, W = col.shape
    if impl == "ref":
        return relax_ell_ref(dist, col, wgt)
    pad = (-R) % block_rows
    if pad:
        n_pad = dist.shape[0] - 1
        col = jnp.concatenate(
            [col, jnp.full((pad, W), n_pad, dtype=col.dtype)]
        )
        wgt = jnp.concatenate(
            [wgt, jnp.full((pad, W), jnp.inf, dtype=wgt.dtype)]
        )
    out = relax_ell(
        dist, col, wgt,
        block_rows=block_rows,
        interpret=(impl == "pallas_interpret"),
    )
    return out[:R]
