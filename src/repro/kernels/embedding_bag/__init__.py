from repro.kernels.embedding_bag.ops import bag_pool
from repro.kernels.embedding_bag.ref import (
    embedding_bag_ref,
    embedding_bag_segment_ref,
)

__all__ = ["bag_pool", "embedding_bag_ref", "embedding_bag_segment_ref"]
