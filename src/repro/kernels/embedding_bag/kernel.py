"""Pallas TPU kernel: embedding-bag (ragged gather + weighted segment
sum) — the recsys hot path (MIND user-history pooling).

JAX has no native EmbeddingBag; this kernel is the TPU-native
formulation.  Unlike relax/spmm (whose operand strips are VMEM-
resident), the embedding table lives in HBM: a (1, d) table row per
grid step is DMA'd into VMEM, with the row *selected by a scalar-
prefetched index* (PrefetchScalarGridSpec) — the BlockSpec index map
reads `idx[b, l]`, so the DMA engine streams exactly the rows the
bags need while compute overlaps.  The output (1, d) bag block is
revisited across the L inner steps and accumulated in place.

Weighted sum; padding slots carry weight 0 (and index 0, a real row,
which the zero weight annihilates).  mean is a host-side divide in
ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(idx_ref, row_ref, w_ref, out_ref):
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += row_ref[...] * w_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag(
    table: jax.Array,    # (V, d) f32
    idx: jax.Array,      # (B, L) int32 rows per bag
    w: jax.Array,        # (B, L) f32 per-sample weights (0 = padding)
    *,
    interpret: bool = False,
) -> jax.Array:
    V, d = table.shape
    B, L = idx.shape
    grid = (B, L)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda b, l, idx_ref: (idx_ref[b, l], 0)),
            pl.BlockSpec((1, 1), lambda b, l, idx_ref: (b, l)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda b, l, idx_ref: (b, 0)),
    )
    return pl.pallas_call(
        _bag_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, d), jnp.float32),
        interpret=interpret,
    )(idx, table, w)
