"""Pure-jnp oracle for embedding_bag: take + weighted sum.

This is also the implementation pattern recommended for plain-XLA
paths (jnp.take + segment reduce), used by the MIND model when the
Pallas backend is off.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(table, idx, w):
    rows = jnp.take(table, idx, axis=0)  # (B, L, d)
    return jnp.sum(rows * w[..., None], axis=1)


def embedding_bag_segment_ref(table, flat_idx, segment_ids, w, num_bags):
    """Ragged formulation via segment_sum (CSR-style offsets upstream)."""
    rows = jnp.take(table, flat_idx, axis=0) * w[:, None]
    return jax.ops.segment_sum(rows, segment_ids, num_segments=num_bags)
