"""Public op: embedding bag (sum / mean) with backend dispatch."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.kernel import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref


def bag_pool(
    table: jax.Array,
    idx: jax.Array,
    mask: jax.Array,
    *,
    mode: str = "mean",
    impl: str = "ref",
) -> jax.Array:
    """Pool `table[idx]` per bag; `mask` marks valid slots."""
    w = mask.astype(jnp.float32)
    if impl == "ref":
        s = embedding_bag_ref(table, idx, w)
    else:
        s = embedding_bag(
            table, idx.astype(jnp.int32), w,
            interpret=(impl == "pallas_interpret"),
        )
    if mode == "sum":
        return s
    if mode == "mean":
        cnt = jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1.0)
        return s / cnt
    raise ValueError(mode)
