from repro.kernels.flash_attention.ops import mha
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["mha", "attention_ref"]
