"""Pallas TPU kernel: blockwise-softmax (flash) attention, causal GQA.

The LM hot spot for the five assigned transformer architectures.
Standard two-pass-free streaming softmax: for each (batch·q-head,
q-block), iterate kv-blocks keeping running max m, normalizer l and
accumulator acc in VMEM scratch; finalize on the last kv step.

TPU mapping: q/k/v tiles are (BQ, D)/(BK, D) with D = head_dim (128 —
MXU-aligned); the (BQ, BK) score tile hits the MXU, the running-stat
updates run on the VPU.  GQA is expressed in the BlockSpec index maps:
the kv operand's head index is q_head // group, so no KV replication
is materialized.  Causal masking is positionwise inside the
tile; tiles entirely above the diagonal skip compute via pl.when.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30  # python scalar: avoids a captured-constant in the kernel


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_i, l_i, *, scale,
                 causal, block_q, block_k, q_offset):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_i[...] = jnp.full_like(m_i, NEG_INF)
        l_i[...] = jnp.zeros_like(l_i)

    # absolute query positions are offset by (Sk - Sq) when the KV
    # prefix is longer than the query block (prefix/cross decode)
    q_start = qi * block_q + q_offset
    k_start = ki * block_k
    # visit only tiles that intersect the lower triangle when causal
    run = (k_start <= q_start + block_q - 1) if causal else (ki >= 0)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (BQ, D)
        k = k_ref[0].astype(jnp.float32)  # (BK, D)
        v = v_ref[0].astype(jnp.float32)  # (BK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (BQ, BK)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0
            )
            cols = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1
            )
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_i[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_i[...] = l_i[...] * alpha + jnp.sum(p, axis=1)
        acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_i[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_i[...], 1e-30)
        o_ref[0] = (acc[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,  # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    from jax.experimental.pallas import tpu as pltpu

    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk)
    scale = 1.0 / (D ** 0.5)

    grid = (B * Hq, Sq // block_q, Sk // block_k)

    def q_map(h, qi, ki):
        return (h, qi, 0)

    def kv_map(h, qi, ki):
        return (h // group, ki, 0)

    qr = q.reshape(B * Hq, Sq, D)
    kr = k.reshape(B * Hkv, Sk, D)
    vr = v.reshape(B * Hkv, Sk, D)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, q_offset=Sk - Sq,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_map),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_k, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, Hq, Sq, D)
