"""Public op: multi-head (GQA) attention with backend dispatch."""

from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def mha(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    impl: str = "ref",
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    if impl == "ref":
        return attention_ref(q, k, v, causal=causal)
    return flash_attention(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        interpret=(impl == "pallas_interpret"),
    )
