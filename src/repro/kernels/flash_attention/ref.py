"""Pure-jnp oracle for flash attention (materializes the score matrix)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    group = Hq // Hkv
    kf = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    qf = q.astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) / (D ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), dtype=bool), k=Sk - Sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)
