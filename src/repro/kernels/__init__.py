"""Pallas TPU kernels for the compute hot spots (validated on CPU in
interpret mode; `impl='pallas'` targets real TPUs).

relax_ell        min-plus ELL relaxation — the paper's rule R1 / SSSP hot loop
relax_push       push-mode frontier relaxation (sparse supersteps; the
                 scalar-prefetch gather of exactly the eligible rows)
spmm_ell         neighbor aggregation (GNN SpMM regime)
flash_attention  blockwise-softmax causal GQA (LM hot spot)
embedding_bag    scalar-prefetch ragged gather+reduce (recsys hot path)
"""

from repro.kernels.relax_ell import relax_rows
from repro.kernels.relax_push import relax_push_rows
from repro.kernels.spmm_ell import aggregate_neighbors
from repro.kernels.flash_attention import mha
from repro.kernels.embedding_bag import bag_pool

__all__ = [
    "relax_rows", "relax_push_rows", "aggregate_neighbors", "mha",
    "bag_pool",
]
