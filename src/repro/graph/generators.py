"""Synthetic graph generators used by the paper's evaluation.

RMAT1: Graph500 BFS-benchmark R-MAT (A=0.57, B=C=0.19, D=0.05),
       uniform random integer weights in [1, 100].
RMAT2: proposed Graph500 SSSP-benchmark R-MAT (A=0.50, B=C=0.10,
       D=0.30), weights in [1, 255].

Plus "real-world shaped" stand-ins for the SNAP graphs of Table I
(the container has no network access): a 2D grid with perturbed
weights (roadNet-CA: high diameter), and Watts-Strogatz small-world /
power-law R-MAT graphs (social networks: low diameter, skewed degree).
"""

from __future__ import annotations

import numpy as np

from repro.graph.formats import Graph


def _rmat_edges(
    scale: int,
    m: int,
    a: float,
    b: float,
    c: float,
    rng: np.random.Generator,
) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized R-MAT: decide one bit of (src, dst) per level."""
    n_bits = scale
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab = a + b
    abc = a + b + c
    for _ in range(n_bits):
        r = rng.random(m)
        src_bit = r >= ab
        dst_bit = (r >= a) & (r < ab) | (r >= abc)
        src = (src << 1) | src_bit
        dst = (dst << 1) | dst_bit
    return src.astype(np.int32), dst.astype(np.int32)


def rmat_graph(
    scale: int,
    edge_factor: int = 16,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    max_weight: int = 100,
    seed: int = 0,
    symmetrize: bool = True,
    name: str = "rmat",
) -> Graph:
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    src, dst = _rmat_edges(scale, m, a, b, c, rng)
    # Graph500 permutes vertex labels so locality is not an artifact of
    # the generator's bit recursion.
    perm = rng.permutation(n).astype(np.int32)
    src, dst = perm[src], perm[dst]
    w = rng.integers(1, max_weight + 1, size=m).astype(np.float32)
    g = Graph(n, src, dst, w, name=f"{name}_s{scale}")
    if symmetrize:
        g = g.symmetrized()
    return g.deduplicated()


def rmat1(scale: int, seed: int = 0, edge_factor: int = 16) -> Graph:
    """Graph500 BFS-spec R-MAT, weights 1..100 (paper's RMAT1)."""
    return rmat_graph(
        scale, edge_factor, a=0.57, b=0.19, c=0.19, max_weight=100,
        seed=seed, name="rmat1",
    )


def rmat2(scale: int, seed: int = 0, edge_factor: int = 16) -> Graph:
    """Graph500 SSSP-spec R-MAT, weights 1..255 (paper's RMAT2)."""
    return rmat_graph(
        scale, edge_factor, a=0.50, b=0.10, c=0.10, max_weight=255,
        seed=seed, name="rmat2",
    )


def grid_road_graph(side: int, seed: int = 0, max_weight: int = 100) -> Graph:
    """2D grid with random weights — a high-diameter road-network proxy
    (roadNet-CA in the paper has diameter 849)."""
    n = side * side
    rng = np.random.default_rng(seed)
    idx = np.arange(n, dtype=np.int32).reshape(side, side)
    right_src = idx[:, :-1].ravel()
    right_dst = idx[:, 1:].ravel()
    down_src = idx[:-1, :].ravel()
    down_dst = idx[1:, :].ravel()
    src = np.concatenate([right_src, down_src])
    dst = np.concatenate([right_dst, down_dst])
    w = rng.integers(1, max_weight + 1, size=src.shape[0]).astype(np.float32)
    return Graph(n, src, dst, w, name=f"grid_{side}x{side}").symmetrized()


def small_world_graph(
    n: int, k: int = 8, p: float = 0.1, seed: int = 0, max_weight: int = 100
) -> Graph:
    """Watts-Strogatz ring rewiring — low-diameter social-network proxy."""
    rng = np.random.default_rng(seed)
    base = np.arange(n, dtype=np.int64)
    srcs, dsts = [], []
    for off in range(1, k // 2 + 1):
        srcs.append(base)
        dsts.append((base + off) % n)
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    rewire = rng.random(src.shape[0]) < p
    dst = np.where(rewire, rng.integers(0, n, size=src.shape[0]), dst)
    w = rng.integers(1, max_weight + 1, size=src.shape[0]).astype(np.float32)
    g = Graph(n, src.astype(np.int32), dst.astype(np.int32), w,
              name=f"smallworld_{n}")
    return g.symmetrized().deduplicated()


def erdos_renyi_graph(
    n: int, avg_degree: float = 8.0, seed: int = 0, max_weight: int = 100
) -> Graph:
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree)
    src = rng.integers(0, n, size=m).astype(np.int32)
    dst = rng.integers(0, n, size=m).astype(np.int32)
    w = rng.integers(1, max_weight + 1, size=m).astype(np.float32)
    return Graph(n, src, dst, w, name=f"er_{n}").symmetrized().deduplicated()
