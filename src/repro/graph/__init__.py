"""Graph substrate: formats, generators, partitioning, sampling.

This package is the data layer for the AGM/EAGM engine (the paper's
contribution) and for the assigned GNN architectures.  Everything is
host-side numpy until `device_arrays()` / the partitioner hand padded,
fixed-shape buffers to JAX.
"""

from repro.graph.formats import (
    Graph, CSR, ELL, chain_fingerprint, clear_fingerprint_chain,
    coo_to_csr, csr_to_ell, graph_fingerprint,
)
from repro.graph.generators import (
    rmat_graph,
    rmat1,
    rmat2,
    grid_road_graph,
    small_world_graph,
    erdos_renyi_graph,
)
from repro.graph.partition import (
    PARTITIONER_KINDS,
    PartitionedGraph,
    canonical_partitioner,
    partition_1d,
    partition_graph,
)
from repro.graph.sampler import FanoutSampler, SampledBlock

__all__ = [
    "Graph",
    "CSR",
    "ELL",
    "coo_to_csr",
    "csr_to_ell",
    "graph_fingerprint",
    "chain_fingerprint",
    "clear_fingerprint_chain",
    "rmat_graph",
    "rmat1",
    "rmat2",
    "grid_road_graph",
    "small_world_graph",
    "erdos_renyi_graph",
    "PartitionedGraph",
    "partition_1d",
    "partition_graph",
    "canonical_partitioner",
    "PARTITIONER_KINDS",
    "FanoutSampler",
    "SampledBlock",
]
