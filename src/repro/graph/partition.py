"""1D vertex partitioning for the distributed AGM/EAGM engine.

Same distribution *mechanism* as the paper (§V): each rank stores the
out-edges of its owned vertices, contiguously in a padded per-rank
slot space.  The paper hardwires naive block partitioning (rank =
v // n_local); here the ownership map is a first-class, swappable
**relabeling partitioner**: a strategy computes a permutation ``perm``
of vertex ids into the padded slot space ``[0, P·n_local)`` and the
contiguous-slot engine runs unchanged on the relabeled graph.  The
engine stays completely partition-agnostic — every strategy produces
the same stacked-ELL buffer layout, only *which* vertex lands in which
(rank, slot) cell changes, and the facade un-permutes the final state
back to original vertex ids.

Strategies (``PARTITIONER_KINDS``):

* ``block`` — today's behavior, the identity relabeling (the paper's
  naive 1D distribution).
* ``shuffle:<seed>`` — pseudo-random relabeling; breaks adversarial
  id-locality (RMAT hubs cluster at low ids, so block gives one rank
  all the hubs) by spreading vertices uniformly over ranks.
* ``ebal`` — edge-balanced contiguous boundaries via a prefix sum of
  per-vertex virtual-row counts: boundaries are chosen so every rank
  gets ~the same number of ELL virtual rows, minimizing the stacked
  row count R = max over ranks (and hence the padding every rank pays
  on the dense relax path).
* ``degree`` — descending-degree striping: vertices sorted by degree
  round-robin over ranks, so hub rows spread evenly.

Because every ordering in the engine is a function of workitem
*values* (distances / levels), and min-plus relaxation is exact per
edge, the final un-permuted state is bit-identical across partitioners
— only the per-rank load balance (and, for spatially-scoped
orderings, the intermediate schedule) changes.

Two TPU-specific adaptations (unchanged from the seed):

* **Padded ELL with fat-row chunking.**  TPU programs need static
  shapes.  Rows are padded to a fixed width W; a vertex with degree
  > W is split into ceil(deg/W) *virtual rows* that share the same
  source vertex (``row_src``).  This doubles as straggler mitigation:
  no single hub vertex makes one device's relaxation row arbitrarily
  long — work per (virtual) row is bounded by W everywhere.

* **Uniform shapes across ranks.**  All per-rank buffers are padded to
  the max over ranks and stacked into leading-axis-P arrays so that
  ``shard_map`` can shard axis 0 over the device mesh.

Padding sentinels: ``col = n_pad`` (one past the last padded slot; the
scatter target array has one extra slot that is discarded) and
``weight = +inf`` (min-plus through it is a no-op).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.graph.formats import Graph, CSR, coo_to_csr, INF


def default_ell_width(avg_degree: float) -> int:
    """Power-of-two ELL width near 2x the average degree, in [4, 128]."""
    w = 1 << max(2, math.ceil(math.log2(max(1.0, 2.0 * avg_degree))))
    return int(min(128, w))


def chunk_fat_rows(
    csr: CSR, width: int, pad_col: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Split rows of ``csr`` into virtual rows of at most ``width``
    entries.  Returns (row_src, col, wgt) with shapes (R,), (R, width),
    (R, width)."""
    deg = (csr.row_ptr[1:] - csr.row_ptr[:-1]).astype(np.int64)
    chunks = np.maximum(1, -(-deg // width))  # ceil, >=1 so empty rows exist
    R = int(chunks.sum())
    row_src = np.repeat(np.arange(csr.n, dtype=np.int32), chunks)
    col = np.full((R, width), pad_col, dtype=np.int32)
    wgt = np.full((R, width), INF, dtype=np.float32)
    # For each edge, compute its (virtual_row, slot) position.
    row_start = np.zeros(csr.n + 1, dtype=np.int64)
    np.cumsum(chunks, out=row_start[1:])
    edge_row = np.repeat(np.arange(csr.n, dtype=np.int64), deg)
    edge_off = np.arange(csr.m, dtype=np.int64) - np.repeat(
        csr.row_ptr[:-1], deg
    )
    vrow = row_start[edge_row] + edge_off // width
    slot = edge_off % width
    col[vrow, slot] = csr.col_idx
    wgt[vrow, slot] = csr.weight
    return row_src, col, wgt


# ---------------------------------------------------------------------
# relabeling partitioners
# ---------------------------------------------------------------------

PARTITIONER_KINDS = ("block", "shuffle", "ebal", "degree")


def _suggest(word: str, choices) -> str:
    # late import: graph must stay importable before repro.core is
    from repro.core.ordering import suggest

    return suggest(word, choices)


def canonical_partitioner(spec: str) -> str:
    """Validate and canonicalize a partitioner spec: ``block`` |
    ``shuffle[:seed]`` | ``ebal`` | ``degree``.  Unknown kinds raise
    with a did-you-mean suggestion (EngineConfig error style);
    ``shuffle`` normalizes to ``shuffle:0`` so equal configs compare
    equal."""
    s = str(spec).strip().lower()
    if not s:
        raise ValueError(f"empty partitioner spec {spec!r}")
    kind, sep, arg = s.partition(":")
    kind = kind.strip()
    if kind not in PARTITIONER_KINDS:
        raise ValueError(
            f"unknown partitioner {spec!r}; valid kinds "
            f"{PARTITIONER_KINDS}{_suggest(kind, PARTITIONER_KINDS)}"
        )
    if kind == "shuffle":
        arg = arg.strip() or "0"
        try:
            seed = int(arg)
        except ValueError:
            raise ValueError(
                f"shuffle seed must be an integer: {spec!r}"
            ) from None
        if seed < 0:
            raise ValueError(
                f"shuffle seed must be non-negative: {spec!r}"
            )
        return f"shuffle:{seed}"
    if sep:
        raise ValueError(
            f"partitioner {kind!r} takes no argument (got {spec!r})"
        )
    return kind


@dataclasses.dataclass(frozen=True)
class Assignment:
    """A vertex→(rank, slot) ownership map, encoded as a permutation
    into the padded global slot space: vertex ``v`` lives at padded id
    ``perm[v]`` = ``rank · n_local + slot``.  Padded ids in
    ``[0, n_pad)`` not hit by ``perm`` are dummy slots (no vertex, no
    edges, state stays at ``worst``)."""

    n: int
    n_parts: int
    n_local: int
    perm: np.ndarray  # (n,) int64
    spec: str         # canonical partitioner spec

    @property
    def n_pad(self) -> int:
        return self.n_parts * self.n_local


def _positions(order: np.ndarray) -> np.ndarray:
    """Invert ``order``: position of each vertex in the sorted order.
    A contiguous even split reads this directly as the padded id
    (rank i // n_local, slot i % n_local)."""
    pos = np.empty(order.shape[0], dtype=np.int64)
    pos[order] = np.arange(order.shape[0], dtype=np.int64)
    return pos


def assign_vertices(
    g: Graph, n_parts: int, spec: str, width: int
) -> Assignment:
    """Compute the ownership permutation for ``spec`` (canonical form;
    see :func:`canonical_partitioner`)."""
    spec = canonical_partitioner(spec)
    kind, _, arg = spec.partition(":")
    n = g.n
    even_local = -(-n // n_parts)  # ceil

    if kind == "block":
        perm = np.arange(n, dtype=np.int64)
        return Assignment(n, n_parts, even_local, perm, spec)

    if kind == "shuffle":
        order = np.random.default_rng(int(arg)).permutation(n)
        return Assignment(n, n_parts, even_local, _positions(order), spec)

    deg = np.bincount(g.src, minlength=n).astype(np.int64)

    if kind == "degree":
        # descending-degree striping: sorted position i -> rank i % P,
        # slot i // P, so the heaviest rows round-robin over ranks
        pos = _positions(np.lexsort((np.arange(n), -deg)))
        perm = (pos % n_parts) * even_local + pos // n_parts
        return Assignment(n, n_parts, even_local, perm, spec)

    # ebal: contiguous boundaries balancing per-rank virtual-row counts
    # (the quantity the stacked ELL pads every rank to).  Boundaries by
    # prefix sum: rank p owns the id range whose cumulative row count
    # first reaches p/P of the total.
    rows = np.maximum(1, -(-deg // width))
    cum = np.cumsum(rows)
    total = int(cum[-1])
    targets = np.arange(1, n_parts) * (total / n_parts)
    bounds = np.searchsorted(cum, targets, side="left")
    bounds = np.concatenate([[0], bounds, [n]]).astype(np.int64)
    counts = np.diff(bounds)
    n_local = int(counts.max(initial=1))
    perm = np.empty(n, dtype=np.int64)
    for p in range(n_parts):
        lo, hi = int(bounds[p]), int(bounds[p + 1])
        perm[lo:hi] = p * n_local + np.arange(hi - lo, dtype=np.int64)
    return Assignment(n, n_parts, n_local, perm, spec)


# ---------------------------------------------------------------------
# partitioned graph
# ---------------------------------------------------------------------


@dataclasses.dataclass
class PartitionedGraph:
    """1D-partitioned graph with stacked per-rank ELL buffers.

    Shapes: ``row_src`` (P, R); ``col``/``wgt`` (P, R, W).
    Ownership: rank p owns the vertices whose padded id
    ``perm[v]`` falls in [p*n_local, (p+1)*n_local); for ``block``
    (``perm is None``, the identity) that is the classic
    [p*n_local, (p+1)*n_local) id range.
    ``col`` holds *padded global* destination ids; padding = n_pad.
    ``row_src`` holds *local* source slots (0..n_local-1); padded
    virtual rows point at local slot n_local (a dummy whose state is
    ``worst``).  This object is the single owner-mapping seam:
    :meth:`owner_slot`, :meth:`to_global` and :meth:`unpermute` are
    the only places vertex ids translate between the original and the
    padded space.
    """

    n: int            # real vertex count
    m: int            # real edge count
    n_parts: int
    n_local: int      # owned slots per rank (n_pad = P * n_local)
    width: int
    row_src: np.ndarray
    col: np.ndarray
    wgt: np.ndarray
    name: str = "pgraph"
    partitioner: str = "block"
    # relabeling permutation: original id -> padded global id.
    # None = identity (block), i.e. perm[v] == v.
    perm: Optional[np.ndarray] = None

    @property
    def n_pad(self) -> int:
        return self.n_parts * self.n_local

    @property
    def rows_per_rank(self) -> int:
        return int(self.row_src.shape[1])

    @property
    def inv_perm(self) -> np.ndarray:
        """(n_pad,) padded id -> original id, -1 on dummy slots."""
        inv = getattr(self, "_inv_perm", None)
        if inv is None:
            inv = np.full(self.n_pad, -1, dtype=np.int64)
            if self.perm is None:
                inv[: self.n] = np.arange(self.n, dtype=np.int64)
            else:
                inv[self.perm] = np.arange(self.n, dtype=np.int64)
            self._inv_perm = inv
        return inv

    # -- the owner-mapping seam ---------------------------------------

    def padded_id(self, v):
        """Original vertex id(s) -> padded global id(s)."""
        v = np.asarray(v)
        return v if self.perm is None else self.perm[v]

    def owner_slot(self, v):
        """Original vertex id(s) -> (rank, slot)."""
        pid = self.padded_id(v)
        return pid // self.n_local, pid % self.n_local

    def owner(self, v):
        return self.owner_slot(v)[0]

    def to_global(self, rank, slot):
        """(rank, slot) -> original vertex id, -1 for dummy slots."""
        pid = np.asarray(rank) * self.n_local + np.asarray(slot)
        return self.inv_perm[pid]

    def unpermute(self, padded_state: np.ndarray) -> np.ndarray:
        """(..., n_pad) padded-space state -> (..., n) original-id
        state.  The inverse of the relabeling: for ``block`` this is
        the classic ``[:n]`` truncation."""
        padded_state = np.asarray(padded_state)
        if self.perm is None:
            return padded_state[..., : self.n]
        return padded_state[..., self.perm]

    def same_layout(self, other: "PartitionedGraph") -> bool:
        """True iff states padded under ``self`` are valid under
        ``other`` (same shape AND same vertex→slot map) — the warm-
        restart compatibility check."""
        if (self.n, self.n_parts, self.n_local) != (
            other.n, other.n_parts, other.n_local
        ):
            return False
        if (self.perm is None) != (other.perm is None):
            return False
        return self.perm is None or bool(
            np.array_equal(self.perm, other.perm)
        )

    # -- load-balance statistics --------------------------------------

    def load_stats(self) -> dict:
        """Per-rank load balance: real edges and virtual rows per rank,
        ELL occupancy, and straggler ratios (max/mean — 1.0 is perfect
        balance; the dense relax path costs every rank the padded max,
        so ``straggler_rows`` is the padding overhead of the stacked
        ELL)."""
        edges = np.sum(self.col != self.n_pad, axis=(1, 2))
        rows = np.sum(self.row_src != self.n_local, axis=1)
        def _straggler(x):
            mean = float(np.mean(x))
            return float(np.max(x)) / mean if mean > 0 else 1.0
        return dict(
            edges_per_rank=[int(e) for e in edges],
            rows_per_rank=[int(r) for r in rows],
            max_rows=self.rows_per_rank,
            ell_occupancy=float(edges.sum()) / max(1, self.col.size),
            straggler_rows=_straggler(rows),
            straggler_edges=_straggler(edges),
        )

    def describe(self, stats: Optional[dict] = None) -> str:
        st = stats if stats is not None else self.load_stats()
        return (
            f"{self.name}: n={self.n} m={self.m} P={self.n_parts} "
            f"n_local={self.n_local} rows/rank={self.rows_per_rank} "
            f"W={self.width} ell_density={st['ell_occupancy']:.3f} "
            f"partition={self.partitioner} "
            f"straggler={st['straggler_rows']:.2f}"
        )


def partition_graph(
    g: Graph,
    n_parts: int,
    width: Optional[int] = None,
    partitioner: str = "block",
    name: Optional[str] = None,
) -> PartitionedGraph:
    """Partition ``g`` over ``n_parts`` ranks under a relabeling
    strategy (see module docstring).  The returned buffers are in the
    padded relabeled space; the :class:`PartitionedGraph` carries the
    permutation for translating back."""
    spec = canonical_partitioner(partitioner)
    if width is None:
        width = default_ell_width(g.m / max(1, g.n))
    asn = assign_vertices(g, n_parts, spec, width)
    n_local, n_pad = asn.n_local, asn.n_pad

    # Relabeled graph over the padded id space: dummy slots are real
    # (degree-0) vertices here, so per-rank CSR slicing is uniform.
    perm32 = asn.perm.astype(np.int32)
    g2 = Graph(
        n_pad, perm32[g.src], perm32[g.dst], g.weight, name=g.name
    )
    csr_all = coo_to_csr(g2)
    # real vertices occupy a contiguous slot prefix [0, counts[p]) on
    # every rank (all strategies assign positionally); dummy tail slots
    # get no virtual rows at all — they have no edges and a row each
    # would defeat ebal's row balancing.
    counts = np.bincount(
        asn.perm // n_local, minlength=n_parts
    ).astype(np.int64)

    per_rank = []
    for p in range(n_parts):
        lo, hi = p * n_local, p * n_local + int(counts[p])
        row_ptr = csr_all.row_ptr[lo : hi + 1] - csr_all.row_ptr[lo]
        sl = slice(csr_all.row_ptr[lo], csr_all.row_ptr[hi])
        local = CSR(
            hi - lo, row_ptr, csr_all.col_idx[sl], csr_all.weight[sl]
        )
        per_rank.append(chunk_fat_rows(local, width, pad_col=n_pad))

    R = max(rs.shape[0] for rs, _, _ in per_rank)
    P = n_parts
    row_src = np.full((P, R), n_local, dtype=np.int32)  # pad -> dummy slot
    col = np.full((P, R, width), n_pad, dtype=np.int32)
    wgt = np.full((P, R, width), INF, dtype=np.float32)
    for p, (rs, c, w) in enumerate(per_rank):
        row_src[p, : rs.shape[0]] = rs
        col[p, : c.shape[0]] = c
        wgt[p, : w.shape[0]] = w

    return PartitionedGraph(
        n=g.n, m=g.m, n_parts=P, n_local=n_local, width=width,
        row_src=row_src, col=col, wgt=wgt, name=name or g.name,
        partitioner=spec, perm=None if spec == "block" else asn.perm,
    )


def partition_1d(
    g: Graph, n_parts: int, width: int | None = None, name: str | None = None
) -> PartitionedGraph:
    """Block 1D partitioning (the paper's §V distribution) — kept as
    the stable name for the identity-relabeling strategy."""
    return partition_graph(g, n_parts, width=width, partitioner="block",
                           name=name)
