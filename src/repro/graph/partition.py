"""1D vertex partitioning for the distributed AGM/EAGM engine.

Same distribution as the paper (§V): vertices are block-partitioned
over P ranks, each rank stores the out-edges of its owned vertices.
Two TPU-specific adaptations:

* **Padded ELL with fat-row chunking.**  TPU programs need static
  shapes.  Rows are padded to a fixed width W; a vertex with degree
  > W is split into ceil(deg/W) *virtual rows* that share the same
  source vertex (``row_src``).  This doubles as straggler mitigation:
  no single hub vertex makes one device's relaxation row arbitrarily
  long — work per (virtual) row is bounded by W everywhere.

* **Uniform shapes across ranks.**  All per-rank buffers are padded to
  the max over ranks and stacked into leading-axis-P arrays so that
  ``shard_map`` can shard axis 0 over the device mesh.

Padding sentinels: ``col = n_pad`` (one past the last real vertex; the
scatter target array has one extra slot that is discarded) and
``weight = +inf`` (min-plus through it is a no-op).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.graph.formats import Graph, CSR, coo_to_csr, INF


def default_ell_width(avg_degree: float) -> int:
    """Power-of-two ELL width near 2x the average degree, in [4, 128]."""
    w = 1 << max(2, math.ceil(math.log2(max(1.0, 2.0 * avg_degree))))
    return int(min(128, w))


def chunk_fat_rows(
    csr: CSR, width: int, pad_col: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Split rows of ``csr`` into virtual rows of at most ``width``
    entries.  Returns (row_src, col, wgt) with shapes (R,), (R, width),
    (R, width)."""
    deg = (csr.row_ptr[1:] - csr.row_ptr[:-1]).astype(np.int64)
    chunks = np.maximum(1, -(-deg // width))  # ceil, >=1 so empty rows exist
    R = int(chunks.sum())
    row_src = np.repeat(np.arange(csr.n, dtype=np.int32), chunks)
    col = np.full((R, width), pad_col, dtype=np.int32)
    wgt = np.full((R, width), INF, dtype=np.float32)
    # For each edge, compute its (virtual_row, slot) position.
    row_start = np.zeros(csr.n + 1, dtype=np.int64)
    np.cumsum(chunks, out=row_start[1:])
    edge_row = np.repeat(np.arange(csr.n, dtype=np.int64), deg)
    edge_off = np.arange(csr.m, dtype=np.int64) - np.repeat(
        csr.row_ptr[:-1], deg
    )
    vrow = row_start[edge_row] + edge_off // width
    slot = edge_off % width
    col[vrow, slot] = csr.col_idx
    wgt[vrow, slot] = csr.weight
    return row_src, col, wgt


@dataclasses.dataclass
class PartitionedGraph:
    """Block 1D-partitioned graph with stacked per-rank ELL buffers.

    Shapes: ``row_src`` (P, R); ``col``/``wgt`` (P, R, W).
    Ownership: rank p owns global vertices [p*n_local, (p+1)*n_local).
    ``col`` holds *global* destination ids; padded entries = n_pad.
    ``row_src`` holds *local* source ids (0..n_local-1); padded virtual
    rows point at local slot n_local (a dummy whose distance is inf).
    """

    n: int            # real vertex count
    m: int            # real edge count
    n_parts: int
    n_local: int      # owned vertices per rank (n_pad = P * n_local)
    width: int
    row_src: np.ndarray
    col: np.ndarray
    wgt: np.ndarray
    name: str = "pgraph"

    @property
    def n_pad(self) -> int:
        return self.n_parts * self.n_local

    @property
    def rows_per_rank(self) -> int:
        return int(self.row_src.shape[1])

    def owner(self, v: np.ndarray) -> np.ndarray:
        return v // self.n_local

    def describe(self) -> str:
        real = int(np.sum(self.col != self.n_pad))
        dens = real / max(1, self.col.size)
        return (
            f"{self.name}: n={self.n} m={self.m} P={self.n_parts} "
            f"n_local={self.n_local} rows/rank={self.rows_per_rank} "
            f"W={self.width} ell_density={dens:.3f}"
        )


def partition_1d(
    g: Graph, n_parts: int, width: int | None = None, name: str | None = None
) -> PartitionedGraph:
    csr_all = coo_to_csr(g)
    if width is None:
        width = default_ell_width(g.m / max(1, g.n))
    n_local = -(-g.n // n_parts)
    n_pad = n_parts * n_local

    per_rank = []
    for p in range(n_parts):
        # tail ranks may own no real vertices at all (n < p*n_local)
        lo = min(p * n_local, g.n)
        hi = min((p + 1) * n_local, g.n)
        # Local CSR over owned rows (possibly fewer than n_local at tail).
        row_ptr = csr_all.row_ptr[lo : hi + 1] - csr_all.row_ptr[lo]
        # pad tail rows (empty)
        if hi - lo < n_local:
            row_ptr = np.concatenate(
                [row_ptr, np.full(n_local - (hi - lo), row_ptr[-1])]
            )
        sl = slice(csr_all.row_ptr[lo], csr_all.row_ptr[hi])
        local = CSR(n_local, row_ptr, csr_all.col_idx[sl], csr_all.weight[sl])
        per_rank.append(chunk_fat_rows(local, width, pad_col=n_pad))

    R = max(rs.shape[0] for rs, _, _ in per_rank)
    P = n_parts
    row_src = np.full((P, R), n_local, dtype=np.int32)  # pad -> dummy slot
    col = np.full((P, R, width), n_pad, dtype=np.int32)
    wgt = np.full((P, R, width), INF, dtype=np.float32)
    for p, (rs, c, w) in enumerate(per_rank):
        row_src[p, : rs.shape[0]] = rs
        col[p, : c.shape[0]] = c
        wgt[p, : w.shape[0]] = w

    return PartitionedGraph(
        n=g.n, m=g.m, n_parts=P, n_local=n_local, width=width,
        row_src=row_src, col=col, wgt=wgt, name=name or g.name,
    )
