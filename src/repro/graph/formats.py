"""Graph storage formats: COO (edge list), CSR, ELL.

The paper (§V) stores the local graph in CSR with a 1D vertex
distribution.  On TPU we additionally need fixed-shape, padded buffers,
so the distributed engine consumes ELL (padded CSR rows).  Padding
sentinels: column index ``n`` (one past the last vertex — targets index
into a length ``n+1`` scratch array whose last slot is discarded) and
weight ``+inf`` so min-plus relaxation through a padded slot is a no-op.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Optional

import numpy as np

# Edge weights are float32 everywhere; +inf is the "unreachable" value.
INF = np.float32(np.inf)


@dataclasses.dataclass
class Graph:
    """A weighted directed graph in COO (edge-list) form, host-side.

    ``src``/``dst`` are int32 arrays of shape (m,), ``weight`` float32
    of shape (m,).  Vertices are 0..n-1.
    """

    n: int
    src: np.ndarray
    dst: np.ndarray
    weight: np.ndarray
    name: str = "graph"

    @property
    def m(self) -> int:
        return int(self.src.shape[0])

    def __post_init__(self):
        self.src = np.asarray(self.src, dtype=np.int32)
        self.dst = np.asarray(self.dst, dtype=np.int32)
        self.weight = np.asarray(self.weight, dtype=np.float32)
        assert self.src.shape == self.dst.shape == self.weight.shape

    def symmetrized(self) -> "Graph":
        """Add reverse edges (Graph500 graphs are treated as undirected)."""
        src = np.concatenate([self.src, self.dst])
        dst = np.concatenate([self.dst, self.src])
        w = np.concatenate([self.weight, self.weight])
        return Graph(self.n, src, dst, w, name=self.name + "+sym")

    def deduplicated(self) -> "Graph":
        """Keep the minimum-weight edge per (src, dst) pair, drop self loops."""
        keep = self.src != self.dst
        src, dst, w = self.src[keep], self.dst[keep], self.weight[keep]
        key = src.astype(np.int64) * np.int64(self.n) + dst.astype(np.int64)
        order = np.lexsort((w, key))
        key, src, dst, w = key[order], src[order], dst[order], w[order]
        first = np.ones(key.shape[0], dtype=bool)
        first[1:] = key[1:] != key[:-1]
        return Graph(self.n, src[first], dst[first], w[first], name=self.name)

    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.n).astype(np.int32)


@dataclasses.dataclass
class CSR:
    """Compressed sparse row adjacency: out-edges of each vertex."""

    n: int
    row_ptr: np.ndarray  # (n+1,) int64
    col_idx: np.ndarray  # (m,) int32
    weight: np.ndarray  # (m,) float32

    @property
    def m(self) -> int:
        return int(self.col_idx.shape[0])

    def neighbors(self, v: int):
        lo, hi = self.row_ptr[v], self.row_ptr[v + 1]
        return self.col_idx[lo:hi], self.weight[lo:hi]

    def max_degree(self) -> int:
        return int(np.max(self.row_ptr[1:] - self.row_ptr[:-1], initial=0))


@dataclasses.dataclass
class ELL:
    """ELLPACK: every row padded to a fixed width.

    ``col`` (n_rows, width) int32 — padded entries point at ``pad_col``
    (= global n, one past the real vertices).  ``weight`` padded with inf.
    """

    n_rows: int
    width: int
    col: np.ndarray  # (n_rows, width) int32
    weight: np.ndarray  # (n_rows, width) float32
    pad_col: int

    def density(self) -> float:
        real = int(np.sum(self.col != self.pad_col))
        return real / max(1, self.n_rows * self.width)


def graph_fingerprint(g: Graph, *, full: bool = False) -> tuple:
    """Cheap content token so in-place edge mutation (the perturbation
    idiom) invalidates derived-buffer memos (partitions, transpose
    ELLs) instead of silently reusing stale data.  CRC over the COO
    arrays — one pass, no copy, negligible next to a solve.  (Not
    xor-reduce: a uniform transformation like ``weight *= 2`` flips
    the same bit in every element and cancels out of xor whenever the
    count is even.)

    A graph that carries a hash-chain token (maintained by
    :func:`chain_fingerprint` — the streaming-update path) returns it
    directly instead of rehashing the full edge list per lookup;
    ``full=True`` forces the O(m) rehash (the oracle the chain is
    tested against).  The chain is only valid while every mutation
    goes through :func:`chain_fingerprint`; code that mutates edge
    arrays directly must call :func:`clear_fingerprint_chain` first.
    """
    if not full:
        chain = getattr(g, "_fp_chain", None)
        if chain is not None:
            return chain
    crc = 0
    for arr in (g.src, g.dst, g.weight):
        crc = zlib.crc32(memoryview(np.ascontiguousarray(arr)), crc)
    return (g.n, g.m, crc)


def chain_fingerprint(g: Graph, record: bytes) -> tuple:
    """Extend ``g``'s fingerprint by one update record *incrementally*:
    the new token is a CRC chained over (previous token, record), an
    O(len(record)) step instead of the O(m) full rehash — the seam the
    streaming edge-update feed (``repro.serve.updates``) uses so a
    long-lived service doesn't rehash the edge list per update.

    Call AFTER applying the mutation the record describes (the token
    covers ``g.m``/``g.n`` as mutated).  Chained tokens live in a
    different value space than full-rehash tokens on purpose: the two
    must never collide for graphs with different histories, and any
    chained token differs from the unchained token of the same arrays
    (the chain is seeded with the pre-update token, which covers the
    pre-update bytes).  Returns the new token and installs it on ``g``
    so subsequent :func:`graph_fingerprint` lookups are O(1).
    """
    prev = graph_fingerprint(g)  # chain if present, else full rehash
    crc = zlib.crc32(repr(prev).encode(), 0)
    crc = zlib.crc32(record, crc)
    token = (g.n, g.m, crc, "chain")
    g._fp_chain = token
    return token


def clear_fingerprint_chain(g: Graph) -> None:
    """Drop a chained fingerprint (next lookup rehashes) — required
    before mutating edge arrays outside the update-record path."""
    if hasattr(g, "_fp_chain"):
        del g._fp_chain


def coo_to_csr(g: Graph) -> CSR:
    order = np.argsort(g.src, kind="stable")
    src, dst, w = g.src[order], g.dst[order], g.weight[order]
    counts = np.bincount(src, minlength=g.n)
    row_ptr = np.zeros(g.n + 1, dtype=np.int64)
    np.cumsum(counts, out=row_ptr[1:])
    return CSR(g.n, row_ptr, dst.astype(np.int32), w.astype(np.float32))


def csr_to_ell(
    csr: CSR,
    width: Optional[int] = None,
    pad_col: Optional[int] = None,
) -> ELL:
    """Pad CSR rows to ``width``.  Rows longer than ``width`` raise —
    callers chunk fat rows first (see partition.chunk_fat_rows)."""
    deg = (csr.row_ptr[1:] - csr.row_ptr[:-1]).astype(np.int64)
    w_req = int(deg.max(initial=0))
    if width is None:
        width = max(1, w_req)
    if w_req > width:
        raise ValueError(f"max degree {w_req} exceeds ELL width {width}")
    if pad_col is None:
        pad_col = csr.n
    col = np.full((csr.n, width), pad_col, dtype=np.int32)
    wgt = np.full((csr.n, width), INF, dtype=np.float32)
    # vectorized row-major fill
    rows = np.repeat(np.arange(csr.n, dtype=np.int64), deg)
    offs = np.arange(csr.m, dtype=np.int64) - np.repeat(csr.row_ptr[:-1], deg)
    col[rows, offs] = csr.col_idx
    wgt[rows, offs] = csr.weight
    return ELL(csr.n, width, col, wgt, pad_col)
