"""Host-side fanout neighbor sampler (GraphSAGE-style) for the GNN
``minibatch_lg`` shape cells.

Given seed nodes and per-layer fanouts (e.g. [15, 10]), builds a
layered block: layer l samples up to ``fanout[l]`` neighbors of every
frontier node.  The device step consumes *padded, fixed-shape* arrays
(src/dst indices into the block's node list plus a validity mask), so
the same jitted GNN step serves every minibatch.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.graph.formats import Graph, CSR, coo_to_csr


@dataclasses.dataclass
class SampledBlock:
    """A layered minibatch block.

    nodes:      (n_nodes_pad,) int32 global ids of all block nodes
                (seeds first), padded with 0 beyond ``n_nodes``.
    node_mask:  (n_nodes_pad,) bool validity.
    edge_src/edge_dst: (n_edges_pad,) int32 *block-local* indices.
    edge_mask:  (n_edges_pad,) bool validity.
    edge_layer: (n_edges_pad,) int8 which hop the edge belongs to.
    n_seeds:    number of seed (output) nodes = first n_seeds of nodes.
    """

    nodes: np.ndarray
    node_mask: np.ndarray
    edge_src: np.ndarray
    edge_dst: np.ndarray
    edge_mask: np.ndarray
    edge_layer: np.ndarray
    n_seeds: int
    n_nodes: int
    n_edges: int


class FanoutSampler:
    """Uniform without-replacement fanout sampling over a CSR graph."""

    def __init__(self, graph: Graph, fanouts: Sequence[int], seed: int = 0):
        self.csr: CSR = coo_to_csr(graph)
        self.fanouts = list(fanouts)
        self.rng = np.random.default_rng(seed)

    def padded_sizes(self, batch_nodes: int) -> tuple[int, int]:
        """Static (n_nodes_pad, n_edges_pad) for a given seed count."""
        n_nodes = batch_nodes
        n_edges = 0
        frontier = batch_nodes
        for f in self.fanouts:
            n_edges += frontier * f
            frontier = frontier * f
            n_nodes += frontier
        return n_nodes, n_edges

    def sample(self, seeds: np.ndarray) -> SampledBlock:
        seeds = np.asarray(seeds, dtype=np.int32)
        n_nodes_pad, n_edges_pad = self.padded_sizes(seeds.shape[0])

        # block-local node table: seeds first, then per-layer samples
        nodes = [seeds]
        local_of = {int(v): i for i, v in enumerate(seeds)}
        e_src, e_dst, e_layer = [], [], []
        frontier_local = np.arange(seeds.shape[0], dtype=np.int32)
        frontier_global = seeds

        for layer, fan in enumerate(self.fanouts):
            new_src, new_dst_global = [], []
            for lidx, v in zip(frontier_local, frontier_global):
                lo, hi = self.csr.row_ptr[v], self.csr.row_ptr[v + 1]
                deg = int(hi - lo)
                if deg == 0:
                    continue
                take = min(fan, deg)
                pick = self.rng.choice(deg, size=take, replace=False)
                nbrs = self.csr.col_idx[lo + pick]
                new_src.extend([int(lidx)] * take)
                new_dst_global.extend(int(u) for u in nbrs)
            # register new nodes
            dst_local = []
            next_frontier_local, next_frontier_global = [], []
            for u in new_dst_global:
                if u not in local_of:
                    local_of[u] = sum(len(a) for a in nodes) + len(
                        next_frontier_global
                    )
                    next_frontier_global.append(u)
                    next_frontier_local.append(local_of[u])
                dst_local.append(local_of[u])
            if next_frontier_global:
                nodes.append(np.asarray(next_frontier_global, dtype=np.int32))
            e_src.extend(new_src)
            e_dst.extend(dst_local)
            e_layer.extend([layer] * len(new_src))
            frontier_local = np.asarray(next_frontier_local, dtype=np.int32)
            frontier_global = np.asarray(next_frontier_global, dtype=np.int32)
            if frontier_global.size == 0:
                break

        all_nodes = np.concatenate(nodes) if nodes else seeds
        n_nodes = int(all_nodes.shape[0])
        n_edges = len(e_src)

        out_nodes = np.zeros(n_nodes_pad, dtype=np.int32)
        out_nodes[:n_nodes] = all_nodes
        node_mask = np.zeros(n_nodes_pad, dtype=bool)
        node_mask[:n_nodes] = True
        edge_src = np.zeros(n_edges_pad, dtype=np.int32)
        edge_dst = np.zeros(n_edges_pad, dtype=np.int32)
        edge_mask = np.zeros(n_edges_pad, dtype=bool)
        edge_layer = np.zeros(n_edges_pad, dtype=np.int8)
        edge_src[:n_edges] = e_src
        edge_dst[:n_edges] = e_dst
        edge_mask[:n_edges] = True
        edge_layer[:n_edges] = e_layer

        return SampledBlock(
            nodes=out_nodes, node_mask=node_mask, edge_src=edge_src,
            edge_dst=edge_dst, edge_mask=edge_mask, edge_layer=edge_layer,
            n_seeds=int(seeds.shape[0]), n_nodes=n_nodes, n_edges=n_edges,
        )
