"""repro: AGM/EAGM distributed graph algorithms (Kanewala et al. 2017)
as a multi-pod JAX framework, plus the assigned architecture zoo.

Public entry point: ``repro.api`` (Problem/Solver facade —
compile-once engines, batched sources, warm restarts).

Subpackages: api (facade), core (the paper), graph, kernels (Pallas),
models, train, data, configs (--arch registry), launch, roofline.
"""

__version__ = "1.2.0"
