"""repro: AGM/EAGM distributed graph algorithms (Kanewala et al. 2017)
as a multi-pod JAX framework, plus the assigned architecture zoo.

Subpackages: core (the paper), graph, kernels (Pallas), models,
train, data, configs (--arch registry), launch, roofline.
"""

__version__ = "1.0.0"
