"""Int8 gradient compression with error feedback.

Two uses in the framework:

1. **Grad-accumulation compression** (wired into train_step): the
   microbatch gradient accumulator is kept in int8 + per-tensor scale
   with an fp32 error-feedback buffer, cutting accumulator memory
   bandwidth ~4x for long accumulation chains.

2. **Cross-pod reduce compression** (`compressed_psum`, for
   shard_map'd training loops): quantize → psum int32 → dequantize,
   with the quantization error fed back next round — the standard
   error-feedback trick that keeps convergence unaffected while the
   pod-to-pod (DCN) all-reduce moves 4x fewer bytes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x, scale=None):
    """Per-tensor symmetric int8.  Returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    if scale is None:
        scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress(grad, error):
    """Error-feedback compression of one tensor.
    Returns (q, scale, new_error)."""
    corrected = grad.astype(jnp.float32) + error
    q, scale = quantize_int8(corrected)
    new_error = corrected - dequantize_int8(q, scale)
    return q, scale, new_error


def ef_compress_tree(grads, errors):
    """Pytree error-feedback compression.
    Returns (quantized dict {q, scale}, new errors)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    err_leaves = treedef.flatten_up_to(errors)
    qs, scales, new_err = [], [], []
    for g, e in zip(leaves, err_leaves):
        q, s, ne = ef_compress(g, e)
        qs.append(q)
        scales.append(s)
        new_err.append(ne)
    return (
        {
            "q": treedef.unflatten(qs),
            "scale": treedef.unflatten(scales),
        },
        treedef.unflatten(new_err),
    )


def dequantize_tree(comp):
    return jax.tree_util.tree_map(
        dequantize_int8, comp["q"], comp["scale"]
    )


def init_error_tree(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def compressed_psum(grads, errors, axis_name):
    """Error-feedback int8 psum for shard_map'd reductions: each
    device quantizes its local contribution, the int8 payloads are
    summed (accumulate in int32), then dequantized with the mean
    scale.  Residual goes to the error buffer."""

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize_int8(corrected)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        scale_mean = jax.lax.pmean(scale, axis_name)
        reduced = total.astype(jnp.float32) * scale_mean
        new_e = corrected - dequantize_int8(q, scale)
        return reduced, new_e

    leaves, treedef = jax.tree_util.tree_flatten(grads)
    errs = treedef.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(leaves, errs)]
    return (
        treedef.unflatten([o[0] for o in out]),
        treedef.unflatten([o[1] for o in out]),
    )
