"""Sharded, atomic, elastic checkpointing.

Layout:
    <dir>/step_<N>/manifest.json     tree structure + shapes/dtypes +
                                     mesh/sharding metadata
    <dir>/step_<N>/<leaf_path>.npy   one file per pytree leaf
    <dir>/LATEST                     text file with the newest step

Atomicity: the step directory is written as ``.tmp-step_<N>`` and
``os.rename``d into place, then LATEST is updated (rename is atomic on
POSIX) — a crashed writer can never leave a half checkpoint visible.

Elasticity: ``restore`` re-places leaves with ``jax.device_put``
against the *current* mesh/sharding (which may differ from the mesh
at save time — e.g. resume a 512-chip run on 256 chips) as long as
logical shapes match.  The manifest records the saving mesh for
validation/telemetry.

Async: ``save_async`` snapshots to host memory synchronously (cheap)
and writes files on a daemon thread, overlapping I/O with compute;
``wait()`` joins before the next save to bound dirty state.

Multi-host note: in a real multi-controller pod each host writes only
the shards it owns (``leaf.addressable_shards``); the container runs a
single process so full-array writes are exact here.
"""

from __future__ import annotations

import json
import os
import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"

# numpy cannot natively serialize ml_dtypes (bfloat16, fp8...): store
# them as same-width unsigned views and restore via the manifest dtype
_VIEW_FOR_ITEMSIZE = {1: np.uint8, 2: np.uint16, 4: np.uint32}


def _to_storable(v: np.ndarray) -> np.ndarray:
    if v.dtype.kind in "fiub?":
        return v
    return v.view(_VIEW_FOR_ITEMSIZE[v.dtype.itemsize])


def _from_storable(v: np.ndarray, dtype_str: str) -> np.ndarray:
    want = jnp.dtype(dtype_str)
    if v.dtype == want:
        return v
    return v.view(want)


def _flatten_with_paths(tree) -> dict:
    flat = {}

    def rec(node, path):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(node[k], path + [str(k)])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(v, path + [str(i)])
        else:
            flat[_SEP.join(path)] = node

    rec(tree, [])
    return flat


def _tree_structure(tree):
    if isinstance(tree, dict):
        return {k: _tree_structure(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return [_tree_structure(v) for v in tree]
    return None  # leaf marker


def _unflatten(structure, flat, path=()):
    if isinstance(structure, dict):
        return {
            k: _unflatten(v, flat, path + (str(k),))
            for k, v in structure.items()
        }
    if isinstance(structure, list):
        return [
            _unflatten(v, flat, path + (str(i),))
            for i, v in enumerate(structure)
        ]
    return flat[_SEP.join(path)]


class Checkpointer:
    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ---------- write ----------

    def save(self, step: int, tree) -> str:
        self.wait()
        host = {
            k: np.asarray(v) for k, v in _flatten_with_paths(tree).items()
        }
        return self._write(step, host, _tree_structure(tree))

    def save_async(self, step: int, tree) -> None:
        self.wait()
        host = {
            k: np.asarray(v) for k, v in _flatten_with_paths(tree).items()
        }
        structure = _tree_structure(tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, host, structure), daemon=True
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: dict, structure) -> str:
        final = os.path.join(self.dir, f"step_{step}")
        tmp = os.path.join(self.dir, f".tmp-step_{step}")
        if os.path.exists(tmp):
            import shutil

            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {
            "step": step,
            "structure": structure,
            "leaves": {
                k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in host.items()
            },
            "n_devices": jax.device_count(),
        }
        for k, v in host.items():
            fname = k.replace(_SEP, "__") + ".npy"
            np.save(os.path.join(tmp, fname), _to_storable(v))
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            import shutil

            shutil.rmtree(final)
        os.rename(tmp, final)
        latest_tmp = os.path.join(self.dir, ".LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(str(step))
        os.rename(latest_tmp, os.path.join(self.dir, "LATEST"))
        return final

    # ---------- read ----------

    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return int(f.read().strip())

    def restore(self, step: Optional[int] = None, shardings=None):
        """Load a checkpoint; ``shardings`` (same tree shape, of
        jax.sharding.Sharding) re-places leaves on the current mesh —
        the elastic-resharding path."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat = {}
        for k, meta in manifest["leaves"].items():
            fname = k.replace(_SEP, "__") + ".npy"
            flat[k] = _from_storable(
                np.load(os.path.join(d, fname)), meta["dtype"]
            )
        tree = _unflatten(manifest["structure"], flat)
        if shardings is not None:
            flat_sh = _flatten_with_paths(shardings)
            flat_tr = _flatten_with_paths(tree)
            placed = {
                k: jax.device_put(v, flat_sh[k])
                for k, v in flat_tr.items()
            }
            tree = _unflatten(manifest["structure"], placed)
        return tree, manifest
