"""AdamW from scratch (pytree-native), with fp32 master weights for
low-precision params and global-norm clipping.

State layout mirrors the param tree, so the same PartitionSpecs shard
the optimizer state (ZeRO-style: FSDP-sharded params ⇒ FSDP-sharded
m/v/master — no replication of optimizer memory).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4                  # peak LR (schedule scales it)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    master_fp32: bool = True


def init_state(params, cfg: AdamWConfig) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "m": jax.tree_util.tree_map(zeros32, params),
        "v": jax.tree_util.tree_map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.master_fp32:
        state["master"] = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.float32), params
        )
    return state


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(tree)
        )
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return (
        jax.tree_util.tree_map(
            lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
            grads,
        ),
        norm,
    )


def apply_updates(params, grads, state, cfg: AdamWConfig,
                  lr_scale: jax.Array):
    """One AdamW step.  Returns (params, state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    masters = state.get("master", params)

    def upd(p_master, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        mhat = m / bc1
        vhat = v / bc2
        p32 = p_master.astype(jnp.float32)
        p32 = p32 - lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p32
        )
        return p32, m, v

    flat_p, treedef = jax.tree_util.tree_flatten(masters)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(*args) for args in zip(flat_p, flat_g, flat_m, flat_v)]
    new_master = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])

    ref_dtypes = jax.tree_util.tree_map(lambda p: p.dtype, params)
    new_params = jax.tree_util.tree_map(
        lambda p32, dt: p32.astype(dt), new_master, ref_dtypes
    )
    new_state = {"m": new_m, "v": new_v, "step": step}
    if cfg.master_fp32:
        new_state["master"] = new_master
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def state_specs(param_specs, cfg: AdamWConfig):
    """PartitionSpecs for the optimizer state given param specs."""
    from jax.sharding import PartitionSpec as P

    specs = {
        "m": param_specs,
        "v": param_specs,
        "step": P(),
    }
    if cfg.master_fp32:
        specs["master"] = param_specs
    return specs
