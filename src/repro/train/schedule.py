"""LR schedules (warmup + cosine decay), as pure functions of step."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    """Returns a multiplier in (0, 1] for the peak LR."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(1, warmup_steps))
    prog = jnp.clip(
        (step - warmup_steps) / max(1, total_steps - warmup_steps),
        0.0, 1.0,
    )
    cos = final_frac + (1 - final_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog)
    )
    return warm * cos


def constant(step):
    return jnp.ones_like(step, jnp.float32)
