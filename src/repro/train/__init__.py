"""Training substrate: optimizer, schedules, grad compression,
checkpointing, generic distributed train step."""

from repro.train.optimizer import (
    AdamWConfig, init_state, apply_updates, global_norm,
    clip_by_global_norm, state_specs,
)
from repro.train.schedule import warmup_cosine, constant
from repro.train.train_step import (
    TrainConfig, build_train_step, init_train_state,
)
from repro.train.checkpoint import Checkpointer
from repro.train import compression

__all__ = [
    "AdamWConfig", "init_state", "apply_updates", "global_norm",
    "clip_by_global_norm", "state_specs", "warmup_cosine", "constant",
    "TrainConfig", "build_train_step", "init_train_state",
    "Checkpointer", "compression",
]
