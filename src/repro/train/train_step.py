"""Generic distributed train step: microbatch gradient accumulation,
optional int8+error-feedback accumulator compression, global-norm
clip, AdamW, LR schedule.

`build_train_step(loss_fn, adamw_cfg, ...)` returns a pure function
    (params, opt_state, batch, step) -> (params, opt_state, metrics)
suitable for jax.jit with in/out shardings.  ``loss_fn(params, batch)``
must be a pure scalar loss (the model closures carry their configs).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.train import compression
from repro.train.optimizer import AdamWConfig, apply_updates, init_state
from repro.train.schedule import warmup_cosine


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: AdamWConfig = AdamWConfig()
    microbatches: int = 1          # grad-accumulation chunks per step
    compress_accum: bool = False   # int8+EF gradient accumulator
    warmup_steps: int = 100
    total_steps: int = 10_000


def _split_batch(batch, n: int):
    """Reshape each leaf (B, ...) -> (n, B/n, ...)."""
    def r(x):
        assert x.shape[0] % n == 0, (x.shape, n)
        return x.reshape((n, x.shape[0] // n) + x.shape[1:])

    return jax.tree_util.tree_map(r, batch)


def build_train_step(
    loss_fn: Callable,
    cfg: TrainConfig,
):
    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(params, opt_state, batch, step):
        if cfg.microbatches > 1:
            micro = _split_batch(batch, cfg.microbatches)

            def accum(carry, mb):
                gacc, lacc, err = carry
                loss, grads = grad_fn(params, mb)
                if cfg.compress_accum:
                    # int8 error-feedback accumulation
                    summed = jax.tree_util.tree_map(
                        lambda a, g: a + g.astype(jnp.float32),
                        compression.dequantize_tree(gacc), grads,
                    )
                    comp, err = compression.ef_compress_tree(summed, err)
                    return (comp, lacc + loss, err), None
                gacc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), gacc, grads
                )
                return (gacc, lacc + loss, err), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            if cfg.compress_accum:
                g0 = {
                    "q": jax.tree_util.tree_map(
                        lambda p: jnp.zeros(p.shape, jnp.int8), params
                    ),
                    "scale": jax.tree_util.tree_map(
                        lambda p: jnp.zeros((), jnp.float32), params
                    ),
                }
                err0 = compression.init_error_tree(params)
            else:
                g0, err0 = zeros, zeros
            (gfin, ltot, _), _ = jax.lax.scan(
                accum, (g0, jnp.float32(0), err0), micro
            )
            grads = (
                compression.dequantize_tree(gfin)
                if cfg.compress_accum else gfin
            )
            grads = jax.tree_util.tree_map(
                lambda g: g / cfg.microbatches, grads
            )
            loss = ltot / cfg.microbatches
        else:
            loss, grads = grad_fn(params, batch)

        lr_scale = warmup_cosine(
            step, warmup_steps=cfg.warmup_steps,
            total_steps=cfg.total_steps,
        )
        params, opt_state, om = apply_updates(
            params, grads, opt_state, cfg.adamw, lr_scale
        )
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return train_step


def init_train_state(params, cfg: TrainConfig):
    return init_state(params, cfg.adamw)
