"""Synthetic deterministic batches (pure functions of seed and step)."""

from __future__ import annotations

import numpy as np


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def lm_batch(step: int, batch: int, seq: int, vocab: int,
             seed: int = 0) -> dict:
    """Markov-ish token stream: next token depends on the previous one
    so a small LM can actually reduce loss against it."""
    rng = _rng(seed, step)
    base = rng.integers(0, vocab, size=(batch, 1))
    steps = rng.integers(1, 7, size=(batch, seq))
    toks = (base + np.cumsum(steps, axis=1)) % vocab
    toks = np.concatenate([base, toks], axis=1).astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def mind_batch(step: int, batch: int, cfg, seed: int = 0) -> dict:
    rng = _rng(seed, step)
    F = cfg.n_profile_fields * cfg.profile_multi
    return {
        "hist": rng.integers(0, cfg.n_items, (batch, cfg.hist_len)
                             ).astype(np.int32),
        "hist_mask": rng.random((batch, cfg.hist_len)) > 0.2,
        "profile_ids": rng.integers(0, cfg.n_profile, (batch, F)
                                    ).astype(np.int32),
        "profile_mask": np.ones((batch, F), dtype=bool),
        "target": rng.integers(0, cfg.n_items, (batch,)).astype(np.int32),
        "negatives": rng.integers(
            0, cfg.n_items, (batch, cfg.n_negatives)
        ).astype(np.int32),
    }


def gnn_flat_batch(graph, d_feat: int, n_classes: int, *,
                   coords: bool = False, triplets: bool = False,
                   triplet_cap=4, seed: int = 0) -> dict:
    from repro.models.gnn.batch import flat_batch_from_graph

    fb = flat_batch_from_graph(
        graph, d_feat, n_classes, with_coords=coords,
        with_triplets=triplets, triplet_cap=triplet_cap, seed=seed,
    )
    out = {
        "x": fb.x, "edge_src": fb.edge_src, "edge_dst": fb.edge_dst,
        "edge_mask": fb.edge_mask, "labels": fb.labels,
    }
    if coords:
        out["coords"] = fb.coords
    if triplets:
        out["tri_kj"] = fb.tri_kj
        out["tri_ji"] = fb.tri_ji
        out["tri_mask"] = fb.tri_mask
    return out


def molecule_batch(step: int, batch: int, n_atoms: int, n_edges: int,
                   *, triplets: bool = False, triplet_pad: int = 512,
                   seed: int = 0) -> dict:
    from repro.models.gnn.batch import random_molecule_batch

    mb = random_molecule_batch(
        batch, n_atoms, n_edges, seed=seed + 7919 * step,
        with_triplets=triplets, triplet_pad=triplet_pad,
    )
    out = {
        "x": mb.x, "coords": mb.coords, "edge_src": mb.edge_src,
        "edge_dst": mb.edge_dst, "edge_mask": mb.edge_mask, "y": mb.y,
    }
    if triplets:
        out["tri_kj"] = mb.tri_kj
        out["tri_ji"] = mb.tri_ji
        out["tri_mask"] = mb.tri_mask
    return out
