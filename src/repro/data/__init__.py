"""Deterministic synthetic data pipelines.

Every batch is a pure function of (seed, step), so training resumes
after a checkpoint restore replay the exact same stream — the
idempotent-resume property the fault-tolerance tests rely on.
"""

from repro.data.synthetic import (
    lm_batch, mind_batch, gnn_flat_batch, molecule_batch,
)

__all__ = ["lm_batch", "mind_batch", "gnn_flat_batch", "molecule_batch"]
