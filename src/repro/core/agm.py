"""The Abstract Graph Machine (paper §III, Definition 3) and its
*logical* (sequentially-emulated, exactly-faithful) execution engine.

The logical engine is the executable form of the paper's semantics:

    "An AGM starts execution with the initial workitem set.  [...] the
    workitems within the smallest equivalence class are fed to the
    processing function.  [...] The AGM executes workitems in the next
    equivalence class once it finished executing all the workitems in
    the current smallest equivalence class.  An AGM terminates when it
    executes all the workitems in all the equivalence classes."

Because the state combine is monotone (min/max — paper §II), executing
the workitems of one equivalence class in any sequential order is
observationally equivalent to the parallel distributed-demon execution
with composite atomicity; this engine is therefore a *semantic oracle*
for the distributed engine in :mod:`repro.core.engine`, and the work
metrics it reports (classes, workitems, relaxations, commits) are the
paper's work/ordering quantities.
"""

from __future__ import annotations

import dataclasses
import math
from collections import defaultdict

import numpy as np

from repro.core.metrics import WorkMetrics
from repro.core.ordering import Ordering, Chaotic, Dijkstra, DeltaStepping, KLA
from repro.core.processing import ProcessingFn, SSSP
from repro.graph.formats import Graph, CSR, coo_to_csr


def _class_key_scalar(ordering: Ordering, dist: float, level: int) -> float:
    if isinstance(ordering, Chaotic):
        return 0.0
    if isinstance(ordering, Dijkstra):
        return dist
    if isinstance(ordering, DeltaStepping):
        return math.floor(dist / ordering.delta)
    if isinstance(ordering, KLA):
        return math.floor(level / ordering.k)
    raise TypeError(ordering)


@dataclasses.dataclass
class AGM:
    """The 6-tuple (G, WorkItem, Q, π, <_wis, S) of Definition 3.

    ``WorkItem`` is implicit in (π, ordering): ⟨v, state⟩ plus a level
    attribute when the ordering requires one (KLA, Definition 8).
    """

    graph: Graph
    processing: ProcessingFn
    ordering: Ordering
    initial_workitems: list  # [(v, state, level)]

    def run(self, max_classes: int = 10**9) -> tuple[np.ndarray, WorkMetrics]:
        return run_logical(self, max_classes=max_classes)


def sssp_agm(graph: Graph, source: int, ordering: Ordering) -> AGM:
    """Proposition 1/2/3: the SSSP AGM with S = {⟨source, 0⟩}.
    Rule R0 of Algorithm 1 (d(r) := 0) is the initial workitem set."""
    return AGM(graph, SSSP, ordering, [(int(source), 0.0, 0)])


def run_logical(
    agm: AGM, max_classes: int = 10**9
) -> tuple[np.ndarray, WorkMetrics]:
    """Execute the AGM per Definition 3 semantics."""
    csr: CSR = coo_to_csr(agm.graph)
    p = agm.processing
    state = np.full(agm.graph.n + 1, p.worst, dtype=np.float64)
    m = WorkMetrics()

    # pending workitems bucketed by equivalence-class key
    buckets: dict[float, list] = defaultdict(list)
    for (v, s, l) in agm.initial_workitems:
        buckets[_class_key_scalar(agm.ordering, s, l)].append((v, s, l))

    while buckets and m.classes < max_classes:
        kmin = min(buckets.keys())
        batch = buckets.pop(kmin)
        m.classes += 1
        # Workitems in one class execute in parallel; by monotonicity an
        # arbitrary sequential order is equivalent.  New workitems may
        # land in the same class (re-entering `buckets[kmin]`).
        for (v, s, l) in batch:
            m.workitems += 1
            if p.better(s, state[v]):  # condition C
                state[v] = s  # update U (atomic)
                m.commits += 1
                nbrs, ws = csr.neighbors(v)
                for u, w in zip(nbrs, ws):  # construct N(w)
                    m.relaxations += 1
                    cand = float(p.edge_update(s, float(w)))
                    key = _class_key_scalar(agm.ordering, cand, l + 1)
                    assert key >= kmin - 1e-9, (
                        "AGM invariant violated: generated workitem in an "
                        "already-executed equivalence class"
                    )
                    buckets[key].append((int(u), cand, l + 1))
    return state[: agm.graph.n], m


def dijkstra_reference(graph: Graph, source: int) -> np.ndarray:
    """Independent textbook Dijkstra (heapq) — the ground-truth oracle."""
    import heapq

    csr = coo_to_csr(graph)
    dist = np.full(graph.n, np.inf, dtype=np.float64)
    dist[source] = 0.0
    heap = [(0.0, source)]
    while heap:
        d, v = heapq.heappop(heap)
        if d > dist[v]:
            continue
        nbrs, ws = csr.neighbors(v)
        for u, w in zip(nbrs, ws):
            nd = d + float(w)
            if nd < dist[u]:
                dist[u] = nd
                heapq.heappush(heap, (nd, u))
    return dist
