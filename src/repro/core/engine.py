"""Distributed EAGM execution engine (shard_map + lax collectives).

This is the TPU-native realization of the paper's AGM/EAGM semantics
(DESIGN.md §2).  The graph is 1D-partitioned (paper §V); pending
workitems are a *dense frontier*: per owned vertex v the device keeps

    D[v] — committed state (the paper's ``distance`` mapping), and
    T[v] — the best pending workitem state for v (min over all
           outstanding ⟨v, s⟩ workitems; min-monotonicity makes the
           dominated ones semantically inert, they only ever counted
           as the paper's wasted work).

``v`` is a pending workitem iff ``better(T[v], D[v])``.

One loop iteration = one superstep:

  1. class keys of pending workitems under the ROOT ordering; global
     pmin ⇒ the current smallest equivalence class (AGM semantics).
  2. EAGM sub-ordering refines eligibility *within* the root class at
     a spatial scope: pod (pmin over intra-pod axes), device (local
     reduction only) or chunk (local top-B) — less synchronization at
     lower levels, the paper's §IV knob.
  3. commit eligible workitems (atomic in the dataflow sense),
  4. relax their out-edges (ELL min-plus, fat rows pre-chunked),
  5. exchange candidates to owners: paper-faithful baseline = dense
     all-reduce-min (`pmin`); optimized = all_to_all transpose +
     local min (a min-reduce-scatter, (P-1)/P of the bytes and no
     full-|V| receive buffer) — the beyond-paper §Perf variant,
  6. fold into T, count pending via psum ⇒ termination detection
     (active-work count, paper §II).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.eagm import EAGMPolicy
from repro.core.metrics import WorkMetrics
from repro.core.ordering import needs_level
from repro.core.processing import ProcessingFn, SSSP
from repro.graph.partition import PartitionedGraph

INF = jnp.float32(jnp.inf)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    policy: EAGMPolicy
    processing: ProcessingFn = SSSP
    exchange: str = "a2a"  # 'a2a' (reduce-scatter-min) | 'pmin' (baseline)
    max_iters: int = 10**9
    collect_metrics: bool = True

    def __post_init__(self):
        if self.exchange not in ("a2a", "pmin"):
            raise ValueError(self.exchange)


def _flat_rank(axis_names, mesh_shape):
    r = jnp.int32(0)
    for name, size in zip(axis_names, mesh_shape):
        r = r * size + jax.lax.axis_index(name)
    return r


def _ranks_within_pod(axis_names):
    """Axis names forming the intra-pod scope (all but 'pod')."""
    return tuple(a for a in axis_names if a != "pod")


def build_step(
    cfg: EngineConfig,
    axis_names: tuple,
    mesh_shape: tuple,
    n_local: int,
    n_parts: int,
):
    """Build the shard_map-inner superstep body + loop."""
    p = cfg.processing
    pol = cfg.policy
    use_level = needs_level(pol.root)
    is_min = p.reduce is jnp.minimum
    worst = jnp.float32(p.worst)
    n_pad = n_parts * n_local
    all_axes = axis_names
    pod_axes = _ranks_within_pod(axis_names)

    def scatter_reduce(col, vals, size):
        """Dense scatter-combine of edge candidates into a (size+1,)
        buffer (slot `size` swallows ELL padding)."""
        buf = jnp.full((size + 1,), worst, dtype=jnp.float32)
        if is_min:
            return buf.at[col.reshape(-1)].min(vals.reshape(-1))
        return buf.at[col.reshape(-1)].max(vals.reshape(-1))

    def reduce2(a, b):
        return p.reduce(a, b)

    def local_extreme(x):
        return jnp.min(x) if is_min else jnp.max(x)

    def pextreme(x, axes):
        return jax.lax.pmin(x, axes) if is_min else jax.lax.pmax(x, axes)

    def step(row_src, col, wgt, carry):
        D, T, L, it, active, commits, relax, classes, last_key = carry
        del active

        # ---- 1. root ordering: current global minimal class ----------
        pending = p.better(T, D)
        key = jnp.where(pending, pol.root.class_key(T, L), INF)
        kmin = jax.lax.pmin(jnp.min(key), all_axes)
        eligible = pending & (key == kmin)

        # ---- 2. EAGM spatial sub-ordering (within root class) --------
        if pol.sub_level is not None:
            sub = jnp.where(eligible, pol.sub_ordering.class_key(T, L), INF)
            if pol.sub_level == "pod":
                smin = jax.lax.pmin(jnp.min(sub), pod_axes)
                eligible = eligible & (sub == smin)
            elif pol.sub_level == "device":
                eligible = eligible & (sub == jnp.min(sub))
            elif pol.sub_level == "chunk":
                B = min(pol.chunk_size, n_local)
                kth = -jax.lax.top_k(-sub, B)[0][B - 1]
                eligible = eligible & (sub <= kth)

        # ---- 3. commit (atomic monotone state update) -----------------
        D = jnp.where(eligible, T, D)

        # ---- 4. relax out-edges of eligible vertices (ELL) ------------
        if is_min:
            # §Perf(S2): semiring-implicit masking — mask at the
            # (n_local,) vertex level and let +inf padding annihilate
            # padded slots (inf + w = inf = identity of min).  Avoids
            # materializing two (R, W) mask/select buffers per step.
            Dm = jnp.where(eligible, D, worst)  # (n_local+1,)
            src_val = Dm[row_src]               # (R,)
            cand = jnp.broadcast_to(
                p.edge_update(src_val[:, None], wgt), wgt.shape
            )  # (R, W); CC's update ignores wgt -> explicit broadcast.
            # Padded ELL slots always carry col == n_pad, so they land
            # in the discarded dummy scatter slot for ANY semiring.
        else:
            src_on = eligible[row_src]
            src_val = jnp.where(src_on, D[row_src], worst)
            cand = p.edge_update(src_val[:, None], wgt)
            cand = jnp.where(src_on[:, None] & (wgt < INF), cand, worst)

        C = scatter_reduce(col, cand, n_pad)[:n_pad]

        if use_level:
            live = eligible[row_src][:, None] & (wgt < INF)
            lvl_cand = jnp.where(live, (L[row_src] + 1.0)[:, None], INF)
            # second scatter: min level among candidates matching the
            # winning value (deterministic tie-break)
            win = live & (cand == C[jnp.clip(col, 0, n_pad - 1)]) & (
                col < n_pad
            )
            CL = jnp.full((n_pad + 1,), INF, dtype=jnp.float32)
            CL = CL.at[col.reshape(-1)].min(
                jnp.where(win, lvl_cand, INF).reshape(-1)
            )[:n_pad]
        else:
            CL = None

        # ---- 5. exchange candidates to owner devices ------------------
        if cfg.exchange == "pmin":
            # paper-faithful dense exchange: all-reduce-combine of the
            # full |V| candidate array ("send every update to the owner")
            Cg = pextreme(C, all_axes)
            me = _flat_rank(axis_names, mesh_shape)
            mine = jax.lax.dynamic_slice(Cg, (me * n_local,), (n_local,))
            if use_level:
                CLw = jnp.where(C == Cg, CL, INF)  # my levels where I win
                CLg = jax.lax.pmin(CLw, all_axes)
                mineL = jax.lax.dynamic_slice(
                    CLg, (me * n_local,), (n_local,)
                )
        else:
            # optimized: all_to_all transpose + local combine
            # (= reduce-scatter with a min/max combiner)
            C2 = C.reshape(n_parts, n_local)
            X = jax.lax.all_to_all(
                C2, all_axes, split_axis=0, concat_axis=0, tiled=True
            )
            mine = p.reduce_array(X, axis=0)
            if use_level:
                L2 = CL.reshape(n_parts, n_local)
                XL = jax.lax.all_to_all(
                    L2, all_axes, split_axis=0, concat_axis=0, tiled=True
                )
                mineL = jnp.min(jnp.where(X == mine[None, :], XL, INF), 0)

        # ---- 6. fold into pending state T ------------------------------
        mine_ext = jnp.concatenate([mine, jnp.array([worst])])
        improved = p.better(mine_ext, T)
        T = jnp.where(improved, mine_ext, T)
        if use_level:
            mineL_ext = jnp.concatenate([mineL, jnp.array([INF])])
            L = jnp.where(improved, mineL_ext, L)

        if cfg.collect_metrics:
            live = eligible[row_src][:, None] & (wgt < INF)
            commits = commits + jax.lax.psum(
                jnp.sum(eligible.astype(jnp.int32)), all_axes
            )
            relax = relax + jax.lax.psum(
                jnp.sum(live.astype(jnp.int32)), all_axes
            )
            classes = classes + jnp.int32(kmin != last_key)

        # termination detection: global count of pending workitems
        # (paper §II "active work"); kept in the carry so the while
        # predicate stays collective-free.
        pending_new = p.better(T, D)
        active = jax.lax.psum(
            jnp.sum(pending_new.astype(jnp.int32)), all_axes
        )

        return (D, T, L, it + 1, active, commits, relax, classes, kmin)

    def cond(carry):
        it, active = carry[3], carry[4]
        return (active > 0) & (it < cfg.max_iters)

    def loop(row_src, col, wgt, D, T, L):
        carry = (
            D, T, L,
            jnp.int32(0), jnp.int32(1),
            jnp.int32(0), jnp.int32(0), jnp.int32(0),
            jnp.float32(jnp.nan),
        )
        body = functools.partial(step, row_src, col, wgt)
        carry = jax.lax.while_loop(cond, lambda c: body(c), carry)
        D, T, L, it, active, commits, relax, classes, _ = carry
        return D[:n_local], it, commits, relax, classes

    return loop


def make_engine(
    pg_shape: dict,
    mesh: Mesh,
    cfg: EngineConfig,
    *,
    batch: Optional[int] = None,
    trace_hook: Optional[callable] = None,
):
    """Return a jitted distributed solver for graphs with the given
    partition shape.  ``pg_shape`` = dict(n_parts, n_local, rows, width).

    ``batch=B`` builds the batched-sources engine: state arrays carry a
    batch axis — (P, B, n_local+1) in, (P, B, n_local) out — and the
    superstep loop is vmapped over it inside ``shard_map``, so B
    queries share one graph residency and one collective schedule.
    Monotonicity makes the shared loop safe: a converged batch element
    has no pending workitems, so extra supersteps are no-ops on it.

    ``trace_hook`` is called once per jit trace (not per call) — the
    facade's compile-once tests count traces through it.
    """
    axis_names = tuple(mesh.axis_names)
    mesh_shape = tuple(mesh.devices.shape)
    n_parts = pg_shape["n_parts"]
    n_local = pg_shape["n_local"]
    assert n_parts == int(np.prod(mesh_shape)), (
        f"partition parts {n_parts} != mesh devices {np.prod(mesh_shape)}"
    )

    loop = build_step(cfg, axis_names, mesh_shape, n_local, n_parts)

    if batch is None:
        def local(row_src, col, wgt, D, T, L):
            # shard_map hands each device a leading axis of size 1
            Dn, it, commits, relax, classes = loop(
                row_src[0], col[0], wgt[0], D[0], T[0], L[0]
            )
            return Dn[None], it, commits, relax, classes
    else:
        vloop = jax.vmap(loop, in_axes=(None, None, None, 0, 0, 0))

        def local(row_src, col, wgt, D, T, L):
            # D/T/L local slices are (1, B, n_local+1)
            Dn, it, commits, relax, classes = vloop(
                row_src[0], col[0], wgt[0], D[0], T[0], L[0]
            )
            return Dn[None], it, commits, relax, classes

    shard = P(axis_names)  # leading axis split over the whole mesh
    sharded = shard_map(
        local,
        mesh=mesh,
        in_specs=(shard, shard, shard, shard, shard, shard),
        out_specs=(shard, P(), P(), P(), P()),
    )

    @jax.jit
    def solve(row_src, col, wgt, D0, T0, L0):
        if trace_hook is not None:
            trace_hook()
        return sharded(row_src, col, wgt, D0, T0, L0)

    return solve


def initial_state(
    pg: PartitionedGraph, processing: ProcessingFn, sources: list[tuple]
):
    """Dense initial state from the initial workitem set S.

    ``sources`` — [(vertex, state, level)].  D = worst everywhere,
    T[v] = s for each initial workitem.  Shapes (P, n_local+1); the
    trailing slot per device is the dummy target of padded virtual
    rows and stays at `worst` forever.
    """
    P_, nl = pg.n_parts, pg.n_local
    worst = np.float32(processing.worst)
    D = np.full((P_, nl + 1), worst, dtype=np.float32)
    T = np.full((P_, nl + 1), worst, dtype=np.float32)
    L = np.full((P_, nl + 1), np.inf, dtype=np.float32)
    for (v, s, lvl) in sources:
        T[v // nl, v % nl] = s
        L[v // nl, v % nl] = lvl
    return D, T, L


def initial_state_batch(
    pg: PartitionedGraph,
    processing: ProcessingFn,
    sources_batch: list[list[tuple]],
):
    """Stack per-query initial states along a batch axis: (P, B,
    n_local+1) arrays for the ``batch=B`` engine."""
    per = [initial_state(pg, processing, s) for s in sources_batch]
    D = np.stack([d for d, _, _ in per], axis=1)
    T = np.stack([t for _, t, _ in per], axis=1)
    L = np.stack([l for _, _, l in per], axis=1)
    return D, T, L


def run_distributed(
    pg: PartitionedGraph,
    mesh: Mesh,
    cfg: EngineConfig,
    sources: list[tuple],
) -> tuple[np.ndarray, WorkMetrics]:
    """Deprecated: use :class:`repro.api.Solver` (compile-once cache,
    batched sources, warm restarts).  This shim keeps the old signature
    working; it routes through the facade's shared engine cache, so
    repeated calls on the same shapes no longer re-trace.
    """
    import warnings

    warnings.warn(
        "run_distributed is deprecated; use repro.api.Solver "
        "(see README 'Migrating from run_distributed')",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.api.solver import solve_with_engine_config

    return solve_with_engine_config(pg, mesh, cfg, sources)


def sssp_sources(source: int) -> list[tuple]:
    return [(int(source), 0.0, 0)]


def cc_sources(n: int) -> list[tuple]:
    return [(v, float(v), 0) for v in range(n)]
