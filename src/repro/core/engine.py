"""Distributed EAGM execution engine (shard_map + lax collectives).

This is the TPU-native realization of the paper's AGM/EAGM semantics
(DESIGN.md §2).  The graph is 1D-partitioned (paper §V); pending
workitems are a *dense frontier*: per owned vertex v the device keeps

    D[v] — committed state (the paper's ``distance`` mapping), and
    T[v] — the best pending workitem state for v (min over all
           outstanding ⟨v, s⟩ workitems; min-monotonicity makes the
           dominated ones semantically inert, they only ever counted
           as the paper's wasted work).

``v`` is a pending workitem iff ``better(T[v], D[v])``.

One loop iteration = one superstep:

  1.+2. fold over the EAGM ordering hierarchy (core/eagm.py): the
     GLOBAL annotation is the AGM root (global pmin of class keys ⇒
     the current smallest equivalence class); every further
     annotation refines eligibility *within* the selection above it
     at its spatial scope — pod (pmin over intra-pod axes), device
     (local reduction only), or a TopK drain (local top-B).  One code
     path realizes every family member; less synchronization at lower
     levels, the paper's §IV knob.
  3. commit eligible workitems (atomic in the dataflow sense),
  4. relax their out-edges (ELL min-plus, fat rows pre-chunked),
  5. exchange candidates to owners: paper-faithful baseline = dense
     all-reduce-min (`pmin`); optimized = all_to_all transpose +
     local min (a min-reduce-scatter, (P-1)/P of the bytes and no
     full-|V| receive buffer) — the beyond-paper §Perf variant,
  6. fold into T, count pending via psum ⇒ termination detection
     (active-work count, paper §II).

Frontier-sparse path (``exchange='sparse'`` / ``'auto'``): instead of
relaxing all R rows and moving O(|V|) floats, the eligible rows are
compacted into a fixed-capacity index list (cap F, the
``frontier_cap`` knob; see core/frontier.py) and only those rows are
gathered and relaxed (push mode — the Pallas realization is
kernels/relax_push); candidates are slotted into per-destination-rank
(idx, val) buffers of capacity S ≈ F·W/P and moved with ONE
``all_to_all`` — per-superstep communication scales with the frontier
capacity, not |V|.  Overflow of either capacity falls back to the
dense path *for that superstep only* (the fallback decision is made
globally uniform with a pmin so every rank takes the same collective
branch); ``'auto'`` additionally prefers the dense exchange while the
carried global pending count is large.  Both paths produce bit-
identical candidate buffers, so results match the dense engine
exactly.  The carry threads the dense-exchange superstep count out to
:class:`repro.core.metrics.WorkMetrics` (each branch moves a
statically known word count per superstep, so the facade reconstructs
exact exchange bytes host-side in Python ints), plus the final active
count for convergence/truncation detection.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.frontier import (
    PAYLOAD_MODES,
    compact_rows,
    frontier_caps,
    payload_plane_words,
    sparse_payload,
    unpack_combine,
)
from repro.core.eagm import EAGMPolicy, Hierarchy, as_hierarchy
from repro.core.metrics import WorkMetrics
from repro.core.ordering import DeltaStepping, suggest
from repro.core.processing import ProcessingFn, SSSP
from repro.graph.partition import PartitionedGraph

INF = jnp.float32(jnp.inf)


#: valid candidate-exchange strategies:
#:   'a2a'    dense all_to_all transpose + local combine (reduce-scatter)
#:   'pmin'   dense all-reduce combine (the paper-faithful baseline)
#:   'sparse' frontier-compacted (idx, val) exchange, dense fallback on
#:            capacity overflow
#:   'auto'   'sparse' while the carried pending count is small, dense
#:            otherwise
EXCHANGE_MODES = ("a2a", "pmin", "sparse", "auto")


#: valid relaxation backends for the sparse push path:
#:   'ref'    inline jnp gather/relax/scatter (XLA fuses it fine)
#:   'pallas' / 'pallas_interpret'   kernels/relax_push — Pallas gather
#:            + relax, XLA scatter
#:   'fused'  / 'fused_interpret'    kernels/superstep_fused — gather +
#:            relax + scatter-min in ONE kernel launch (no (F, W)
#:            intermediates in HBM)
#: Kernel impls apply to min-plus (sssp) processing without levels and
#: silently keep 'ref' otherwise (the analyze 'fused-kernel-escape'
#: lint surfaces that); '*_interpret' forces the Pallas interpreter,
#: which is also auto-selected on backends without a Mosaic compiler.
RELAX_IMPLS = ("ref", "pallas", "pallas_interpret", "fused",
               "fused_interpret")


def _interpret_kernels(relax_impl: str) -> bool:
    """Pallas kernels run interpreted when explicitly requested or when
    the backend has no Mosaic compiler (CPU)."""
    return relax_impl.endswith("_interpret") or jax.default_backend() == "cpu"


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    # the EAGM ordering hierarchy; a legacy EAGMPolicy or a spec
    # string is accepted and normalized to a Hierarchy, so equality /
    # the engine cache key see one canonical form
    policy: "Hierarchy | EAGMPolicy | str"
    processing: ProcessingFn = SSSP
    exchange: str = "a2a"
    max_iters: int = 10**9
    collect_metrics: bool = True
    # max eligible virtual rows compacted per device per superstep on
    # the sparse path (None = rows/8); exchange slot capacity derives
    # from it (frontier.frontier_caps)
    frontier_cap: Optional[int] = None
    # relaxation backend for the sparse push path (see RELAX_IMPLS):
    # 'ref' (inline jnp, the default) | 'pallas'[_interpret] |
    # 'fused'[_interpret]; kernels apply to min-plus processing only,
    # others stay 'ref'
    relax_impl: str = "ref"
    # sparse-exchange payload encoding (frontier.PAYLOAD_MODES):
    # 'exact' (f32 + i32, bit-identical to dense) | 'bf16' | 'u16'
    # (u32 indices + 16-bit round-up quantized value deltas — errors
    # are strictly inflationary, self-stabilization repairs them; the
    # facade's repair loop makes final states exact).  Min-reduce
    # semirings only; dense-fallback supersteps stay exact f32.
    payload: str = "exact"
    # adaptive segment window: 0 builds the classic run-to-convergence
    # loop; W > 0 builds a *segment* engine that runs at most W
    # supersteps per jitted call, threads (active, last_key, streak)
    # through as dynamic scalars, takes a dynamic delta bucket width
    # and exchange-force override, and returns the full (D, T, L)
    # state plus a (W,) per-superstep metrics window so a host-side
    # controller (repro.tune) can retune between segments.  Being an
    # EngineConfig field puts it in the engine cache key, so adaptive
    # and static engines never collide.
    adapt_window: int = 0

    def __post_init__(self):
        object.__setattr__(self, "policy", as_hierarchy(self.policy))
        if self.exchange not in EXCHANGE_MODES:
            raise ValueError(
                f"exchange must be one of {EXCHANGE_MODES}, got "
                f"{self.exchange!r}{suggest(str(self.exchange), EXCHANGE_MODES)}"
            )
        if self.frontier_cap is not None and self.frontier_cap <= 0:
            raise ValueError(f"frontier_cap must be positive: {self.frontier_cap}")
        if self.relax_impl not in RELAX_IMPLS:
            raise ValueError(
                f"relax_impl must be one of {RELAX_IMPLS}, got "
                f"{self.relax_impl!r}{suggest(str(self.relax_impl), RELAX_IMPLS)}"
            )
        if self.adapt_window < 0:
            raise ValueError(
                f"adapt_window must be >= 0: {self.adapt_window}"
            )
        if self.payload not in PAYLOAD_MODES:
            raise ValueError(
                f"payload must be one of {PAYLOAD_MODES}, got "
                f"{self.payload!r}{suggest(str(self.payload), PAYLOAD_MODES)}"
            )
        if self.payload != "exact" and self.processing.reduce is not jnp.minimum:
            raise ValueError(
                f"quantized payload {self.payload!r} requires a min-reduce "
                f"semiring (round-up errors must be inflationary); "
                f"processing fn {self.processing.name!r} reduces with "
                f"{getattr(self.processing.reduce, '__name__', self.processing.reduce)}"
            )

    @property
    def hierarchy(self) -> Hierarchy:
        """The normalized ordering hierarchy (alias of ``policy``)."""
        return self.policy


def _flat_rank(axis_names, mesh_shape):
    r = jnp.int32(0)
    for name, size in zip(axis_names, mesh_shape):
        r = r * size + jax.lax.axis_index(name)
    return r


def _ranks_within_pod(axis_names):
    """Axis names forming the intra-pod scope (all but 'pod')."""
    return tuple(a for a in axis_names if a != "pod")


def build_step(
    cfg: EngineConfig,
    axis_names: tuple,
    mesh_shape: tuple,
    n_local: int,
    n_parts: int,
):
    """Build the shard_map-inner superstep body + loop."""
    p = cfg.processing
    hier = cfg.hierarchy
    use_level = hier.needs_level
    is_min = p.reduce is jnp.minimum
    worst = jnp.float32(p.worst)
    n_pad = n_parts * n_local
    all_axes = axis_names
    pod_axes = _ranks_within_pod(axis_names)
    sparse_mode = cfg.exchange in ("sparse", "auto")
    # f32 planes moved by the dense exchange (values [+ KLA levels]) and
    # by the sparse payload (values, bitcast indices [+ levels])
    nplanes = 2 if use_level else 1
    kplanes = 3 if use_level else 2

    def scatter_reduce(col, vals, size):
        """Dense scatter-combine of edge candidates into a (size+1,)
        buffer (slot `size` swallows ELL padding)."""
        buf = jnp.full((size + 1,), worst, dtype=jnp.float32)
        if is_min:
            return buf.at[col.reshape(-1)].min(vals.reshape(-1))
        return buf.at[col.reshape(-1)].max(vals.reshape(-1))

    def reduce2(a, b):
        return p.reduce(a, b)

    def local_extreme(x):
        return jnp.min(x) if is_min else jnp.max(x)

    def pextreme(x, axes):
        return jax.lax.pmin(x, axes) if is_min else jax.lax.pmax(x, axes)

    adaptive = cfg.adapt_window > 0

    def step(row_src, col, wgt, dyn, carry):
        if adaptive:
            (D, T, L, it, active, commits, relax, classes, last_key,
             fallbacks, streak, max_streak,
             pend_w, elig_w, rows_w, sparse_w) = carry
            delta_dyn, force_ex = dyn
        else:
            (D, T, L, it, active, commits, relax, classes, last_key,
             fallbacks, streak, max_streak) = carry
        active_prev = active
        sp_used = jnp.int32(0)
        R, W = col.shape
        if sparse_mode:
            row_cap, slot_cap = frontier_caps(
                R, W, n_local, n_parts, cfg.frontier_cap
            )
            # 'auto' heuristic: the carried pending count (an
            # overestimate of the next eligible class) gates sparse —
            # with more than half the graph pending the frontier is
            # dense by definition; below that, try sparse and let the
            # capacity-overflow veto catch the bursty supersteps
            auto_thresh = max(1, (n_parts * n_local) // 2)

        # ---- 1+2. ordering hierarchy: fold over annotations ----------
        # Each annotation refines eligibility strictly *within* the
        # previous level's selection (the EAGM extension condition),
        # using the cheapest collective its spatial scope allows:
        # global/pod -> pmin over the scope's mesh axes, device ->
        # local reduction, drain (TopK) -> local top-B.  The first
        # annotation is the AGM root; its class key feeds the
        # distinct-classes metric.
        pending = p.better(T, D)
        eligible = pending
        kmin = INF
        for ai, (lvl, o) in enumerate(hier.annotations):
            if adaptive and ai == 0 and isinstance(o, DeltaStepping):
                # dynamic bucket width: the same op sequence as
                # DeltaStepping.class_key with delta a traced scalar —
                # bit-identical to the static engine whenever the
                # scalar equals the spec's constant, retunable by the
                # controller without retracing
                raw_key = jnp.floor(T / delta_dyn)
            else:
                raw_key = o.class_key(T, L)
            key = jnp.where(eligible, raw_key, INF)
            if lvl in ("global", "pod"):
                axes = all_axes if lvl == "global" else pod_axes
                m = jnp.min(key)
                if axes:
                    m = jax.lax.pmin(m, axes)
                eligible = eligible & (key == m)
                if lvl == "global":
                    kmin = m
            elif getattr(o, "drain", None) is not None:  # local top-B drain
                B = min(o.drain, n_local)
                kth = -jax.lax.top_k(-key, B)[0][B - 1]
                eligible = eligible & (key <= kth)
            else:  # device/chunk minimal class, collective-free
                eligible = eligible & (key == jnp.min(key))

        # ---- 3. commit (atomic monotone state update) -----------------
        D = jnp.where(eligible, T, D)

        # ---- 4. relax out-edges of eligible vertices (ELL) ------------
        def level_scatter(cols, cands, lvl_cands, C):
            """Second scatter: min level among candidates matching the
            winning value (deterministic tie-break)."""
            win = (
                (lvl_cands < INF)
                & (cands == C[jnp.clip(cols, 0, n_pad - 1)])
                & (cols < n_pad)
            )
            buf = jnp.full((n_pad + 1,), INF, dtype=jnp.float32)
            return buf.at[cols.reshape(-1)].min(
                jnp.where(win, lvl_cands, INF).reshape(-1)
            )[:n_pad]

        def relax_dense(_):
            """Pull sweep over all R virtual rows (masked)."""
            if is_min:
                # §Perf(S2): semiring-implicit masking — mask at the
                # (n_local,) vertex level and let +inf padding
                # annihilate padded slots (inf + w = inf = identity of
                # min).  Avoids materializing two (R, W) mask/select
                # buffers per step.
                Dm = jnp.where(eligible, D, worst)  # (n_local+1,)
                src_val = Dm[row_src]               # (R,)
                cand = jnp.broadcast_to(
                    p.edge_update(src_val[:, None], wgt), wgt.shape
                )  # (R, W); CC's update ignores wgt -> explicit bcast.
                # Padded ELL slots always carry col == n_pad, so they
                # land in the discarded dummy scatter slot for ANY
                # semiring.
            else:
                src_on = eligible[row_src]
                src_val = jnp.where(src_on, D[row_src], worst)
                cand = p.edge_update(src_val[:, None], wgt)
                cand = jnp.where(src_on[:, None] & (wgt < INF), cand, worst)
            C = scatter_reduce(col, cand, n_pad)[:n_pad]
            if not use_level:
                return C, jnp.zeros_like(C)
            live = eligible[row_src][:, None] & (wgt < INF)
            lvl_cand = jnp.where(live, (L[row_src] + 1.0)[:, None], INF)
            return C, level_scatter(col, cand, lvl_cand, C)

        if sparse_mode:
            elig_rows = eligible[row_src]
            f_idx, f_cnt, row_overflow = compact_rows(elig_rows, row_cap)

            def relax_push(_):
                """Push mode: gather only the F eligible virtual rows
                (kernels/relax_push is the TPU realization of the
                gather half, kernels/superstep_fused of the whole
                gather+relax+scatter); filled slots carry col == n_pad
                and annihilate in the scatter."""
                kernel_ok = p.name == "sssp" and not use_level
                if cfg.relax_impl.startswith("fused") and kernel_ok:
                    from repro.kernels.superstep_fused import fused_superstep

                    C = fused_superstep(
                        D, f_idx, f_cnt, row_src, col, wgt, n_pad,
                        interpret=_interpret_kernels(cfg.relax_impl),
                    )[:n_pad]
                    return C, jnp.zeros_like(C)
                colg = jnp.take(
                    col, f_idx, axis=0, mode="fill", fill_value=n_pad
                )
                if cfg.relax_impl.startswith("pallas") and kernel_ok:
                    from repro.kernels.relax_push import relax_push_gather

                    cand = relax_push_gather(
                        D, f_idx, f_cnt, row_src, col, wgt,
                        interpret=_interpret_kernels(cfg.relax_impl),
                    )
                    return scatter_reduce(colg, cand, n_pad)[:n_pad], \
                        jnp.zeros((n_pad,), jnp.float32)
                srcg = jnp.take(
                    row_src, f_idx, mode="fill", fill_value=n_local
                )
                wgtg = jnp.take(
                    wgt, f_idx, axis=0, mode="fill", fill_value=jnp.inf
                )
                # every gathered row is eligible (filled rows point at
                # the dummy vertex, whose state is `worst`), so no
                # eligibility masking is needed in push mode
                cand = jnp.broadcast_to(
                    p.edge_update(D[srcg][:, None], wgtg), wgtg.shape
                )
                C = scatter_reduce(colg, cand, n_pad)[:n_pad]
                if not use_level:
                    return C, jnp.zeros_like(C)
                lvl_cand = jnp.where(
                    wgtg < INF, (L[srcg] + 1.0)[:, None], INF
                )
                return C, level_scatter(colg, cand, lvl_cand, C)

            # local decision, collective-free branches: a device whose
            # frontier overflows F sweeps densely on its own
            C, CL = jax.lax.cond(row_overflow, relax_dense, relax_push, None)
        else:
            C, CL = relax_dense(None)

        # ---- 5. exchange candidates to owner devices ------------------
        # Each exchange returns (mine, mineL): the combined (n_local,)
        # candidates for my owned vertices and their levels (zeros when
        # unused).  Words moved are NOT carried on-device: each branch
        # moves a statically known word count per superstep, so the
        # facade reconstructs exact exchange bytes in Python ints from
        # (supersteps, dense-exchange-step count) — no int32 overflow
        # on long solves (see api.solver._finish_metrics).

        def exchange_pmin(_):
            # paper-faithful dense exchange: all-reduce-combine of the
            # full |V| candidate array ("send every update to the
            # owner"); ring all-reduce moves ~2(P-1)/P of the array
            Cg = pextreme(C, all_axes)
            me = _flat_rank(axis_names, mesh_shape)
            mine = jax.lax.dynamic_slice(Cg, (me * n_local,), (n_local,))
            if use_level:
                CLw = jnp.where(C == Cg, CL, INF)  # my levels where I win
                CLg = jax.lax.pmin(CLw, all_axes)
                mineL = jax.lax.dynamic_slice(
                    CLg, (me * n_local,), (n_local,)
                )
            else:
                mineL = jnp.zeros_like(mine)
            return mine, mineL

        def exchange_a2a(_):
            # optimized: all_to_all transpose + local combine
            # (= reduce-scatter with a min/max combiner)
            C2 = C.reshape(n_parts, n_local)
            X = jax.lax.all_to_all(
                C2, all_axes, split_axis=0, concat_axis=0, tiled=True
            )
            mine = p.reduce_array(X, axis=0)
            if use_level:
                L2 = CL.reshape(n_parts, n_local)
                XL = jax.lax.all_to_all(
                    L2, all_axes, split_axis=0, concat_axis=0, tiled=True
                )
                mineL = jnp.min(jnp.where(X == mine[None, :], XL, INF), 0)
            else:
                mineL = jnp.zeros_like(mine)
            return mine, mineL

        if cfg.exchange == "pmin":
            mine, mineL = exchange_pmin(None)
        elif cfg.exchange == "a2a":
            mine, mineL = exchange_a2a(None)
        elif cfg.exchange == "auto" and payload_plane_words(
            slot_cap, use_level, cfg.payload
        ) >= nplanes * n_local:
            # static shortcut: at these capacities the sparse payload
            # can never move fewer words than the dense reduce-scatter
            # (payload words ≥ planes·n_local), so 'auto' resolves to
            # dense at trace time — no compaction, no decision collective
            mine, mineL = exchange_a2a(None)
            fallbacks = fallbacks + 1
        else:  # 'sparse' | 'auto'
            extra = [(CL, INF)] if use_level else []
            payload, ex_overflow = sparse_payload(
                C, extra, n_parts, slot_cap, worst, payload=cfg.payload
            )
            cap_ok = jnp.logical_not(ex_overflow)
            ok = cap_ok
            if cfg.exchange == "auto":
                ok = ok & (active_prev <= jnp.int32(auto_thresh))
            if adaptive:
                # controller override: 1 forces sparse (the capacity
                # veto still applies — exactness over preference),
                # 2 forces dense, 0 keeps the mode's own heuristic
                ok = jnp.where(force_ex == jnp.int32(1), cap_ok, ok)
                ok = ok & jnp.logical_not(force_ex == jnp.int32(2))
            # the all_to_all shapes differ between branches, so every
            # rank must take the same one: agree globally (pmin of the
            # local votes — a rank whose buckets overflow vetoes).
            # Votes are pinned to strong int32: a weak-typed Python
            # scalar here would thread promotion through the carry
            # (jaxpr lint rule 'weak-scalar').  Lane 1 piggybacks the
            # capacity-overflow vote for the consecutive-overflow
            # streak, so the streak costs no extra collective round.
            over_local = row_overflow | ex_overflow
            votes = jnp.stack([
                jnp.where(ok, jnp.int32(1), jnp.int32(0)),
                jnp.where(over_local, jnp.int32(0), jnp.int32(1)),
            ])
            gvote = jax.lax.pmin(votes, all_axes)
            use_sp = gvote[0] > jnp.int32(0)
            overflow_g = gvote[1] == jnp.int32(0)

            def exchange_sparse(_):
                recv = jax.lax.all_to_all(
                    payload, all_axes, split_axis=0, concat_axis=0,
                    tiled=True,
                )
                mine, mineL = unpack_combine(
                    recv, n_local, slot_cap, is_min, worst, use_level,
                    payload=cfg.payload,
                )
                if mineL is None:
                    mineL = jnp.zeros_like(mine)
                return mine, mineL

            mine, mineL = jax.lax.cond(
                use_sp, exchange_sparse, exchange_a2a, None
            )
            fallbacks = fallbacks + jnp.where(
                use_sp, jnp.int32(0), jnp.int32(1)
            )
            sp_used = jnp.where(use_sp, jnp.int32(1), jnp.int32(0))
            streak = jnp.where(
                overflow_g, streak + jnp.int32(1), jnp.int32(0)
            )
            max_streak = jnp.maximum(max_streak, streak)

        # ---- 6. fold into pending state T ------------------------------
        mine_ext = jnp.concatenate([mine, jnp.array([worst])])
        improved = p.better(mine_ext, T)
        T = jnp.where(improved, mine_ext, T)
        if use_level:
            mineL_ext = jnp.concatenate([mineL, jnp.array([INF])])
            L = jnp.where(improved, mineL_ext, L)

        if adaptive:
            # one stacked psum publishes the whole metrics window row
            # (eligible class size, eligible ELL rows, live edge
            # relaxations) in a single collective round
            live = eligible[row_src][:, None] & (wgt < INF)
            if sparse_mode:
                erows = f_cnt
            else:
                erows = jnp.sum(eligible[row_src].astype(jnp.int32))
            sums = jax.lax.psum(
                jnp.stack([
                    jnp.sum(eligible.astype(jnp.int32)),
                    erows,
                    jnp.sum(live.astype(jnp.int32)),
                ]),
                all_axes,
            )
            commits = commits + sums[0]
            relax = relax + sums[2]
            classes = classes + (kmin != last_key).astype(jnp.int32)
        elif cfg.collect_metrics:
            live = eligible[row_src][:, None] & (wgt < INF)
            commits = commits + jax.lax.psum(
                jnp.sum(eligible.astype(jnp.int32)), all_axes
            )
            relax = relax + jax.lax.psum(
                jnp.sum(live.astype(jnp.int32)), all_axes
            )
            classes = classes + (kmin != last_key).astype(jnp.int32)

        # termination detection: global count of pending workitems
        # (paper §II "active work"); kept in the carry so the while
        # predicate stays collective-free.
        pending_new = p.better(T, D)
        active = jax.lax.psum(
            jnp.sum(pending_new.astype(jnp.int32)), all_axes
        )

        if adaptive:
            pend_w = pend_w.at[it].set(active)
            elig_w = elig_w.at[it].set(sums[0])
            rows_w = rows_w.at[it].set(sums[1])
            sparse_w = sparse_w.at[it].set(sp_used)
            return (D, T, L, it + 1, active, commits, relax, classes,
                    kmin, fallbacks, streak, max_streak,
                    pend_w, elig_w, rows_w, sparse_w)
        return (D, T, L, it + 1, active, commits, relax, classes, kmin,
                fallbacks, streak, max_streak)

    def cond(carry):
        it, active = carry[3], carry[4]
        return (active > 0) & (it < cfg.max_iters)

    def loop(row_src, col, wgt, D, T, L):
        carry = (
            D, T, L,
            jnp.int32(0), jnp.int32(1),
            jnp.int32(0), jnp.int32(0), jnp.int32(0),
            jnp.float32(jnp.nan),
            jnp.int32(0), jnp.int32(0), jnp.int32(0),
        )
        body = functools.partial(step, row_src, col, wgt, None)
        carry = jax.lax.while_loop(cond, lambda c: body(c), carry)
        (D, T, L, it, active, commits, relax, classes, _,
         fallbacks, _streak, max_streak) = carry
        # `active` == 0 iff the loop converged (vs. truncation at
        # max_iters); `fallbacks` = supersteps on which a
        # sparse-capable mode used the dense exchange (capacity
        # overflow, the auto pending heuristic, or the static
        # can't-pay shortcut); `max_streak` = longest run of
        # consecutive capacity-overflow supersteps (0 in dense modes).
        return (D[:n_local], it, commits, relax, classes, active,
                fallbacks, max_streak)

    def segment(row_src, col, wgt, D, T, L,
                active0, last_key0, streak0, limit, delta_dyn, force_ex):
        """One adaptive segment: at most ``limit`` (≤ adapt_window)
        supersteps with the given dynamic tunables, returning full
        (D, T, L) for continuation plus segment-local counters and the
        per-superstep metrics window."""
        zw = jnp.zeros((cfg.adapt_window,), jnp.int32)
        carry = (
            D, T, L,
            jnp.int32(0), active0,
            jnp.int32(0), jnp.int32(0), jnp.int32(0),
            last_key0,
            jnp.int32(0), streak0, jnp.int32(0),
            zw, zw, zw, zw,
        )

        def seg_cond(c):
            return (c[4] > 0) & (c[3] < limit)

        body = functools.partial(
            step, row_src, col, wgt, (delta_dyn, force_ex)
        )
        carry = jax.lax.while_loop(seg_cond, lambda c: body(c), carry)
        (D, T, L, it, active, commits, relax, classes, last_key,
         fallbacks, streak, max_streak,
         pend_w, elig_w, rows_w, sparse_w) = carry
        return (D, T, L, it, commits, relax, classes, active, fallbacks,
                last_key, streak, max_streak,
                pend_w, elig_w, rows_w, sparse_w)

    return segment if adaptive else loop


def make_engine(
    pg_shape: dict,
    mesh: Mesh,
    cfg: EngineConfig,
    *,
    batch: Optional[int] = None,
    trace_hook: Optional[callable] = None,
):
    """Return a jitted distributed solver for graphs with the given
    partition shape.  ``pg_shape`` = dict(n_parts, n_local, rows, width).

    ``batch=B`` builds the batched-sources engine: state arrays carry a
    batch axis — (P, B, n_local+1) in, (P, B, n_local) out — and the
    superstep loop is vmapped over it inside ``shard_map``, so B
    queries share one graph residency and one collective schedule.
    Monotonicity makes the shared loop safe: a converged batch element
    has no pending workitems, so extra supersteps are no-ops on it.

    ``trace_hook`` is called once per jit trace (not per call) — the
    facade's compile-once tests count traces through it.
    """
    axis_names = tuple(mesh.axis_names)
    mesh_shape = tuple(mesh.devices.shape)
    n_parts = pg_shape["n_parts"]
    n_local = pg_shape["n_local"]
    assert n_parts == int(np.prod(mesh_shape)), (
        f"partition parts {n_parts} != mesh devices {np.prod(mesh_shape)}"
    )

    loop = build_step(cfg, axis_names, mesh_shape, n_local, n_parts)
    shard = P(axis_names)  # leading axis split over the whole mesh

    if cfg.adapt_window > 0:
        if batch is not None:
            raise ValueError(
                "adaptive segment engines (adapt_window > 0) do not "
                "support batched sources; solve one query at a time "
                "or use a static spec for solve_batch"
            )

        def local_seg(row_src, col, wgt, D, T, L,
                      active0, last_key0, streak0, limit, delta, force):
            out = loop(row_src[0], col[0], wgt[0], D[0], T[0], L[0],
                       active0, last_key0, streak0, limit, delta, force)
            return (out[0][None], out[1][None], out[2][None]) + out[3:]

        sharded_seg = shard_map(
            local_seg,
            mesh=mesh,
            in_specs=(shard,) * 6 + (P(),) * 6,
            out_specs=(shard,) * 3 + (P(),) * 13,
        )

        @jax.jit
        def solve_segment(row_src, col, wgt, D0, T0, L0,
                          active0, last_key0, streak0, limit, delta,
                          force):
            if trace_hook is not None:
                trace_hook()
            return sharded_seg(row_src, col, wgt, D0, T0, L0,
                               active0, last_key0, streak0, limit,
                               delta, force)

        return solve_segment

    if batch is None:
        def local(row_src, col, wgt, D, T, L):
            # shard_map hands each device a leading axis of size 1
            out = loop(row_src[0], col[0], wgt[0], D[0], T[0], L[0])
            return (out[0][None],) + out[1:]
    else:
        vloop = jax.vmap(loop, in_axes=(None, None, None, 0, 0, 0))

        def local(row_src, col, wgt, D, T, L):
            # D/T/L local slices are (1, B, n_local+1)
            out = vloop(row_src[0], col[0], wgt[0], D[0], T[0], L[0])
            return (out[0][None],) + out[1:]

    sharded = shard_map(
        local,
        mesh=mesh,
        in_specs=(shard, shard, shard, shard, shard, shard),
        out_specs=(shard,) + (P(),) * 7,
    )

    @jax.jit
    def solve(row_src, col, wgt, D0, T0, L0):
        if trace_hook is not None:
            trace_hook()
        return sharded(row_src, col, wgt, D0, T0, L0)

    return solve


def initial_state(
    pg: PartitionedGraph, processing: ProcessingFn, sources: list[tuple]
):
    """Dense initial state from the initial workitem set S.

    ``sources`` — [(vertex, state, level)] in *original* vertex ids;
    the partition's owner map (``pg.owner_slot``, the relabeling
    permutation) places each on its owning rank.  D = worst
    everywhere, T[v] = the `processing.reduce`-combine of all initial
    workitems targeting v (duplicates keep the best state, not the
    last written one — matters for SSWP's max-reduce and multi-source
    sets with repeats); ties keep the smallest level.  Shapes
    (P, n_local+1); the trailing slot per device is the dummy target
    of padded virtual rows and stays at `worst` forever.
    """
    P_, nl = pg.n_parts, pg.n_local
    worst = np.float32(processing.worst)
    D = np.full((P_, nl + 1), worst, dtype=np.float32)
    T = np.full((P_, nl + 1), worst, dtype=np.float32)
    L = np.full((P_, nl + 1), np.inf, dtype=np.float32)
    for (v, s, lvl) in sources:
        i, j = pg.owner_slot(int(v))
        i, j = int(i), int(j)
        s, lvl = np.float32(s), np.float32(lvl)
        if bool(processing.better(s, T[i, j])):
            T[i, j] = s
            L[i, j] = lvl
        elif s == T[i, j]:
            L[i, j] = min(L[i, j], lvl)
    return D, T, L


def initial_state_batch(
    pg: PartitionedGraph,
    processing: ProcessingFn,
    sources_batch: list[list[tuple]],
):
    """Stack per-query initial states along a batch axis: (P, B,
    n_local+1) arrays for the ``batch=B`` engine."""
    per = [initial_state(pg, processing, s) for s in sources_batch]
    D = np.stack([d for d, _, _ in per], axis=1)
    T = np.stack([t for _, t, _ in per], axis=1)
    L = np.stack([l for _, _, l in per], axis=1)
    return D, T, L


def run_distributed(
    pg: PartitionedGraph,
    mesh: Mesh,
    cfg: EngineConfig,
    sources: list[tuple],
) -> tuple[np.ndarray, WorkMetrics]:
    """Deprecated: use :class:`repro.api.Solver` (compile-once cache,
    batched sources, warm restarts).  This shim keeps the old signature
    working; it routes through the facade's shared engine cache, so
    repeated calls on the same shapes no longer re-trace.
    """
    import warnings

    warnings.warn(
        "run_distributed is deprecated; use repro.api.Solver "
        "(see README 'Migrating from run_distributed')",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.api.solver import solve_with_engine_config

    return solve_with_engine_config(pg, mesh, cfg, sources)


def sssp_sources(source: int) -> list[tuple]:
    return [(int(source), 0.0, 0)]


def cc_sources(n: int) -> list[tuple]:
    return [(v, float(v), 0) for v in range(n)]
