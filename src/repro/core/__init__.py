"""AGM/EAGM core — the paper's primary contribution.

Layers:
  ordering.py    strict weak orderings (chaotic/dijkstra/Δ/KLA/topk)
  processing.py  processing functions π (SSSP/BFS/CC/SSWP)
  agm.py         Definition-3 AGM + logical (oracle) engine
  eagm.py        per-level ordering hierarchies (Hierarchy; the paper
                 presets buffer/threadq/nodeq/numaq are points in it)
  frontier.py    O(frontier) compaction + sparse candidate exchange
  engine.py      distributed shard_map engine (the TPU realization)
  metrics.py     work/sync metrics + calibrated cost model
"""

from repro.core.ordering import (
    Chaotic,
    Dijkstra,
    DeltaStepping,
    KLA,
    Ordering,
    TopK,
    make_ordering,
    ordering_kinds,
    register_ordering,
)
from repro.core.processing import SSSP, BFS, CC, SSWP, ProcessingFn
from repro.core.agm import AGM, sssp_agm, run_logical, dijkstra_reference
from repro.core.eagm import (
    EAGMPolicy,
    Hierarchy,
    LEVELS,
    as_hierarchy,
    make_hierarchy,
    make_policy,
    paper_variant_grid,
    paper_variant_specs,
)
from repro.core.engine import (
    EXCHANGE_MODES,
    RELAX_IMPLS,
    EngineConfig,
    run_distributed,
    make_engine,
    initial_state,
    sssp_sources,
    cc_sources,
)
from repro.core.frontier import (
    compact_rows,
    frontier_caps,
    sparse_payload,
    unpack_combine,
)
from repro.core.metrics import LatencyStats, WorkMetrics, model_time_s

__all__ = [
    "Chaotic", "Dijkstra", "DeltaStepping", "KLA", "TopK", "Ordering",
    "make_ordering", "ordering_kinds", "register_ordering",
    "SSSP", "BFS", "CC", "SSWP", "ProcessingFn",
    "AGM", "sssp_agm", "run_logical", "dijkstra_reference",
    "Hierarchy", "LEVELS", "as_hierarchy", "make_hierarchy",
    "EAGMPolicy", "make_policy", "paper_variant_grid",
    "paper_variant_specs",
    "EXCHANGE_MODES", "RELAX_IMPLS", "EngineConfig", "run_distributed",
    "make_engine", "initial_state", "sssp_sources", "cc_sources",
    "compact_rows", "frontier_caps", "sparse_payload", "unpack_combine",
    "WorkMetrics", "LatencyStats", "model_time_s",
]
