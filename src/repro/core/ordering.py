"""Strict weak orderings on workitems (paper §III, Definitions 5-9).

A strict weak ordering ``<_wis`` partitions the pending workitem set
into ordered equivalence classes.  In the dense-frontier realization a
workitem is ⟨v, T[v]⟩ (plus the KLA level attribute L[v]); the ordering
is represented by a *class key* function: two workitems are in the
same equivalence class iff their keys are equal, and classes are
processed in increasing key order.  The engine computes the global
minimum key over pending workitems each superstep and processes
exactly the workitems whose key attains it — which is precisely the
AGM semantics ("execute the smallest equivalence class; repeat").

Keys are float32 so that ``pmin`` collectives implement the induced
class ordering ``<_WIS`` directly.

Every ordering satisfies one uniform protocol, so the EAGM hierarchy
(core/eagm.py) can put any of them at any spatial level:

    class_key(dist, level) -> f32 array   the equivalence-class key
    needs_level: bool                     True iff the key reads the
                                          KLA level attribute L
    drain: Optional[int]                  top-B drain size (TopK only)
    spec: str                             canonical parseable spec,
                                          ``make_ordering(o.spec) == o``

Orderings register themselves in a kind registry; ``make_ordering``
parses specs through it and offers a did-you-mean suggestion on
unknown kinds.
"""

from __future__ import annotations

import dataclasses
import difflib
from typing import Callable, Optional, Union

import jax.numpy as jnp

INF = jnp.float32(jnp.inf)


@dataclasses.dataclass(frozen=True)
class Chaotic:
    """Definition 5: w1 <_chaotic w2 is always False — one giant class."""

    name: str = "chaotic"
    needs_level = False
    drain = None

    @property
    def spec(self) -> str:
        return "chaotic"

    def class_key(self, dist, level):
        return jnp.zeros_like(dist)


@dataclasses.dataclass(frozen=True)
class Dijkstra:
    """Definition 6: w1 <_dj w2 iff d1 < d2 — one class per distance."""

    name: str = "dijkstra"
    needs_level = False
    drain = None

    @property
    def spec(self) -> str:
        return "dijkstra"

    def class_key(self, dist, level):
        return dist


@dataclasses.dataclass(frozen=True)
class DeltaStepping:
    """Definition 7: w1 <_Δ w2 iff ⌊d1/Δ⌋ < ⌊d2/Δ⌋."""

    delta: float = 5.0
    needs_level = False
    drain = None

    @property
    def name(self) -> str:
        return f"delta{self.delta:g}"

    @property
    def spec(self) -> str:
        return f"delta:{self.delta:g}"

    def class_key(self, dist, level):
        return jnp.floor(dist / jnp.float32(self.delta))


@dataclasses.dataclass(frozen=True)
class KLA:
    """Definition 9: w1 <_kla w2 iff ⌊l1/k⌋ < ⌊l2/k⌋ (level attribute)."""

    k: int = 2
    drain = None

    @property
    def name(self) -> str:
        return f"kla{self.k}"

    @property
    def spec(self) -> str:
        return f"kla:{self.k}"

    @property
    def needs_level(self) -> bool:
        return True

    def class_key(self, dist, level):
        return jnp.floor(level.astype(jnp.float32) / jnp.float32(self.k))


@dataclasses.dataclass(frozen=True)
class TopK:
    """Drain ordering: keep the B smallest workitems under ``key``.

    Unlike the class orderings above, a TopK annotation does not select
    one equivalence class — it bounds *how many* workitems a local
    scope drains per superstep (the B smallest by ``key``'s class key,
    ties included).  This is the paper's thread-level priority-queue
    behavior: ``threadq`` is ``TopK(b)`` with the Dijkstra key at the
    CHUNK level (each device drains the B smallest pending items of
    the current root class).  Only meaningful at the device-local
    scopes (device, chunk) — a distributed top-B would need a
    collective k-selection.
    """

    b: int = 1024
    key: Union[Chaotic, Dijkstra, DeltaStepping, KLA] = Dijkstra()

    def __post_init__(self):
        if self.b <= 0:
            raise ValueError(f"TopK drain size must be positive: {self.b}")
        if isinstance(self.key, TopK):
            raise ValueError("TopK cannot nest another TopK as its key")

    @property
    def name(self) -> str:
        inner = "" if isinstance(self.key, Dijkstra) else f"[{self.key.name}]"
        return f"topk{self.b}{inner}"

    @property
    def spec(self) -> str:
        if isinstance(self.key, Dijkstra):
            return f"topk:{self.b}"
        return f"topk:{self.b}:{self.key.spec}"

    @property
    def needs_level(self) -> bool:
        return needs_level(self.key)

    @property
    def drain(self) -> int:
        return self.b

    def class_key(self, dist, level):
        return self.key.class_key(dist, level)


Ordering = Union[Chaotic, Dijkstra, DeltaStepping, KLA, TopK]


def needs_level(ordering: Ordering) -> bool:
    return getattr(ordering, "needs_level", False)


# ---------------------------------------------------------------------
# registry + spec parsing
# ---------------------------------------------------------------------

#: canonical kind -> (parser(arg_str_or_None) -> Ordering)
_REGISTRY: "dict[str, Callable[[Optional[str]], Ordering]]" = {}
#: alias -> canonical kind
_ALIASES: "dict[str, str]" = {}


def register_ordering(kind: str, parser, *aliases: str) -> None:
    """Register an ordering kind for :func:`make_ordering`.  ``parser``
    receives the text after ``kind:`` (or None) and returns the
    ordering instance."""
    _REGISTRY[kind] = parser
    _ALIASES[kind] = kind
    for a in aliases:
        _ALIASES[a] = kind


def _parse_topk(arg: Optional[str]) -> TopK:
    if arg is None:
        return TopK()
    if ":" in arg:  # topk:B:inner-ordering-spec
        b, inner = arg.split(":", 1)
        return TopK(int(b), make_ordering(inner))
    return TopK(int(arg))


register_ordering("chaotic", lambda a: Chaotic())
register_ordering("dijkstra", lambda a: Dijkstra(), "dj")
register_ordering(
    "delta",
    lambda a: DeltaStepping(float(a) if a else 5.0),
    "delta-stepping", "ds",
)
register_ordering("kla", lambda a: KLA(int(a) if a else 2))
register_ordering("topk", _parse_topk)


def ordering_kinds() -> tuple:
    """The registered canonical ordering kinds."""
    return tuple(sorted(_REGISTRY))


def suggest(word: str, choices) -> str:
    """``" (did you mean 'x'?)"`` when a close match exists, else ""."""
    close = difflib.get_close_matches(word, list(choices), n=1, cutoff=0.6)
    return f" (did you mean {close[0]!r}?)" if close else ""


def make_ordering(spec: str) -> Ordering:
    """Parse 'chaotic' | 'dijkstra' | 'delta:5' | 'kla:2' | 'topk:64'
    (or 'topk:64:delta:1' for a non-Dijkstra drain key)."""
    if isinstance(spec, str) and ":" in spec:
        kind, arg = spec.split(":", 1)
    else:
        kind, arg = spec, None
    kind = str(kind).strip().lower()
    canonical = _ALIASES.get(kind)
    if canonical is None:
        raise ValueError(
            f"unknown ordering spec: {spec!r} — kind must be one of "
            f"{sorted(_REGISTRY)}{suggest(kind, _ALIASES)}"
        )
    try:
        return _REGISTRY[canonical](arg)
    except (TypeError, ValueError) as e:
        # already-informative parse errors (incl. from a recursive
        # make_ordering on a nested TopK key) pass through unwrapped
        if isinstance(e, ValueError) and str(e).startswith(
            ("unknown ordering spec", "bad argument in ordering spec")
        ):
            raise
        raise ValueError(f"bad argument in ordering spec {spec!r}: {e}")
