"""Strict weak orderings on workitems (paper §III, Definitions 5-9).

A strict weak ordering ``<_wis`` partitions the pending workitem set
into ordered equivalence classes.  In the dense-frontier realization a
workitem is ⟨v, T[v]⟩ (plus the KLA level attribute L[v]); the ordering
is represented by a *class key* function: two workitems are in the
same equivalence class iff their keys are equal, and classes are
processed in increasing key order.  The engine computes the global
minimum key over pending workitems each superstep and processes
exactly the workitems whose key attains it — which is precisely the
AGM semantics ("execute the smallest equivalence class; repeat").

Keys are float32 so that ``pmin`` collectives implement the induced
class ordering ``<_WIS`` directly.
"""

from __future__ import annotations

import dataclasses
from typing import Union

import jax.numpy as jnp

INF = jnp.float32(jnp.inf)


@dataclasses.dataclass(frozen=True)
class Chaotic:
    """Definition 5: w1 <_chaotic w2 is always False — one giant class."""

    name: str = "chaotic"

    def class_key(self, dist, level):
        return jnp.zeros_like(dist)


@dataclasses.dataclass(frozen=True)
class Dijkstra:
    """Definition 6: w1 <_dj w2 iff d1 < d2 — one class per distance."""

    name: str = "dijkstra"

    def class_key(self, dist, level):
        return dist


@dataclasses.dataclass(frozen=True)
class DeltaStepping:
    """Definition 7: w1 <_Δ w2 iff ⌊d1/Δ⌋ < ⌊d2/Δ⌋."""

    delta: float = 5.0

    @property
    def name(self) -> str:
        return f"delta{self.delta:g}"

    def class_key(self, dist, level):
        return jnp.floor(dist / jnp.float32(self.delta))


@dataclasses.dataclass(frozen=True)
class KLA:
    """Definition 9: w1 <_kla w2 iff ⌊l1/k⌋ < ⌊l2/k⌋ (level attribute)."""

    k: int = 2

    @property
    def name(self) -> str:
        return f"kla{self.k}"

    @property
    def needs_level(self) -> bool:
        return True

    def class_key(self, dist, level):
        return jnp.floor(level.astype(jnp.float32) / jnp.float32(self.k))


Ordering = Union[Chaotic, Dijkstra, DeltaStepping, KLA]


def needs_level(ordering: Ordering) -> bool:
    return getattr(ordering, "needs_level", False)


def make_ordering(spec: str) -> Ordering:
    """Parse 'chaotic' | 'dijkstra' | 'delta:5' | 'kla:2'."""
    if ":" in spec:
        kind, arg = spec.split(":", 1)
    else:
        kind, arg = spec, None
    kind = kind.strip().lower()
    if kind == "chaotic":
        return Chaotic()
    if kind in ("dijkstra", "dj"):
        return Dijkstra()
    if kind in ("delta", "delta-stepping", "ds"):
        return DeltaStepping(float(arg) if arg else 5.0)
    if kind == "kla":
        return KLA(int(arg) if arg else 2)
    raise ValueError(f"unknown ordering spec: {spec!r}")
