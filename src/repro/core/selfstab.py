"""The self-stabilizing SSSP kernel itself (paper Algorithm 1,
Huang & Lin 2002), executed under a synchronous demon.

    R0:  d(r) ≠ 0                     → d(r) := 0
    R1:  d(i) ≠ min_j (d(j) + w(i,j)) → d(i) := min_j (d(j) + w(i,j))

Note R1 *replaces* the state (it can RAISE d(i)) — that is what makes
the algorithm self-stabilizing: started from an arbitrary corrupted
state it still converges to the shortest-path fixpoint.  The AGM
engine (engine.py) is the paper's *stabilizing* derivation of this
kernel (monotone decrease from a specific initial state + ordering);
this module keeps the original rule as (a) the semantic ground truth
the AGM engine is tested against and (b) the dense synchronous sweep
whose hot loop is the Pallas `relax_ell` kernel (pull-mode min-plus
over in-edges).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.formats import Graph, coo_to_csr, csr_to_ell
from repro.graph.partition import chunk_fat_rows
from repro.kernels.relax_ell import relax_rows


def in_ell(g: Graph, width: int | None = None):
    """ELL over *in*-edges (transpose), fat rows chunked; returns
    (row_dst, col, wgt) where row_dst maps virtual rows -> vertex."""
    gt = Graph(g.n, g.dst, g.src, g.weight, name=g.name + "^T")
    csr = coo_to_csr(gt)
    w = width or max(1, min(64, csr.max_degree()))
    return chunk_fat_rows(csr, w, pad_col=g.n)


def synchronous_sweep(
    g: Graph,
    source: int,
    d0: np.ndarray,
    iters: int,
    *,
    impl: str = "ref",
) -> np.ndarray:
    """Run `iters` synchronous applications of R0/R1 from state d0."""
    row_dst, col, wgt = in_ell(g)
    row_dst = jnp.asarray(row_dst)
    col = jnp.asarray(col)
    wgt = jnp.asarray(wgt)
    n = g.n

    d = jnp.asarray(d0, jnp.float32)

    @jax.jit
    def step(d):
        d_ext = jnp.concatenate([d, jnp.array([jnp.inf])])
        row_min = relax_rows(d_ext, col, wgt, impl=impl)  # (R,)
        # combine virtual rows of the same vertex (fat-row chunking)
        new = jnp.full((n + 1,), jnp.inf).at[row_dst].min(row_min)[:n]
        new = new.at[source].set(0.0)  # rule R0
        return new

    for _ in range(iters):
        d_next = step(d)
        if bool(jnp.all(d_next == d)):
            break
        d = d_next
    return np.asarray(d)
