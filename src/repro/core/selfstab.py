"""The self-stabilizing SSSP kernel itself (paper Algorithm 1,
Huang & Lin 2002), executed under a synchronous demon.

    R0:  d(r) ≠ 0                     → d(r) := 0
    R1:  d(i) ≠ min_j (d(j) + w(i,j)) → d(i) := min_j (d(j) + w(i,j))

Note R1 *replaces* the state (it can RAISE d(i)) — that is what makes
the algorithm self-stabilizing: started from an arbitrary corrupted
state it still converges to the shortest-path fixpoint.  The AGM
engine (engine.py) is the paper's *stabilizing* derivation of this
kernel (monotone decrease from a specific initial state + ordering);
this module keeps the original rule as (a) the semantic ground truth
the AGM engine is tested against and (b) the dense synchronous sweep
whose hot loop is the Pallas `relax_ell` kernel (pull-mode min-plus
over in-edges).
"""

from __future__ import annotations

import functools
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.formats import Graph, coo_to_csr, graph_fingerprint
from repro.graph.partition import chunk_fat_rows
from repro.kernels.relax_ell import relax_rows

# transpose-ELL memo: rebuilding the in-edge ELL is an O(m) sort +
# scatter per call, which repeated --verify runs and the reference-
# equivalence tests used to pay on EVERY sweep.  Keyed by graph
# identity + content fingerprint (so in-place edge mutation, the
# perturbation idiom, invalidates) + width; bounded LRU.
_IN_ELL_CACHE: "OrderedDict[tuple, tuple]" = OrderedDict()
_IN_ELL_CACHE_SIZE = 8


def in_ell_cache_clear() -> None:
    _IN_ELL_CACHE.clear()


def in_ell(g: Graph, width: int | None = None, *, cache: bool = True):
    """ELL over *in*-edges (transpose), fat rows chunked; returns
    (row_dst, col, wgt) where row_dst maps virtual rows -> vertex.
    Memoized per (graph content, width) — pass ``cache=False`` to
    force a rebuild."""
    key = (id(g), graph_fingerprint(g), width)
    if cache:
        hit = _IN_ELL_CACHE.get(key)
        if hit is not None:
            _IN_ELL_CACHE.move_to_end(key)
            return hit
    gt = Graph(g.n, g.dst, g.src, g.weight, name=g.name + "^T")
    csr = coo_to_csr(gt)
    w = width or max(1, min(64, csr.max_degree()))
    ell = chunk_fat_rows(csr, w, pad_col=g.n)
    if cache:
        _IN_ELL_CACHE[key] = ell
        if len(_IN_ELL_CACHE) > _IN_ELL_CACHE_SIZE:
            _IN_ELL_CACHE.popitem(last=False)
    return ell


@functools.partial(jax.jit, static_argnames=("n", "source", "impl"))
def _sweep_step(d, row_dst, col, wgt, *, n, source, impl):
    """One synchronous R0/R1 application.  Module-level jit so repeated
    sweeps over same-shaped graphs reuse the trace (the old per-call
    closure re-traced every invocation)."""
    d_ext = jnp.concatenate([d, jnp.array([jnp.inf])])
    row_min = relax_rows(d_ext, col, wgt, impl=impl)  # (R,)
    # combine virtual rows of the same vertex (fat-row chunking)
    new = jnp.full((n + 1,), jnp.inf).at[row_dst].min(row_min)[:n]
    return new.at[source].set(0.0)  # rule R0


def synchronous_sweep(
    g: Graph,
    source: int,
    d0: np.ndarray,
    iters: int,
    *,
    impl: str = "ref",
    ell: tuple | None = None,
) -> np.ndarray:
    """Run `iters` synchronous applications of R0/R1 from state d0.

    ``ell`` accepts a precomputed ``in_ell(g)`` triple; otherwise the
    per-graph memo supplies it, so repeated sweeps on one graph
    re-chunk nothing."""
    row_dst, col, wgt = ell if ell is not None else in_ell(g)
    row_dst = jnp.asarray(row_dst)
    col = jnp.asarray(col)
    wgt = jnp.asarray(wgt)

    d = jnp.asarray(d0, jnp.float32)
    for _ in range(iters):
        d_next = _sweep_step(
            d, row_dst, col, wgt, n=g.n, source=int(source), impl=impl
        )
        if bool(jnp.all(d_next == d)):
            break
        d = d_next
    return np.asarray(d)
