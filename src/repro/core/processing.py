"""Processing functions π (paper §III, Definition 4 and variants).

All the graph problems the AGM models here share the monotone
state-update structure that makes the self-stabilizing kernel
lock-free (paper §II): the per-vertex state combine is ``min`` (or
``max``), so composite atomicity collapses to an atomic scatter-min.

A :class:`ProcessingFn` specifies, in jnp-traceable form:

* ``edge_update(s, w)`` — N of the statement: the candidate state a
  workitem ⟨u, s⟩ generates for a neighbor across an edge of weight w
  (π^sssp: ``s + w``; BFS: ``s + 1``; CC: ``s``; SSWP: ``min(s, w)``).
* ``better(a, b)`` — C of the statement: does candidate a improve b.
* ``reduce`` / ``worst`` — the monotone combine and its identity.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ProcessingFn:
    name: str
    edge_update: Callable  # (src_state, edge_weight) -> candidate
    better: Callable       # (a, b) -> bool, True iff a strictly improves b
    reduce: Callable       # jnp.minimum or jnp.maximum
    worst: float           # identity of `reduce` (= "no candidate")
    uses_weights: bool = True
    # natural initial workitem state for a source vertex (π^sssp: 0;
    # CC: the vertex's own label; SSWP: unbounded capacity).  None
    # means 0.0 — the additive-path default.
    source_init: Optional[Callable] = None

    def initial_value(self, vertex: int) -> float:
        if self.source_init is None:
            return 0.0
        return float(self.source_init(vertex))

    def reduce_array(self, x, axis):
        return (
            jnp.min(x, axis=axis)
            if self.reduce is jnp.minimum
            else jnp.max(x, axis=axis)
        )


SSSP = ProcessingFn(
    name="sssp",
    edge_update=lambda s, w: s + w,
    better=lambda a, b: a < b,
    reduce=jnp.minimum,
    worst=float("inf"),
)

BFS = ProcessingFn(
    name="bfs",
    edge_update=lambda s, w: s + 1.0,
    better=lambda a, b: a < b,
    reduce=jnp.minimum,
    worst=float("inf"),
    uses_weights=False,
)

# Connected components by min-label propagation.  Initial workitem set
# S = {⟨v, v⟩ : v ∈ V} (every vertex starts pending with its own id).
CC = ProcessingFn(
    name="cc",
    edge_update=lambda s, w: s,
    better=lambda a, b: a < b,
    reduce=jnp.minimum,
    worst=float("inf"),
    uses_weights=False,
    source_init=lambda v: float(v),
)

# Single-source widest path: maximize the bottleneck capacity.
SSWP = ProcessingFn(
    name="sswp",
    edge_update=lambda s, w: jnp.minimum(s, w),
    better=lambda a, b: a > b,
    reduce=jnp.maximum,
    worst=float("-inf"),
    source_init=lambda v: float("inf"),
)

PROCESSING_FNS = {p.name: p for p in (SSSP, BFS, CC, SSWP)}
