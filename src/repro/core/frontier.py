"""Frontier compaction + sparse candidate exchange (O(frontier) supersteps).

The dense engine relaxes all R ELL rows and exchanges O(|V|) candidate
floats per superstep no matter how small the eligible class is — so the
paper's finer orderings (arXiv:1706.05760 §IV) shrink *work* but not
*communication*.  The AGM's workitem sets (arXiv:1604.04772) are
exactly the sparse structure this module recovers, under the TPU
constraint that every shape is static:

* :func:`compact_rows` — ``jnp.where``-style compaction of the eligible
  virtual-row mask into a fixed-capacity index list (cap F, overflow
  flag for the dense fallback),
* :func:`bucket_slots` / :func:`scatter_plane` — per-destination-rank
  slotting of the candidate buffer into fixed-capacity (idx, val)
  buffers,
* :func:`sparse_payload` / :func:`unpack_combine` — the (P, K·S)
  payload moved by one ``all_to_all`` (values, bitcast int32 indices
  and, for KLA, levels as f32 planes — or u32 indices + packed 16-bit
  round-up value-delta codes in the quantized :data:`PAYLOAD_MODES`)
  and the owner-side scatter-combine back into a dense per-vertex
  array.

Everything here is collective-free local compute; the engine supplies
the ``all_to_all`` and the global (uniform-across-ranks) fallback
decision.  Capacities are static Python ints fixed at trace time —
:func:`frontier_caps` derives them from the partition shape and the
``frontier_cap`` knob.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

INF = jnp.float32(jnp.inf)

#: Sparse-exchange payload encodings.  "exact" moves f32 values +
#: bitcast-i32 indices (bit-identical to the dense path).  "bf16" /
#: "u16" move u32 indices + 16-bit quantized value *deltas* against
#: each segment's lower bound — round-up-only, so every decoded
#: candidate is >= the exact candidate (inflationary) and the
#: self-stabilizing kernel repairs the error (min-reduce semirings
#: only; the engine enforces this).
PAYLOAD_MODES = ("exact", "bf16", "u16")


def payload_plane_words(
    slot_cap: int, use_level: bool, payload: str = "exact"
) -> int:
    """Axis-1 width, in 32-bit words, of one destination segment of the
    sparse all_to_all payload.

    exact:     [f32 values | bitcast-i32 indices | (f32 levels)]
    quantized: [u32 indices | packed u16-pair deltas | lo
                | (scale, u16 only) | (bitcast-f32 levels)]
    """
    S = slot_cap
    if payload == "exact":
        return (3 if use_level else 2) * S
    if payload not in PAYLOAD_MODES:
        raise ValueError(f"unknown payload mode {payload!r}")
    head = 1 if payload == "bf16" else 2  # lo (+ scale)
    return S + (S + 1) // 2 + head + (S if use_level else 0)


def _quantize_bf16(val_buf: jax.Array, lo_fin: jax.Array) -> jax.Array:
    """Round-up bf16 codes for ``val_buf - lo_fin`` (both >= 0 planes).

    The code is the high half of the delta's f32 bits, bumped by one
    when any low bit is set (carry into the exponent is exactly IEEE
    round-toward-+inf, and +inf's code 0x7F80 is a fixed point).  The
    sender then *verifies* its own code with the receiver's decode
    expression; any code that would reconstruct below the exact value
    (the f32 subtraction itself can round down) is replaced by the
    +inf code — a dropped candidate is inflationary-to-+inf and gets
    repaired, never a deflation.
    """
    delta = val_buf - lo_fin[:, None]
    bits = jax.lax.bitcast_convert_type(delta, jnp.uint32)
    carry = (bits & jnp.uint32(0xFFFF)) != jnp.uint32(0)
    q = (bits >> jnp.uint32(16)) + carry.astype(jnp.uint32)
    recon = lo_fin[:, None] + jax.lax.bitcast_convert_type(
        q << jnp.uint32(16), jnp.float32
    )
    return jnp.where(recon < val_buf, jnp.uint32(0x7F80), q)


def _quantize_u16(
    val_buf: jax.Array, lo_fin: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Round-up linear u16 codes + per-segment scale (65535 = +inf).

    ``q = 0`` is pinned to slots whose value *equals* the segment
    lower bound (they decode to ``lo_fin`` bit-exactly, so the
    segment minimum always survives quantization); everything else is
    ceil-scaled with a +1 guard and then sender-verified against the
    receiver's decode expression exactly as in bf16 mode.
    """
    fin = jnp.isfinite(val_buf)
    delta = val_buf - lo_fin[:, None]
    dmax = jnp.max(jnp.where(fin, delta, jnp.float32(0.0)), axis=1)
    scale = jnp.maximum(dmax / jnp.float32(65534.0), jnp.float32(1e-30))
    qf = jnp.ceil(delta / scale[:, None]) + jnp.float32(1.0)
    q = jnp.clip(qf, 0.0, 65534.0).astype(jnp.uint32)
    exact0 = val_buf == lo_fin[:, None]
    q = jnp.where(exact0, jnp.uint32(0), q)
    recon = lo_fin[:, None] + q.astype(jnp.float32) * scale[:, None]
    good = exact0 | (fin & (recon >= val_buf))
    return jnp.where(good, q, jnp.uint32(65535)), scale


def _pack_u16_pairs(q: jax.Array, slot_cap: int) -> jax.Array:
    """Pack (P, S) u16 codes into (P, ceil(S/2)) u32 words, low code
    in the low half."""
    H = (slot_cap + 1) // 2
    qp = jnp.pad(q, ((0, 0), (0, 2 * H - slot_cap)))
    return qp[:, 0::2] | (qp[:, 1::2] << jnp.uint32(16))


def _unpack_u16_pairs(pairs: jax.Array, slot_cap: int) -> jax.Array:
    """Inverse of :func:`_pack_u16_pairs`: (P, ceil(S/2)) -> (P, S)."""
    Pn, H = pairs.shape
    lo = pairs & jnp.uint32(0xFFFF)
    hi = pairs >> jnp.uint32(16)
    return jnp.stack([lo, hi], axis=-1).reshape(Pn, 2 * H)[:, :slot_cap]


def frontier_caps(
    rows: int,
    width: int,
    n_local: int,
    n_parts: int,
    frontier_cap: int | None = None,
) -> tuple[int, int]:
    """Static (row_cap, slot_cap) for the sparse path.

    ``row_cap`` — max eligible virtual rows compacted per device per
    superstep (the knob F; default R/8).  ``slot_cap`` — per-destination
    -rank candidate slots in the sparse exchange, sized so a row_cap
    frontier's candidates spread evenly over ranks fit.  The ELL width
    is ~2x the average degree (graph.partition.default_ell_width), so
    half of F·W is padding by construction and slots are provisioned
    for F·W/(2P); skewed destinations (or denser-than-average
    frontiers) overflow into the dense fallback for that superstep
    instead of corrupting anything.
    """
    if frontier_cap is None:
        row_cap = max(8, rows // 8)
    else:
        row_cap = max(1, int(frontier_cap))
    row_cap = min(rows, row_cap)
    # beyond n_local/2 slots the (idx, val) payload can never move
    # fewer words than the dense reduce-scatter, so cap there and let
    # overflow fall back instead
    slot_cap = max(
        1,
        min(n_local // 2, (row_cap * width) // (2 * max(1, n_parts))),
    )
    return row_cap, slot_cap


def grow_frontier_cap(rows: int, cap: int) -> int:
    """Next rho-stepping row capacity after overflow: double, clamped
    to the per-device ELL row count (beyond which compaction is moot
    and the dense sweep is strictly cheaper)."""
    return min(int(rows), max(1, int(cap)) * 2)


def compact_rows(mask: jax.Array, cap: int):
    """Compact a (R,) bool mask into a capacity-``cap`` index list.

    Returns ``(idx, count, overflow)``: ``idx`` (cap,) int32 holds the
    first ``cap`` set positions in order, padded with the sentinel R
    (one past the last row — gathers fill through it); ``count`` the
    true population; ``overflow`` True iff the mask doesn't fit.
    """
    R = mask.shape[0]
    (idx,) = jnp.nonzero(mask, size=cap, fill_value=R)
    count = jnp.sum(mask.astype(jnp.int32))
    return idx.astype(jnp.int32), count, count > jnp.int32(cap)


def bucket_slots(mask2d: jax.Array, slot_cap: int):
    """Per-destination slot assignment for candidate compaction.

    ``mask2d`` (P, n_local) marks real candidates per destination rank.
    Returns ``(slot, overflow)``: ``slot`` (P, n_local) int32 gives each
    candidate its position within destination p's buffer (``slot_cap``
    for non-candidates and overflow spill — a dropped slot); ``overflow``
    True iff some destination holds more than ``slot_cap`` candidates.
    """
    pos = jnp.cumsum(mask2d.astype(jnp.int32), axis=1) - jnp.int32(1)
    overflow = jnp.max(pos[:, -1]) + jnp.int32(1) > jnp.int32(slot_cap)
    slot = jnp.where(
        mask2d & (pos < jnp.int32(slot_cap)), pos, jnp.int32(slot_cap)
    )
    return slot, overflow


def scatter_plane(vals2d: jax.Array, slot: jax.Array, slot_cap: int, fill):
    """Scatter (P, n_local) values into their (P, slot_cap) buffer
    positions; slot ``slot_cap`` is a discarded spill column."""
    Pn = vals2d.shape[0]
    rows = jnp.broadcast_to(
        jnp.arange(Pn, dtype=jnp.int32)[:, None], vals2d.shape
    )
    buf = jnp.full((Pn, slot_cap + 1), fill, vals2d.dtype)
    return buf.at[rows, slot].set(vals2d, mode="drop")[:, :slot_cap]


def sparse_payload(
    C: jax.Array,
    extra_planes,
    n_parts: int,
    slot_cap: int,
    worst,
    payload: str = "exact",
):
    """Build the per-destination all_to_all payload from the (n_pad,)
    local candidate buffer ``C``.

    ``payload="exact"`` (default): f32, axis-1 layout [values | bitcast
    int32 local indices | extra planes...] — ``extra_planes`` is a list
    of ``(array, fill)`` pairs of (n_pad,) f32 attributes riding along
    (the KLA level).  Bit-identical to the dense exchange.

    ``payload="bf16"`` / ``"u16"``: u32, axis-1 layout [indices |
    packed 16-bit value-delta codes | segment lower bound (+ scale for
    u16) | bitcast extra planes...].  Indices stay full-width (the
    payload-overflow lint's invariant: quantize values, never indices);
    values are round-up-only deltas, so decoded candidates are >= the
    exact ones and self-stabilization repairs them.  Requires a
    min-reduce semiring with ``worst == +inf`` (the engine enforces).

    Returns ``(payload, overflow)``; empty slots carry ``worst`` values
    and the index sentinel n_local (the owner's discarded dummy slot).
    """
    Pn = n_parts
    n_local = C.shape[0] // Pn
    C2 = C.reshape(Pn, n_local)
    slot, overflow = bucket_slots(C2 != worst, slot_cap)
    lidx = jnp.broadcast_to(
        jnp.arange(n_local, dtype=jnp.int32)[None, :], C2.shape
    )
    idx_buf = scatter_plane(lidx, slot, slot_cap, jnp.int32(n_local))
    val_buf = scatter_plane(C2, slot, slot_cap, jnp.float32(worst))
    if payload == "exact":
        planes = [
            val_buf,
            jax.lax.bitcast_convert_type(idx_buf, jnp.float32),
        ]
        for arr, fill in extra_planes:
            planes.append(
                scatter_plane(
                    arr.reshape(Pn, n_local), slot, slot_cap,
                    jnp.float32(fill),
                )
            )
        return jnp.concatenate(planes, axis=1), overflow
    if payload not in PAYLOAD_MODES:
        raise ValueError(f"unknown payload mode {payload!r}")
    lo = jnp.min(val_buf, axis=1)  # per-destination-segment lower bound
    lo_fin = jnp.where(jnp.isfinite(lo), lo, jnp.float32(0.0))
    if payload == "bf16":
        q = _quantize_bf16(val_buf, lo_fin)
        head = [lo]
    else:
        q, scale = _quantize_u16(val_buf, lo_fin)
        head = [lo, scale]
    words = [
        idx_buf.astype(jnp.uint32),
        _pack_u16_pairs(q, slot_cap),
        jax.lax.bitcast_convert_type(jnp.stack(head, axis=1), jnp.uint32),
    ]
    for arr, fill in extra_planes:
        lvl_buf = scatter_plane(
            arr.reshape(Pn, n_local), slot, slot_cap, jnp.float32(fill)
        )
        words.append(jax.lax.bitcast_convert_type(lvl_buf, jnp.uint32))
    return jnp.concatenate(words, axis=1), overflow


def unpack_combine(
    recv: jax.Array,
    n_local: int,
    slot_cap: int,
    is_min: bool,
    worst,
    has_level: bool,
    payload: str = "exact",
):
    """Owner-side combine of a received (P, K·S) payload.

    Returns ``(mine, mineL)``: the (n_local,) combined candidate per
    owned vertex and, when ``has_level``, the minimum level among
    candidates matching the winning value (the dense path's
    deterministic tie-break); ``mineL`` is None otherwise.

    For quantized payloads the codes are decoded with the *same*
    expression the sender verified against, so every decoded value is
    exactly the sender's reconstruction: >= the exact candidate, equal
    at each segment's lower bound.
    """
    S = slot_cap
    if payload == "exact":
        val = recv[:, :S]
        idx = jax.lax.bitcast_convert_type(recv[:, S : 2 * S], jnp.int32)
        lvl_base = 2 * S
    else:
        if payload not in PAYLOAD_MODES:
            raise ValueError(f"unknown payload mode {payload!r}")
        H = (S + 1) // 2
        idx = recv[:, :S].astype(jnp.int32)
        q = _unpack_u16_pairs(recv[:, S : S + H], S)
        lo = jax.lax.bitcast_convert_type(recv[:, S + H], jnp.float32)
        lo_fin = jnp.where(jnp.isfinite(lo), lo, jnp.float32(0.0))
        if payload == "bf16":
            # the +inf code 0x7F80 decodes to lo_fin + inf = +inf
            val = lo_fin[:, None] + jax.lax.bitcast_convert_type(
                q << jnp.uint32(16), jnp.float32
            )
            lvl_base = S + H + 1
        else:
            scale = jax.lax.bitcast_convert_type(
                recv[:, S + H + 1], jnp.float32
            )
            val = jnp.where(
                q == jnp.uint32(65535),
                INF,
                lo_fin[:, None] + q.astype(jnp.float32) * scale[:, None],
            )
            lvl_base = S + H + 2
    buf = jnp.full((n_local + 1,), worst, jnp.float32)
    flat_i, flat_v = idx.reshape(-1), val.reshape(-1)
    buf = buf.at[flat_i].min(flat_v) if is_min else buf.at[flat_i].max(flat_v)
    mine = buf[:n_local]
    if not has_level:
        return mine, None
    lvl = recv[:, lvl_base : lvl_base + S]
    if payload != "exact":
        lvl = jax.lax.bitcast_convert_type(lvl, jnp.float32)
    win = val == buf[idx]  # sentinel slots: worst == worst, lvl fill = inf
    lbuf = jnp.full((n_local + 1,), INF, jnp.float32)
    lbuf = lbuf.at[flat_i].min(jnp.where(win, lvl, INF).reshape(-1))
    return mine, lbuf[:n_local]
