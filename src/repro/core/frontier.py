"""Frontier compaction + sparse candidate exchange (O(frontier) supersteps).

The dense engine relaxes all R ELL rows and exchanges O(|V|) candidate
floats per superstep no matter how small the eligible class is — so the
paper's finer orderings (arXiv:1706.05760 §IV) shrink *work* but not
*communication*.  The AGM's workitem sets (arXiv:1604.04772) are
exactly the sparse structure this module recovers, under the TPU
constraint that every shape is static:

* :func:`compact_rows` — ``jnp.where``-style compaction of the eligible
  virtual-row mask into a fixed-capacity index list (cap F, overflow
  flag for the dense fallback),
* :func:`bucket_slots` / :func:`scatter_plane` — per-destination-rank
  slotting of the candidate buffer into fixed-capacity (idx, val)
  buffers,
* :func:`sparse_payload` / :func:`unpack_combine` — the (P, K·S)
  payload moved by one ``all_to_all`` (values, bitcast int32 indices
  and, for KLA, levels as f32 planes) and the owner-side
  scatter-combine back into a dense per-vertex array.

Everything here is collective-free local compute; the engine supplies
the ``all_to_all`` and the global (uniform-across-ranks) fallback
decision.  Capacities are static Python ints fixed at trace time —
:func:`frontier_caps` derives them from the partition shape and the
``frontier_cap`` knob.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

INF = jnp.float32(jnp.inf)


def frontier_caps(
    rows: int,
    width: int,
    n_local: int,
    n_parts: int,
    frontier_cap: int | None = None,
) -> tuple[int, int]:
    """Static (row_cap, slot_cap) for the sparse path.

    ``row_cap`` — max eligible virtual rows compacted per device per
    superstep (the knob F; default R/8).  ``slot_cap`` — per-destination
    -rank candidate slots in the sparse exchange, sized so a row_cap
    frontier's candidates spread evenly over ranks fit.  The ELL width
    is ~2x the average degree (graph.partition.default_ell_width), so
    half of F·W is padding by construction and slots are provisioned
    for F·W/(2P); skewed destinations (or denser-than-average
    frontiers) overflow into the dense fallback for that superstep
    instead of corrupting anything.
    """
    if frontier_cap is None:
        row_cap = max(8, rows // 8)
    else:
        row_cap = max(1, int(frontier_cap))
    row_cap = min(rows, row_cap)
    # beyond n_local/2 slots the (idx, val) payload can never move
    # fewer words than the dense reduce-scatter, so cap there and let
    # overflow fall back instead
    slot_cap = max(
        1,
        min(n_local // 2, (row_cap * width) // (2 * max(1, n_parts))),
    )
    return row_cap, slot_cap


def grow_frontier_cap(rows: int, cap: int) -> int:
    """Next rho-stepping row capacity after overflow: double, clamped
    to the per-device ELL row count (beyond which compaction is moot
    and the dense sweep is strictly cheaper)."""
    return min(int(rows), max(1, int(cap)) * 2)


def compact_rows(mask: jax.Array, cap: int):
    """Compact a (R,) bool mask into a capacity-``cap`` index list.

    Returns ``(idx, count, overflow)``: ``idx`` (cap,) int32 holds the
    first ``cap`` set positions in order, padded with the sentinel R
    (one past the last row — gathers fill through it); ``count`` the
    true population; ``overflow`` True iff the mask doesn't fit.
    """
    R = mask.shape[0]
    (idx,) = jnp.nonzero(mask, size=cap, fill_value=R)
    count = jnp.sum(mask.astype(jnp.int32))
    return idx.astype(jnp.int32), count, count > jnp.int32(cap)


def bucket_slots(mask2d: jax.Array, slot_cap: int):
    """Per-destination slot assignment for candidate compaction.

    ``mask2d`` (P, n_local) marks real candidates per destination rank.
    Returns ``(slot, overflow)``: ``slot`` (P, n_local) int32 gives each
    candidate its position within destination p's buffer (``slot_cap``
    for non-candidates and overflow spill — a dropped slot); ``overflow``
    True iff some destination holds more than ``slot_cap`` candidates.
    """
    pos = jnp.cumsum(mask2d.astype(jnp.int32), axis=1) - jnp.int32(1)
    overflow = jnp.max(pos[:, -1]) + jnp.int32(1) > jnp.int32(slot_cap)
    slot = jnp.where(
        mask2d & (pos < jnp.int32(slot_cap)), pos, jnp.int32(slot_cap)
    )
    return slot, overflow


def scatter_plane(vals2d: jax.Array, slot: jax.Array, slot_cap: int, fill):
    """Scatter (P, n_local) values into their (P, slot_cap) buffer
    positions; slot ``slot_cap`` is a discarded spill column."""
    Pn = vals2d.shape[0]
    rows = jnp.broadcast_to(
        jnp.arange(Pn, dtype=jnp.int32)[:, None], vals2d.shape
    )
    buf = jnp.full((Pn, slot_cap + 1), fill, vals2d.dtype)
    return buf.at[rows, slot].set(vals2d, mode="drop")[:, :slot_cap]


def sparse_payload(
    C: jax.Array,
    extra_planes,
    n_parts: int,
    slot_cap: int,
    worst,
):
    """Build the (P, K·S) all_to_all payload from the (n_pad,) local
    candidate buffer ``C``.

    Plane layout along axis 1: [values | bitcast int32 local indices |
    extra planes...] — ``extra_planes`` is a list of ``(array, fill)``
    pairs of (n_pad,) f32 attributes riding along (the KLA level).
    Returns ``(payload, overflow)``; empty slots carry ``worst`` values
    and the index sentinel n_local (the owner's discarded dummy slot).
    """
    Pn = n_parts
    n_local = C.shape[0] // Pn
    C2 = C.reshape(Pn, n_local)
    slot, overflow = bucket_slots(C2 != worst, slot_cap)
    lidx = jnp.broadcast_to(
        jnp.arange(n_local, dtype=jnp.int32)[None, :], C2.shape
    )
    idx_buf = scatter_plane(lidx, slot, slot_cap, jnp.int32(n_local))
    planes = [
        scatter_plane(C2, slot, slot_cap, jnp.float32(worst)),
        jax.lax.bitcast_convert_type(idx_buf, jnp.float32),
    ]
    for arr, fill in extra_planes:
        planes.append(
            scatter_plane(
                arr.reshape(Pn, n_local), slot, slot_cap, jnp.float32(fill)
            )
        )
    return jnp.concatenate(planes, axis=1), overflow


def unpack_combine(
    recv: jax.Array,
    n_local: int,
    slot_cap: int,
    is_min: bool,
    worst,
    has_level: bool,
):
    """Owner-side combine of a received (P, K·S) payload.

    Returns ``(mine, mineL)``: the (n_local,) combined candidate per
    owned vertex and, when ``has_level``, the minimum level among
    candidates matching the winning value (the dense path's
    deterministic tie-break); ``mineL`` is None otherwise.
    """
    S = slot_cap
    val = recv[:, :S]
    idx = jax.lax.bitcast_convert_type(recv[:, S : 2 * S], jnp.int32)
    buf = jnp.full((n_local + 1,), worst, jnp.float32)
    flat_i, flat_v = idx.reshape(-1), val.reshape(-1)
    buf = buf.at[flat_i].min(flat_v) if is_min else buf.at[flat_i].max(flat_v)
    mine = buf[:n_local]
    if not has_level:
        return mine, None
    lvl = recv[:, 2 * S : 3 * S]
    win = val == buf[idx]  # sentinel slots: worst == worst, lvl fill = inf
    lbuf = jnp.full((n_local + 1,), INF, jnp.float32)
    lbuf = lbuf.at[flat_i].min(jnp.where(win, lvl, INF).reshape(-1))
    return mine, lbuf[:n_local]
