"""Work / synchronization metrics.

The container cannot time a Cray (or a TPU pod), so the benchmark
tables report the quantities the paper's wall-clock decomposes into:
work terms (relaxations = edges relaxed, commits = useful state
updates, workitems processed) and synchronization terms (equivalence
classes / supersteps, collective rounds), plus exchanged bytes.  A
calibrated linear cost model over these terms reproduces the *shape*
of the paper's comparisons (EXPERIMENTS.md §Paper-validation).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass
class WorkMetrics:
    classes: int = 0        # equivalence classes executed (root supersteps)
    workitems: int = 0      # workitems fed to the processing function
    commits: int = 0        # U evaluations that changed state (useful work)
    relaxations: int = 0    # edge relaxations (candidate generations)
    supersteps: int = 0     # distributed engine loop iterations
    exchange_bytes: int = 0  # bytes moved by candidate exchange collectives
    collective_rounds: int = 0
    converged: bool = True  # False iff the loop hit max_iters with
    #                         pending work left (state is truncated)
    sparse_fallbacks: int = 0  # supersteps on which a sparse-capable
    #   exchange mode ('sparse'/'auto') used the dense path instead —
    #   capacity overflow, the auto pending-count heuristic, or auto's
    #   static can't-pay shortcut; 0 in plain dense modes
    overflow_streak: int = 0  # longest run of *consecutive* supersteps
    #   on which sparse capacity (row or slot) overflowed somewhere —
    #   the signal behind the actionable frontier_cap RuntimeWarning
    retraces: int = 0  # engine re-traces forced by shape-changing
    #   adaptive decisions (new frontier_cap) during this solve; 0 for
    #   static solves and for adaptive solves that only touched
    #   dynamic scalars (delta, exchange force)
    repair_sweeps: int = 0  # exact warm restarts the quantized-payload
    #   repair loop needed to certify the exact fixpoint (0 for exact
    #   payloads; host re-verification sweeps are folded into
    #   relaxations/supersteps)

    def waste_ratio(self) -> float:
        """Relaxations per useful commit — the paper's redundant-work axis."""
        return self.relaxations / max(1, self.commits)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        s = (
            f"classes={self.classes} supersteps={self.supersteps} "
            f"workitems={self.workitems} commits={self.commits} "
            f"relax={self.relaxations} waste={self.waste_ratio():.2f} "
            f"xbytes={self.exchange_bytes}"
        )
        # anomaly fields appear only when nonzero: the one-liner stays
        # short on clean solves but never hides the events an operator
        # needs to see (dense fallbacks, adaptive retraces, quantized
        # repairs, capacity-overflow runs)
        if self.sparse_fallbacks:
            s += f" sparse_fallbacks={self.sparse_fallbacks}"
        if self.retraces:
            s += f" retraces={self.retraces}"
        if self.repair_sweeps:
            s += f" repair_sweeps={self.repair_sweeps}"
        if self.overflow_streak:
            s += f" overflow_streak={self.overflow_streak}"
        return s + ("" if self.converged else " TRUNCATED")


@dataclasses.dataclass
class SuperstepWindow:
    """Bounded per-superstep metrics window published by an adaptive
    segment engine (``EngineConfig.adapt_window > 0``) — the
    observation a :mod:`repro.tune` controller policy maps to the next
    segment's tunables.  Lists hold one entry per superstep actually
    executed in the segment (``<= adapt_window``), all global
    (psum'd) counts; byte costs are reconstructed host-side from the
    sparse/dense choice and the segment's static capacities, so the
    window itself stays int32 on device."""

    pending: list          # global pending workitems after each superstep
    eligible: list         # global eligible-class size per superstep
    rows: list             # global eligible ELL rows per superstep
    sparse_used: list      # 1 iff the sparse exchange ran that superstep
    bytes_moved: list      # exchange bytes per superstep (host-derived)
    overflow_streak: int   # consecutive-overflow run live at segment end
    supersteps_total: int  # supersteps executed since solve start
    n: int                 # global padded vertex count (P * n_local)
    rows_per_rank: int     # ELL rows per device (frontier_cap ceiling)
    sparse_capable: bool   # exchange mode is 'sparse' or 'auto'

    def last_pending(self) -> int:
        return int(self.pending[-1]) if self.pending else 0

    def mean_eligible(self) -> float:
        if not self.eligible:
            return 0.0
        return sum(self.eligible) / len(self.eligible)


@dataclasses.dataclass
class LatencyStats:
    """Order statistics over a batch of latency samples — the serving
    tier's SLO vocabulary (p50/p99 per query, throughput over the
    window).  Percentiles use the nearest-rank method so a reported
    p99 is an actual observed sample, not an interpolation."""

    count: int = 0
    total_s: float = 0.0
    mean_s: float = 0.0
    min_s: float = 0.0
    p50_s: float = 0.0
    p90_s: float = 0.0
    p99_s: float = 0.0
    max_s: float = 0.0

    @classmethod
    def from_samples(cls, samples: Sequence[float]) -> "LatencyStats":
        xs = sorted(float(s) for s in samples)
        if not xs:
            return cls()
        def rank(pct: int) -> float:
            # nearest-rank: smallest sample with cumulative freq >= pct%
            i = (pct * len(xs) + 99) // 100  # ceil(pct·n/100), exact ints
            return xs[min(max(i - 1, 0), len(xs) - 1)]
        return cls(
            count=len(xs),
            total_s=sum(xs),
            mean_s=sum(xs) / len(xs),
            min_s=xs[0],
            p50_s=rank(50),
            p90_s=rank(90),
            p99_s=rank(99),
            max_s=xs[-1],
        )

    def merge(self, other: "LatencyStats") -> "LatencyStats":
        """Combine two windows.  count/total/mean/min/max merge
        exactly; percentiles are not mergeable from order statistics
        alone, so the merged percentile is the count-weighted mean of
        the windows' percentiles — the standard windowed-SLO
        approximation (exact when the windows are identically
        distributed)."""
        if self.count == 0:
            return dataclasses.replace(other)
        if other.count == 0:
            return dataclasses.replace(self)
        total_n = self.count + other.count
        def wmean(a: float, b: float) -> float:
            return (a * self.count + b * other.count) / total_n
        return LatencyStats(
            count=total_n,
            total_s=self.total_s + other.total_s,
            mean_s=(self.total_s + other.total_s) / total_n,
            min_s=min(self.min_s, other.min_s),
            p50_s=wmean(self.p50_s, other.p50_s),
            p90_s=wmean(self.p90_s, other.p90_s),
            p99_s=wmean(self.p99_s, other.p99_s),
            max_s=max(self.max_s, other.max_s),
        )

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        return (
            f"n={self.count} p50={self.p50_s*1e3:.2f}ms "
            f"p90={self.p90_s*1e3:.2f}ms p99={self.p99_s*1e3:.2f}ms "
            f"max={self.max_s*1e3:.2f}ms"
        )


# Calibrated cost model (EXPERIMENTS.md §Paper-validation): seconds =
# a*relaxations + b*commits + c*supersteps + d*exchange_bytes.  The
# coefficients below are per-unit costs on the target (TPU v5e pod):
# an edge relaxation is a few VPU flops + an HBM access amortized over
# ELL rows; a superstep costs one small-collective latency; exchange
# bytes move at ICI bandwidth.
COST_RELAX_S = 2.0e-9       # ~0.5 Gedge/s/chip effective scatter-min
COST_SUPERSTEP_S = 15e-6    # small all-reduce latency on a pod
COST_BYTE_S = 1.0 / 45e9    # ~45 GB/s effective per-chip ICI


def model_time_s(m: WorkMetrics, n_chips: int = 1) -> float:
    """Cost-model seconds for one SSSP solve on ``n_chips`` (work terms
    divide across chips; superstep latency does not)."""
    return (
        COST_RELAX_S * m.relaxations / n_chips
        + COST_SUPERSTEP_S * m.supersteps
        + COST_BYTE_S * m.exchange_bytes / n_chips
    )
