"""Extended AGM (paper §IV): spatial hierarchies with annotated
orderings.

An EAGM keeps the AGM's ordering at the *root* of a spatial hierarchy
(so it generates the same root equivalence classes — the EAGM
extension condition) and attaches additional, more relaxed orderings
to lower spatial levels, each ordering only the workitems resident in
that level's memory.

Hardware adaptation (DESIGN.md §2/§5): the paper's hierarchy
GLOBAL → PROCESS(node) → NUMA → THREAD maps onto a TPU pod cluster as

    GLOBAL → POD → DEVICE (chip) → CHUNK (VMEM-resident top-B prefix)

and the paper's variant names keep their meaning:

    buffer   — root ordering only (the plain AGM)
    nodeq    — Dijkstra ordering at PROCESS level → POD scope here
    numaq    — Dijkstra ordering at NUMA level → DEVICE scope here
    threadq  — Dijkstra ordering at THREAD level → CHUNK scope here
               (each device drains the B smallest pending items of the
               current root class, like a thread-local priority queue)

The scope tells the distributed engine which collective implements the
sub-ordering decision: POD needs a pod-internal pmin (cheaper than
global), DEVICE needs a local reduction only, CHUNK needs a local
top-B only.  Lower level ⇒ less synchronization — the paper's core
performance knob.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.ordering import Ordering, Dijkstra, make_ordering

# spatial levels, outermost to innermost
LEVELS = ("global", "pod", "device", "chunk")

# paper variant name -> spatial level carrying the <_dj sub-ordering
VARIANT_LEVEL = {
    "buffer": None,
    "nodeq": "pod",
    "numaq": "device",
    "threadq": "chunk",
}


@dataclasses.dataclass(frozen=True)
class EAGMPolicy:
    """Root ordering + (at most one) sub-root Dijkstra annotation.

    ``sub_level=None`` is the plain AGM (= the paper's `buffer`).
    ``chunk_size`` is B, the drain size for chunk-level ordering.
    """

    root: Ordering
    sub_level: Optional[str] = None  # 'pod' | 'device' | 'chunk' | None
    sub_ordering: Ordering = Dijkstra()
    chunk_size: int = 1024

    def __post_init__(self):
        if self.sub_level is not None and self.sub_level not in LEVELS[1:]:
            raise ValueError(f"bad spatial level {self.sub_level!r}")

    @property
    def variant(self) -> str:
        for name, lvl in VARIANT_LEVEL.items():
            if lvl == self.sub_level:
                return name
        return f"custom({self.sub_level})"

    @property
    def name(self) -> str:
        return f"{self.root.name}+{self.variant}"


def make_policy(
    root_spec: str, variant: str = "buffer", chunk_size: int = 1024
) -> EAGMPolicy:
    """E.g. make_policy('delta:5', 'threadq') — the paper's Fig. 4 grid."""
    if variant not in VARIANT_LEVEL:
        raise ValueError(
            f"variant must be one of {sorted(VARIANT_LEVEL)}, got {variant!r}"
        )
    return EAGMPolicy(
        root=make_ordering(root_spec),
        sub_level=VARIANT_LEVEL[variant],
        chunk_size=chunk_size,
    )


def paper_variant_specs(
    deltas=(3.0, 5.0, 7.0), ks=(1, 2, 3)
) -> list[str]:
    """The paper's evaluation grid as ``root+variant`` spec strings:
    {Δ-stepping, KLA, Chaotic} × {buffer, threadq, nodeq, numaq}
    (Figures 5-7), with the Δ and K sweeps of the experiments, plus
    the Dijkstra AGM baseline."""
    roots = (
        [f"delta:{d:g}" for d in deltas]
        + [f"kla:{k}" for k in ks]
        + ["chaotic"]
    )
    specs = [
        f"{root}+{variant}"
        for root in roots
        for variant in ("buffer", "threadq", "nodeq", "numaq")
    ]
    specs.append("dijkstra+buffer")
    return specs


def paper_variant_grid(
    deltas=(3.0, 5.0, 7.0), ks=(1, 2, 3), chunk_size: int = 1024
) -> list[EAGMPolicy]:
    """:func:`paper_variant_specs` materialized as policies."""
    grid: list[EAGMPolicy] = []
    for spec in paper_variant_specs(deltas, ks):
        root, variant = spec.split("+", 1)
        grid.append(make_policy(root, variant, chunk_size))
    return grid
