"""Extended AGM (paper §IV): spatial hierarchies with annotated
orderings.

An EAGM keeps the AGM's ordering at the *root* of a spatial hierarchy
(so it generates the same root equivalence classes — the EAGM
extension condition) and attaches additional, more relaxed orderings
to lower spatial levels, each ordering only the workitems resident in
that level's memory.

Hardware adaptation (DESIGN.md §2/§5): the paper's hierarchy
GLOBAL → PROCESS(node) → NUMA → THREAD maps onto a TPU pod cluster as

    GLOBAL → POD → DEVICE (chip) → CHUNK (VMEM-resident top-B prefix)

The central value type is :class:`Hierarchy`: an ordered list of
``(level, Ordering)`` annotations over ``LEVELS``, outermost first.
ANY ordering (chaotic / dijkstra / delta / kla / topk) may annotate
any level, and several levels may be annotated simultaneously — e.g.
Δ-stepping at GLOBAL refined by Dijkstra at POD refined by a finer Δ
at CHUNK::

    Hierarchy.from_spec("delta:5 > pod:dijkstra > chunk:delta:1")

The level determines which collective realizes the annotation's
equivalence-class decision (its *scope*):

    global  pmin over every mesh axis (the AGM root decision)
    pod     pmin over the intra-pod axes only (cheaper than global)
    device  device-local reduction, no communication
    chunk   device-local; a TopK annotation drains the B smallest
            workitems (the VMEM-resident prefix), a class ordering
            selects its locally-minimal class

Lower level ⇒ less synchronization — the paper's core performance
knob.  The EAGM *extension condition* (root equivalence classes must
be preserved) is structural here: annotations refine eligibility
strictly inside the previous level's selection, so validation only
needs the root to sit at GLOBAL and levels to nest outermost →
innermost.

The paper's variant names are presets over this algebra:

    buffer   — root ordering only (the plain AGM)
    nodeq    — Dijkstra at POD       (paper: PROCESS level)
    numaq    — Dijkstra at DEVICE    (paper: NUMA level)
    threadq  — TopK(B) at CHUNK      (paper: THREAD level; each device
               drains the B smallest pending items of the current
               root class, like a thread-local priority queue)

``EAGMPolicy`` / ``make_policy`` (the pre-hierarchy one-slot API)
remain as thin deprecation shims constructing equivalent hierarchies.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

from repro.core.ordering import (
    Dijkstra,
    Ordering,
    TopK,
    make_ordering,
    needs_level,
    suggest,
)

# spatial levels, outermost to innermost
LEVELS = ("global", "pod", "device", "chunk")

#: levels whose decision is a device-local computation (no collective)
LOCAL_LEVELS = ("device", "chunk")

#: human description of the collective realizing each level's decision
LEVEL_SCOPE = {
    "global": "pmin over all mesh axes",
    "pod": "pmin over intra-pod axes",
    "device": "device-local reduction",
    "chunk": "device-local top-B drain",
}

# paper variant name -> spatial level carrying the sub-root annotation
VARIANT_LEVEL = {
    "buffer": None,
    "nodeq": "pod",
    "numaq": "device",
    "threadq": "chunk",
}

DEFAULT_CHUNK = 1024


@dataclasses.dataclass(frozen=True)
class Hierarchy:
    """An EAGM: ordered ``(level, Ordering)`` annotations, outermost
    (GLOBAL — the AGM root) first.

    Validation enforces the EAGM extension condition's structural
    form: exactly one GLOBAL annotation, in first position; levels
    strictly outermost → innermost with no duplicates (so every
    annotation refines *within* the classes of the one above it, and
    the root classes are preserved); TopK (a drain, not a class
    selection) only at the local levels where a top-B is collective-
    free.
    """

    annotations: Tuple[Tuple[str, Ordering], ...]

    def __post_init__(self):
        annos = tuple(
            (lvl, o) if not isinstance(o, str) else (lvl, make_ordering(o))
            for lvl, o in self.annotations
        )
        object.__setattr__(self, "annotations", annos)
        if not annos:
            raise ValueError("Hierarchy needs at least the root annotation")
        for lvl, o in annos:
            if lvl not in LEVELS:
                raise ValueError(
                    f"bad spatial level {lvl!r} — must be one of "
                    f"{list(LEVELS)}{suggest(str(lvl), LEVELS)}"
                )
        if annos[0][0] != "global":
            raise ValueError(
                "the first annotation must sit at the 'global' level — it "
                "is the AGM root ordering whose equivalence classes the "
                f"EAGM must preserve (got {annos[0][0]!r})"
            )
        order = [LEVELS.index(lvl) for lvl, _ in annos]
        if any(b <= a for a, b in zip(order, order[1:])):
            raise ValueError(
                "annotations must nest one per level, outermost to "
                f"innermost {list(LEVELS)}; got levels "
                f"{[lvl for lvl, _ in annos]}"
            )
        for lvl, o in annos:
            if isinstance(o, TopK) and lvl not in LOCAL_LEVELS:
                raise ValueError(
                    f"TopK is a device-local drain and cannot annotate "
                    f"{lvl!r} — use it at one of {list(LOCAL_LEVELS)}, or "
                    "annotate this level with a class ordering"
                )

    # -- structure -----------------------------------------------------

    @property
    def root(self) -> Ordering:
        """The GLOBAL (AGM root) ordering."""
        return self.annotations[0][1]

    @property
    def sub(self) -> Tuple[Tuple[str, Ordering], ...]:
        """The sub-root annotations, outermost first."""
        return self.annotations[1:]

    @property
    def needs_level(self) -> bool:
        """True iff any annotation reads the KLA level attribute."""
        return any(needs_level(o) for _, o in self.annotations)

    def at(self, level: str) -> Optional[Ordering]:
        for lvl, o in self.annotations:
            if lvl == level:
                return o
        return None

    # -- construction --------------------------------------------------

    @classmethod
    def single(cls, root: Union[str, Ordering]) -> "Hierarchy":
        """The plain AGM: a root ordering and nothing below it."""
        return cls((("global", root),))

    @classmethod
    def from_spec(
        cls, spec: str, chunk_size: int = DEFAULT_CHUNK
    ) -> "Hierarchy":
        """Parse the hierarchy grammar: ``>``-separated annotations,
        outermost first; the first is the bare root ordering spec (an
        explicit ``global:`` prefix is allowed), later ones are
        ``level:ordering``::

            "delta:5 > pod:dijkstra > chunk:delta:1"
            "chaotic > chunk:topk:64"

        ``chunk_size`` supplies B for a bare ``chunk:topk`` (no drain
        size given).  The legacy preset form ``root+variant`` is also
        accepted, so ``Hierarchy.from_spec(h.name) == h`` for every
        hierarchy.
        """
        s = str(spec).strip()
        if "+" in s and ">" not in s:
            root, variant = s.split("+", 1)
            root, variant = root.strip(), variant.strip()
            if not root or not variant:
                raise ValueError(
                    f"empty {'variant' if root else 'root'} segment in "
                    f"spec {spec!r}"
                )
            return make_hierarchy(root, variant, chunk_size)
        segments = [seg.strip() for seg in str(spec).split(">")]
        if any(not seg for seg in segments):
            raise ValueError(
                f"empty annotation segment in hierarchy spec {spec!r}"
            )
        annos = []
        for i, seg in enumerate(segments):
            head = seg.split(":", 1)[0].strip().lower()
            if head in LEVELS:
                if ":" not in seg:
                    raise ValueError(
                        f"annotation {seg!r} in {spec!r} names level "
                        f"{head!r} but no ordering (expected "
                        "'level:ordering')"
                    )
                lvl, rest = seg.split(":", 1)
                lvl, rest = lvl.strip().lower(), rest.strip()
            elif i == 0:
                lvl, rest = "global", seg
            else:
                raise ValueError(
                    f"annotation {seg!r} in hierarchy spec {spec!r} must "
                    f"be 'level:ordering' with level in {list(LEVELS)}"
                    f"{suggest(head, LEVELS)}"
                )
            ordering = (
                TopK(chunk_size) if rest.lower() == "topk"
                else make_ordering(rest)
            )
            annos.append((lvl, ordering))
        return cls(tuple(annos))

    # -- naming --------------------------------------------------------

    @property
    def spec(self) -> str:
        """Canonical grammar-v2 string; ``from_spec(h.spec) == h``."""
        parts = [self.root.spec]
        parts += [f"{lvl}:{o.spec}" for lvl, o in self.sub]
        return " > ".join(parts)

    @property
    def variant(self) -> Optional[str]:
        """The paper preset name this hierarchy realizes, or None if
        it is a beyond-paper family point."""
        for variant, lvl in VARIANT_LEVEL.items():
            if self == make_hierarchy(self.root, variant,
                                      chunk_size=self._preset_chunk()):
                return variant
        return None

    def _preset_chunk(self) -> int:
        o = self.at("chunk")
        return o.drain if isinstance(o, TopK) else DEFAULT_CHUNK

    @property
    def name(self) -> str:
        v = self.variant
        if v is not None and self._preset_chunk() == DEFAULT_CHUNK:
            return f"{self.root.spec}+{v}"
        return self.spec

    def describe(self) -> str:
        """One line per annotation with its collective scope."""
        def scope(lvl, o):
            if lvl in LOCAL_LEVELS and isinstance(o, TopK):
                return f"device-local top-{o.drain} drain"
            if lvl in LOCAL_LEVELS:
                return "device-local minimal class"
            return LEVEL_SCOPE[lvl]

        return "; ".join(
            f"{lvl}: {o.spec} ({scope(lvl, o)})"
            for lvl, o in self.annotations
        )


def make_hierarchy(
    root: Union[str, Ordering],
    variant: str = "buffer",
    chunk_size: int = DEFAULT_CHUNK,
) -> Hierarchy:
    """The paper's Fig. 4 presets as hierarchies:
    ``make_hierarchy('delta:5', 'threadq')``."""
    if variant not in VARIANT_LEVEL:
        raise ValueError(
            f"variant must be one of {sorted(VARIANT_LEVEL)}, got "
            f"{variant!r}{suggest(str(variant), VARIANT_LEVEL)}"
        )
    if isinstance(root, str):
        root = make_ordering(root)
    annos = [("global", root)]
    lvl = VARIANT_LEVEL[variant]
    if lvl == "chunk":
        annos.append(("chunk", TopK(chunk_size)))
    elif lvl is not None:
        annos.append((lvl, Dijkstra()))
    return Hierarchy(tuple(annos))


def as_hierarchy(h) -> Hierarchy:
    """Coerce a Hierarchy | EAGMPolicy | spec string."""
    if isinstance(h, Hierarchy):
        return h
    if isinstance(h, EAGMPolicy):
        return h.hierarchy
    if isinstance(h, str):
        return Hierarchy.from_spec(h)
    raise TypeError(f"cannot interpret {h!r} as a Hierarchy")


# ---------------------------------------------------------------------
# deprecation shims: the pre-hierarchy one-slot variant API
# ---------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EAGMPolicy:
    """Deprecated one-slot API: root ordering + (at most one) sub-root
    Dijkstra annotation.  Kept as a shim; the engine consumes the
    equivalent :class:`Hierarchy` (``.hierarchy``).

    ``sub_level=None`` is the plain AGM (= the paper's `buffer`).
    ``chunk_size`` is B, the drain size for chunk-level ordering.
    """

    root: Ordering
    sub_level: Optional[str] = None  # 'pod' | 'device' | 'chunk' | None
    sub_ordering: Ordering = Dijkstra()
    chunk_size: int = DEFAULT_CHUNK

    def __post_init__(self):
        if self.sub_level is not None and self.sub_level not in LEVELS[1:]:
            raise ValueError(f"bad spatial level {self.sub_level!r}")

    @property
    def hierarchy(self) -> Hierarchy:
        """The equivalent per-level hierarchy (chunk-level Dijkstra
        draining is ``TopK(chunk_size)``, exactly the old behavior)."""
        annos = [("global", self.root)]
        if self.sub_level == "chunk":
            annos.append(
                ("chunk", TopK(self.chunk_size, key=self.sub_ordering))
            )
        elif self.sub_level is not None:
            annos.append((self.sub_level, self.sub_ordering))
        return Hierarchy(tuple(annos))

    @property
    def variant(self) -> str:
        for name, lvl in VARIANT_LEVEL.items():
            if lvl == self.sub_level:
                return name
        return f"custom({self.sub_level})"

    @property
    def name(self) -> str:
        return f"{self.root.name}+{self.variant}"


def make_policy(
    root_spec: str, variant: str = "buffer", chunk_size: int = DEFAULT_CHUNK
) -> EAGMPolicy:
    """Deprecated shim for the paper's Fig. 4 grid; prefer
    :func:`make_hierarchy` (e.g. ``make_hierarchy('delta:5',
    'threadq')``) or the spec grammar."""
    if variant not in VARIANT_LEVEL:
        raise ValueError(
            f"variant must be one of {sorted(VARIANT_LEVEL)}, got "
            f"{variant!r}{suggest(str(variant), VARIANT_LEVEL)}"
        )
    return EAGMPolicy(
        root=make_ordering(root_spec),
        sub_level=VARIANT_LEVEL[variant],
        chunk_size=chunk_size,
    )


# ---------------------------------------------------------------------
# the paper's evaluation grid
# ---------------------------------------------------------------------


def paper_variant_specs(
    deltas=(3.0, 5.0, 7.0), ks=(1, 2, 3)
) -> list:
    """The paper's evaluation grid as ``root+variant`` spec strings:
    {Δ-stepping, KLA, Chaotic} × {buffer, threadq, nodeq, numaq}
    (Figures 5-7), with the Δ and K sweeps of the experiments, plus
    the Dijkstra AGM baseline.  Every string parses (legacy grammar)
    to a preset hierarchy — the grid is a finite subset of the family
    space :class:`Hierarchy` spans."""
    roots = (
        [f"delta:{d:g}" for d in deltas]
        + [f"kla:{k}" for k in ks]
        + ["chaotic"]
    )
    specs = [
        f"{root}+{variant}"
        for root in roots
        for variant in ("buffer", "threadq", "nodeq", "numaq")
    ]
    specs.append("dijkstra+buffer")
    return specs


def paper_variant_grid(
    deltas=(3.0, 5.0, 7.0), ks=(1, 2, 3), chunk_size: int = DEFAULT_CHUNK
) -> list:
    """:func:`paper_variant_specs` materialized as hierarchies."""
    grid = []
    for spec in paper_variant_specs(deltas, ks):
        root, variant = spec.split("+", 1)
        grid.append(make_hierarchy(root, variant, chunk_size))
    return grid
