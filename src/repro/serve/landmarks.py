"""Landmark (hub) tier: cheap point-to-point estimates by triangle
inequality.

At startup, one ``solve_batch`` over K hub sources (highest out-degree
by default — RMAT hubs cover most shortest paths) materializes the
K×n distance matrix.  A point-to-point query (s, t) is then answered
in O(K) without touching the engine:

    lower = max_k ( d(L_k, t) - d(L_k, s) )      valid on any digraph
    upper = min_k ( d(L_k, s) + d(L_k, t) )      valid when the graph
                                                 is weight-symmetric
                                                 (rmat1/rmat2/road are)

The upper bound is the classic landmark estimate d(s,t) ≤ d(s,L)+d(L,t)
with d(s,L) read as d(L,s) — exact only under symmetry, so the index
must be built with ``symmetric=True`` to serve it; on directed graphs
only the lower bound is offered and the router escalates to an exact
solve.  ``exact=`` escalation is always available: the router routes
the query through the full single-source path (cached, batched).

The landmark solutions are ordinary :class:`Solution` objects, so the
streaming-update feed refreshes them with the same self-stabilizing
warm restarts as any cached answer.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.api import Problem, SingleSource, Solver
from repro.api.solver import Solution
from repro.graph.formats import Graph, graph_fingerprint
from repro.obs import trace as obs


@dataclasses.dataclass
class Estimate:
    """Point-to-point bounds from the landmark tier.  ``upper`` is the
    served estimate; ``exact`` is True when the bounds pinch (e.g. s
    or t is itself a landmark), in which case the estimate IS the
    distance."""

    source: int
    target: int
    lower: float
    upper: float

    @property
    def exact(self) -> bool:
        return self.lower == self.upper

    @property
    def servable(self) -> bool:
        """A finite upper bound serves as the estimate; lower == +inf
        proves unreachability, which serves as distance +inf."""
        return bool(np.isfinite(self.upper)) or bool(np.isinf(self.lower))


def pick_landmarks(g: Graph, k: int) -> list[int]:
    """Top-k vertices by out-degree (ties to smaller id, so the pick
    is deterministic across processes)."""
    k = min(int(k), g.n)
    deg = np.bincount(g.src, minlength=g.n)
    order = np.lexsort((np.arange(g.n), -deg))
    return [int(v) for v in order[:k]]


class LandmarkIndex:
    """K hub single-source solutions + the triangle-inequality reads.

    Build cost is one batched solve (the K sources share one engine
    invocation); serving cost is O(K) numpy per query.
    """

    def __init__(
        self,
        solver: Solver,
        graph: Graph,
        k: int = 8,
        *,
        landmarks: Optional[Sequence[int]] = None,
        symmetric: bool = False,
        processing: str = "sssp",
    ):
        self.solver = solver
        self.graph = graph
        self.symmetric = bool(symmetric)
        self.processing = processing
        self.landmarks = (
            [int(v) for v in landmarks]
            if landmarks is not None
            else pick_landmarks(graph, k)
        )
        with obs.span("landmarks.build", k=len(self.landmarks)):
            self.solutions: list[Solution] = solver.solve_batch(
                [Problem(graph, SingleSource(v), processing=processing)
                 for v in self.landmarks]
            )
        self._rebuild_matrix()

    def _rebuild_matrix(self):
        self.dist = np.stack([s.state for s in self.solutions])  # (K, n)
        self.fingerprint = graph_fingerprint(self.graph)

    @property
    def k(self) -> int:
        return len(self.landmarks)

    @property
    def nbytes(self) -> int:
        return int(self.dist.nbytes)

    def estimate(self, source: int, target: int) -> Estimate:
        s, t = int(source), int(target)
        ds, dt = self.dist[:, s], self.dist[:, t]
        # d(L,t) <= d(L,s) + d(s,t)  =>  d(s,t) >= d(L,t) - d(L,s);
        # only landmarks that reach s give information
        reach = np.isfinite(ds)
        lower = 0.0
        if reach.any():
            lower = float(np.max((dt - ds)[reach], initial=0.0))
        if np.isinf(dt).all() and reach.any() and self.symmetric:
            # no landmark reaches t but one reaches s: in a symmetric
            # graph s and t are then in different components
            lower = float("inf")
        upper = float("inf")
        if self.symmetric:
            both = reach & np.isfinite(dt)
            if both.any():
                upper = float(np.min((ds + dt)[both]))
        if s == t:
            lower = upper = 0.0
        return Estimate(source=s, target=t, lower=max(lower, 0.0),
                        upper=upper)

    # -- streaming updates --------------------------------------------

    def refresh(self, *, warm: bool = True) -> "LandmarkIndex":
        """Re-converge every landmark solution against the (perturbed)
        graph.  ``warm=True`` uses self-stabilizing warm restarts
        (exact after improving updates); ``warm=False`` cold-solves
        (required after non-improving updates).  Falls back to cold
        per-landmark when the partition layout changed."""
        with obs.span("landmarks.refresh", k=self.k, warm=warm) as sp:
            if warm:
                fresh = []
                for sol in self.solutions:
                    try:
                        fresh.append(
                            self.solver.resolve(sol, graph=self.graph)
                        )
                    except ValueError:  # partition layout changed
                        warm = False
                        break
                if warm:
                    self.solutions = fresh
            if not warm:
                self.solutions = self.solver.solve_batch(
                    [Problem(self.graph, SingleSource(v),
                             processing=self.processing)
                     for v in self.landmarks]
                )
            sp.set(warm_used=warm)
        self._rebuild_matrix()
        return self
