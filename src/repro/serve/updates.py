"""Streaming graph updates with self-stabilizing freshness.

The paper's self-stabilization guarantee is a *serving* primitive:
after a perturbation that only improves candidate states — an edge
insertion or a weight drop — the previous fixpoint is a valid warm
start, and ``Solver.resolve`` re-converges in a few supersteps.  The
feed exploits exactly that dichotomy:

* **improving** updates (insert edge, lower a weight): apply to the
  live graph, advance the hash-chained fingerprint in O(1) (no full
  edge-list rehash), and refresh every cached solution (and the
  landmark tier) via warm restarts — *exact*, not approximate, by
  self-stabilization.
* **non-improving** updates (raise a weight, delete an edge): the
  cached states may sit above the new fixpoint, which the monotone
  engine cannot correct — stale entries are invalidated and refreshed
  by cold solves (eagerly, or lazily on the next query miss).

Either way, the fingerprint advance makes stale cache entries
unreachable *before* any refresh runs, so correctness never depends
on the refresh policy.  A layout change under a data-dependent
partitioner (``ebal`` boundaries moving) downgrades warm refreshes to
cold solves automatically (``resolve`` raises, the feed catches).

Edge deletion is implemented as weight := +inf (min-plus identity):
the ELL shape is untouched and the edge stops contributing to any
path, which is equivalent to removal for every registered semiring.
"""

from __future__ import annotations

import dataclasses
import struct
from typing import Optional

import numpy as np

from repro.api import Problem, SingleSource, Solver
from repro.graph.formats import Graph, chain_fingerprint, graph_fingerprint
from repro.obs import trace as obs
from repro.serve.cache import SolutionCache
from repro.serve.landmarks import LandmarkIndex

INF = float("inf")


@dataclasses.dataclass(frozen=True)
class EdgeUpdate:
    """One streamed mutation: set the weight of edge (src, dst) to
    ``weight`` (inserting it if absent), or delete it
    (``delete=True``)."""

    src: int
    dst: int
    weight: float = 1.0
    delete: bool = False

    def record(self) -> bytes:
        """Canonical byte encoding for the fingerprint hash-chain."""
        return struct.pack(
            "<cqqd", b"D" if self.delete else b"U",
            int(self.src), int(self.dst), float(self.weight),
        )


@dataclasses.dataclass
class UpdateResult:
    update: EdgeUpdate
    improving: bool
    inserted: bool              # the edge did not exist before
    fingerprint: tuple          # the graph's fingerprint after the update
    warm_refreshes: int = 0
    cold_refreshes: int = 0
    invalidated: int = 0
    warm_supersteps: int = 0    # summed over warm refreshes
    cold_supersteps: int = 0    # summed over cold refreshes


@dataclasses.dataclass
class FeedStats:
    updates: int = 0
    improving: int = 0
    non_improving: int = 0
    insertions: int = 0
    warm_refreshes: int = 0
    cold_refreshes: int = 0
    invalidated: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class UpdateFeed:
    """Applies :class:`EdgeUpdate` records to a live graph and keeps
    the serving caches fresh.

    ``refresh='eager'`` re-converges every cached entry immediately
    (warm for improving updates, cold otherwise); ``refresh='lazy'``
    only invalidates — the next query on each source cold-solves via
    the normal miss path.  Both are exact; eager trades update latency
    for query latency.
    """

    def __init__(
        self,
        graph: Graph,
        solver: Solver,
        *,
        cache: Optional[SolutionCache] = None,
        landmarks: Optional[LandmarkIndex] = None,
        refresh: str = "eager",
    ):
        if refresh not in ("eager", "lazy"):
            raise ValueError(
                f"refresh must be 'eager' or 'lazy', got {refresh!r}"
            )
        self.graph = graph
        self.solver = solver
        self.cache = cache
        self.landmarks = landmarks
        self.refresh = refresh
        self.stats = FeedStats()

    # -- the one entry point ------------------------------------------

    def apply(self, upd: EdgeUpdate) -> UpdateResult:
        with obs.span("feed.apply", src=upd.src, dst=upd.dst,
                      delete=upd.delete) as sp:
            res = self._apply(upd)
            sp.set(improving=res.improving, inserted=res.inserted,
                   warm_refreshes=res.warm_refreshes,
                   cold_refreshes=res.cold_refreshes,
                   invalidated=res.invalidated)
            return res

    def _apply(self, upd: EdgeUpdate) -> UpdateResult:
        g = self.graph
        fp_old = graph_fingerprint(g)
        u, v, w = int(upd.src), int(upd.dst), float(upd.weight)
        if not (0 <= u < g.n and 0 <= v < g.n):
            raise ValueError(
                f"edge ({u}, {v}) outside vertex range [0, {g.n})"
            )
        slots = np.flatnonzero((g.src == u) & (g.dst == v))
        inserted = slots.size == 0

        if upd.delete:
            if inserted:  # deleting a non-edge: no-op, fingerprint still
                pass      # advances (the record happened)
            else:
                g.weight[slots] = np.float32(INF)
            improving = False
        elif inserted:
            if w < 0:
                raise ValueError(f"negative edge weight {w}")
            g.src = np.append(g.src, np.int32(u))
            g.dst = np.append(g.dst, np.int32(v))
            g.weight = np.append(g.weight, np.float32(w))
            improving = True
        else:
            if w < 0:
                raise ValueError(f"negative edge weight {w}")
            old_min = float(g.weight[slots].min())
            g.weight[slots] = np.float32(w)
            # a weight drop only improves path candidates; equality is
            # a no-op but safe to treat as improving (resolve of an
            # unperturbed graph converges immediately)
            improving = w <= old_min

        fp_new = chain_fingerprint(g, upd.record())
        res = UpdateResult(
            update=upd, improving=improving, inserted=inserted,
            fingerprint=fp_new,
        )
        self.stats.updates += 1
        self.stats.improving += int(improving)
        self.stats.non_improving += int(not improving)
        self.stats.insertions += int(inserted)
        self._refresh_cache(fp_old, fp_new, improving, res)
        self._refresh_landmarks(improving)
        return res

    # -- refresh policies ---------------------------------------------

    def _refresh_cache(self, fp_old, fp_new, improving, res: UpdateResult):
        if self.cache is None:
            return
        entries = self.cache.entries_for(fp_old)
        if not entries:
            return
        with obs.span("feed.refresh_cache", entries=len(entries),
                      improving=improving, policy=self.refresh):
            self._refresh_cache_entries(
                fp_old, fp_new, improving, res, entries
            )

    def _refresh_cache_entries(self, fp_old, fp_new, improving,
                               res: UpdateResult, entries):
        if self.refresh == "lazy" or not improving:
            res.invalidated = self.cache.invalidate_graph(fp_old)
            self.stats.invalidated += res.invalidated
            if self.refresh == "lazy":
                return
            if not improving:
                # eager cold refresh: re-solve each previously cached
                # source from scratch (bit-identical to a fresh solve —
                # it IS a fresh solve)
                for key, _ in entries:
                    sol = self.solver.solve(Problem(
                        self.graph, SingleSource(key[1]),
                        processing=key[3],
                    ))
                    self.cache.put(
                        SolutionCache.key_for(fp_new, key[1], key[2],
                                              key[3]),
                        sol,
                    )
                    res.cold_refreshes += 1
                    res.cold_supersteps += sol.metrics.supersteps
                self.stats.cold_refreshes += res.cold_refreshes
            return
        # improving: warm-restart every cached entry — exact by
        # self-stabilization, a few supersteps each
        for key, prev in entries:
            self.cache.pop(key)
            try:
                sol = self.solver.resolve(prev, graph=self.graph)
                res.warm_refreshes += 1
                res.warm_supersteps += sol.metrics.supersteps
            except ValueError:
                # partition layout changed (data-dependent partitioner
                # moved its boundaries) — warm start is unsound, fall
                # back to a cold solve
                obs.event("feed.warm_fallback", source=key[1])
                sol = self.solver.solve(Problem(
                    self.graph, SingleSource(key[1]), processing=key[3],
                ))
                res.cold_refreshes += 1
                res.cold_supersteps += sol.metrics.supersteps
            self.cache.put(
                SolutionCache.key_for(fp_new, key[1], key[2], key[3]),
                sol,
            )
        self.stats.warm_refreshes += res.warm_refreshes
        self.stats.cold_refreshes += res.cold_refreshes

    def _refresh_landmarks(self, improving: bool):
        if self.landmarks is not None:
            self.landmarks.refresh(warm=improving)
