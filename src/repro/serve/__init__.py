"""repro.serve — the persistent SSSP query service.

The paper's self-stabilization guarantee turned into a serving loop:
one long-lived :class:`repro.api.Solver` (compile-once engines), a
request :class:`Router` that admits point-to-point and single-source
queries into fixed-shape batches (pad/timeout batching, so every
flush hits the engine cache), a byte-budgeted LRU
:class:`SolutionCache`, an :class:`UpdateFeed` that applies streamed
edge insertions / weight changes to the live graph and keeps cached
answers fresh via self-stabilizing warm restarts (exact — improving
perturbations re-converge from the previous fixpoint in a few
supersteps), and a :class:`LandmarkIndex` hub tier serving
point-to-point estimates by triangle inequality with an ``exact=``
escalation path.

    from repro.serve import Router, Query, SolutionCache, UpdateFeed
    from repro.api import Solver

    solver = Solver("delta:5+threadq/a2a")
    router = Router(solver, g, cache=SolutionCache(byte_budget=1 << 28))
    ans = router.serve([Query(source=0, target=42)])[0]

    feed = UpdateFeed(g, solver, cache=router.cache)
    feed.apply(EdgeUpdate(src=3, dst=7, weight=0.5))   # warm refresh

End-to-end demo: ``examples/sssp_serve.py``; service CLI:
``python -m repro.launch.serve``; SLO benchmark:
``benchmarks/bench_serving.py`` → ``BENCH_serving.json``.
"""

from repro.serve.cache import CacheKey, CacheStats, SolutionCache
from repro.serve.landmarks import Estimate, LandmarkIndex, pick_landmarks
from repro.serve.router import (
    Answer, Query, Router, RouterStats, Ticket, serve_latency_stats,
)
from repro.serve.updates import (
    EdgeUpdate, FeedStats, UpdateFeed, UpdateResult,
)

__all__ = [
    "Answer",
    "CacheKey",
    "CacheStats",
    "EdgeUpdate",
    "Estimate",
    "FeedStats",
    "LandmarkIndex",
    "Query",
    "Router",
    "RouterStats",
    "SolutionCache",
    "Ticket",
    "UpdateFeed",
    "UpdateResult",
    "pick_landmarks",
    "serve_latency_stats",
]
