"""Byte-budgeted LRU solution cache for the serving tier.

One entry = one committed :class:`repro.api.Solution`, keyed by
``(graph_fingerprint, source, config_name, processing)`` — exactly the
inputs that determine the fixpoint, so a hit is always servable as-is.
The fingerprint component is what makes streaming updates safe by
construction: every applied edge update advances the graph's
(hash-chained) fingerprint, so stale entries become unreachable the
moment the graph changes, whether or not the feed refreshes them.

Eviction is by resident bytes, not entry count: solutions on a
scale-24 graph are ~128 MB each while scale-9 test solutions are KBs,
so a count-bounded cache would be either useless or unbounded.  LRU
order; hit/miss/eviction counters feed the serving SLO report.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Iterator, Optional, Tuple

from repro.api.solver import Solution

#: (graph_fingerprint, source_vertex, config_name, processing_name)
CacheKey = Tuple[tuple, int, str, str]


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    invalidations: int = 0
    bytes: int = 0        # currently resident
    peak_bytes: int = 0

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["hit_rate"] = self.hit_rate()
        return d

    def __str__(self) -> str:
        return (
            f"hits={self.hits} misses={self.misses} "
            f"rate={self.hit_rate():.3f} evictions={self.evictions} "
            f"bytes={self.bytes}"
        )


class SolutionCache:
    """LRU over solutions with a byte budget.

    ``get``/``put`` are the serving hot path; ``entries_for`` /
    ``invalidate_graph`` are the streaming-update seams (refresh every
    cached answer for a perturbed graph via warm restarts, or drop
    them when the perturbation was non-improving).
    """

    def __init__(self, byte_budget: int = 64 << 20):
        if byte_budget <= 0:
            raise ValueError(f"byte_budget must be positive: {byte_budget}")
        self.byte_budget = int(byte_budget)
        self._d: "OrderedDict[CacheKey, Solution]" = OrderedDict()
        self.stats = CacheStats()

    def __len__(self) -> int:
        return len(self._d)

    @staticmethod
    def key_for(
        fingerprint: tuple, source: int, config_name: str,
        processing: str = "sssp",
    ) -> CacheKey:
        return (tuple(fingerprint), int(source), str(config_name),
                str(processing))

    def get(self, key: CacheKey) -> Optional[Solution]:
        sol = self._d.get(key)
        if sol is None:
            self.stats.misses += 1
            return None
        self._d.move_to_end(key)
        self.stats.hits += 1
        return sol

    def peek(self, key: CacheKey) -> Optional[Solution]:
        """Lookup without touching LRU order or counters (the update
        feed inspecting entries must not skew the serving hit rate)."""
        return self._d.get(key)

    def put(self, key: CacheKey, sol: Solution) -> None:
        old = self._d.pop(key, None)
        if old is not None:
            self.stats.bytes -= old.nbytes
        self._d[key] = sol
        self.stats.bytes += sol.nbytes
        self.stats.peak_bytes = max(self.stats.peak_bytes, self.stats.bytes)
        # evict least-recently-used until under budget; a single entry
        # larger than the whole budget stays resident alone (evicting
        # it would make the cache never admit large-graph solutions)
        while self.stats.bytes > self.byte_budget and len(self._d) > 1:
            _, victim = self._d.popitem(last=False)
            self.stats.bytes -= victim.nbytes
            self.stats.evictions += 1

    def pop(self, key: CacheKey) -> Optional[Solution]:
        sol = self._d.pop(key, None)
        if sol is not None:
            self.stats.bytes -= sol.nbytes
        return sol

    # -- streaming-update seams ---------------------------------------

    def entries_for(self, fingerprint: tuple) -> list:
        """[(key, solution)] currently cached for one graph version —
        snapshot list, safe to mutate the cache while iterating."""
        fingerprint = tuple(fingerprint)
        return [(k, s) for k, s in self._d.items() if k[0] == fingerprint]

    def invalidate_graph(self, fingerprint: tuple) -> int:
        """Drop every entry for one graph version (non-improving
        perturbation: the cached states may exceed the new fixpoint,
        which the monotone engine cannot correct).  Returns the number
        dropped."""
        dropped = 0
        for key, _ in self.entries_for(fingerprint):
            self.pop(key)
            dropped += 1
        self.stats.invalidations += dropped
        return dropped

    def clear(self) -> None:
        self._d.clear()
        self.stats.bytes = 0

    def keys(self) -> Iterator[CacheKey]:
        return iter(self._d.keys())
