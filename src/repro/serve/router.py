"""Request router: admission batching over the compile-once engine.

The serving loop's contract with the engine is *fixed shapes*: every
distinct batch size is a distinct compiled engine, so the router's job
is to turn an irregular query stream into a small set of batch shapes
that all hit the process-wide engine cache.  Admission is pad/timeout
batching:

* queries accumulate in an admission queue;
* a flush fires when ``max_batch`` distinct uncached sources are
  pending (size trigger) or the oldest pending query has waited
  ``max_wait_s`` (latency trigger, checked by :meth:`pump`);
* the flush dedupes sources, serves cache hits, batch-solves the
  misses (``Solver.solve_batch`` pads to a power-of-two bucket), and
  resolves every waiting ticket.

Query kinds:

* single-source (``target=None``): the full distance vector.
* point-to-point exact: the source's single-source solution (cached,
  batched) read at ``target``.
* point-to-point ``exact=False``: answered from the landmark tier in
  O(K) with triangle-inequality bounds, no engine invocation; if the
  index can't bound it (no index, directed graph, unreachable hubs)
  the query silently escalates to the exact path.

When constructed with a ``tuned`` :class:`repro.tune.TunedSpecCache`,
admission consults it per flush: if the current graph's fingerprint
has a tuned record whose spec differs from the default solver's, the
flush batch-solves with a memoized solver built from the tuned spec
(and keys the solution cache under the tuned config name, so tuned
and default answers never alias).  Fingerprints are hash-chain aware,
so a streamed update automatically falls back to the default solver
until the mutated graph is re-tuned.

The router is synchronous and single-threaded by design — the engine
itself is the concurrency (one batched solve serves B queries); an
injectable ``clock`` makes the timeout trigger testable without
sleeping.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import TYPE_CHECKING, Callable, Optional, Sequence

from repro.api import Problem, SingleSource, Solver
from repro.api.solver import Solution
from repro.core.metrics import LatencyStats
from repro.graph.formats import Graph, graph_fingerprint
from repro.obs import trace as obs
from repro.serve.cache import SolutionCache
from repro.serve.landmarks import LandmarkIndex

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from repro.tune.autotune import TunedSpecCache


@dataclasses.dataclass(frozen=True)
class Query:
    """One serving request.  ``target=None`` asks for the full
    single-source state; otherwise a point-to-point distance, exact
    (engine) or estimated (landmark tier) per ``exact``."""

    source: int
    target: Optional[int] = None
    exact: bool = True
    processing: str = "sssp"


@dataclasses.dataclass
class Answer:
    query: Query
    distance: Optional[float]       # point-to-point result (or estimate)
    solution: Optional[Solution]    # full solution (single-source/exact)
    served_by: str                  # 'cache' | 'batch' | 'landmark'
    latency_s: float = 0.0
    lower: Optional[float] = None   # landmark bounds, when estimated
    upper: Optional[float] = None

    @property
    def estimated(self) -> bool:
        return self.served_by == "landmark"


class Ticket:
    """Handle for a submitted query; resolved at flush time.  Calling
    :meth:`result` before the batch filled forces a flush (a caller
    blocking on its answer is the ultimate latency trigger).  ``qid``
    is the router-assigned correlation key: the submit event, the
    flush span that served the ticket, and the solve spans under it
    all carry it, so a p99 outlier can be traced to its batch and
    spec."""

    def __init__(self, router: "Router", query: Query, t_submit: float,
                 qid: int = 0):
        self._router = router
        self.query = query
        self.t_submit = t_submit
        self.qid = qid
        self.answer: Optional[Answer] = None

    @property
    def done(self) -> bool:
        return self.answer is not None

    def result(self) -> Answer:
        if self.answer is None:
            self._router.flush()
        assert self.answer is not None
        return self.answer


@dataclasses.dataclass
class RouterStats:
    queries: int = 0
    batches: int = 0
    batched_solves: int = 0     # uncached sources actually solved
    landmark_served: int = 0
    escalations: int = 0        # estimate queries the index couldn't bound
    tuned_batches: int = 0      # flushes served by a tuned-spec solver
    latency_evictions: int = 0  # samples aged out of the latency ring

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class Router:
    def __init__(
        self,
        solver: Solver,
        graph: Graph,
        *,
        cache: Optional[SolutionCache] = None,
        landmarks: Optional[LandmarkIndex] = None,
        tuned: Optional["TunedSpecCache"] = None,
        max_batch: int = 8,
        max_wait_s: float = 0.01,
        clock: Callable[[], float] = time.monotonic,
        latency_window: int = 1024,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be positive: {max_batch}")
        if latency_window < 1:
            raise ValueError(
                f"latency_window must be positive: {latency_window}"
            )
        self.solver = solver
        self.graph = graph
        self.cache = cache if cache is not None else SolutionCache()
        self.landmarks = landmarks
        self.tuned = tuned
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self.clock = clock
        self.stats = RouterStats()
        self._pending: list[Ticket] = []
        self._tuned_solvers: dict = {}  # tuned spec -> memoized Solver
        # bounded ring of recent per-answer latencies: stats() summaries
        # stay O(window) however long the router lives; ring overflow is
        # counted, not silent
        self._latency: deque = deque(maxlen=int(latency_window))
        self._qids = 0

    # -- admission ----------------------------------------------------

    def submit(self, query: Query) -> Ticket:
        self._qids += 1
        ticket = Ticket(self, query, self.clock(), qid=self._qids)
        self.stats.queries += 1
        obs.event("router.submit", qid=ticket.qid, source=query.source,
                  exact=query.exact)
        if self._try_landmark(ticket):
            return ticket
        self._pending.append(ticket)
        if self._distinct_misses() >= self.max_batch:
            self.flush()
        return ticket

    def _record_latency(self, latency_s: float) -> None:
        if len(self._latency) == self._latency.maxlen:
            self.stats.latency_evictions += 1
        self._latency.append(float(latency_s))

    def latency_stats(self) -> LatencyStats:
        """Order statistics over the retained latency ring (at most
        ``latency_window`` recent answers; older samples are evicted
        and counted in ``stats.latency_evictions``)."""
        return LatencyStats.from_samples(self._latency)

    def pump(self) -> bool:
        """The latency trigger: flush if the oldest pending query has
        waited past ``max_wait_s``.  Returns True if a flush fired.
        Call from the serving loop between arrivals."""
        if self._pending and (
            self.clock() - self._pending[0].t_submit >= self.max_wait_s
        ):
            self.flush()
            return True
        return False

    def serve(self, queries: Sequence[Query]) -> list[Answer]:
        """Convenience batch entry: submit everything, flush, return
        answers in submission order."""
        tickets = [self.submit(q) for q in queries]
        self.flush()
        return [t.result() for t in tickets]

    # -- flush --------------------------------------------------------

    def flush(self) -> int:
        """Serve every pending ticket now.  Returns how many were
        answered."""
        tickets, self._pending = self._pending, []
        if not tickets:
            return 0
        self.stats.batches += 1
        with obs.span("router.flush", batch=len(tickets),
                      qids=[t.qid for t in tickets]) as sp:
            fp = graph_fingerprint(self.graph)
            solver = self._solver_for(fp)
            if solver is not self.solver:
                self.stats.tuned_batches += 1
            cfg_name = solver.config.name
            sp.set(spec=cfg_name, tuned=solver is not self.solver)

            # one solution per distinct (source, processing); cache first
            need: dict = {}
            sols: dict = {}
            hit: dict = {}
            for t in tickets:
                q = t.query
                skey = (q.source, q.processing)
                if skey in sols or skey in need:
                    continue
                ckey = SolutionCache.key_for(fp, q.source, cfg_name,
                                             q.processing)
                cached = self.cache.get(ckey)
                if cached is not None:
                    sols[skey] = cached
                    hit[skey] = True
                else:
                    need[skey] = ckey
            for group in self._by_processing(need):
                problems = [
                    Problem(self.graph, SingleSource(src), processing=proc)
                    for (src, proc) in group
                ]
                if solver.config.adapt is not None and len(problems) > 1:
                    # adaptive solves are unbatchable (segmented engine);
                    # serve the flush sequentially instead
                    solved = [solver.solve(pb) for pb in problems]
                else:
                    solved = solver.solve_batch(problems)
                self.stats.batched_solves += len(solved)
                for (skey, sol) in zip(group, solved):
                    self.cache.put(need[skey], sol)
                    sols[skey] = sol
                    hit[skey] = False
                    obs.event("router.cache_fill", source=skey[0],
                              bytes=sol.nbytes)
            sp.set(cache_hits=sum(1 for h in hit.values() if h),
                   solved=len(need))

            now = self.clock()
            for t in tickets:
                q = t.query
                sol = sols[(q.source, q.processing)]
                t.answer = Answer(
                    query=q,
                    distance=(sol.distance_to(q.target)
                              if q.target is not None else None),
                    solution=sol,
                    served_by=("cache" if hit[(q.source, q.processing)]
                               else "batch"),
                    latency_s=now - t.t_submit,
                )
                self._record_latency(t.answer.latency_s)
            return len(tickets)

    # -- internals ----------------------------------------------------

    def _solver_for(self, fp) -> Solver:
        """The solver this flush should use: the tuned-spec solver when
        the tuned cache has a record for the graph's current
        fingerprint with a spec that differs from the default, else
        the router's default solver.  Tuned solvers are memoized per
        spec (they share the process-wide engine cache, but partition
        memos and stats live on the Solver)."""
        if self.tuned is None:
            return self.solver
        rec = self.tuned.get(fp)
        if rec is None or rec.spec == self.solver.config.name:
            return self.solver
        s = self._tuned_solvers.get(rec.spec)
        if s is None:
            s = Solver(rec.spec, mesh=self.solver.mesh)
            self._tuned_solvers[rec.spec] = s
        return s

    def _try_landmark(self, ticket: Ticket) -> bool:
        q = ticket.query
        if (q.exact or q.target is None or self.landmarks is None
                or q.processing != self.landmarks.processing):
            return False
        est = self.landmarks.estimate(q.source, q.target)
        if not est.servable:
            self.stats.escalations += 1
            obs.event("router.landmark_escalation", qid=ticket.qid,
                      source=q.source, target=q.target)
            return False  # escalate to the exact path
        self.stats.landmark_served += 1
        obs.event("router.landmark_served", qid=ticket.qid,
                  source=q.source, target=q.target)
        ticket.answer = Answer(
            query=q,
            distance=est.upper,
            solution=None,
            served_by="landmark",
            latency_s=self.clock() - ticket.t_submit,
            lower=est.lower,
            upper=est.upper,
        )
        self._record_latency(ticket.answer.latency_s)
        return True

    def _distinct_misses(self) -> int:
        seen = set()
        for t in self._pending:
            seen.add((t.query.source, t.query.processing))
        return len(seen)

    @staticmethod
    def _by_processing(need: dict) -> list:
        """Group distinct miss keys by processing fn (solve_batch
        requires one π per batch), preserving admission order."""
        groups: dict = {}
        for skey in need:
            groups.setdefault(skey[1], []).append(skey)
        return list(groups.values())


def serve_latency_stats(answers: Sequence[Answer]) -> LatencyStats:
    """Order statistics over a batch of served answers."""
    return LatencyStats.from_samples([a.latency_s for a in answers])
