"""minicpm3-4b [hf:openbmb/MiniCPM3-4B].

62L d_model=2560 40H d_ff=6400 vocab=73448, MLA attention
(q_lora 768, kv_lora 256, qk_nope 64, qk_rope 32, v_head 64), tied
embeddings.  The decode cells use the absorbed-MLA formulation (the
KV cache stays in latent space: 288 values/token vs 10240 for MHA).
"""

from repro.configs.cells import LM_SHAPES, lm_cell
from repro.models.lm import LMConfig

ARCH_ID = "minicpm3-4b"
FAMILY = "lm"
SHAPES = list(LM_SHAPES)


def make_config(reduced: bool = False) -> LMConfig:
    if reduced:
        return LMConfig(
            name=ARCH_ID + "-reduced", n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=4, d_ff=128, vocab=181,
            param_dtype="float32", loss_chunk=8, attn_type="mla",
            q_lora_rank=48, kv_lora_rank=32, qk_nope_dim=16,
            qk_rope_dim=8, v_head_dim=16, tie_embeddings=True,
        )
    # vocab padded 73448 -> 73472 so the embedding TP-shards over 16
    # (standard vocab padding; the 24 pad rows are never produced)
    return LMConfig(
        name=ARCH_ID, n_layers=62, d_model=2560, n_heads=40,
        n_kv_heads=40, d_ff=6400, vocab=73472, attn_type="mla",
        q_lora_rank=768, kv_lora_rank=256, qk_nope_dim=64,
        qk_rope_dim=32, v_head_dim=64, tie_embeddings=True,
        # §Perf iteration 2: 8k kv-chunks — the blockwise-softmax
        # carry (B,H,S,dv) f32 is rewritten once per chunk, so fewer,
        # larger chunks cut the dominant HBM term ~4x.
        attn_impl="xla_flash", attn_chunk=8192,
    )


def make_cell(cell: str, topo, reduced: bool = False,
              probe_layers=None):
    return lm_cell(ARCH_ID, make_config(reduced), cell, topo,
                   probe_layers=probe_layers)
