"""Architecture registry: ``--arch <id>`` resolves here.

Ten assigned architectures + the paper's own SSSP workload.  Each
module exposes ARCH_ID, FAMILY, SHAPES, make_config(reduced) and
make_cell(cell, topo, reduced).
"""

from repro.configs import (
    dbrx,
    dimenet_cfg,
    egnn_cfg,
    gin_tu,
    mace_cfg,
    mind_cfg,
    minicpm3,
    minitron,
    phi35_moe,
    phi3_mini,
    sssp_cfg,
)

_MODULES = [
    phi35_moe, dbrx, phi3_mini, minitron, minicpm3,
    mace_cfg, gin_tu, egnn_cfg, dimenet_cfg,
    mind_cfg, sssp_cfg,
]

REGISTRY = {m.ARCH_ID: m for m in _MODULES}
ASSIGNED = [m.ARCH_ID for m in _MODULES if m.ARCH_ID != "sssp"]


def get_arch(arch_id: str):
    if arch_id not in REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(REGISTRY)}"
        )
    return REGISTRY[arch_id]


def list_cells(arch_id: str) -> list:
    return list(get_arch(arch_id).SHAPES)


def all_cells(include_sssp: bool = True) -> list:
    out = []
    for m in _MODULES:
        if m.ARCH_ID == "sssp" and not include_sssp:
            continue
        for c in m.SHAPES:
            out.append((m.ARCH_ID, c))
    return out
