"""minitron-8b [arXiv:2407.14679] (pruned nemotron).

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.  Nemotron
family uses squared-ReLU (2-matrix) MLP — with it the config lands on
~8B params (a 3-matrix SwiGLU would overshoot to ~10B).
"""

from repro.configs.cells import LM_SHAPES, lm_cell
from repro.models.lm import LMConfig

ARCH_ID = "minitron-8b"
FAMILY = "lm"
SHAPES = list(LM_SHAPES)


def make_config(reduced: bool = False) -> LMConfig:
    if reduced:
        return LMConfig(
            name=ARCH_ID + "-reduced", n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=2, d_ff=128, vocab=241,
            param_dtype="float32", loss_chunk=8, mlp_type="relu2",
        )
    return LMConfig(
        name=ARCH_ID, n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, d_ff=16384, vocab=256000, mlp_type="relu2",
        attn_impl="xla_flash", attn_chunk=2048,
    )


def make_cell(cell: str, topo, reduced: bool = False,
              probe_layers=None):
    return lm_cell(ARCH_ID, make_config(reduced), cell, topo,
                   probe_layers=probe_layers)
