"""gin-tu [arXiv:1810.00826].

5 layers, d_hidden 64, sum aggregator, learnable ε.
"""

from repro.configs.cells import GNN_SHAPES, gnn_train_cell
from repro.models.gnn import gin

ARCH_ID = "gin-tu"
FAMILY = "gnn"
SHAPES = list(GNN_SHAPES)


def make_config(reduced: bool = False, cell: str = "full_graph_sm"):
    sh = GNN_SHAPES.get(cell, GNN_SHAPES["full_graph_sm"])
    d_in = sh.get("d_feat", 64)
    n_classes = max(2, sh.get("classes", 2))
    if reduced:
        return gin.GINConfig(n_layers=2, d_hidden=16, d_in=d_in,
                             n_classes=n_classes)
    return gin.GINConfig(n_layers=5, d_hidden=64, d_in=d_in,
                         n_classes=n_classes)


def _flops(cell: str, cfg) -> float:
    sh = GNN_SHAPES[cell]
    e = sh["e"] * sh.get("batch", 1)
    n = sh["n"] * sh.get("batch", 1)
    per_node = 2 * (cfg.d_hidden * cfg.d_hidden * 2)
    return 3.0 * cfg.n_layers * (e * cfg.d_hidden + n * per_node)


def _molecule_loss(params, batch, cfg):
    """Graph-level regression for the packed molecule cell: mean-pool
    node features then score (GIN-ε readout)."""
    import jax
    import jax.numpy as jnp
    def one(x, es, ed, em, y):
        logits = gin.forward(params, x, es, ed, em, cfg)
        pred = jnp.mean(logits)
        return (pred - y) ** 2

    return jnp.mean(
        jax.vmap(one)(
            batch["x"], batch["edge_src"], batch["edge_dst"],
            batch["edge_mask"], batch["y"],
        )
    )


def make_cell(cell: str, topo, reduced: bool = False):
    cfg = make_config(reduced, cell)
    loss = (
        _molecule_loss if cell == "molecule"
        else gin.node_classification_loss
    )
    return gnn_train_cell(
        ARCH_ID, cell, loss, gin.init_params, cfg, topo,
        coords=False, triplets=False, model_flops=_flops(cell, cfg),
    )
