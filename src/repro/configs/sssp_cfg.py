"""sssp — the paper's own workload as a first-class architecture.

Cells lower the full distributed EAGM solve (jitted while_loop with
eligibility, relaxation, exchange and termination collectives) for
representative (ordering × EAGM variant × exchange) points on a
Graph500-scale-26 R-MAT (67M vertices, ~1B directed edges after
symmetrization) and a road-network-diameter proxy.
"""

from repro.configs.cells import sssp_cell

ARCH_ID = "sssp"
FAMILY = "graph"

# cell -> engine configuration
SSSP_CELLS = {
    # paper-faithful Δ-stepping baseline with the dense pmin exchange
    "rmat26_delta_buffer_pmin": dict(
        scale=26, avg_degree=32, width=32,
        root="delta:5", variant="buffer", exchange="pmin",
    ),
    # same AGM, optimized exchange (beyond-paper §Perf)
    "rmat26_delta_buffer_a2a": dict(
        scale=26, avg_degree=32, width=32,
        root="delta:5", variant="buffer", exchange="a2a",
    ),
    # the paper's overall winner: chaotic + thread(chunk)-level dj
    "rmat26_chaotic_threadq_a2a": dict(
        scale=26, avg_degree=32, width=32,
        root="chaotic", variant="threadq", exchange="a2a",
    ),
    # KLA with pod-level ordering (nodeq)
    "rmat26_kla_nodeq_a2a": dict(
        scale=26, avg_degree=32, width=32,
        root="kla:2", variant="nodeq", exchange="a2a",
    ),
    # high-diameter road proxy (Δ large, like the paper's Δ=1200)
    "road27_delta_nodeq_a2a": dict(
        scale=27, avg_degree=4, width=4,
        root="delta:1200", variant="nodeq", exchange="a2a",
    ),
    # beyond-paper 3-level hierarchy: Δ globally, Dijkstra within the
    # pod, a finer Δ drained per chunk — inexpressible in the one-slot
    # variant API, first-class in the hierarchy grammar
    "rmat26_hier3_sparse": dict(
        scale=26, avg_degree=32, width=32,
        spec="delta:5 > pod:dijkstra > chunk:delta:1 /sparse",
    ),
    # beyond-paper partition point: edge-balanced relabeling (@ebal)
    # keeps the stacked ELL row count near the mean rank instead of
    # the RMAT hub rank (see repro.graph.partition)
    "rmat26_delta_ebal_sparse": dict(
        scale=26, avg_degree=32, width=32,
        spec="delta:5+threadq/sparse@ebal",
    ),
}
SHAPES = list(SSSP_CELLS)


def make_config(reduced: bool = False):
    return dict(SSSP_CELLS)


def make_cell(cell: str, topo, reduced: bool = False):
    kw = dict(SSSP_CELLS[cell])
    if reduced:
        kw.update(scale=10, avg_degree=8, width=8)
    return sssp_cell(ARCH_ID, cell, topo, **kw)
