"""phi3-mini-3.8b [arXiv:2404.14219].

32L d_model=3072 32H (GQA kv=32 = full MHA) d_ff=8192 vocab=32064,
RoPE + SwiGLU.
"""

from repro.configs.cells import LM_SHAPES, lm_cell
from repro.models.lm import LMConfig

ARCH_ID = "phi3-mini-3.8b"
FAMILY = "lm"
SHAPES = list(LM_SHAPES)


def make_config(reduced: bool = False) -> LMConfig:
    if reduced:
        return LMConfig(
            name=ARCH_ID + "-reduced", n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=4, d_ff=128, vocab=193,
            param_dtype="float32", loss_chunk=8,
        )
    return LMConfig(
        name=ARCH_ID, n_layers=32, d_model=3072, n_heads=32,
        n_kv_heads=32, d_ff=8192, vocab=32064,
        attn_impl="xla_flash", attn_chunk=2048,
    )


def make_cell(cell: str, topo, reduced: bool = False,
              probe_layers=None):
    return lm_cell(ARCH_ID, make_config(reduced), cell, topo,
                   probe_layers=probe_layers)
