"""mind [arXiv:1904.08030].

embed_dim 64, 4 interest capsules, 3 routing iterations,
multi-interest interaction; 10⁶-row item table.
"""

from repro.configs.cells import RECSYS_SHAPES, mind_cell
from repro.models.mind import MINDConfig

ARCH_ID = "mind"
FAMILY = "recsys"
SHAPES = list(RECSYS_SHAPES)


def make_config(reduced: bool = False) -> MINDConfig:
    if reduced:
        return MINDConfig(n_items=2000, n_profile=500, hist_len=8,
                          n_negatives=15)
    # table rows are powers of two so they shard evenly over both
    # production meshes (2^20 ≈ the assigned 10^6-row table)
    return MINDConfig(embed_dim=64, n_interests=4, capsule_iters=3,
                      n_items=1 << 20, n_profile=1 << 17,
                      hist_len=50, n_negatives=127)


def make_cell(cell: str, topo, reduced: bool = False):
    return mind_cell(ARCH_ID, cell, make_config(reduced), topo)
