"""dbrx-132b [hf:databricks/dbrx-base].

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16e top-4
(fine-grained experts).
"""

from repro.configs.cells import LM_SHAPES, lm_cell
from repro.models.lm import LMConfig
from repro.models.moe import MoEConfig

ARCH_ID = "dbrx-132b"
FAMILY = "lm"
SHAPES = list(LM_SHAPES)


def make_config(reduced: bool = False) -> LMConfig:
    if reduced:
        return LMConfig(
            name=ARCH_ID + "-reduced", n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=2, d_ff=96, vocab=211,
            param_dtype="float32", loss_chunk=8,
            moe=MoEConfig(n_experts=4, top_k=4, d_model=64, d_ff=96,
                          capacity_factor=2.0, min_capacity=16),
        )
    return LMConfig(
        name=ARCH_ID, n_layers=40, d_model=6144, n_heads=48,
        n_kv_heads=8, d_ff=10752, vocab=100352,
        moe=MoEConfig(n_experts=16, top_k=4, d_model=6144, d_ff=10752),
        attn_impl="xla_flash", attn_chunk=2048,
    )


def make_cell(cell: str, topo, reduced: bool = False,
              probe_layers=None):
    return lm_cell(ARCH_ID, make_config(reduced), cell, topo,
                   probe_layers=probe_layers)
