"""dimenet [arXiv:2003.03123].

6 blocks, d_hidden 128, n_bilinear 8, n_spherical 7, n_radial 6.
Triplet lists are exact for the molecule cell and capped at 2 per
edge for the web-scale graphs (DESIGN.md §Arch-applicability —
DimeNet is molecular; running it on OGB-scale topologies requires
triplet truncation).
"""

from repro.configs.cells import GNN_SHAPES, gnn_train_cell
from repro.models.gnn import dimenet

ARCH_ID = "dimenet"
FAMILY = "gnn"
SHAPES = list(GNN_SHAPES)
TRIPLET_CAP = 2


def make_config(reduced: bool = False, cell: str = "molecule"):
    sh = GNN_SHAPES.get(cell, GNN_SHAPES["molecule"])
    d_in = sh.get("d_feat", 10)
    n_classes = 0 if cell == "molecule" else sh.get("classes", 0)
    if reduced:
        return dimenet.DimeNetConfig(n_blocks=2, d_hidden=16, d_in=d_in,
                                     n_classes=n_classes, n_bilinear=4)
    # bf16 messages on the web-scale cells (§Perf H2 iter 3); exact
    # f32 for molecules
    mdt = "float32" if cell == "molecule" else "bfloat16"
    return dimenet.DimeNetConfig(n_blocks=6, d_hidden=128,
                                 n_bilinear=8, n_spherical=7,
                                 n_radial=6, d_in=d_in,
                                 n_classes=n_classes, msg_dtype=mdt)


def _flops(cell: str, cfg) -> float:
    sh = GNN_SHAPES[cell]
    b = sh.get("batch", 1)
    e = sh["e"] * b
    t = (sh.get("triplet_pad", sh["e"] * TRIPLET_CAP)) * b
    d, nb = cfg.d_hidden, cfg.n_bilinear
    per_tri = 2 * (cfg.n_radial * cfg.n_spherical * nb + nb * d * d)
    per_edge = 2 * (3 * d * d)
    return 3.0 * cfg.n_blocks * (t * per_tri + e * per_edge)


def make_cell(cell: str, topo, reduced: bool = False):
    cfg = make_config(reduced, cell)
    loss = (
        dimenet.regression_loss if cell == "molecule"
        else dimenet.node_classification_loss
    )
    return gnn_train_cell(
        ARCH_ID, cell, loss, dimenet.init_params, cfg, topo,
        coords=True, triplets=True, model_flops=_flops(cell, cfg),
    )
