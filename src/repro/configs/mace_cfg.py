"""mace [arXiv:2206.07697].

2 layers, d_hidden 128, l_max 2, correlation order 3, 8 radial Bessel
functions, E(3)-equivariant ACE features (see models/gnn/mace.py for
the invariant-channel adaptation).
"""

from repro.configs.cells import GNN_SHAPES, gnn_train_cell
from repro.models.gnn import mace

ARCH_ID = "mace"
FAMILY = "gnn"
SHAPES = list(GNN_SHAPES)


def make_config(reduced: bool = False, cell: str = "molecule"):
    sh = GNN_SHAPES.get(cell, GNN_SHAPES["molecule"])
    d_in = sh.get("d_feat", 10)
    n_classes = 0 if cell == "molecule" else sh.get("classes", 0)
    if reduced:
        return mace.MACEConfig(n_layers=2, d_hidden=16, d_in=10,
                               n_classes=n_classes)
    return mace.MACEConfig(n_layers=2, d_hidden=128, l_max=2,
                           correlation=3, n_rbf=8, d_in=d_in,
                           n_classes=n_classes)


def _flops(cell: str, cfg) -> float:
    sh = GNN_SHAPES[cell]
    e = sh["e"] * (sh.get("batch", 1))
    n = sh["n"] * (sh.get("batch", 1))
    C = cfg.d_hidden
    # per edge: radial MLP + C*9 message; per node: C*9^3 bispectrum
    per_edge = 2 * (cfg.n_rbf * 32 + 32 * C * 3) + 2 * C * 9
    per_node = 2 * C * 9 ** 3 + 2 * C * (C * 9 + C)
    return 3.0 * cfg.n_layers * (e * per_edge + n * per_node)


def make_cell(cell: str, topo, reduced: bool = False):
    cfg = make_config(reduced, cell)
    loss = (
        mace.regression_loss if cell == "molecule"
        else mace.node_classification_loss
    )
    return gnn_train_cell(
        ARCH_ID, cell, loss, mace.init_params, cfg, topo,
        coords=True, triplets=False, model_flops=_flops(cell, cfg),
    )
