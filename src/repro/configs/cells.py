"""Cell machinery: an (architecture × input-shape) cell is a concrete
jittable program + abstract inputs + shardings + useful-FLOPs formula.

The multi-pod dry-run lowers/compiles every cell on the production
mesh; the roofline package reads each compiled cell's cost analysis.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import Topology
from repro.models import lm as lm_mod
from repro.train import (
    AdamWConfig, TrainConfig, build_train_step, init_state, state_specs,
)


@dataclasses.dataclass
class CellProgram:
    arch: str
    cell: str
    kind: str                      # train | prefill | decode | serve
    fn: Callable
    args: tuple                    # abstract (ShapeDtypeStruct) pytrees
    in_shardings: Any              # matching pytree of NamedSharding
    out_shardings: Any = None      # optional pytree for outputs
    donate_argnums: tuple = ()
    model_flops: float = 0.0       # useful FLOPs per execution
    notes: str = ""

    def lower(self):
        kw = {}
        if self.out_shardings is not None:
            kw["out_shardings"] = self.out_shardings
        jitted = jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            donate_argnums=self.donate_argnums,
            **kw,
        )
        return jitted.lower(*self.args)


def named(topo: Topology, spec_tree):
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(topo.mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def abstract_init(fn, *args, **kwargs):
    """eval_shape with abstract-array args passed positionally (static
    config objects go through the closure untouched)."""
    arr_like = tuple(
        a for a in args
        if isinstance(a, (jax.Array, jax.ShapeDtypeStruct, dict, list,
                          tuple))
    )
    static = tuple(
        a for a in args
        if not isinstance(a, (jax.Array, jax.ShapeDtypeStruct, dict,
                              list, tuple))
    )

    def wrapped(*arrs):
        it = iter(arrs)
        full = [
            next(it) if isinstance(a, (jax.Array, jax.ShapeDtypeStruct,
                                       dict, list, tuple)) else a
            for a in args
        ]
        return fn(*full, **kwargs)

    del static
    return jax.eval_shape(wrapped, *arr_like)


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# ------------------------------------------------------------------ #
# LM cells


def lm_flops_train(cfg: lm_mod.LMConfig, B: int, S: int) -> float:
    """6·N_active·tokens + attention score/value terms (fwd+bwd)."""
    n = cfg.n_active_params()
    attn = 12 * cfg.n_layers * B * S * S * cfg.n_heads * cfg.head_dim
    if cfg.attn_type == "mla":
        attn = 12 * cfg.n_layers * B * S * S * cfg.n_heads * (
            cfg.qk_nope_dim + cfg.qk_rope_dim + cfg.v_head_dim
        ) / 2
    return 6.0 * n * B * S + attn


def lm_flops_prefill(cfg: lm_mod.LMConfig, B: int, S: int) -> float:
    n = cfg.n_active_params()
    attn = 2 * cfg.n_layers * B * S * S * cfg.n_heads * cfg.head_dim
    return 2.0 * n * B * S + attn


def lm_flops_decode(cfg: lm_mod.LMConfig, B: int, S_ctx: int) -> float:
    n = cfg.n_active_params()
    if cfg.attn_type == "mla":
        # absorbed decode: scores/context against the latent cache
        attn = 4 * cfg.n_layers * B * S_ctx * cfg.n_heads * (
            cfg.kv_lora_rank + cfg.qk_rope_dim
        )
    else:
        attn = 4 * cfg.n_layers * B * S_ctx * cfg.n_heads * cfg.head_dim
    return 2.0 * n * B + attn


def lm_train_cell(arch: str, cell: str, cfg: lm_mod.LMConfig,
                  topo: Topology, B: int, S: int) -> CellProgram:
    tc = TrainConfig(adamw=AdamWConfig())
    params = abstract_init(lm_mod.init_params, jax.random.PRNGKey(0), cfg)
    opt = abstract_init(init_state, params, tc.adamw)
    pspecs = lm_mod.param_specs(cfg, topo)
    ospecs = state_specs(pspecs, tc.adamw)
    batch = {
        "tokens": sds((B, S), jnp.int32),
        "labels": sds((B, S), jnp.int32),
    }
    bspecs = {"tokens": topo.spec("dp", None), "labels": topo.spec("dp", None)}

    step = build_train_step(
        lambda p, b: lm_mod.lm_loss(p, b, cfg, topo), tc
    )
    return CellProgram(
        arch=arch, cell=cell, kind="train", fn=step,
        args=(params, opt, batch, sds((), jnp.int32)),
        in_shardings=(
            named(topo, pspecs), named(topo, ospecs),
            named(topo, bspecs), NamedSharding(topo.mesh, P()),
        ),
        donate_argnums=(0, 1),
        model_flops=lm_flops_train(cfg, B, S),
        notes=f"B={B} S={S} params={cfg.n_params()/1e9:.1f}B",
    )


def lm_prefill_cell(arch: str, cell: str, cfg: lm_mod.LMConfig,
                    topo: Topology, B: int, S: int) -> CellProgram:
    params = abstract_init(lm_mod.init_params, jax.random.PRNGKey(0), cfg)
    pspecs = lm_mod.param_specs(cfg, topo)
    tokens = sds((B, S), jnp.int32)

    def fn(p, t):
        return lm_mod.prefill_step(p, t, cfg, topo, max_len=S)

    # §Perf iteration 1: without explicit output shardings XLA chose a
    # REPLICATED cache output (0.2-1.4 TB temp per device); pin the
    # cache to the decode layout it feeds.
    cspecs = lm_mod.cache_specs(cfg, topo, long=False)
    return CellProgram(
        arch=arch, cell=cell, kind="prefill", fn=fn,
        args=(params, tokens),
        in_shardings=(
            named(topo, pspecs),
            NamedSharding(topo.mesh, topo.spec("dp", None)),
        ),
        out_shardings=(
            named(topo, cspecs),
            NamedSharding(topo.mesh, topo.spec("dp", "tp")),
        ),
        model_flops=lm_flops_prefill(cfg, B, S),
        notes=f"B={B} S={S}",
    )


def lm_decode_cell(arch: str, cell: str, cfg: lm_mod.LMConfig,
                   topo: Topology, B: int, S_ctx: int,
                   long: bool) -> CellProgram:
    params = abstract_init(lm_mod.init_params, jax.random.PRNGKey(0), cfg)
    pspecs = lm_mod.param_specs(cfg, topo)
    cache = lm_mod.cache_shapes(cfg, B, S_ctx)
    cspecs = lm_mod.cache_specs(cfg, topo, long=long)
    tokens = sds((B,), jnp.int32)
    tok_spec = P() if long else topo.spec("dp")

    def fn(p, c, t, pos):
        return lm_mod.decode_step(p, c, t, pos, cfg, topo)

    logits_spec = P() if long else topo.spec("dp", "tp")
    return CellProgram(
        arch=arch, cell=cell, kind="decode", fn=fn,
        args=(params, cache, tokens, sds((), jnp.int32)),
        in_shardings=(
            named(topo, pspecs), named(topo, cspecs),
            NamedSharding(topo.mesh, tok_spec),
            NamedSharding(topo.mesh, P()),
        ),
        out_shardings=(
            NamedSharding(topo.mesh, logits_spec),
            named(topo, cspecs),
        ),
        donate_argnums=(1,),
        model_flops=lm_flops_decode(cfg, B, S_ctx),
        notes=f"B={B} S_ctx={S_ctx}" + (" SP-decode" if long else ""),
    )


# LM shape cells shared by all five assigned transformer archs
LM_SHAPES = {
    "train_4k": dict(kind="train", S=4096, B=256),
    "prefill_32k": dict(kind="prefill", S=32768, B=32),
    "decode_32k": dict(kind="decode", S=32768, B=128),
    "long_500k": dict(kind="decode", S=524288, B=1, long=True),
}


def lm_cell(arch: str, cfg: lm_mod.LMConfig, cell: str,
            topo: Topology, probe_layers: Optional[int] = None
            ) -> CellProgram:
    """``probe_layers`` builds a depth-L *unrolled* probe variant of
    the cell: XLA's cost model counts a lax.scan body once regardless
    of trip count, so probes unroll layers into straight-line HLO and
    the roofline reconstructs true totals from two probes (L=1, L=2):
    total = f(1) + (n_layers - 1) · (f(2) - f(1))."""
    if probe_layers is not None:
        cfg = dataclasses.replace(
            cfg, n_layers=probe_layers, scan_layers=False
        )
    sh = LM_SHAPES[cell]
    if sh["kind"] == "train":
        return lm_train_cell(arch, cell, cfg, topo, sh["B"], sh["S"])
    if sh["kind"] == "prefill":
        return lm_prefill_cell(arch, cell, cfg, topo, sh["B"], sh["S"])
    return lm_decode_cell(
        arch, cell, cfg, topo, sh["B"], sh["S"], sh.get("long", False)
    )


# ------------------------------------------------------------------ #
# GNN cells

GNN_SHAPES = {
    "full_graph_sm": dict(n=2708, e=10556, d_feat=1433, classes=7),
    "minibatch_lg": dict(
        seeds=1024, fanouts=(15, 10), d_feat=602, classes=41,
        n=169984, e=168960,  # padded block sizes for the fanout
    ),
    "ogb_products": dict(n=2449029, e=61859140, d_feat=100, classes=47),
    "molecule": dict(batch=128, n=30, e=64, d_feat=10, triplet_pad=512),
}


_PAD = 512  # lcm of both production meshes' device counts


def _pad_up(x: int, m: int = _PAD) -> int:
    return -(-x // m) * m


def gnn_flat_batch_shapes(sh: dict, *, coords: bool, triplets: bool,
                          tri_cap: int = 2) -> dict:
    """Node/edge/triplet counts are padded up to a multiple of the
    device count (jit in_shardings need even shards); padded entries
    carry mask=False and the models multiply messages by the mask."""
    n, e = _pad_up(sh["n"]), _pad_up(sh["e"])
    batch = {
        "x": sds((n, sh["d_feat"]), jnp.float32),
        "edge_src": sds((e,), jnp.int32),
        "edge_dst": sds((e,), jnp.int32),
        "edge_mask": sds((e,), jnp.bool_),
        "labels": sds((n,), jnp.int32),
    }
    if coords:
        batch["coords"] = sds((n, 3), jnp.float32)
    if triplets:
        t = _pad_up(e * tri_cap)
        batch["tri_kj"] = sds((t,), jnp.int32)
        batch["tri_ji"] = sds((t,), jnp.int32)
        batch["tri_mask"] = sds((t,), jnp.bool_)
    return batch


def gnn_flat_specs(topo: Topology, batch: dict) -> dict:
    """Nodes/edges/triplets shard over the whole mesh (uneven shards
    are fine under jit/GSPMD)."""
    allax = topo.all_axes
    specs = {}
    for k, v in batch.items():
        specs[k] = P(allax, *([None] * (len(v.shape) - 1)))
    return specs


def gnn_packed_specs(topo: Topology, batch: dict) -> dict:
    """Molecule batch (128 graphs) shards over the dp axes."""
    return {
        k: P(topo.dp, *([None] * (len(v.shape) - 1)))
        for k, v in batch.items()
    }


def gnn_packed_batch_shapes(sh: dict, *, triplets: bool) -> dict:
    b, n, e = sh["batch"], sh["n"], sh["e"]
    batch = {
        "x": sds((b, n, sh["d_feat"]), jnp.float32),
        "coords": sds((b, n, 3), jnp.float32),
        "edge_src": sds((b, e), jnp.int32),
        "edge_dst": sds((b, e), jnp.int32),
        "edge_mask": sds((b, e), jnp.bool_),
        "y": sds((b,), jnp.float32),
    }
    if triplets:
        t = sh["triplet_pad"]
        batch["tri_kj"] = sds((b, t), jnp.int32)
        batch["tri_ji"] = sds((b, t), jnp.int32)
        batch["tri_mask"] = sds((b, t), jnp.bool_)
    return batch


def gnn_train_cell(arch: str, cell: str, loss_fn, init_fn, mcfg,
                   topo: Topology, *, coords: bool, triplets: bool,
                   model_flops: float) -> CellProgram:
    sh = GNN_SHAPES[cell]
    tc = TrainConfig(adamw=AdamWConfig())
    params = abstract_init(init_fn, jax.random.PRNGKey(0), mcfg)
    opt = abstract_init(init_state, params, tc.adamw)
    rep = jax.tree_util.tree_map(lambda _: P(), params)
    ospecs = state_specs(rep, tc.adamw)
    if cell == "molecule":
        batch = gnn_packed_batch_shapes(sh, triplets=triplets)
        bspecs = gnn_packed_specs(topo, batch)
    else:
        batch = gnn_flat_batch_shapes(
            sh, coords=coords, triplets=triplets
        )
        bspecs = gnn_flat_specs(topo, batch)

    if cell != "molecule":
        # §Perf: pin segment-reduce outputs to the mesh-sharded layout
        # and enable owner-aligned local scatters for dst-sorted index
        # lists (dimenet triplets)
        from repro.models.gnn.layers import (
            aligned_scatter, segment_output_sharding,
        )

        seg_sh = NamedSharding(topo.mesh, P(topo.all_axes))

        def sharded_loss(p, b):
            with segment_output_sharding(seg_sh), aligned_scatter(topo):
                return loss_fn(p, b, mcfg)
    else:
        def sharded_loss(p, b):
            return loss_fn(p, b, mcfg)

    step = build_train_step(sharded_loss, tc)
    return CellProgram(
        arch=arch, cell=cell, kind="train", fn=step,
        args=(params, opt, batch, sds((), jnp.int32)),
        in_shardings=(
            named(topo, rep), named(topo, ospecs), named(topo, bspecs),
            NamedSharding(topo.mesh, P()),
        ),
        donate_argnums=(0, 1),
        model_flops=model_flops,
        notes=f"{cell}: " + ", ".join(f"{k}={v}" for k, v in sh.items()),
    )


# ------------------------------------------------------------------ #
# recsys (MIND) cells

RECSYS_SHAPES = {
    "train_batch": dict(kind="train", B=65536),
    "serve_p99": dict(kind="serve", B=512),
    "serve_bulk": dict(kind="serve", B=262144),
    "retrieval_cand": dict(kind="retrieval", B=1, n_candidates=1_000_000),
}


def mind_batch_shapes(cfg, B: int, *, with_labels: bool) -> dict:
    F = cfg.n_profile_fields * cfg.profile_multi
    batch = {
        "hist": sds((B, cfg.hist_len), jnp.int32),
        "hist_mask": sds((B, cfg.hist_len), jnp.bool_),
        "profile_ids": sds((B, F), jnp.int32),
        "profile_mask": sds((B, F), jnp.bool_),
    }
    if with_labels:
        batch["target"] = sds((B,), jnp.int32)
        batch["negatives"] = sds((B, cfg.n_negatives), jnp.int32)
    return batch


def mind_batch_specs(topo: Topology, batch: dict, B: int) -> dict:
    ax = topo.all_axes if B % topo.n_devices == 0 else (
        topo.dp if B % topo.dp_size == 0 else None
    )
    return {
        k: P(ax, *([None] * (len(v.shape) - 1)))
        for k, v in batch.items()
    }


def mind_param_specs(cfg, topo: Topology) -> dict:
    """Embedding tables row-sharded over the whole mesh (the huge-
    sparse-table layout); small dense params replicated."""
    allax = topo.all_axes
    return {
        "item_table": P(allax, None),
        "profile_table": P(allax, None),
        "bilinear": P(),
        "routing_init": P(),
        "interest_mlp": {
            k: P() for k in ("w0", "w1", "b0", "b1")
        },
    }


def mind_flops(cfg, B: int, kind: str, n_candidates: int = 0) -> float:
    d, K, L = cfg.embed_dim, cfg.n_interests, cfg.hist_len
    routing = 2 * cfg.capsule_iters * 2 * B * L * K * d + 2 * B * L * d * d
    mlp = 2 * B * K * (2 * d * d + d * d)
    fwd = routing + mlp
    if kind == "train":
        return 3 * fwd + 6 * B * (1 + cfg.n_negatives) * d
    if kind == "retrieval":
        return fwd + 2 * B * K * n_candidates * d
    return fwd


def mind_cell(arch: str, cell: str, cfg, topo: Topology) -> CellProgram:
    from repro.models import mind as mind_mod

    sh = RECSYS_SHAPES[cell]
    B = sh["B"]
    params = abstract_init(mind_mod.init_params, jax.random.PRNGKey(0), cfg)
    pspecs = mind_param_specs(cfg, topo)

    if sh["kind"] == "train":
        tc = TrainConfig(adamw=AdamWConfig())
        opt = abstract_init(init_state, params, tc.adamw)
        ospecs = state_specs(pspecs, tc.adamw)
        batch = mind_batch_shapes(cfg, B, with_labels=True)
        bspecs = mind_batch_specs(topo, batch, B)
        step = build_train_step(
            lambda p, b: mind_mod.sampled_softmax_loss(p, b, cfg), tc
        )
        return CellProgram(
            arch=arch, cell=cell, kind="train", fn=step,
            args=(params, opt, batch, sds((), jnp.int32)),
            in_shardings=(
                named(topo, pspecs), named(topo, ospecs),
                named(topo, bspecs), NamedSharding(topo.mesh, P()),
            ),
            donate_argnums=(0, 1),
            model_flops=mind_flops(cfg, B, "train"),
            notes=f"B={B}",
        )

    batch = mind_batch_shapes(cfg, B, with_labels=False)
    bspecs = mind_batch_specs(topo, batch, B)
    if sh["kind"] == "retrieval":
        nc = sh["n_candidates"]
        cand = sds((nc,), jnp.int32)

        def fn(p, b, c):
            return mind_mod.retrieval_scores(p, b, c, cfg)

        return CellProgram(
            arch=arch, cell=cell, kind="serve", fn=fn,
            args=(params, batch, cand),
            in_shardings=(
                named(topo, pspecs), named(topo, bspecs),
                NamedSharding(topo.mesh, P(topo.dp)),
            ),
            model_flops=mind_flops(cfg, B, "retrieval", nc),
            notes=f"B={B} n_candidates={nc}",
        )

    def fn(p, b):
        return mind_mod.serve_interests(p, b, cfg)

    return CellProgram(
        arch=arch, cell=cell, kind="serve", fn=fn,
        args=(params, batch),
        in_shardings=(named(topo, pspecs), named(topo, bspecs)),
        model_flops=mind_flops(cfg, B, "serve"),
        notes=f"B={B}",
    )


# ------------------------------------------------------------------ #
# SSSP (the paper's own workload) cells


def sssp_cell(arch: str, cell: str, topo: Topology, *,
              scale: int, avg_degree: int, width: int,
              root: str = "delta:5", variant: str = "buffer",
              exchange: str = "a2a",
              spec: "str | None" = None) -> CellProgram:
    """Abstract partitioned-graph SSSP solve on the production mesh.
    Shapes derive from (scale, avg_degree, width) without building
    the graph: rows/rank ~ n_local * ceil(avg_deg/width) * safety.
    ``spec`` (any solver spec — legacy ``root+variant/exchange`` or a
    grammar-v2 hierarchy) overrides root/variant/exchange."""
    from repro.api import Solver, SolverConfig
    from repro.core.engine import build_step  # noqa: F401 (doc link)

    P_ = topo.n_devices
    n = 1 << scale
    n_local = -(-n // P_)
    n_pad = n_local * P_
    # virtual rows per rank: ceil(deg/width) summed ~ e/width + n_local
    rows = int(1.3 * (n_local * avg_degree / width + n_local))
    cfg = (
        SolverConfig.from_spec(spec, chunk_size=4096)
        if spec is not None
        else SolverConfig(root=root, variant=variant, exchange=exchange,
                          chunk_size=4096)
    )
    solver = Solver(
        cfg,
        mesh=topo.mesh,
    )
    solve = solver.compiled(n_parts=P_, n_local=n_local)

    args = (
        sds((P_, rows), jnp.int32),
        sds((P_, rows, width), jnp.int32),
        sds((P_, rows, width), jnp.float32),
        sds((P_, n_local + 1), jnp.float32),
        sds((P_, n_local + 1), jnp.float32),
        sds((P_, n_local + 1), jnp.float32),
    )
    shard = NamedSharding(topo.mesh, P(topo.all_axes))
    # per-superstep useful flops: relax (2 flops/edge) + scatter+min
    flops_per_step = 3.0 * n * avg_degree / 1.0
    return CellProgram(
        arch=arch, cell=cell, kind="sssp", fn=solve,
        args=args,
        in_shardings=(shard,) * 6,
        model_flops=flops_per_step,
        notes=(
            f"scale={scale} deg={avg_degree} W={width} "
            f"{cfg.name} (flops = one superstep)"
        ),
    )
