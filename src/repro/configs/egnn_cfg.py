"""egnn [arXiv:2102.09844].

4 layers, d_hidden 64, E(n)-equivariant coordinate updates.
"""

from repro.configs.cells import GNN_SHAPES, gnn_train_cell
from repro.models.gnn import egnn

ARCH_ID = "egnn"
FAMILY = "gnn"
SHAPES = list(GNN_SHAPES)


def make_config(reduced: bool = False, cell: str = "molecule"):
    sh = GNN_SHAPES.get(cell, GNN_SHAPES["molecule"])
    d_in = sh.get("d_feat", 10)
    n_classes = 0 if cell == "molecule" else sh.get("classes", 0)
    if reduced:
        return egnn.EGNNConfig(n_layers=2, d_hidden=16, d_in=d_in,
                               n_classes=n_classes)
    return egnn.EGNNConfig(n_layers=4, d_hidden=64, d_in=d_in,
                           n_classes=n_classes)


def _flops(cell: str, cfg) -> float:
    sh = GNN_SHAPES[cell]
    e = sh["e"] * sh.get("batch", 1)
    n = sh["n"] * sh.get("batch", 1)
    d = cfg.d_hidden
    per_edge = 2 * ((2 * d + 1) * d + d * d + d * d + d)
    per_node = 2 * (2 * d * d + d * d)
    return 3.0 * cfg.n_layers * (e * per_edge + n * per_node)


def make_cell(cell: str, topo, reduced: bool = False):
    cfg = make_config(reduced, cell)
    loss = (
        egnn.regression_loss if cell == "molecule"
        else egnn.node_classification_loss
    )
    return gnn_train_cell(
        ARCH_ID, cell, loss, egnn.init_params, cfg, topo,
        coords=True, triplets=False, model_flops=_flops(cell, cfg),
    )
