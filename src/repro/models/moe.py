"""Expert-parallel Mixture-of-Experts FFN (token-choice top-k).

Design (DESIGN.md §6): experts are sharded over the tensor-parallel
axis (EP-as-TP).  Activations arriving at the FFN are replicated over
`tp` (the Megatron pattern), so every tp shard sees the full local
token set, selects the tokens routed to *its* experts with a local
sort-based dispatch (static capacity C per expert, drops beyond C),
runs its expert FFNs, and the per-shard partial outputs are combined
with the same `psum` a dense TP FFN needs — no all-to-all, no
(N, E, C) one-hot dispatch tensor.  Expert weights are additionally
FSDP-sharded over the dp axes and all-gathered per use (ZeRO-3; the
gather's transpose is a reduce-scatter on the gradient path).

The router is computed identically on every tp shard (same replicated
inputs → same top-k), which keeps dispatch decisions consistent
without any routing collective.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.models.common import Topology, swiglu


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int
    capacity_factor: float = 1.25
    min_capacity: int = 4
    aux_loss_weight: float = 0.01


def capacity(cfg: MoEConfig, n_local_tokens: int) -> int:
    c = int(cfg.capacity_factor * n_local_tokens * cfg.top_k
            / cfg.n_experts)
    return max(cfg.min_capacity, c)


def _moe_local(x, router_w, w_gate, w_up, w_down, *, cfg: MoEConfig,
               topo: Topology, C: int, fsdp_axes: tuple, dp_axes: tuple):
    """Per-device MoE FFN.  x: (N, d) local tokens (replicated over tp).
    w_*: (E_loc, d/fsdp, f) FSDP-sharded expert weights.  ``dp_axes``
    are the axes the *tokens* are sharded over (may be () when the
    batch is replicated); ``fsdp_axes`` shard the weights regardless."""
    N, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    tp = topo.tp_axis if topo.tp_size > 1 else None
    E_loc = E // (topo.tp_size if tp else 1)

    # FSDP: gather full expert weights for this shard's experts
    if fsdp_axes:
        w_gate = jax.lax.all_gather(w_gate, fsdp_axes, axis=1, tiled=True)
        w_up = jax.lax.all_gather(w_up, fsdp_axes, axis=1, tiled=True)
        w_down = jax.lax.all_gather(w_down, fsdp_axes, axis=2, tiled=True)

    # ---- routing (identical on every tp shard) ----
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)  # (N, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- sort-based local dispatch ----
    flat_e = idx.reshape(-1)  # (N*k,)
    flat_tok = jnp.repeat(jnp.arange(N, dtype=jnp.int32), k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e)
    se = flat_e[order]
    st = flat_tok[order]
    sg = flat_gate[order]
    counts = jax.ops.segment_sum(
        jnp.ones_like(se, dtype=jnp.int32), se, num_segments=E
    )
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]]
    )
    r = jnp.arange(N * k, dtype=jnp.int32) - starts[se]  # rank within expert

    m = jax.lax.axis_index(tp) if tp else 0
    local_e = se - m * E_loc
    keep = (local_e >= 0) & (local_e < E_loc) & (r < C)
    slot = jnp.where(keep, local_e * (C + 1) + r, E_loc * (C + 1) - 1)

    gathered = jnp.where(keep[:, None], x[st], 0)
    buf = jnp.zeros((E_loc * (C + 1), d), x.dtype).at[slot].add(gathered)
    buf = buf.reshape(E_loc, C + 1, d)[:, :C]  # drop overflow slot

    # ---- expert FFN (SwiGLU, f32 accumulation on the MXU) ----
    h = swiglu(
        jnp.einsum("ecd,edf->ecf", buf, w_gate,
                   preferred_element_type=jnp.float32),
        jnp.einsum("ecd,edf->ecf", buf, w_up,
                   preferred_element_type=jnp.float32),
    ).astype(x.dtype)
    y = jnp.einsum("ecf,efd->ecd", h, w_down,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    y = y.reshape(E_loc * C, d)

    # ---- combine ----
    yslot = jnp.where(keep, local_e * C + jnp.minimum(r, C - 1), 0)
    vals = jnp.where(keep[:, None], y[yslot], 0)  # (N*k, d)
    out = jnp.zeros((N, d), x.dtype).at[st].add(
        sg[:, None].astype(x.dtype) * vals
    )
    if tp:
        out = jax.lax.psum(out, tp)

    # ---- Switch-style load-balance aux loss (global mean) ----
    frac = counts.astype(jnp.float32) / jnp.float32(N * k)
    mean_prob = jnp.mean(probs, axis=0)
    aux = jnp.float32(E) * jnp.sum(frac * mean_prob)
    if dp_axes:
        aux = jax.lax.pmean(aux, dp_axes)
    if tp:
        aux = jax.lax.pmean(aux, tp)  # no-op value-wise; marks replicated
    return out, aux


def moe_ffn(
    x: jax.Array,          # (B, S, d) — replicated over tp
    router_w: jax.Array,   # (d, E)
    w_gate: jax.Array,     # (E, d, f)
    w_up: jax.Array,       # (E, d, f)
    w_down: jax.Array,     # (E, f, d)
    cfg: MoEConfig,
    topo: Topology,
) -> tuple[jax.Array, jax.Array]:
    """Returns (out (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    # batch shards over dp when divisible; otherwise (e.g. the
    # global_batch=1 long-context decode cell) tokens stay replicated.
    shard_batch = B % topo.dp_size == 0
    n_local = (B // topo.dp_size if shard_batch else B) * S
    C = capacity(cfg, n_local)
    fsdp_axes = topo.dp_axes if topo.dp_size > 1 else ()
    dp_axes = topo.dp_axes if shard_batch and topo.dp_size > 1 else ()
    tp_spec = topo.tp_axis if topo.tp_size > 1 else None
    x_spec = P(topo.dp, None, None) if shard_batch else P(None, None, None)

    def fn(xb, rw, wg, wu, wd):
        xl = xb.reshape(-1, d)
        out, aux = _moe_local(
            xl, rw, wg, wu, wd, cfg=cfg, topo=topo, C=C,
            fsdp_axes=fsdp_axes, dp_axes=dp_axes,
        )
        if not shard_batch and topo.dp_size > 1:
            # tokens were processed redundantly on every dp shard;
            # mark the result replicated for the out_spec.
            out = jax.lax.pmean(out, topo.dp_axes)
        # mark aux replicated over the whole mesh (value already equal)
        aux = jax.lax.pmean(aux, topo.axis_names)
        return out.reshape(xb.shape), aux

    out, aux = shard_map(
        fn,
        mesh=topo.mesh,
        in_specs=(
            x_spec,
            P(None, None),
            P(tp_spec, topo.dp, None),
            P(tp_spec, topo.dp, None),
            P(tp_spec, None, topo.dp),
        ),
        out_specs=(x_spec, P()),
    )(x, router_w, w_gate, w_up, w_down)
    return out, aux
