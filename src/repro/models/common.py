"""Shared model building blocks: topology, norms, RoPE, init, sharding.

The framework separates the *logical* model from its *placement*: a
:class:`Topology` names the mesh axes used for data parallelism
(pod × data → "dp"), tensor/expert parallelism ("tp") and, for long-
context decode, sequence parallelism over the KV cache.  Models emit
`PartitionSpec` trees keyed off the topology, and internal activation
shardings are pinned with `with_sharding_constraint` so GSPMD's
choices match the design (Megatron TP + FSDP + sequence-sharded
residual stream).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Topology:
    """Mesh + axis-role mapping.

    dp_axes: axes that shard the batch (and FSDP-shard params).
    tp_axis: axis for tensor/expert parallelism (None = no TP).
    """

    mesh: Mesh
    dp_axes: tuple = ("data",)
    tp_axis: Optional[str] = "model"

    @property
    def axis_names(self) -> tuple:
        return tuple(self.mesh.axis_names)

    @property
    def dp(self):
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    @property
    def tp_size(self) -> int:
        if self.tp_axis is None or self.tp_axis not in self.axis_names:
            return 1
        return self.mesh.shape[self.tp_axis]

    @property
    def dp_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.dp_axes]))

    @property
    def n_devices(self) -> int:
        return int(np.prod(list(self.mesh.shape.values())))

    @property
    def all_axes(self) -> tuple:
        return tuple(self.mesh.axis_names)

    def tp(self):
        return self.tp_axis

    def spec(self, *axes) -> P:
        """PartitionSpec, dropping axis roles the mesh doesn't have."""
        out = []
        for a in axes:
            if a == "dp":
                out.append(self.dp)
            elif a == "tp":
                out.append(self.tp_axis if self.tp_size > 1 else None)
            elif a == "all":
                out.append(self.all_axes)
            else:
                out.append(a)
        return P(*out)


def single_device_topology() -> Topology:
    mesh = jax.make_mesh((1,), ("data",))
    return Topology(mesh=mesh, dp_axes=("data",), tp_axis=None)


def constrain(x, topo: Topology, *axes):
    """with_sharding_constraint via the topology's axis roles."""
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(topo.mesh, topo.spec(*axes))
    )


# ----------------------------------------------------------------- #
# numerics


def rms_norm(x, scale, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(
        dtype
    )


def rope_angles(positions, dim: int, theta: float = 10000.0):
    """(..., dim/2) cos/sin tables for rotary embedding."""
    freqs = jnp.exp(
        -math.log(theta)
        * jnp.arange(0, dim, 2, dtype=jnp.float32)
        / dim
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., S, H, D); cos/sin: (S, D/2) or broadcastable."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    # broadcast cos/sin over head axis
    while cos.ndim < x1.ndim:
        cos, sin = cos[..., None, :], sin[..., None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def swiglu(gate, up):
    return jax.nn.silu(gate) * up


def relu2(x):
    r = jax.nn.relu(x)
    return r * r


# ----------------------------------------------------------------- #
# initialization


def normal_init(key, shape, scale: float, dtype=jnp.float32):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def fan_in_init(key, shape, fan_in: Optional[int] = None,
                dtype=jnp.float32):
    fan = fan_in if fan_in is not None else shape[-2] if len(shape) > 1 else shape[-1]
    return normal_init(key, shape, 1.0 / math.sqrt(fan), dtype)


def split_keys(key, names: Sequence[str]) -> dict:
    ks = jax.random.split(key, len(names))
    return dict(zip(names, ks))


def param_count(params) -> int:
    return int(
        sum(np.prod(p.shape) for p in jax.tree_util.tree_leaves(params))
    )


def tree_bytes(params) -> int:
    return int(
        sum(
            np.prod(p.shape) * p.dtype.itemsize
            for p in jax.tree_util.tree_leaves(params)
        )
    )
