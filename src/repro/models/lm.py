"""Config-driven decoder-only transformer LM family.

Covers the five assigned architectures through one implementation:
  phi3-mini / minitron  — dense GQA (SwiGLU or squared-ReLU MLP)
  minicpm3              — MLA (latent-compressed KV, absorbed decode)
  phi3.5-moe / dbrx     — GQA + token-choice top-k MoE (EP-as-TP)

Distribution (DESIGN.md §6): Megatron TP over heads/ffn/vocab on the
`tp` axis, FSDP over the dp axes, sequence-parallel residual stream
(constrained S-sharding between blocks), MoE experts on `tp` via
:mod:`repro.models.moe`.  Long-context decode shards the KV cache
over the sequence axis (SP decode) so no full-length score tensor is
ever materialized on one chip.

Steps exposed (all pure functions of (params, batch)):
  lm_loss      — training loss (chunked vocab CE, no (B,S,V) f32 blowup)
  prefill_step — build KV cache from a prompt, last-position logits
  decode_step  — one token against a full cache (the decode_* and
                 long_* shape cells lower THIS, not train_step)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import (
    Topology,
    apply_rope,
    constrain,
    fan_in_init,
    normal_init,
    rms_norm,
    rope_angles,
    relu2,
    swiglu,
)
from repro.models.moe import MoEConfig, moe_ffn
from repro.kernels.flash_attention import mha as mha_kernel


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None
    mlp_type: str = "swiglu"          # 'swiglu' | 'relu2'
    attn_type: str = "gqa"            # 'gqa' | 'mla'
    moe: Optional[MoEConfig] = None
    # --- MLA (minicpm3) ---
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64
    # --- misc ---
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: str = "bfloat16"
    remat: str = "full"               # 'none' | 'full'
    attn_impl: str = "xla"            # 'xla' | 'xla_flash' | 'pallas*'
    attn_chunk: int = 1024            # kv chunk for xla_flash
    loss_chunk: int = 512             # seq chunk for vocab CE
    seq_shard_resid: bool = True      # Megatron-style sequence parallelism
    scan_layers: bool = True          # False: python-unrolled (probes)

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def n_params(self) -> int:
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        if self.attn_type == "mla":
            qk = self.qk_nope_dim + self.qk_rope_dim
            attn = (
                d * self.q_lora_rank
                + self.q_lora_rank * self.n_heads * qk
                + d * (self.kv_lora_rank + self.qk_rope_dim)
                + self.kv_lora_rank * self.n_heads
                * (self.qk_nope_dim + self.v_head_dim)
                + self.n_heads * self.v_head_dim * d
            )
        else:
            dh = self.head_dim
            attn = d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh \
                + self.n_heads * dh * d
        if self.moe:
            mlp = self.moe.n_experts * 3 * d * self.moe.d_ff + \
                d * self.moe.n_experts
        else:
            mlp = (3 if self.mlp_type == "swiglu" else 2) * d * f
        emb = V * d * (1 if self.tie_embeddings else 2)
        return L * (attn + mlp) + emb

    def n_active_params(self) -> int:
        """Per-token active parameters (MoE counts top_k experts)."""
        if not self.moe:
            return self.n_params()
        d = self.d_model
        dense = self.n_params() - self.n_layers * (
            self.moe.n_experts * 3 * d * self.moe.d_ff
        )
        return dense + self.n_layers * self.moe.top_k * 3 * d * self.moe.d_ff


# ----------------------------------------------------------------- #
# parameters


def init_params(key, cfg: LMConfig) -> dict:
    dt = cfg.dtype
    L, d, f, V = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    keys = jax.random.split(key, 24)

    def fi(k, shape, fan):
        return fan_in_init(k, shape, fan, dt)

    layers: dict = {
        "ln1": jnp.ones((L, d), dt),
        "ln2": jnp.ones((L, d), dt),
    }
    if cfg.attn_type == "gqa":
        layers.update(
            wq=fi(keys[0], (L, d, H * dh), d),
            wk=fi(keys[1], (L, d, KV * dh), d),
            wv=fi(keys[2], (L, d, KV * dh), d),
            wo=fi(keys[3], (L, H * dh, d), H * dh),
        )
    else:  # mla
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        layers.update(
            wq_a=fi(keys[0], (L, d, cfg.q_lora_rank), d),
            q_norm=jnp.ones((L, cfg.q_lora_rank), dt),
            wq_b=fi(keys[1], (L, cfg.q_lora_rank, H * qk),
                    cfg.q_lora_rank),
            wkv_a=fi(keys[2], (L, d, cfg.kv_lora_rank + cfg.qk_rope_dim),
                     d),
            kv_norm=jnp.ones((L, cfg.kv_lora_rank), dt),
            wk_b=fi(keys[4], (L, cfg.kv_lora_rank, H * cfg.qk_nope_dim),
                    cfg.kv_lora_rank),
            wv_b=fi(keys[5], (L, cfg.kv_lora_rank, H * cfg.v_head_dim),
                    cfg.kv_lora_rank),
            wo=fi(keys[3], (L, H * cfg.v_head_dim, d), H * cfg.v_head_dim),
        )
    if cfg.moe:
        E, fe = cfg.moe.n_experts, cfg.moe.d_ff
        layers.update(
            router=fi(keys[6], (L, d, E), d),
            wg_e=fi(keys[7], (L, E, d, fe), d),
            wu_e=fi(keys[8], (L, E, d, fe), d),
            wd_e=fi(keys[9], (L, E, fe, d), fe),
        )
    else:
        layers.update(
            wg=fi(keys[7], (L, d, f), d),
            wd=fi(keys[9], (L, f, d), f),
        )
        if cfg.mlp_type == "swiglu":
            layers.update(wu=fi(keys[8], (L, d, f), d))

    params = {
        "embed": normal_init(keys[10], (V, d), 0.02, dt),
        "layers": layers,
        "final_norm": jnp.ones((d,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = fi(keys[11], (d, V), d)
    return params


def param_specs(cfg: LMConfig, topo: Topology) -> dict:
    """PartitionSpec tree matching init_params: TP on heads/ffn/vocab
    ('tp'), FSDP on the complementary dim ('dp')."""
    s = topo.spec
    layers: dict = {
        "ln1": s(None, None),
        "ln2": s(None, None),
    }
    if cfg.attn_type == "gqa":
        layers.update(
            wq=s(None, "dp", "tp"),
            wk=s(None, "dp", "tp"),
            wv=s(None, "dp", "tp"),
            wo=s(None, "tp", "dp"),
        )
    else:
        # §Perf iteration 1 (minicpm3 prefill): the MLA lora
        # projections are small (q_lora 768 / kv_lora 256 wide), but
        # FSDP-sharding their CONTRACTION dims made GSPMD all-reduce
        # (B,S,H·d) activations — ~0.7 TB/layer/device on the 32k
        # prefill.  Keep them replicated / TP-only instead: the whole
        # MLA stack is ~14M params/layer, so replication costs ~1.7 GB
        # per device for minicpm3 and removes the activation
        # reductions entirely (weights are gathered, not activations).
        layers.update(
            wq_a=s(None, None, None),
            q_norm=s(None, None),
            wq_b=s(None, None, "tp"),
            wkv_a=s(None, None, None),
            kv_norm=s(None, None),
            wk_b=s(None, None, "tp"),
            wv_b=s(None, None, "tp"),
            wo=s(None, "tp", "dp"),
        )
    if cfg.moe:
        layers.update(
            router=s(None, None, None),
            wg_e=s(None, "tp", "dp", None),
            wu_e=s(None, "tp", "dp", None),
            wd_e=s(None, "tp", None, "dp"),
        )
    else:
        layers.update(wg=s(None, "dp", "tp"), wd=s(None, "tp", "dp"))
        if cfg.mlp_type == "swiglu":
            layers.update(wu=s(None, "dp", "tp"))
    specs = {
        "embed": s("tp", "dp"),
        "layers": layers,
        "final_norm": s(None),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = s("dp", "tp")
    return specs


# ----------------------------------------------------------------- #
# attention


def _grouped_scores(q, k):
    """q (B,S,H,dh), k (B,T,KV,dh) -> scores (B,KV,G,S,T) without
    materializing head-expanded KV."""
    B, S, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, dh)
    return jnp.einsum("bskgd,btkd->bkgst", qg, k,
                      preferred_element_type=jnp.float32)


def _grouped_out(p, v):
    """p (B,KV,G,S,T), v (B,T,KV,dh) -> (B,S,H,dh)."""
    B, KV, G, S, T = p.shape
    out = jnp.einsum("bkgst,btkd->bskgd", p, v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, KV * G, -1)


def attention_xla(q, k, v, *, causal: bool, scale: float):
    """Full-score attention (small S / correctness path)."""
    s = _grouped_scores(q, k) * scale
    S, T = s.shape[-2], s.shape[-1]
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return _grouped_out(p.astype(q.dtype), v).astype(q.dtype)


def attention_xla_flash(q, k, v, *, causal: bool, scale: float,
                        chunk: int):
    """Blockwise-softmax attention in plain XLA (scan over KV chunks);
    memory O(S·chunk) — used for the 32k-prefill cells."""
    B, S, H, dh = q.shape
    T = k.shape[1]
    KV = k.shape[2]
    dv = v.shape[-1]  # may differ from dh (MLA: qk 96, v 64)
    G = H // KV
    nc = T // chunk
    qg = q.reshape(B, S, KV, G, dh)

    def body(carry, ci):
        # unrolled over static ci: causal skipping of fully-masked
        # chunks is free, and XLA's cost model counts every chunk
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k, ci * chunk, chunk, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v, ci * chunk, chunk, axis=1)
        sc = jnp.einsum("bskgd,btkd->bkgst", qg, ks,
                        preferred_element_type=jnp.float32) * scale
        if causal:
            rows = jnp.arange(S)[:, None] + (T - S)
            cols = ci * chunk + jnp.arange(chunk)[None, :]
            sc = jnp.where(rows >= cols, sc, -1e30)
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        pexp = jnp.exp(sc - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(pexp, axis=-1)
        upd = jnp.einsum("bkgst,btkd->bkgsd", pexp, vs,
                         preferred_element_type=jnp.float32)
        acc_new = acc * alpha[..., None] + upd
        return (m_new, l_new, acc_new)

    m0 = jnp.full((B, KV, G, S), -1e30, jnp.float32)
    l0 = jnp.zeros((B, KV, G, S), jnp.float32)
    a0 = jnp.zeros((B, KV, G, S, dv), jnp.float32)
    carry = (m0, l0, a0)
    for ci in range(nc):
        if causal and ci * chunk > (T - S) + S - 1:
            continue  # chunk entirely above the causal diagonal
        carry = body(carry, ci)
    m, l, acc = carry
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, dv).astype(q.dtype)


def run_attention(q, k, v, cfg: LMConfig, *, causal=True):
    """q (B,S,H,dh), k/v (B,T,KV,dh) -> (B,S,H*dh)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    if cfg.attn_impl.startswith("pallas") and q.shape[-1] != v.shape[-1]:
        # the Pallas kernel assumes a single head dim; MLA (qk 96 /
        # v 64) takes the XLA blockwise path instead
        return run_attention(
            q, k, v,
            dataclasses.replace(cfg, attn_impl="xla_flash"),
            causal=causal,
        )
    if cfg.attn_impl.startswith("pallas"):
        out = mha_kernel(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal,
            impl=cfg.attn_impl,
        ).transpose(0, 2, 1, 3)
    elif cfg.attn_impl == "xla_flash" and k.shape[1] >= cfg.attn_chunk:
        out = attention_xla_flash(
            q, k, v, causal=causal, scale=scale, chunk=cfg.attn_chunk
        )
    else:
        out = attention_xla(q, k, v, causal=causal, scale=scale)
    B, S = q.shape[0], q.shape[1]
    return out.reshape(B, S, -1)


def decode_attention(q, k_cache, v_cache, pos, scale):
    """One-position attention against a (possibly sequence-sharded)
    cache.  q (B,1,H,dh); k/v (B,T,KV,dh); mask positions >= pos+1.
    Written as plain reductions so GSPMD turns the T-dim reductions
    into partial-softmax collectives when T is sharded (SP decode)."""
    s = _grouped_scores(q, k_cache) * scale  # (B,KV,G,1,T)
    T = k_cache.shape[1]
    valid = jnp.arange(T)[None, None, None, None, :] <= pos
    s = jnp.where(valid, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = _grouped_out((p / l).astype(q.dtype), v_cache).astype(q.dtype)
    return out.reshape(q.shape[0], 1, -1)


# ----------------------------------------------------------------- #
# blocks


def _mlp(lp, x, cfg: LMConfig):
    if cfg.mlp_type == "swiglu":
        h = swiglu(x @ lp["wg"], x @ lp["wu"])
    else:
        h = relu2(x @ lp["wg"])
    return h @ lp["wd"]


def _gqa_qkv(lp, x, cfg: LMConfig, positions):
    B, S, d = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ lp["wq"]).reshape(B, S, H, dh)
    k = (x @ lp["wk"]).reshape(B, S, KV, dh)
    v = (x @ lp["wv"]).reshape(B, S, KV, dh)
    cos, sin = rope_angles(positions, dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def _mla_q(lp, x, cfg: LMConfig, positions):
    B, S, d = x.shape
    H = cfg.n_heads
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    qa = rms_norm(x @ lp["wq_a"], lp["q_norm"], cfg.norm_eps)
    q = (qa @ lp["wq_b"]).reshape(B, S, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    cos, sin = rope_angles(positions, rope, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def _mla_latent(lp, x, cfg: LMConfig, positions):
    """Compressed KV: returns (c (B,S,kvr) post-norm, k_rope (B,S,rope))."""
    kvr, rope = cfg.kv_lora_rank, cfg.qk_rope_dim
    kv = x @ lp["wkv_a"]
    c = rms_norm(kv[..., :kvr], lp["kv_norm"], cfg.norm_eps)
    k_rope = kv[..., kvr:]
    cos, sin = rope_angles(positions, rope, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    return c, k_rope


def _mla_attention_train(lp, x, cfg: LMConfig, positions):
    """Materialized MLA attention (train / prefill path)."""
    B, S, d = x.shape
    H, nope, vd = cfg.n_heads, cfg.qk_nope_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(lp, x, cfg, positions)
    c, k_rope = _mla_latent(lp, x, cfg, positions)
    k_nope = (c @ lp["wk_b"]).reshape(B, S, H, nope)
    v = (c @ lp["wv_b"]).reshape(B, S, H, vd)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, cfg.qk_rope_dim))], axis=-1
    )
    out = run_attention(q, k, v, cfg, causal=True)
    return out @ lp["wo"], (c, k_rope)


def _mla_attention_decode(lp, x, cfg: LMConfig, c_cache, kr_cache, pos):
    """Absorbed MLA decode: scores/context in latent space — the KV
    cache stays (kvr + rope) per token, never expanded to H heads."""
    B = x.shape[0]
    H, nope, vd = cfg.n_heads, cfg.qk_nope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    positions = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(lp, x, cfg, positions)  # (B,1,H,·)
    wk_b = lp["wk_b"].reshape(kvr, H, nope)
    q_abs = jnp.einsum("bqhn,rhn->bqhr", q_nope, wk_b)  # (B,1,H,kvr)
    s = (
        jnp.einsum("bqhr,bkr->bhqk", q_abs, c_cache,
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bqhp,bkp->bhqk", q_rope, kr_cache,
                     preferred_element_type=jnp.float32)
    ) / math.sqrt(nope + cfg.qk_rope_dim)
    T = c_cache.shape[1]
    valid = jnp.arange(T)[None, None, None, :] <= pos
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhqk,bkr->bqhr", p, c_cache.astype(jnp.float32))
    wv_b = lp["wv_b"].reshape(kvr, H, vd)
    out = jnp.einsum("bqhr,rhv->bqhv", ctx.astype(x.dtype), wv_b)
    return out.reshape(B, 1, H * vd) @ lp["wo"]


# ----------------------------------------------------------------- #
# forward passes


def _layer_train(lp, x, cfg: LMConfig, topo: Topology, positions):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.attn_type == "gqa":
        q, k, v = _gqa_qkv(lp, h, cfg, positions)
        attn = run_attention(q, k, v, cfg, causal=True) @ lp["wo"]
    else:
        attn, _ = _mla_attention_train(lp, h, cfg, positions)
    x = x + attn
    if cfg.seq_shard_resid and topo.tp_size > 1:
        x = constrain(x, topo, "dp", "tp", None)
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.moe:
        mlp_out, aux = moe_ffn(
            h, lp["router"], lp["wg_e"], lp["wu_e"], lp["wd_e"],
            cfg.moe, topo,
        )
    else:
        mlp_out, aux = _mlp(lp, h, cfg), jnp.float32(0)
    x = x + mlp_out
    if cfg.seq_shard_resid and topo.tp_size > 1:
        x = constrain(x, topo, "dp", "tp", None)
    return x, aux


def forward(params, tokens, cfg: LMConfig, topo: Topology):
    """Token ids (B, S) -> final hidden states (B, S, d), aux loss."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, topo, "dp", None, None)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def layer(x, lp):
        return _layer_train(lp, x, cfg, topo, positions)

    if cfg.remat == "full":
        layer = jax.checkpoint(layer)
    if cfg.scan_layers:
        x, auxs = jax.lax.scan(layer, x, params["layers"])
        aux = jnp.sum(auxs)
    else:
        aux = jnp.float32(0)
        for li in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda v: v[li], params["layers"])
            x, a = layer(x, lp)
            aux = aux + a
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def lm_head_weight(params, cfg: LMConfig):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def lm_loss(params, batch, cfg: LMConfig, topo: Topology):
    """Next-token CE with chunked vocab projection.  batch:
    {'tokens': (B, S), 'labels': (B, S)} with labels < 0 masked."""
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    x, aux = forward(params, tokens, cfg, topo)
    head = lm_head_weight(params, cfg)
    chunk = min(cfg.loss_chunk or S, S)
    n_chunks = S // chunk

    def chunk_ce(ci):
        xc = jax.lax.dynamic_slice_in_dim(x, ci * chunk, chunk, axis=1)
        lc = jax.lax.dynamic_slice_in_dim(labels, ci * chunk, chunk, 1)
        logits = (xc @ head).astype(jnp.float32)  # (B, c, V)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1
        )[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        return jnp.sum((logz - ll) * mask), jnp.sum(mask)

    # unrolled python loop (static trip count): keeps cost_analysis
    # exact (lax.scan bodies are counted once by XLA's cost model)
    tot, cnt = jnp.float32(0), jnp.float32(0)
    for ci in range(n_chunks):
        l, c = chunk_ce(ci)
        tot, cnt = tot + l, cnt + c
    loss = tot / jnp.maximum(cnt, 1.0)
    if cfg.moe:
        loss = loss + cfg.moe.aux_loss_weight * aux / cfg.n_layers
    return loss


# ----------------------------------------------------------------- #
# serving: prefill + single-token decode with a static-size cache


def cache_shapes(cfg: LMConfig, batch: int, max_len: int) -> dict:
    L = cfg.n_layers
    dt = cfg.dtype
    if cfg.attn_type == "mla":
        return {
            "c": jax.ShapeDtypeStruct(
                (L, batch, max_len, cfg.kv_lora_rank), dt),
            "kr": jax.ShapeDtypeStruct(
                (L, batch, max_len, cfg.qk_rope_dim), dt),
        }
    KV, dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jax.ShapeDtypeStruct((L, batch, max_len, KV, dh), dt),
        "v": jax.ShapeDtypeStruct((L, batch, max_len, KV, dh), dt),
    }


def cache_specs(cfg: LMConfig, topo: Topology, *, long: bool) -> dict:
    """Sequence-sharded KV cache.  decode_*: batch over dp, seq over
    tp.  long_*: batch unshardable (B=1) — seq over every axis."""
    s = topo.spec
    if long:
        seq = s(None, None, "all", None)
        seq5 = s(None, None, "all", None, None)
    else:
        seq = s(None, "dp", "tp", None)
        seq5 = s(None, "dp", "tp", None, None)
    if cfg.attn_type == "mla":
        return {"c": seq, "kr": seq}
    return {"k": seq5, "v": seq5}


def prefill_step(params, tokens, cfg: LMConfig, topo: Topology,
                 max_len: int):
    """Prompt (B, S) -> (cache dict, last-position logits (B, V))."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def layer(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        if cfg.attn_type == "gqa":
            q, k, v = _gqa_qkv(lp, h, cfg, positions)
            attn = run_attention(q, k, v, cfg, causal=True) @ lp["wo"]
            kv = {"k": k, "v": v}
        else:
            attn, (c, kr) = _mla_attention_train(lp, h, cfg, positions)
            kv = {"c": c, "kr": kr}
        x = x + attn
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.moe:
            mlp_out, _ = moe_ffn(
                h, lp["router"], lp["wg_e"], lp["wu_e"], lp["wd_e"],
                cfg.moe, topo,
            )
        else:
            mlp_out = _mlp(lp, h, cfg)
        return x + mlp_out, kv

    if cfg.remat == "full":
        layer = jax.checkpoint(layer)
    if cfg.scan_layers:
        x, kvs = jax.lax.scan(layer, x, params["layers"])
    else:
        outs = []
        for li in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda v: v[li], params["layers"])
            x, kv = layer(x, lp)
            outs.append(kv)
        kvs = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *outs
        )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, -1] @ lm_head_weight(params, cfg)).astype(jnp.float32)

    # place prefix into the static-size cache
    cache = {}
    for name, arr in kvs.items():
        pad = [(0, 0)] * arr.ndim
        pad[2] = (0, max_len - S)
        cache[name] = jnp.pad(arr, pad)
    return cache, logits


def decode_step(params, cache, tokens, pos, cfg: LMConfig,
                topo: Topology):
    """One decode step: tokens (B,) against cache at position ``pos``.
    Returns (logits (B, V), updated cache)."""
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)[:, None, :]  # (B,1,d)
    positions = jnp.full((B, 1), pos, jnp.int32)
    scale_dh = cfg.head_dim

    def layer(x, layer_in):
        lp, cache_l = layer_in
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        if cfg.attn_type == "gqa":
            q, k, v = _gqa_qkv(lp, h, cfg, positions)
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache_l["k"], k, pos, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache_l["v"], v, pos, axis=1)
            attn = decode_attention(
                q, k_cache, v_cache, pos, 1.0 / math.sqrt(scale_dh)
            ) @ lp["wo"]
            new_cache = {"k": k_cache, "v": v_cache}
        else:
            c, kr = _mla_latent(lp, h, cfg, positions)
            c_cache = jax.lax.dynamic_update_slice_in_dim(
                cache_l["c"], c, pos, axis=1)
            kr_cache = jax.lax.dynamic_update_slice_in_dim(
                cache_l["kr"], kr, pos, axis=1)
            attn = _mla_attention_decode(
                lp, h, cfg, c_cache, kr_cache, pos)
            new_cache = {"c": c_cache, "kr": kr_cache}
        x = x + attn
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.moe:
            mlp_out, _ = moe_ffn(
                h, lp["router"], lp["wg_e"], lp["wu_e"], lp["wd_e"],
                cfg.moe, topo,
            )
        else:
            mlp_out = _mlp(lp, h, cfg)
        return x + mlp_out, new_cache

    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(layer, x, (params["layers"], cache))
    else:
        outs = []
        for li in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda v: v[li], params["layers"])
            cl = jax.tree_util.tree_map(lambda v: v[li], cache)
            x, nc = layer(x, (lp, cl))
            outs.append(nc)
        new_cache = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *outs
        )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, 0] @ lm_head_weight(params, cfg)).astype(jnp.float32)
    return logits, new_cache
