"""MIND — Multi-Interest Network with Dynamic routing (arXiv:1904.08030).

Assigned config: embed_dim 64, 4 interest capsules, 3 routing
iterations, multi-interest interaction.

Pipeline:
  item/profile embedding tables (the huge-sparse-embedding hot path —
  rows sharded over the whole mesh; profile fields pool through the
  embedding_bag op/kernel) →
  B2I dynamic-routing capsules over the user's behavior sequence →
  (train) label-aware attention + sampled-softmax loss
  (retrieval)  max-over-interests dot scoring of 10⁶ candidates as one
  batched matmul — no loop.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag import bag_pool
from repro.models.common import fan_in_init, normal_init
from repro.models.gnn.layers import init_mlp, mlp_apply


@dataclasses.dataclass(frozen=True)
class MINDConfig:
    name: str = "mind"
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    n_items: int = 1_000_000
    n_profile: int = 100_000
    hist_len: int = 50
    n_profile_fields: int = 4
    profile_multi: int = 4     # multi-hot ids per profile field
    n_negatives: int = 127
    power: float = 2.0         # label-aware attention sharpness
    bag_impl: str = "ref"      # 'ref' | 'pallas_interpret' | 'pallas'


def init_params(key, cfg: MINDConfig) -> dict:
    ks = jax.random.split(key, 5)
    d = cfg.embed_dim
    return {
        "item_table": normal_init(ks[0], (cfg.n_items, d), 0.02),
        "profile_table": normal_init(ks[1], (cfg.n_profile, d), 0.02),
        "bilinear": fan_in_init(ks[2], (d, d), d),
        "routing_init": normal_init(ks[3], (cfg.n_interests,), 1.0),
        "interest_mlp": init_mlp(ks[4], [2 * d, d, d]),
    }


def squash(x, axis=-1, eps=1e-9):
    n2 = jnp.sum(x * x, axis=axis, keepdims=True)
    return (n2 / (1.0 + n2)) * x / jnp.sqrt(n2 + eps)


def interests(params, hist, hist_mask, profile_ids, profile_mask,
              cfg: MINDConfig):
    """B2I dynamic routing.  hist (B, L) item ids; profile_ids
    (B, F*M) multi-hot profile ids.  Returns (B, K, d)."""
    B, L = hist.shape
    K, d = cfg.n_interests, cfg.embed_dim
    e = jnp.take(params["item_table"], hist, axis=0)       # (B, L, d)
    e = e * hist_mask[..., None].astype(e.dtype)
    eh = e @ params["bilinear"]                            # (B, L, d)

    # routing logits: fixed (non-trainable in-iteration) init per paper
    b = jnp.broadcast_to(
        params["routing_init"][None, None, :], (B, L, K)
    )
    neg = jnp.float32(-1e30)
    mask3 = hist_mask[..., None]
    caps = None
    for _ in range(cfg.capsule_iters):
        w = jax.nn.softmax(jnp.where(mask3, b, neg), axis=1)  # over L
        caps = squash(jnp.einsum("blk,bld->bkd", w, eh))      # (B, K, d)
        b = b + jnp.einsum("bkd,bld->blk", caps, eh)

    # profile features pool through the embedding-bag op
    prof = bag_pool(
        params["profile_table"], profile_ids, profile_mask,
        mode="mean", impl=cfg.bag_impl,
    )                                                       # (B, d)
    prof = jnp.broadcast_to(prof[:, None, :], (B, K, d))
    out = mlp_apply(
        params["interest_mlp"], jnp.concatenate([caps, prof], -1)
    )
    return squash(out)


def label_aware_attention(caps, target_e, power: float):
    """caps (B, K, d), target (B, d) -> user vector (B, d)."""
    att = jnp.einsum("bkd,bd->bk", caps, target_e)
    att = jax.nn.softmax(jnp.abs(att) ** power * jnp.sign(att), axis=-1)
    return jnp.einsum("bk,bkd->bd", att, caps)


def sampled_softmax_loss(params, batch, cfg: MINDConfig):
    """batch: hist (B,L), hist_mask, profile_ids, profile_mask,
    target (B,), negatives (B, n_neg)."""
    caps = interests(
        params, batch["hist"], batch["hist_mask"],
        batch["profile_ids"], batch["profile_mask"], cfg,
    )
    tgt_e = jnp.take(params["item_table"], batch["target"], axis=0)
    user = label_aware_attention(caps, tgt_e, cfg.power)    # (B, d)
    neg_e = jnp.take(params["item_table"], batch["negatives"], axis=0)
    pos = jnp.einsum("bd,bd->b", user, tgt_e)[:, None]      # (B, 1)
    negs = jnp.einsum("bd,bnd->bn", user, neg_e)            # (B, n)
    logits = jnp.concatenate([pos, negs], axis=1).astype(jnp.float32)
    return jnp.mean(
        jax.nn.logsumexp(logits, axis=1) - logits[:, 0]
    )


def serve_interests(params, batch, cfg: MINDConfig):
    """Online inference (serve_p99 / serve_bulk): user interests."""
    return interests(
        params, batch["hist"], batch["hist_mask"],
        batch["profile_ids"], batch["profile_mask"], cfg,
    )


def retrieval_scores(params, batch, cand_ids, cfg: MINDConfig):
    """Score n_candidates items against each user's interests:
    one batched matmul + max over interests (no loop)."""
    caps = serve_interests(params, batch, cfg)              # (B, K, d)
    cand = jnp.take(params["item_table"], cand_ids, axis=0)  # (Nc, d)
    scores = jnp.einsum("bkd,nd->bkn", caps, cand)
    return jnp.max(scores, axis=1)                          # (B, Nc)
