"""Assigned GNN architectures (DESIGN.md §Arch-applicability: they
share the graph substrate with the AGM engine — 1D partition, segment
ops, the spmm_ell kernel — but the paper's *ordering* contribution is
inapplicable: GNN layers are bulk-synchronous, i.e. exactly the
Chaotic / synchronous-demon special case of the AGM)."""

from repro.models.gnn import gin, egnn, dimenet, mace
from repro.models.gnn.gin import GINConfig
from repro.models.gnn.egnn import EGNNConfig
from repro.models.gnn.dimenet import DimeNetConfig
from repro.models.gnn.mace import MACEConfig
from repro.models.gnn.batch import (
    FlatGraphBatch,
    PackedGraphBatch,
    build_triplets,
    flat_batch_from_graph,
    random_molecule_batch,
)

__all__ = [
    "gin", "egnn", "dimenet", "mace",
    "GINConfig", "EGNNConfig", "DimeNetConfig", "MACEConfig",
    "FlatGraphBatch", "PackedGraphBatch", "build_triplets",
    "flat_batch_from_graph", "random_molecule_batch",
]
