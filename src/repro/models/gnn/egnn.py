"""EGNN (E(n)-equivariant GNN, arXiv:2102.09844).

    m_ij  = φ_e(h_i, h_j, ||x_i − x_j||²)
    x_i' = x_i + C Σ_j (x_i − x_j) φ_x(m_ij)
    h_i' = φ_h(h_i, Σ_j m_ij)

Assigned config: 4 layers, d_hidden 64.  Coordinates update
equivariantly (tests verify E(3): rotate+translate inputs ⇒ h
invariant, x equivariant).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn.layers import (
    init_mlp, mlp_apply, scatter_mean, scatter_sum,
)


@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    name: str = "egnn"
    n_layers: int = 4
    d_hidden: int = 64
    d_in: int = 64
    n_classes: int = 0       # 0 -> regression readout (energy)


def init_params(key, cfg: EGNNConfig) -> dict:
    ks = jax.random.split(key, 3 * cfg.n_layers + 2)
    d = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        d_in = cfg.d_in if i == 0 else d
        layers.append(
            {
                "phi_e": init_mlp(ks[3 * i], [2 * d_in + 1, d, d]),
                "phi_x": init_mlp(ks[3 * i + 1], [d, d, 1]),
                "phi_h": init_mlp(ks[3 * i + 2], [d_in + d, d, d]),
            }
        )
    out_dim = cfg.n_classes if cfg.n_classes > 0 else 1
    return {
        "layers": layers,
        "readout": init_mlp(ks[-1], [d, d, out_dim]),
    }


def forward(params, x, coords, edge_src, edge_dst, edge_mask,
            cfg: EGNNConfig):
    """Returns (node features (N, d), coords (N, 3))."""
    n = x.shape[0]
    w = edge_mask.astype(x.dtype)[:, None]
    h = x
    for lp in params["layers"]:
        hs = jnp.take(h, edge_src, axis=0)
        hd = jnp.take(h, edge_dst, axis=0)
        diff = jnp.take(coords, edge_dst, axis=0) - jnp.take(
            coords, edge_src, axis=0
        )
        d2 = jnp.sum(diff * diff, axis=-1, keepdims=True)
        m = mlp_apply(lp["phi_e"], jnp.concatenate([hd, hs, d2], -1),
                      final_act=True) * w
        xw = mlp_apply(lp["phi_x"], m)  # (E, 1)
        coords = coords + scatter_mean(diff * xw * w, edge_dst, n)
        agg = scatter_sum(m, edge_dst, n)
        h = mlp_apply(lp["phi_h"], jnp.concatenate([h, agg], -1))
    return h, coords


def energy(params, x, coords, edge_src, edge_dst, edge_mask,
           cfg: EGNNConfig):
    h, _ = forward(params, x, coords, edge_src, edge_dst, edge_mask, cfg)
    return jnp.sum(mlp_apply(params["readout"], h))


def regression_loss(params, batch, cfg: EGNNConfig):
    """Packed molecule batch: energy MSE (vmapped over graphs)."""
    def one(x, c, es, ed, em, y):
        e = energy(params, x, c, es, ed, em, cfg)
        return (e - y) ** 2

    losses = jax.vmap(one)(
        batch["x"], batch["coords"], batch["edge_src"],
        batch["edge_dst"], batch["edge_mask"], batch["y"],
    )
    return jnp.mean(losses)


def node_classification_loss(params, batch, cfg: EGNNConfig):
    h, _ = forward(
        params, batch["x"], batch["coords"], batch["edge_src"],
        batch["edge_dst"], batch["edge_mask"], cfg,
    )
    logits = mlp_apply(params["readout"], h).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, batch["labels"][:, None], axis=-1
    )[:, 0]
    return jnp.mean(logz - ll)
