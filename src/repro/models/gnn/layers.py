"""Shared GNN building blocks.

Message passing is implemented with `jnp.take` (gather) +
`jax.ops.segment_sum` over an edge-index list — JAX has no sparse
message-passing primitive (BCOO only), so this IS the system's SpMM
layer (see kernel_taxonomy §GNN).  The Pallas `spmm_ell` kernel is the
TPU hot-loop realization of the same contraction for ELL-layout
graphs; these segment-op paths are the XLA reference used by the
models (and the dry-run).
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.models.common import fan_in_init

# §Perf (dimenet/ogb_products): when set, every segment-reduce output
# is pinned to a sharded layout so GSPMD lowers the cross-device
# combine as a reduce-scatter (1x payload) instead of an all-reduce
# (2x payload) — and downstream edge-sharded consumers stay aligned.
_SEG_SHARDING: contextvars.ContextVar = contextvars.ContextVar(
    "gnn_segment_sharding", default=None
)


@contextlib.contextmanager
def segment_output_sharding(sharding_1d):
    """sharding_1d: a jax NamedSharding whose spec shards axis 0."""
    tok = _SEG_SHARDING.set(sharding_1d)
    try:
        yield
    finally:
        _SEG_SHARDING.reset(tok)


def _constrain_seg(out):
    sh = _SEG_SHARDING.get()
    if sh is None:
        return out
    from jax.sharding import NamedSharding, PartitionSpec as P

    spec = P(sh.spec[0], *([None] * (out.ndim - 1)))
    return jax.lax.with_sharding_constraint(
        out, NamedSharding(sh.mesh, spec)
    )


def scatter_sum(values, index, n):
    return _constrain_seg(
        jax.ops.segment_sum(values, index, num_segments=n)
    )


# §Perf H2 iter 2 (dimenet/ogb_products): GSPMD lowers a segment-sum
# over mesh-sharded values as per-device DENSE partials + all-reduce
# (390 GB/device on the ogb triplet aggregation).  When the index list
# is *owner-aligned* — host-sorted so that shard p's values target
# exactly the segment range [p·n/P, (p+1)·n/P), which build_triplets'
# dst-ordered output gives after align_segments() padding — the
# reduction is purely local: a shard_map segment-sum with zero
# collectives.  This is the same owner-aligned exchange discipline the
# AGM engine's 1D partition uses (DESIGN.md §2).
_ALIGNED_TOPO: contextvars.ContextVar = contextvars.ContextVar(
    "gnn_aligned_topology", default=None
)


@contextlib.contextmanager
def aligned_scatter(topo):
    tok = _ALIGNED_TOPO.set(topo)
    try:
        yield
    finally:
        _ALIGNED_TOPO.reset(tok)


def scatter_sum_owner_aligned(values, index, n):
    """segment-sum for an owner-aligned (host-sorted+padded) index
    list; falls back to the plain path outside distributed context or
    when shapes don't divide the mesh."""
    topo = _ALIGNED_TOPO.get()
    P_ = topo.n_devices if topo is not None else 1
    if (topo is None or P_ == 1 or n % P_ != 0
            or values.shape[0] % P_ != 0):
        return scatter_sum(values, index, n)
    from jax.sharding import PartitionSpec as P

    n_loc = n // P_
    axes = topo.all_axes

    def local(v, s):
        # v (T/P, d) local slice; s (T/P,) GLOBAL segment ids, all
        # inside this shard's range by the alignment contract
        rank = 0
        for name in axes:
            rank = rank * topo.mesh.shape[name] + jax.lax.axis_index(
                name
            )
        local_ids = s - rank * n_loc
        return jax.ops.segment_sum(v, local_ids, num_segments=n_loc)

    trail = tuple([None] * (values.ndim - 1))
    out = shard_map(
        local, mesh=topo.mesh,
        in_specs=(P(axes, *trail), P(axes)),
        out_specs=P(axes, *trail),
    )(values, index)
    return out


def scatter_mean(values, index, n, eps: float = 1e-9):
    s = scatter_sum(values, index, n)
    cnt = scatter_sum(jnp.ones(values.shape[:1], values.dtype), index, n)
    return s / jnp.maximum(cnt, eps)[:, None]


def scatter_max(values, index, n):
    return _constrain_seg(
        jax.ops.segment_max(values, index, num_segments=n)
    )


def gather_src(x, edge_src):
    return jnp.take(x, edge_src, axis=0)


def init_mlp(key, dims, dtype=jnp.float32):
    ks = jax.random.split(key, len(dims) - 1)
    return {
        f"w{i}": fan_in_init(ks[i], (dims[i], dims[i + 1]), dims[i], dtype)
        for i in range(len(dims) - 1)
    } | {
        f"b{i}": jnp.zeros((dims[i + 1],), dtype)
        for i in range(len(dims) - 1)
    }


def mlp_apply(p, x, act=jax.nn.silu, final_act: bool = False):
    n = len([k for k in p if k.startswith("w")])
    for i in range(n):
        x = x @ p[f"w{i}"] + p[f"b{i}"]
        if i < n - 1 or final_act:
            x = act(x)
    return x
