"""DimeNet (directional message passing, arXiv:2003.03123).

Edge-based messages m_ji with *triplet* interactions: the update of
message m_ji aggregates, over incoming edges k→j, the source message
m_kj modulated by a radial×angular basis of (d_kj, angle(kj, ji)) and
a bilinear layer — the triplet-gather kernel regime that plain SpMM
cannot express.

Assigned config: 6 blocks, d_hidden 128, n_bilinear 8, n_spherical 7,
n_radial 6.  Simplification vs the paper (DESIGN.md): the 2D
spherical-Bessel basis j_l(z_ln r) is replaced by the separable
bessel(n_radial) ⊗ Legendre_l(cos α) product (same tensor shape and
information structure; avoids root-finding for Bessel zeros).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn.geometry import bessel_basis, cosine_cutoff
from repro.models.gnn.layers import (
    init_mlp, mlp_apply, scatter_sum, scatter_sum_owner_aligned,
)
from repro.models.common import fan_in_init


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    d_in: int = 10           # species one-hot
    n_classes: int = 0       # 0 -> regression readout
    # §Perf H2 iter 3: message/edge tensors in bf16 halve the gather-
    # side collective + HBM bytes on web-scale graphs; bases and the
    # readout stay f32.
    msg_dtype: str = "float32"


def _legendre(cos_a, n: int):
    """P_0..P_{n-1}(cos α) via the recurrence, stacked (..., n)."""
    p0 = jnp.ones_like(cos_a)
    if n == 1:
        return p0[..., None]
    ps = [p0, cos_a]
    for l in range(2, n):
        ps.append(
            ((2 * l - 1) * cos_a * ps[-1] - (l - 1) * ps[-2]) / l
        )
    return jnp.stack(ps[:n], axis=-1)


def init_params(key, cfg: DimeNetConfig) -> dict:
    d, nb = cfg.d_hidden, cfg.n_bilinear
    n_sbf = cfg.n_radial * cfg.n_spherical
    ks = jax.random.split(key, 6 * cfg.n_blocks + 4)
    blocks = []
    for i in range(cfg.n_blocks):
        k = ks[6 * i : 6 * (i + 1)]
        blocks.append(
            {
                "w_rbf": fan_in_init(k[0], (cfg.n_radial, d), cfg.n_radial),
                "w_sbf": fan_in_init(k[1], (n_sbf, nb), n_sbf),
                "w_kj": init_mlp(k[2], [d, d]),
                "bilinear": fan_in_init(k[3], (nb, d, d), d),
                "mlp_update": init_mlp(k[4], [d, d, d]),
                "out_atom": init_mlp(k[5], [d, d]),
            }
        )
    return {
        "blocks": blocks,
        "embed_atom": init_mlp(ks[-1], [cfg.d_in, d]),
        "embed_edge": init_mlp(ks[-2], [2 * d + cfg.n_radial, d]),
        "readout": init_mlp(
            ks[-3], [d, d, cfg.n_classes if cfg.n_classes > 0 else 1]
        ),
    }


def forward(params, x, coords, edge_src, edge_dst, edge_mask,
            tri_kj, tri_ji, tri_mask, cfg: DimeNetConfig):
    """Returns per-node features (N, d) (sum of per-block outputs)."""
    n = x.shape[0]
    ew = edge_mask.astype(jnp.float32)[:, None]
    tw = tri_mask.astype(jnp.float32)[:, None]

    # ---- edge geometry + radial basis ----
    vec = jnp.take(coords, edge_dst, axis=0) - jnp.take(
        coords, edge_src, axis=0
    )
    dist = jnp.linalg.norm(vec + 1e-12, axis=-1)
    rbf = bessel_basis(dist, cfg.n_radial, cfg.cutoff) * cosine_cutoff(
        dist, cfg.cutoff
    )[:, None]

    # ---- triplet geometry + angular basis ----
    v_kj = jnp.take(vec, tri_kj, axis=0)
    v_ji = jnp.take(vec, tri_ji, axis=0)
    cos_a = jnp.sum(-v_kj * v_ji, axis=-1) / (
        jnp.linalg.norm(v_kj + 1e-12, axis=-1)
        * jnp.linalg.norm(v_ji + 1e-12, axis=-1)
    )
    d_kj = jnp.take(dist, tri_kj, axis=0)
    sbf = (
        bessel_basis(d_kj, cfg.n_radial, cfg.cutoff)[:, :, None]
        * _legendre(jnp.clip(cos_a, -1, 1), cfg.n_spherical)[:, None, :]
    ).reshape(tri_kj.shape[0], -1) * tw  # (T, n_radial*n_spherical)

    # ---- embedding block ----
    mdt = jnp.dtype(cfg.msg_dtype)
    h = mlp_apply(params["embed_atom"], x, final_act=True)
    m = (mlp_apply(
        params["embed_edge"],
        jnp.concatenate(
            [jnp.take(h, edge_src, 0), jnp.take(h, edge_dst, 0), rbf], -1
        ),
        final_act=True,
    ) * ew).astype(mdt)  # (E, d) directional messages
    sbf = sbf.astype(mdt)
    tw = tw.astype(mdt)
    ew = ew.astype(mdt)

    # ---- interaction blocks (triplet gather + bilinear) ----
    node_out = jnp.zeros((n, cfg.d_hidden), jnp.float32)
    E = m.shape[0]
    for bp in params["blocks"]:
        # the (T,) gather below is the collective hot spot at web
        # scale; messages travel in cfg.msg_dtype (bf16 halves it)
        m_kj = jnp.take(
            mlp_apply(bp["w_kj"], m, final_act=True).astype(mdt),
            tri_kj, axis=0,
        )                                           # (T, d)
        s = sbf @ bp["w_sbf"].astype(mdt)           # (T, nb)
        contrib = jnp.einsum(
            "tb,td,bdf->tf", s, m_kj, bp["bilinear"].astype(mdt),
            preferred_element_type=jnp.float32,
        ).astype(mdt)                               # (T, d)
        # triplet lists are dst-ordered (build_triplets), so in
        # distributed mode this reduces locally per shard — §Perf H2
        agg = scatter_sum_owner_aligned(
            contrib * tw, tri_ji, E
        )                                           # (E, d)
        gate = (rbf @ bp["w_rbf"]).astype(mdt)      # (E, d)
        m = ((m + mlp_apply(bp["mlp_update"], agg * gate + m))
             * ew).astype(mdt)
        node_out = node_out + scatter_sum(
            (mlp_apply(bp["out_atom"], m, final_act=True)
             * ew).astype(jnp.float32),
            edge_dst, n,
        )
    return node_out


def energy(params, x, coords, es, ed, em, tk, tj, tm,
           cfg: DimeNetConfig):
    node = forward(params, x, coords, es, ed, em, tk, tj, tm, cfg)
    return jnp.sum(mlp_apply(params["readout"], node))


def regression_loss(params, batch, cfg: DimeNetConfig):
    def one(x, c, es, ed, em, tk, tj, tm, y):
        return (energy(params, x, c, es, ed, em, tk, tj, tm, cfg) - y) ** 2

    losses = jax.vmap(one)(
        batch["x"], batch["coords"], batch["edge_src"],
        batch["edge_dst"], batch["edge_mask"], batch["tri_kj"],
        batch["tri_ji"], batch["tri_mask"], batch["y"],
    )
    return jnp.mean(losses)


def node_classification_loss(params, batch, cfg: DimeNetConfig):
    node = forward(
        params, batch["x"], batch["coords"], batch["edge_src"],
        batch["edge_dst"], batch["edge_mask"], batch["tri_kj"],
        batch["tri_ji"], batch["tri_mask"], cfg,
    )
    logits = mlp_apply(params["readout"], node).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, batch["labels"][:, None], axis=-1
    )[:, 0]
    return jnp.mean(logz - ll)
