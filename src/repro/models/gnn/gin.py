"""GIN (Graph Isomorphism Network, arXiv:1810.00826).

h_i' = MLP( (1 + ε) · h_i + Σ_{j∈N(i)} h_j ),  ε learnable.
Assigned config: 5 layers, d_hidden 64, sum aggregator.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn.layers import (
    gather_src, init_mlp, mlp_apply, scatter_sum,
)


@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str = "gin-tu"
    n_layers: int = 5
    d_hidden: int = 64
    d_in: int = 64
    n_classes: int = 7


def init_params(key, cfg: GINConfig) -> dict:
    ks = jax.random.split(key, cfg.n_layers + 2)
    layers = []
    for i in range(cfg.n_layers):
        d_in = cfg.d_in if i == 0 else cfg.d_hidden
        layers.append(
            {
                "mlp": init_mlp(ks[i], [d_in, cfg.d_hidden, cfg.d_hidden]),
                "eps": jnp.zeros(()),
                # GIN aggregates raw h, so layer-input projection is in MLP
            }
        )
    return {
        "layers": layers,
        "readout": init_mlp(ks[-1], [cfg.d_hidden, cfg.n_classes]),
    }


def forward(params, x, edge_src, edge_dst, edge_mask, cfg: GINConfig):
    """Node logits (N, n_classes)."""
    n = x.shape[0]
    w = edge_mask.astype(x.dtype)[:, None]
    for lp in params["layers"]:
        agg = scatter_sum(gather_src(x, edge_src) * w, edge_dst, n)
        x = mlp_apply(lp["mlp"], (1.0 + lp["eps"]) * x + agg,
                      act=jax.nn.relu)
    return mlp_apply(params["readout"], x)


def node_classification_loss(params, batch, cfg: GINConfig):
    logits = forward(
        params, batch["x"], batch["edge_src"], batch["edge_dst"],
        batch["edge_mask"], cfg,
    ).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, batch["labels"][:, None], axis=-1
    )[:, 0]
    return jnp.mean(logz - ll)
