"""Geometric bases for the equivariant/molecular GNNs.

* Bessel radial basis (DimeNet eq. 7) and cosine cutoff.
* Real spherical harmonics, closed form for l ≤ 2 (MACE l_max = 2).
* Real Gaunt coefficient tables  G[(l1,m1),(l2,m2),(l3,m3)] =
  ∫ Y_{l1m1} Y_{l2m2} Y_{l3m3} dΩ  computed *numerically but exactly*
  with a Gauss-Legendre × uniform-φ product quadrature (the integrand
  is band-limited, so the quadrature is exact up to fp rounding).
  These drive the order-3 symmetric (bispectrum) contraction of MACE's
  ACE product basis — the invariant so produced is exactly E(3)-
  invariant, which the tests verify by random rotation.
"""

from __future__ import annotations

import functools
import math

import jax.numpy as jnp
import numpy as np

# (l, m) index layout for l <= 2: 1 + 3 + 5 = 9 components
LM_INDEX = [(l, m) for l in range(3) for m in range(-l, l + 1)]
N_LM = len(LM_INDEX)


def bessel_basis(r, n_rbf: int, cutoff: float):
    """DimeNet radial Bessel basis, shape (..., n_rbf)."""
    r = jnp.maximum(r, 1e-9)[..., None]
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    return (
        jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * r / cutoff) / r
    )


def cosine_cutoff(r, cutoff: float):
    u = jnp.clip(r / cutoff, 0.0, 1.0)
    return 0.5 * (jnp.cos(jnp.pi * u) + 1.0)


def real_sph_harm_l2(unit_vec):
    """Real spherical harmonics Y_lm(r̂) for l <= 2.
    unit_vec (..., 3) -> (..., 9) in LM_INDEX order."""
    x, y, z = unit_vec[..., 0], unit_vec[..., 1], unit_vec[..., 2]
    c00 = 0.5 * math.sqrt(1.0 / math.pi)
    c1 = math.sqrt(3.0 / (4.0 * math.pi))
    c2_2 = 0.5 * math.sqrt(15.0 / math.pi)
    c2_1 = 0.5 * math.sqrt(15.0 / math.pi)
    c20 = 0.25 * math.sqrt(5.0 / math.pi)
    return jnp.stack(
        [
            jnp.full_like(x, c00),          # (0, 0)
            c1 * y,                          # (1,-1)
            c1 * z,                          # (1, 0)
            c1 * x,                          # (1, 1)
            c2_2 * x * y,                    # (2,-2)
            c2_1 * y * z,                    # (2,-1)
            c20 * (3 * z * z - 1.0),         # (2, 0)
            c2_1 * x * z,                    # (2, 1)
            0.5 * c2_2 * (x * x - y * y),    # (2, 2)
        ],
        axis=-1,
    )


def _real_sph_harm_np(theta, phi):
    """Numpy version on a (theta, phi) grid, (..., 9)."""
    st, ct = np.sin(theta), np.cos(theta)
    x = st * np.cos(phi)
    y = st * np.sin(phi)
    z = ct
    c00 = 0.5 * math.sqrt(1.0 / math.pi)
    c1 = math.sqrt(3.0 / (4.0 * math.pi))
    c2_2 = 0.5 * math.sqrt(15.0 / math.pi)
    c20 = 0.25 * math.sqrt(5.0 / math.pi)
    return np.stack(
        [
            np.full_like(x, c00),
            c1 * y, c1 * z, c1 * x,
            c2_2 * x * y, c2_2 * y * z,
            c20 * (3 * z * z - 1.0),
            c2_2 * x * z, 0.5 * c2_2 * (x * x - y * y),
        ],
        axis=-1,
    )


@functools.lru_cache(maxsize=None)
def real_gaunt_table() -> np.ndarray:
    """(9, 9, 9) table of ∫ Y_a Y_b Y_c dΩ over real SH, l <= 2.

    Gauss-Legendre (16 pts in cosθ) × uniform (32 pts in φ) quadrature:
    exact for the degree-≤6 band-limited integrand."""
    xs, ws = np.polynomial.legendre.leggauss(16)
    theta = np.arccos(xs)                      # (16,)
    phi = np.linspace(0, 2 * np.pi, 32, endpoint=False)  # (32,)
    th, ph = np.meshgrid(theta, phi, indexing="ij")
    Y = _real_sph_harm_np(th, ph)              # (16, 32, 9)
    w = ws[:, None] * (2 * np.pi / 32)         # (16, 1)
    G = np.einsum("tpa,tpb,tpc,tp->abc", Y, Y, Y,
                  np.broadcast_to(w, th.shape))
    G[np.abs(G) < 1e-12] = 0.0
    return G.astype(np.float32)


def gaunt_jnp():
    return jnp.asarray(real_gaunt_table())
