"""MACE (higher-order equivariant message passing, arXiv:2206.07697).

Assigned config: 2 layers, 128 channels, l_max = 2, correlation
order 3, 8 radial Bessel functions, E(3)-equivariant ACE features.

Structure per layer (the ACE "density trick"):

  A_i^{c,lm} = Σ_{j∈N(i)} R_{c,l}(r_ij) · Y_lm(r̂_ij) · (W h_j)_c

  B-features: symmetric contractions of A up to correlation order 3:
    ν=1:  A_{c,00}                                    (1 / channel)
    ν=2:  Σ_m A_{c,lm}²  for l = 0,1,2                (3 / channel,
          the power spectrum)
    ν=3:  Σ G[(l1m1),(l2m2),(l3m3)] A A A  per allowed
          (l1,l2,l3) ∈ {(000),(011),(022),(112),(222)} (5 / channel,
          the bispectrum; G = real Gaunt table, geometry.py)

  h_i' = MLP([h_i, B_i])   (9 invariants per channel)

Adaptation vs the full MACE (DESIGN.md §Arch-applicability): node
features carry invariant (L=0) channels between layers — the
"invariant readout" MACE variant; equivariance lives inside the
A-features (verified by the rotation-invariance property test).  The
generalized L>0 message carriers of full MACE add bookkeeping, not a
different kernel regime (the contraction above IS the O(l_max^6)
CG-product hot spot).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn.geometry import (
    LM_INDEX, bessel_basis, cosine_cutoff, real_gaunt_table,
    real_sph_harm_l2,
)
from repro.models.gnn.layers import init_mlp, mlp_apply, scatter_sum
from repro.models.common import fan_in_init


@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    d_hidden: int = 128
    l_max: int = 2
    correlation: int = 3
    n_rbf: int = 8
    cutoff: float = 5.0
    d_in: int = 10
    n_classes: int = 0


# allowed (l1, l2, l3) bispectrum combos for l_max = 2 (even parity,
# triangle inequality)
_BIS_COMBOS = [(0, 0, 0), (0, 1, 1), (0, 2, 2), (1, 1, 2), (2, 2, 2)]


def _combo_gaunt() -> np.ndarray:
    """(5, 9, 9, 9) per-combo real-Gaunt tensors."""
    G = real_gaunt_table()
    ls = np.array([l for l, m in LM_INDEX])
    out = np.zeros((len(_BIS_COMBOS),) + G.shape, np.float32)
    for ci, (l1, l2, l3) in enumerate(_BIS_COMBOS):
        mask = (
            (ls[:, None, None] == l1)
            & (ls[None, :, None] == l2)
            & (ls[None, None, :] == l3)
        )
        out[ci] = np.where(mask, G, 0.0)
    return out


def init_params(key, cfg: MACEConfig) -> dict:
    C = cfg.d_hidden
    n_l = cfg.l_max + 1
    ks = jax.random.split(key, 4 * cfg.n_layers + 2)
    layers = []
    n_inv = 1 + n_l + len(_BIS_COMBOS)  # A00 + power + bispectrum
    for i in range(cfg.n_layers):
        k = ks[4 * i : 4 * (i + 1)]
        d_in = cfg.d_in if i == 0 else C
        layers.append(
            {
                "w_h": fan_in_init(k[0], (d_in, C), d_in),
                # radial MLP: bessel -> per (channel, l) weight
                "radial": init_mlp(k[1], [cfg.n_rbf, 32, C * n_l]),
                "update": init_mlp(k[2], [C * n_inv + d_in, C, C]),
            }
        )
    return {
        "layers": layers,
        "readout": init_mlp(
            ks[-1], [C, C, cfg.n_classes if cfg.n_classes > 0 else 1]
        ),
    }


def forward(params, x, coords, edge_src, edge_dst, edge_mask,
            cfg: MACEConfig):
    """Returns invariant node features (N, C)."""
    n = x.shape[0]
    C = cfg.d_hidden
    n_l = cfg.l_max + 1
    ew = edge_mask.astype(jnp.float32)

    vec = jnp.take(coords, edge_dst, axis=0) - jnp.take(
        coords, edge_src, axis=0
    )
    dist = jnp.linalg.norm(vec + 1e-12, axis=-1)
    unit = vec / jnp.maximum(dist, 1e-9)[:, None]
    Y = real_sph_harm_l2(unit)                      # (E, 9)
    rbf = bessel_basis(dist, cfg.n_rbf, cfg.cutoff) * cosine_cutoff(
        dist, cfg.cutoff
    )[:, None]

    ls = jnp.asarray([l for l, m in LM_INDEX])       # (9,)
    Gk = jnp.asarray(_combo_gaunt())                 # (5, 9, 9, 9)

    h = x
    for lp in params["layers"]:
        hm = h @ lp["w_h"]                           # (N, C)
        R = mlp_apply(lp["radial"], rbf).reshape(-1, C, n_l)  # (E,C,n_l)
        R_lm = jnp.take(R, ls, axis=2)               # (E, C, 9)
        msg = (
            jnp.take(hm, edge_src, axis=0)[:, :, None]
            * R_lm
            * Y[:, None, :]
            * ew[:, None, None]
        )                                            # (E, C, 9)
        A = scatter_sum(msg, edge_dst, n)            # (N, C, 9)

        # --- symmetric contractions (ACE product basis) ---
        b1 = A[:, :, 0:1]                            # ν=1 (N, C, 1)
        # ν=2: power spectrum per l (one-hot l-group sum over m)
        l_onehot = (ls[:, None] == jnp.arange(n_l)[None, :]).astype(
            A.dtype
        )                                            # (9, n_l)
        b2 = jnp.einsum("ncm,ml->ncl", A * A, l_onehot)  # (N, C, n_l)
        # ν=3: bispectrum per allowed l-combo (real Gaunt contraction)
        b3 = jnp.einsum("kabc,nxa,nxb,nxc->nxk", Gk, A, A, A)  # (N,C,5)
        B = jnp.concatenate([b1, b2, b3], axis=-1)   # (N, C, 9)
        h = mlp_apply(
            lp["update"],
            jnp.concatenate([B.reshape(n, -1), h], axis=-1),
        )
    return h


def energy(params, x, coords, es, ed, em, cfg: MACEConfig):
    h = forward(params, x, coords, es, ed, em, cfg)
    return jnp.sum(mlp_apply(params["readout"], h))


def regression_loss(params, batch, cfg: MACEConfig):
    def one(x, c, es, ed, em, y):
        return (energy(params, x, c, es, ed, em, cfg) - y) ** 2

    losses = jax.vmap(one)(
        batch["x"], batch["coords"], batch["edge_src"],
        batch["edge_dst"], batch["edge_mask"], batch["y"],
    )
    return jnp.mean(losses)


def node_classification_loss(params, batch, cfg: MACEConfig):
    h = forward(
        params, batch["x"], batch["coords"], batch["edge_src"],
        batch["edge_dst"], batch["edge_mask"], cfg,
    )
    logits = mlp_apply(params["readout"], h).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, batch["labels"][:, None], axis=-1
    )[:, 0]
    return jnp.mean(logz - ll)
