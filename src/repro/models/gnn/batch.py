"""Device-side graph batch containers + host-side builders.

Two layouts:

* flat — one (possibly huge) graph: x (N, d), edge_src/dst (E,),
  used by full_graph_sm / ogb_products / minibatch_lg (the sampled
  block is flattened).  N and E axes shard over the whole mesh.
* packed — batched small graphs (molecule cell): (B, n, d) features
  and (B, e) edges; B shards over the mesh.

Geometric models additionally carry coords (…, 3).  DimeNet needs
triplet index lists (kj → ji pairs sharing the middle vertex); for
large graphs triplets are capped per edge (host-side sampling) —
DimeNet is a molecular model, running it on web-scale graphs requires
truncation (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.graph.formats import Graph


@dataclasses.dataclass
class FlatGraphBatch:
    """Flat single-graph batch (numpy host side; jnp on device)."""

    x: np.ndarray          # (N, d) node features
    edge_src: np.ndarray   # (E,)
    edge_dst: np.ndarray   # (E,)
    edge_mask: np.ndarray  # (E,) bool
    labels: np.ndarray     # (N,) int labels (or regression targets)
    coords: Optional[np.ndarray] = None  # (N, 3)
    # triplets: for edge e2=(j->i), indices of edges e1=(k->j)
    tri_kj: Optional[np.ndarray] = None  # (T,) edge ids k->j
    tri_ji: Optional[np.ndarray] = None  # (T,) edge ids j->i
    tri_mask: Optional[np.ndarray] = None

    @property
    def n(self) -> int:
        return self.x.shape[0]

    @property
    def e(self) -> int:
        return self.edge_src.shape[0]


@dataclasses.dataclass
class PackedGraphBatch:
    """Batched small graphs (molecule cell)."""

    x: np.ndarray          # (B, n, d)
    edge_src: np.ndarray   # (B, e)
    edge_dst: np.ndarray   # (B, e)
    edge_mask: np.ndarray  # (B, e)
    coords: np.ndarray     # (B, n, 3)
    y: np.ndarray          # (B,) regression target (energy)
    tri_kj: Optional[np.ndarray] = None  # (B, T)
    tri_ji: Optional[np.ndarray] = None
    tri_mask: Optional[np.ndarray] = None


def build_triplets(
    edge_src: np.ndarray,
    edge_dst: np.ndarray,
    n: int,
    cap_per_edge: Optional[int] = None,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """For each edge e2 = (j -> i), pair it with incoming edges
    e1 = (k -> j), k != i.  Returns (tri_kj, tri_ji) edge-id arrays.
    ``cap_per_edge`` bounds pairs per e2 by random subsampling."""
    rng = np.random.default_rng(seed)
    E = edge_src.shape[0]
    # incoming edge ids per vertex
    order = np.argsort(edge_dst, kind="stable")
    sorted_dst = edge_dst[order]
    starts = np.searchsorted(sorted_dst, np.arange(n), side="left")
    ends = np.searchsorted(sorted_dst, np.arange(n), side="right")
    tri_kj, tri_ji = [], []
    for e2 in range(E):
        j = edge_src[e2]
        i = edge_dst[e2]
        inc = order[starts[j]:ends[j]]             # edges (* -> j)
        inc = inc[edge_src[inc] != i]              # exclude backtrack
        if cap_per_edge is not None and inc.shape[0] > cap_per_edge:
            inc = rng.choice(inc, size=cap_per_edge, replace=False)
        tri_kj.extend(int(v) for v in inc)
        tri_ji.extend([e2] * inc.shape[0])
    return (
        np.asarray(tri_kj, dtype=np.int32),
        np.asarray(tri_ji, dtype=np.int32),
    )


def flat_batch_from_graph(
    g: Graph,
    d_feat: int,
    n_classes: int,
    *,
    with_coords: bool = False,
    with_triplets: bool = False,
    triplet_cap: Optional[int] = 4,
    seed: int = 0,
) -> FlatGraphBatch:
    """Synthetic features/labels over a real topology (the container
    has no dataset downloads; shapes and sparsity patterns are what
    matter for the system)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(g.n, d_feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, size=g.n).astype(np.int32)
    coords = (
        rng.normal(size=(g.n, 3)).astype(np.float32)
        if with_coords else None
    )
    tri_kj = tri_ji = tri_mask = None
    if with_triplets:
        tri_kj, tri_ji = build_triplets(
            g.src, g.dst, g.n, cap_per_edge=triplet_cap, seed=seed
        )
        tri_mask = np.ones(tri_kj.shape[0], dtype=bool)
    return FlatGraphBatch(
        x=x, edge_src=g.src, edge_dst=g.dst,
        edge_mask=np.ones(g.m, dtype=bool), labels=labels,
        coords=coords, tri_kj=tri_kj, tri_ji=tri_ji, tri_mask=tri_mask,
    )


def random_molecule_batch(
    batch: int, n_atoms: int, n_edges: int, n_species: int = 10,
    seed: int = 0, with_triplets: bool = False, triplet_pad: int = 512,
) -> PackedGraphBatch:
    """Random molecular graphs: kNN-ish edges over random coords."""
    rng = np.random.default_rng(seed)
    coords = rng.normal(size=(batch, n_atoms, 3)).astype(np.float32) * 2.0
    species = rng.integers(0, n_species, size=(batch, n_atoms))
    x = np.eye(n_species, dtype=np.float32)[species]
    es = np.zeros((batch, n_edges), dtype=np.int32)
    ed = np.zeros((batch, n_edges), dtype=np.int32)
    em = np.ones((batch, n_edges), dtype=bool)
    for b in range(batch):
        d = np.linalg.norm(
            coords[b][:, None] - coords[b][None, :], axis=-1
        ) + np.eye(n_atoms) * 1e9
        k = max(1, n_edges // n_atoms)
        nbr = np.argsort(d, axis=1)[:, :k]
        src = np.repeat(np.arange(n_atoms), k)
        dst = nbr.reshape(-1)
        m = src.shape[0]
        if m >= n_edges:
            es[b], ed[b] = src[:n_edges], dst[:n_edges]
        else:
            es[b, :m], ed[b, :m] = src, dst
            em[b, m:] = False
    y = rng.normal(size=(batch,)).astype(np.float32)
    tk = tj = tm = None
    if with_triplets:
        tk = np.zeros((batch, triplet_pad), dtype=np.int32)
        tj = np.zeros((batch, triplet_pad), dtype=np.int32)
        tm = np.zeros((batch, triplet_pad), dtype=bool)
        for b in range(batch):
            kj, ji = build_triplets(es[b], ed[b], n_atoms, seed=seed)
            t = min(triplet_pad, kj.shape[0])
            tk[b, :t], tj[b, :t], tm[b, :t] = kj[:t], ji[:t], True
    return PackedGraphBatch(
        x=x, edge_src=es, edge_dst=ed, edge_mask=em,
        coords=coords, y=y, tri_kj=tk, tri_ji=tj, tri_mask=tm,
    )


def align_segments(
    values_idx: np.ndarray,   # (T,) e.g. tri_kj — payload index list
    seg_ids: np.ndarray,      # (T,) sorted target segment ids
    n_segments: int,
    n_shards: int,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Owner-align a sorted segment-indexed list for the shard_map
    local reduction (layers.scatter_sum_owner_aligned): entries whose
    target falls in shard p's segment range [p·n/P, (p+1)·n/P) are
    placed in shard p's chunk; chunks are padded to a common length
    (mask False, seg id = start of range so local ids stay in range).

    Returns (values_idx', seg_ids', mask') each (P·chunk,)."""
    assert n_segments % n_shards == 0
    n_loc = n_segments // n_shards
    bounds = np.searchsorted(seg_ids, np.arange(0, n_segments + 1, n_loc))
    chunk = int(max(1, (np.diff(bounds)).max()))
    P = n_shards
    vi = np.zeros(P * chunk, dtype=values_idx.dtype)
    si = np.zeros(P * chunk, dtype=seg_ids.dtype)
    mk = np.zeros(P * chunk, dtype=bool)
    for p in range(P):
        lo, hi = bounds[p], bounds[p + 1]
        m = hi - lo
        vi[p * chunk : p * chunk + m] = values_idx[lo:hi]
        si[p * chunk : p * chunk + m] = seg_ids[lo:hi]
        si[p * chunk + m : (p + 1) * chunk] = p * n_loc  # in-range pad
        mk[p * chunk : p * chunk + m] = True
    return vi, si, mk
