"""Problem statements for the solver facade.

A :class:`Problem` is the data half of the paper's Definition-4 AGM
instance: the graph, the processing function π, and the initial
workitem set S — the ordering/EAGM half lives in
:class:`repro.api.SolverConfig`.  Typed source specs replace the old
ad-hoc ``sssp_sources`` / ``cc_sources`` / raw ``(vertex, state,
level)`` tuples:

    Problem(g, SingleSource(0))                  # SSSP/BFS from 0
    Problem(g, EveryVertex(), processing="cc")   # CC label propagation
    Problem(g, SingleSource(0), processing="sswp")  # widest path
    Problem(g, ExplicitSources([(3, 1.5, 0)]))   # escape hatch

``processing`` is a registered name or a :class:`ProcessingFn`; new
problems plug in via :func:`register_processing`.
"""

from __future__ import annotations

import dataclasses
import numbers
from typing import Sequence, Tuple, Union

import numpy as np

from repro.core.ordering import suggest
from repro.core.processing import PROCESSING_FNS, ProcessingFn
from repro.graph.formats import Graph
from repro.graph.partition import PartitionedGraph

_REGISTRY: dict = dict(PROCESSING_FNS)


def register_processing(
    fn: ProcessingFn, *, overwrite: bool = False
) -> ProcessingFn:
    """Register ``fn`` under ``fn.name`` so problems can refer to it by
    string.  Returns ``fn`` (usable as a decorator-style one-liner).

    Registered functions are the contract verifier's enumeration seam:
    ``repro.analyze.contract.verify_registered`` checks every entry of
    :func:`registered_processing` against the self-stabilization laws,
    so a registration that breaks the monotone-kernel contract is
    caught by the CI ``analyze`` gate, not by wrong distances."""
    if not overwrite and _REGISTRY.get(fn.name, fn) is not fn:
        raise ValueError(
            f"processing {fn.name!r} already registered; "
            "pass overwrite=True to replace it"
        )
    _REGISTRY[fn.name] = fn
    return fn


def registered_processing() -> dict:
    """Snapshot of the processing-function registry (name -> fn) — the
    seam the contract verifier and CLI enumerate."""
    return dict(_REGISTRY)


def processing_names() -> tuple:
    """The registered processing-function names, sorted."""
    return tuple(sorted(_REGISTRY))


def get_processing(p: Union[str, ProcessingFn]) -> ProcessingFn:
    if isinstance(p, ProcessingFn):
        return p
    try:
        return _REGISTRY[p]
    except KeyError:
        raise ValueError(
            f"unknown processing {p!r}; registered: {sorted(_REGISTRY)}"
            f"{suggest(str(p), _REGISTRY)}"
        ) from None


@dataclasses.dataclass(frozen=True)
class SingleSource:
    """One initial workitem; ``value=None`` means the processing
    function's natural source state (0 for SSSP/BFS, +inf for SSWP)."""

    vertex: int
    value: float | None = None
    level: int = 0

    def items(self, processing: ProcessingFn, n: int) -> list[tuple]:
        v = int(self.vertex)
        if not 0 <= v < n:
            raise ValueError(f"source vertex {v} outside [0, {n})")
        val = (
            processing.initial_value(v)
            if self.value is None
            else float(self.value)
        )
        return [(v, val, int(self.level))]


@dataclasses.dataclass(frozen=True)
class MultiSource:
    """Several sources, each at its natural initial state."""

    vertices: Tuple[int, ...]

    def __post_init__(self):
        object.__setattr__(self, "vertices", tuple(int(v) for v in self.vertices))

    def items(self, processing: ProcessingFn, n: int) -> list[tuple]:
        out = []
        for v in self.vertices:
            out.extend(SingleSource(v).items(processing, n))
        return out


@dataclasses.dataclass(frozen=True)
class EveryVertex:
    """One initial workitem per vertex (CC's S = {⟨v, v⟩ : v ∈ V})."""

    def items(self, processing: ProcessingFn, n: int) -> list[tuple]:
        return [(v, processing.initial_value(v), 0) for v in range(n)]


@dataclasses.dataclass(frozen=True)
class ExplicitSources:
    """Raw ``(vertex, state, level)`` triples — the old tuple interface."""

    triples: Tuple[Tuple[int, float, int], ...]

    def __post_init__(self):
        object.__setattr__(
            self,
            "triples",
            tuple((int(v), float(s), int(l)) for v, s, l in self.triples),
        )

    def items(self, processing: ProcessingFn, n: int) -> list[tuple]:
        for v, _, _ in self.triples:
            if not 0 <= v < n:
                raise ValueError(f"source vertex {v} outside [0, {n})")
        return list(self.triples)


SourceSpec = Union[SingleSource, MultiSource, EveryVertex, ExplicitSources]


def as_source_spec(x) -> SourceSpec:
    """Coerce loose inputs: an integer (incl. numpy) is a SingleSource,
    a sequence of integers is MultiSource, a sequence of triples is
    ExplicitSources."""
    if isinstance(
        x, (SingleSource, MultiSource, EveryVertex, ExplicitSources)
    ):
        return x
    if isinstance(x, numbers.Integral):
        return SingleSource(int(x))
    if isinstance(x, Sequence) or isinstance(x, np.ndarray):
        if all(isinstance(v, numbers.Integral) for v in x):
            return MultiSource(tuple(int(v) for v in x))
        return ExplicitSources(tuple(x))
    raise TypeError(f"cannot interpret {x!r} as a source spec")


@dataclasses.dataclass(frozen=True, eq=False)
class Problem:
    """One query: graph + initial workitems + processing function."""

    graph: Union[Graph, PartitionedGraph]
    sources: SourceSpec
    processing: Union[str, ProcessingFn] = "sssp"

    def __post_init__(self):
        object.__setattr__(self, "sources", as_source_spec(self.sources))
        get_processing(self.processing)  # validate early

    @property
    def processing_fn(self) -> ProcessingFn:
        return get_processing(self.processing)

    @property
    def n(self) -> int:
        return self.graph.n

    def source_items(self) -> list[tuple]:
        return self.sources.items(self.processing_fn, self.n)
