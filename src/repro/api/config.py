"""One validated configuration object for the whole solver stack.

``SolverConfig`` folds the EAGM ordering hierarchy (paper §IV), the
candidate-exchange strategy and the iteration knobs into one frozen,
hashable value.  The single source of truth is the ``hierarchy``
field — a :class:`repro.core.eagm.Hierarchy` annotating spatial
levels (global / pod / device / chunk) with strict weak orderings;
``root`` / ``variant`` / ``chunk_size`` are legacy convenience inputs
that construct the equivalent hierarchy and are excluded from
equality (two configs are the same iff they run the same engine).

The compact spec grammar has two forms:

legacy (v1, the paper's Figure-4 grid)::

    root[+variant][/exchange]          "delta:5+threadq/a2a"

hierarchy (v2, the full family space)::

    root[ > level:ordering]...[/exchange]
    "delta:5 > pod:dijkstra > chunk:delta:1 /sparse"

with root/ordering ∈ {chaotic, dijkstra, delta:Δ, kla:K, topk:B},
level ∈ {pod, device, chunk} (the root is the implicit ``global``
annotation), variant ∈ {buffer, threadq, nodeq, numaq} and exchange ∈
{a2a, pmin, sparse, auto} — the paper's family grid plus the
frontier-sparse execution modes (``/sparse``: O(frontier) compaction
+ (idx, val) all_to_all with a dense fallback on capacity overflow;
``/auto``: sparse only while the carried pending count is small).
``frontier_cap`` bounds the per-device compacted frontier (None =
rows/8).

Both grammars accept further ``/``-segments in any order beside the
exchange: ``/fused`` selects the fused-superstep Pallas kernel
(``relax_impl="fused"``; min-plus sparse path, kernels/
superstep_fused), ``/q[:dtype]`` a quantized sparse-exchange payload
(``dtype`` ∈ {bf16, u16}, bare ``/q`` = ``/q:bf16`` — round-up-only
deltas, repaired to exact final states by the facade), and
``/adapt[:policy]`` the runtime controller (``repro.tune``): the
engine runs in ``adapt_window``-superstep segments and the named
policy retunes delta / frontier_cap / the sparse-dense choice
between segments — bare ``/adapt`` means ``/adapt:rho``.  ``/trace``
turns on the per-superstep flight recorder (``repro.obs``): the solve
runs through the same segment engine purely to *publish* superstep
windows — bit-identical state and metrics, with a
``repro.obs.SolveTrace`` attached to ``Solution.trace``.  A trailing
partition segment selects the graph relabeling partitioner
(``repro.graph.partition``)::

    root[+variant][/exchange][/fused][/q[:dtype]][/adapt[:policy]][/trace][@partitioner]
    "delta:5+threadq/sparse@ebal"
    "delta:5/sparse/adapt:rho"
    "delta:5/sparse/fused/q:bf16"
    "delta:5/sparse/trace"
    "delta:5 > pod:dijkstra /sparse @shuffle:7"

with partitioner ∈ {block, shuffle[:seed], ebal, degree} (``block``,
the identity relabeling, is the default and is omitted from
``config.name``).  All grammars round-trip through ``config.name``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

from repro.core.eagm import DEFAULT_CHUNK, Hierarchy, make_hierarchy
from repro.core.engine import EXCHANGE_MODES, EngineConfig, RELAX_IMPLS
from repro.core.frontier import PAYLOAD_MODES
from repro.core.ordering import suggest
from repro.core.processing import ProcessingFn
from repro.graph.partition import canonical_partitioner

EXCHANGES = EXCHANGE_MODES


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    # legacy construction inputs; derived from / superseded by
    # ``hierarchy`` and excluded from equality and hashing
    root: str = dataclasses.field(default="delta:5", compare=False)
    variant: str = dataclasses.field(default="buffer", compare=False)
    exchange: str = "a2a"          # candidate exchange strategy
    chunk_size: int = dataclasses.field(default=DEFAULT_CHUNK, compare=False)
    max_iters: int = 10**9
    collect_metrics: bool = True
    frontier_cap: Optional[int] = None  # sparse-path row capacity F
    # sparse relax backend: 'ref' | 'pallas'[_interpret] |
    # 'fused'[_interpret] (spec segment '/fused')
    relax_impl: str = "ref"
    # sparse-exchange payload encoding: 'exact' | 'bf16' | 'u16'
    # (spec segment '/q[:dtype]'); quantized modes round errors up
    # only and the Solver's repair loop makes final states exact
    payload: str = "exact"
    # the EAGM ordering hierarchy — the source of truth.  When given
    # (directly, as a spec string, or via ``from_spec`` grammar v2) it
    # wins and root/variant are re-derived for display.
    hierarchy: Optional[Hierarchy] = None
    # graph relabeling partitioner (repro.graph.partition): 'block' |
    # 'shuffle[:seed]' | 'ebal' | 'degree'; canonicalized so equal
    # configs hash equal.  Part of equality: a different ownership map
    # is a different solver (distinct partition memo / Solution layout).
    partition: str = "block"
    # adaptive execution controller policy (repro.tune): None = static
    # solve; a policy spec ('rho', 'static', 'rho:<target_frac>', or
    # any registered policy) = run the segmented engine and let the
    # policy retune delta / frontier_cap / exchange choice between
    # segments.  Spec segment: '/adapt' (= '/adapt:rho') or
    # '/adapt:<policy>'.  Self-stabilization makes retuning exact —
    # only the schedule changes, never the fixpoint.
    adapt: Optional[str] = None
    # supersteps per adaptive segment (controller decision interval);
    # like max_iters it is part of equality but not of ``name``
    adapt_window: int = 4
    # per-superstep flight recorder (repro.obs): run the solve through
    # the segment engine purely to publish superstep windows — state
    # and WorkMetrics stay bit-identical (self-stabilization: the
    # segmented schedule reaches the same fixpoint), and the collected
    # repro.obs.SolveTrace is attached to Solution.trace.  Spec
    # segment: '/trace'.
    trace: bool = False

    def __post_init__(self):
        if self.chunk_size <= 0:
            raise ValueError(f"chunk_size must be positive: {self.chunk_size}")
        if self.hierarchy is None:
            # make_hierarchy validates root spec and variant (with
            # did-you-mean suggestions)
            object.__setattr__(
                self,
                "hierarchy",
                make_hierarchy(self.root, self.variant, self.chunk_size),
            )
        else:
            h = self.hierarchy
            if isinstance(h, str):
                h = Hierarchy.from_spec(h, chunk_size=self.chunk_size)
            elif not isinstance(h, Hierarchy):
                h = Hierarchy(tuple(h))
            object.__setattr__(self, "hierarchy", h)
            object.__setattr__(self, "root", h.root.spec)
            object.__setattr__(self, "variant", h.variant or "hierarchy")
        if self.exchange not in EXCHANGES:
            raise ValueError(
                f"exchange must be one of {EXCHANGES}, got {self.exchange!r}"
                f"{suggest(str(self.exchange), EXCHANGES)}"
            )
        if self.max_iters <= 0:
            raise ValueError(f"max_iters must be positive: {self.max_iters}")
        if self.frontier_cap is not None and self.frontier_cap <= 0:
            raise ValueError(
                f"frontier_cap must be positive: {self.frontier_cap}"
            )
        if self.relax_impl not in RELAX_IMPLS:
            raise ValueError(
                f"relax_impl must be one of {RELAX_IMPLS}, "
                f"got {self.relax_impl!r}"
                f"{suggest(str(self.relax_impl), RELAX_IMPLS)}"
            )
        if self.payload not in PAYLOAD_MODES:
            raise ValueError(
                f"payload must be one of {PAYLOAD_MODES}, "
                f"got {self.payload!r}"
                f"{suggest(str(self.payload), PAYLOAD_MODES)}"
            )
        if self.payload != "exact" and self.adapt is not None:
            raise ValueError(
                "quantized payloads (/q:...) do not compose with the "
                "adaptive controller (/adapt): the controller's "
                "segmented engine has no repair loop, so final states "
                "would stay inflated; pick one"
            )
        if self.payload != "exact" and self.trace:
            raise ValueError(
                "quantized payloads (/q:...) do not compose with the "
                "flight recorder (/trace): the recorder's segmented "
                "engine has no repair loop, so final states would stay "
                "inflated; trace the exact spec instead"
            )
        # canonicalize (validates with a did-you-mean on unknown kinds)
        object.__setattr__(
            self, "partition", canonical_partitioner(self.partition)
        )
        if self.adapt_window <= 0:
            raise ValueError(
                f"adapt_window must be positive: {self.adapt_window}"
            )
        if self.adapt is not None:
            # canonicalize + validate the policy spec (did-you-mean on
            # unknown policies); lazy import keeps api.config free of a
            # module-level dependency on the tune subsystem
            from repro.tune.policies import canonical_policy

            object.__setattr__(self, "adapt", canonical_policy(self.adapt))

    @classmethod
    def from_spec(cls, spec: str, **overrides) -> "SolverConfig":
        """Parse ``"root[+variant][/exchange]"`` (legacy) or
        ``"root[ > level:ordering]...[/exchange]"`` (hierarchy);
        keyword overrides win over the parsed fields.  Malformed specs
        (empty segments, whitespace-only parts) raise with the
        offending spec quoted."""
        rest = str(spec).strip()
        if not rest:
            raise ValueError(f"empty solver spec {spec!r}")
        if "@" in rest:
            rest, partition = rest.rsplit("@", 1)
            rest, partition = rest.strip(), partition.strip()
            if not partition:
                raise ValueError(f"empty partition segment in spec {spec!r}")
            if not rest:
                raise ValueError(f"empty ordering segment in spec {spec!r}")
            overrides.setdefault("partition", partition)
        if "/" in rest:
            head, *segs = [s.strip() for s in rest.split("/")]
            if not head:
                raise ValueError(f"empty ordering segment in spec {spec!r}")
            exchange_seen = adapt_seen = False
            fused_seen = payload_seen = trace_seen = False
            for seg in segs:
                if not seg:
                    raise ValueError(
                        f"empty exchange segment in spec {spec!r}"
                    )
                kind = seg.split(":", 1)[0].strip()
                if kind == "fused":
                    if fused_seen:
                        raise ValueError(
                            f"duplicate fused segment in spec {spec!r}"
                        )
                    if ":" in seg:
                        raise ValueError(
                            f"fused segment takes no argument in spec "
                            f"{spec!r}; use '/fused'"
                        )
                    fused_seen = True
                    overrides.setdefault("relax_impl", "fused")
                elif kind == "q":
                    if payload_seen:
                        raise ValueError(
                            f"duplicate payload segment in spec {spec!r}"
                        )
                    payload_seen = True
                    payload = seg.split(":", 1)[1].strip() if ":" in seg \
                        else "bf16"
                    if not payload:
                        raise ValueError(
                            f"empty payload dtype in spec {spec!r}; use "
                            "'/q' (= '/q:bf16') or '/q:<dtype>' with "
                            f"dtype in {PAYLOAD_MODES[1:]}"
                        )
                    overrides.setdefault("payload", payload)
                elif kind == "trace":
                    if trace_seen:
                        raise ValueError(
                            f"duplicate trace segment in spec {spec!r}"
                        )
                    if ":" in seg:
                        raise ValueError(
                            f"trace segment takes no argument in spec "
                            f"{spec!r}; use '/trace'"
                        )
                    trace_seen = True
                    overrides.setdefault("trace", True)
                elif kind == "adapt":
                    if adapt_seen:
                        raise ValueError(
                            f"duplicate adapt segment in spec {spec!r}"
                        )
                    adapt_seen = True
                    policy = seg.split(":", 1)[1].strip() if ":" in seg \
                        else "rho"
                    if not policy:
                        raise ValueError(
                            f"empty adapt policy in spec {spec!r}; use "
                            "'/adapt' (= '/adapt:rho') or "
                            "'/adapt:<policy>'"
                        )
                    overrides.setdefault("adapt", policy)
                elif kind in EXCHANGES:
                    if exchange_seen:
                        raise ValueError(
                            f"duplicate exchange segment in spec {spec!r}"
                        )
                    exchange_seen = True
                    overrides.setdefault("exchange", seg)
                else:
                    raise ValueError(
                        f"unknown spec segment {seg!r} in {spec!r}: "
                        f"expected an exchange mode {EXCHANGES}, "
                        "'fused', 'q[:dtype]', 'adapt[:policy]' or "
                        "'trace'"
                        f"{suggest(kind, tuple(EXCHANGES) + ('fused', 'q', 'adapt', 'trace'))}"
                    )
            rest = head
        if ">" in rest or rest.lower().startswith("global:"):
            chunk = overrides.get("chunk_size", DEFAULT_CHUNK)
            return cls(
                hierarchy=Hierarchy.from_spec(rest, chunk_size=chunk),
                **overrides,
            )
        if "+" in rest:
            rest, variant = rest.split("+", 1)
            rest, variant = rest.strip(), variant.strip()
            if not variant:
                raise ValueError(f"empty variant segment in spec {spec!r}")
            overrides.setdefault("variant", variant)
        if not rest:
            raise ValueError(f"empty root segment in spec {spec!r}")
        return cls(root=rest, **overrides)

    @property
    def name(self) -> str:
        """Round-trippable spec: ``from_spec(cfg.name) == cfg``.  Emits
        the legacy ``root+variant`` form when the hierarchy is a paper
        preset (at the default chunk size), the ``>`` grammar
        otherwise; a non-default partitioner appends ``@<partition>``."""
        base = f"{self.hierarchy.name}/{self.exchange}"
        if self.relax_impl == "fused":
            base += "/fused"
        if self.payload != "exact":
            base += f"/q:{self.payload}"
        if self.adapt is not None:
            base += f"/adapt:{self.adapt}"
        if self.trace:
            base += "/trace"
        if self.partition != "block":
            base += f"@{self.partition}"
        return base

    def lint(
        self,
        *,
        shape: Optional[dict] = None,
        mesh_axes=("data",),
        processing: str = "sssp",
    ) -> list:
        """Parse-time cross-checks on this config (exchange ×
        frontier_cap × partitioner × hierarchy interactions); returns
        a list of ``repro.analyze.findings.Finding``.  Pure spec
        arithmetic — never traces or compiles.  ``shape`` (optional,
        ``dict(n_local, rows, width, n_parts)``) enables the
        capacity rules; see ``repro.analyze.spec_check``."""
        from repro.analyze.spec_check import check_config

        return check_config(
            self, shape=shape, mesh_axes=mesh_axes, processing=processing
        )

    def engine_config(self, processing: ProcessingFn) -> EngineConfig:
        return EngineConfig(
            policy=self.hierarchy,
            processing=processing,
            exchange=self.exchange,
            max_iters=self.max_iters,
            collect_metrics=self.collect_metrics,
            frontier_cap=self.frontier_cap,
            relax_impl=self.relax_impl,
            payload=self.payload,
            adapt_window=(
                self.adapt_window
                if (self.adapt is not None or self.trace) else 0
            ),
        )


def as_config(c: Union[str, SolverConfig, None]) -> SolverConfig:
    if c is None:
        return SolverConfig()
    if isinstance(c, str):
        return SolverConfig.from_spec(c)
    if isinstance(c, SolverConfig):
        return c
    raise TypeError(f"cannot interpret {c!r} as a SolverConfig")
