"""One validated configuration object for the whole solver stack.

``SolverConfig`` folds the AGM root ordering, the EAGM spatial variant
(paper §IV), the candidate-exchange strategy and the chunk/iteration
knobs that used to be spread over ``EngineConfig`` + ``EAGMPolicy`` +
string specs.  The compact spec grammar is

    root[+variant][/exchange]     e.g.  "delta:5+threadq/a2a"

with root ∈ {chaotic, dijkstra, delta:Δ, kla:K}, variant ∈ {buffer,
threadq, nodeq, numaq} and exchange ∈ {a2a, pmin, sparse, auto} — the
paper's Figure-4 family grid plus the frontier-sparse execution modes
(``/sparse``: O(frontier) compaction + (idx, val) all_to_all with a
dense fallback on capacity overflow; ``/auto``: sparse only while the
carried pending count is small).  ``frontier_cap`` bounds the
per-device compacted frontier (None = rows/8).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

from repro.core.eagm import EAGMPolicy, VARIANT_LEVEL, make_policy
from repro.core.engine import EXCHANGE_MODES, EngineConfig
from repro.core.ordering import make_ordering
from repro.core.processing import ProcessingFn

EXCHANGES = EXCHANGE_MODES


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    root: str = "delta:5"          # AGM ordering spec
    variant: str = "buffer"        # EAGM spatial variant
    exchange: str = "a2a"          # candidate exchange strategy
    chunk_size: int = 1024         # B for chunk-level (threadq) draining
    max_iters: int = 10**9
    collect_metrics: bool = True
    frontier_cap: Optional[int] = None  # sparse-path row capacity F
    relax_impl: str = "ref"        # sparse relax backend ('ref'|'pallas')

    def __post_init__(self):
        make_ordering(self.root)  # raises on a bad ordering spec
        if self.variant not in VARIANT_LEVEL:
            raise ValueError(
                f"variant must be one of {sorted(VARIANT_LEVEL)}, "
                f"got {self.variant!r}"
            )
        if self.exchange not in EXCHANGES:
            raise ValueError(
                f"exchange must be one of {EXCHANGES}, got {self.exchange!r}"
            )
        if self.chunk_size <= 0:
            raise ValueError(f"chunk_size must be positive: {self.chunk_size}")
        if self.max_iters <= 0:
            raise ValueError(f"max_iters must be positive: {self.max_iters}")
        if self.frontier_cap is not None and self.frontier_cap <= 0:
            raise ValueError(
                f"frontier_cap must be positive: {self.frontier_cap}"
            )
        if self.relax_impl not in ("ref", "pallas", "pallas_interpret"):
            raise ValueError(
                f"relax_impl must be 'ref', 'pallas' or 'pallas_interpret',"
                f" got {self.relax_impl!r}"
            )

    @classmethod
    def from_spec(cls, spec: str, **overrides) -> "SolverConfig":
        """Parse ``"root[+variant][/exchange]"``; keyword overrides win
        over the parsed fields."""
        rest = spec.strip()
        if "/" in rest:
            rest, exchange = rest.rsplit("/", 1)
            overrides.setdefault("exchange", exchange.strip())
        if "+" in rest:
            rest, variant = rest.split("+", 1)
            overrides.setdefault("variant", variant.strip())
        return cls(root=rest.strip(), **overrides)

    @property
    def name(self) -> str:
        return f"{self.root}+{self.variant}/{self.exchange}"

    @property
    def policy(self) -> EAGMPolicy:
        return make_policy(self.root, self.variant, chunk_size=self.chunk_size)

    def engine_config(self, processing: ProcessingFn) -> EngineConfig:
        return EngineConfig(
            policy=self.policy,
            processing=processing,
            exchange=self.exchange,
            max_iters=self.max_iters,
            collect_metrics=self.collect_metrics,
            frontier_cap=self.frontier_cap,
            relax_impl=self.relax_impl,
        )


def as_config(c: Union[str, SolverConfig, None]) -> SolverConfig:
    if c is None:
        return SolverConfig()
    if isinstance(c, str):
        return SolverConfig.from_spec(c)
    if isinstance(c, SolverConfig):
        return c
    raise TypeError(f"cannot interpret {c!r} as a SolverConfig")
