"""repro.api — the single public entry point for graph queries.

The paper generates a *family* of algorithms from one self-stabilizing
kernel plus an ordering; this package presents the family the same
way: a fixed machine (:class:`Solver`, compiled once per shape/mesh/
config) fed data (:class:`Problem`).

    from repro.api import Problem, SingleSource, Solver

    solver = Solver("delta:5+threadq/a2a")          # paper preset
    solver = Solver("delta:5 > pod:dijkstra > chunk:delta:1")  # composed
    sol = solver.solve(Problem(graph, SingleSource(0)))
    sol.state, sol.metrics

One spec = one point of the algorithm family: the EAGM ordering
hierarchy (``repro.core.Hierarchy``) annotates spatial levels
(global > pod > device > chunk) with strict weak orderings, and the
engine realizes each annotation with the cheapest collective its
scope allows.

Capabilities beyond the old ``run_distributed``:
  * compile-once/solve-many — engines live in a process-wide LRU cache
  * ``solve_batch`` — a leading batch axis over sources, one engine
    invocation for B queries
  * ``resolve`` — self-stabilizing warm restart from a prior solution
    after improving perturbations (new sources, cheaper edges)
"""

from repro.core.eagm import Hierarchy, make_hierarchy
from repro.api.config import SolverConfig, as_config
from repro.api.problem import (
    EveryVertex,
    ExplicitSources,
    MultiSource,
    Problem,
    SingleSource,
    SourceSpec,
    as_source_spec,
    get_processing,
    processing_names,
    register_processing,
    registered_processing,
)
from repro.api.solver import (
    Solution,
    Solver,
    batch_bucket,
    compiled_engine,
    engine_cache_clear,
    engine_cache_info,
    solve,
    solve_with_engine_config,
    trace_count,
)

__all__ = [
    "SolverConfig", "as_config", "Hierarchy", "make_hierarchy",
    "Problem", "SingleSource", "MultiSource", "EveryVertex",
    "ExplicitSources", "SourceSpec", "as_source_spec",
    "register_processing", "registered_processing", "processing_names",
    "get_processing",
    "Solver", "Solution", "solve", "solve_with_engine_config",
    "compiled_engine", "engine_cache_clear", "engine_cache_info",
    "batch_bucket", "trace_count",
]
