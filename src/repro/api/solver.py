"""Compile-once / solve-many solver facade.

The paper's thesis is one fixed machine (the self-stabilizing kernel +
EAGM engine) fed many problems; the :class:`Solver` makes the API look
the same.  Engines are jitted once per (partition shape, mesh, config,
batch) and kept in a process-wide LRU cache, so serving a stream of
queries re-traces nothing:

    solver = Solver("delta:5+threadq/a2a")
    sol  = solver.solve(Problem(g, SingleSource(0)))
    sols = solver.solve_batch([Problem(g, SingleSource(v)) for v in vs])
    sol2 = solver.resolve(sol, graph=g_cheaper)   # warm restart

``resolve`` is the self-stabilization dividend (paper §II): the kernel
converges from *any* state that is pointwise no better than the new
fixpoint, so after a perturbation that only improves candidate states
(edge-weight decreases, new edges, added sources) the previous
solution is a valid warm start and stabilizes in a few supersteps
instead of a full solve.  For perturbations that can worsen the
optimum (weight increases, removed edges) the monotone engine cannot
raise committed state — cold-solve those.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import SolverConfig, as_config
from repro.api.problem import (
    ExplicitSources,
    Problem,
    as_source_spec,
    get_processing,
)
from repro.core.engine import (
    EngineConfig,
    initial_state,
    initial_state_batch,
    make_engine,
)
from repro.core.frontier import (
    frontier_caps,
    grow_frontier_cap,
    payload_plane_words,
)
from repro.core.metrics import WorkMetrics
from repro.core.processing import ProcessingFn
from repro.graph.formats import Graph, graph_fingerprint
from repro.graph.partition import PartitionedGraph, partition_graph
from repro.obs import trace as obs
from repro.obs.recorder import FlightRecorder, SolveTrace

# ---------------------------------------------------------------------
# process-wide engine cache (shared by every Solver and by the legacy
# run_distributed shim) + jit trace counter
# ---------------------------------------------------------------------

_ENGINE_CACHE: "OrderedDict[tuple, object]" = OrderedDict()
_ENGINE_CACHE_SIZE = 32
_TRACE_COUNT = [0]
_EVICTIONS = [0]
_ADAPT_RETRACES = [0]


def trace_count() -> int:
    """Total jit traces of facade engines this process — the
    compile-once tests assert it stays flat across repeat solves."""
    return _TRACE_COUNT[0]


def note_adapt_retrace() -> None:
    """Record one engine build forced by a shape-changing adaptive
    decision (a frontier_cap the solve had not used before).  Called by
    the :mod:`repro.tune` controller; surfaced via
    :func:`engine_cache_info` and ``Solution.metrics.retraces``."""
    _ADAPT_RETRACES[0] += 1


def engine_cache_clear() -> None:
    _ENGINE_CACHE.clear()


def engine_cache_info() -> dict:
    """Stats seam for the serving tier: size/capacity of the process-
    wide compiled-engine cache, the cumulative trace count, LRU
    evictions, and engine builds forced by shape-changing adaptive
    retuning decisions."""
    return dict(
        size=len(_ENGINE_CACHE),
        capacity=_ENGINE_CACHE_SIZE,
        traces=_TRACE_COUNT[0],
        evictions=_EVICTIONS[0],
        adapt_retraces=_ADAPT_RETRACES[0],
    )


def batch_bucket(b: int) -> int:
    """Round a batch size up to the next power of two.  ``solve_batch``
    pads problem batches to these buckets so a serving workload whose
    batch size jitters between flushes (7, 8, 5, ...) reuses at most
    log2(max_batch) compiled engines instead of tracing one per size."""
    if b < 1:
        raise ValueError(f"batch size must be positive: {b}")
    return 1 << (b - 1).bit_length()


def _bump_trace():
    _TRACE_COUNT[0] += 1


def compiled_engine(
    mesh,
    ecfg: EngineConfig,
    n_parts: int,
    n_local: int,
    batch: Optional[int] = None,
):
    """The compiled (jitted) engine for this (shape, mesh, config,
    batch) cell, built at most once per process."""
    key = (mesh, ecfg, n_parts, n_local, batch)
    try:
        fn = _ENGINE_CACHE[key]
        _ENGINE_CACHE.move_to_end(key)
        obs.event("engine_cache_hit", exchange=ecfg.exchange,
                  n_parts=n_parts, batch=batch)
        return fn
    except KeyError:
        pass
    obs.event("engine_cache_miss", exchange=ecfg.exchange,
              n_parts=n_parts, batch=batch)
    with obs.span("engine.build", exchange=ecfg.exchange,
                  n_parts=n_parts, n_local=n_local, batch=batch,
                  adapt_window=ecfg.adapt_window):
        fn = make_engine(
            dict(n_parts=n_parts, n_local=n_local),
            mesh,
            ecfg,
            batch=batch,
            trace_hook=_bump_trace,
        )
    _ENGINE_CACHE[key] = fn
    if len(_ENGINE_CACHE) > _ENGINE_CACHE_SIZE:
        _ENGINE_CACHE.popitem(last=False)
        _EVICTIONS[0] += 1
    return fn


# consecutive sparse-overflow supersteps before _finish_metrics emits
# the actionable frontier_cap RuntimeWarning (below this, occasional
# dense fallbacks are the capacity veto working as designed)
OVERFLOW_WARN_STREAK = 3

# hard cap on quantized-payload repair restarts (each restart strictly
# lowers some committed value, so this is a safety net, not a tuning
# knob — one or two sweeps repair everything in practice)
QUANT_REPAIR_MAX_SWEEPS = 25


def exchange_words(
    pg: PartitionedGraph, ecfg: EngineConfig, it: int, fallbacks: int
) -> int:
    """Exact exchange word count per device for ``it`` supersteps of
    which ``fallbacks`` took the dense path, in Python ints (the
    engine moves a statically known word count per superstep and
    branch, so no overflow-prone on-device accumulator is needed).
    Per device per superstep:

      a2a   (P-1)·n_local·planes words — the reduce-scatter sends
            (P-1)/P of the n_pad candidate array (+ KLA levels).
            NOTE the seed's formula multiplied before its integer
            division (`n_pad * 4 * (P-1) // P`), which is nonzero for
            P > 1 but obscured the per-rank intent; this form is
            explicit.
      pmin  2x a2a — a full-array ring all-reduce per combine.
      sparse (P-1)·payload_plane_words(S) words on sparse supersteps
            (exact: (idx, val) [+ level] planes; quantized: u32
            indices + packed 16-bit delta codes + the per-segment
            bound words — the dtype-parametrized accounting), dense
            a2a words on the `fallbacks` dense ones.

    The adaptive driver calls this per segment with that segment's
    ``frontier_cap``, so byte totals stay exact across cap growth.
    """
    use_level = ecfg.hierarchy.needs_level
    nplanes = 2 if use_level else 1
    P_, nl = pg.n_parts, pg.n_local
    dense_words = (P_ - 1) * nl * nplanes
    if ecfg.exchange == "pmin":
        return it * 2 * dense_words
    if ecfg.exchange == "a2a":
        return it * dense_words
    _, slot_cap = frontier_caps(
        pg.rows_per_rank, pg.width, nl, P_, ecfg.frontier_cap
    )
    sparse_words = (P_ - 1) * payload_plane_words(
        slot_cap, use_level, ecfg.payload
    )
    return (it - fallbacks) * sparse_words + fallbacks * dense_words


def _warn_metrics(
    m: WorkMetrics, ecfg: EngineConfig, pg: PartitionedGraph, active
) -> None:
    """Actionable RuntimeWarnings derived from a finished solve's
    metrics: truncation at max_iters, and a consecutive-sparse-
    overflow run long enough that the silent per-superstep dense
    fallback is costing real bandwidth."""
    import warnings

    if not m.converged:
        warnings.warn(
            f"engine hit max_iters={ecfg.max_iters} with {int(active)} "
            "pending workitems left — the returned state is truncated "
            "(monotone but not yet the fixpoint); raise max_iters or "
            "check Solution.metrics.converged",
            RuntimeWarning,
            stacklevel=4,
        )
    if (
        ecfg.exchange in ("sparse", "auto")
        and m.overflow_streak >= OVERFLOW_WARN_STREAK
    ):
        row_cap, slot_cap = frontier_caps(
            pg.rows_per_rank, pg.width, pg.n_local, pg.n_parts,
            ecfg.frontier_cap,
        )
        spec = f"{ecfg.hierarchy.name}/{ecfg.exchange}"
        warnings.warn(
            f"sparse exchange capacity overflowed on "
            f"{m.overflow_streak} consecutive supersteps (spec "
            f"{spec!r}: row_cap={row_cap}, slot_cap={slot_cap}), each "
            "falling back to the dense exchange; raise frontier_cap "
            f"(try {grow_frontier_cap(pg.rows_per_rank, row_cap)}) or "
            "solve with /adapt:rho for automatic cap growth",
            RuntimeWarning,
            stacklevel=4,
        )


def _finish_metrics(
    pg: PartitionedGraph,
    ecfg: EngineConfig,
    it,
    commits,
    relax,
    classes,
    active=None,
    fallbacks=0,
    overflow_streak=0,
) -> WorkMetrics:
    it = int(it)
    fallbacks = int(fallbacks)
    converged = True if active is None else int(active) == 0
    m = WorkMetrics(
        classes=int(classes),
        commits=int(commits),
        relaxations=int(relax),
        supersteps=it,
        workitems=int(commits),
        converged=converged,
        sparse_fallbacks=fallbacks,
        overflow_streak=int(overflow_streak),
    )
    m.exchange_bytes = exchange_words(pg, ecfg, it, fallbacks) * 4 * pg.n_parts
    m.collective_rounds = it * (
        (3 if ecfg.collect_metrics else 2)
        + (1 if ecfg.exchange in ("sparse", "auto") else 0)
    )
    _warn_metrics(m, ecfg, pg, active)
    return m


def solve_with_engine_config(
    pg: PartitionedGraph, mesh, ecfg: EngineConfig, sources: list[tuple]
) -> tuple[np.ndarray, WorkMetrics]:
    """Low-level entry with the legacy ``run_distributed`` signature;
    shares the facade's engine cache."""
    fn = compiled_engine(mesh, ecfg, pg.n_parts, pg.n_local)
    D0, T0, L0 = initial_state(pg, ecfg.processing, sources)
    D, it, commits, relax, classes, active, fallbacks, streak = fn(
        pg.row_src, pg.col, pg.wgt, D0, T0, L0
    )
    m = _finish_metrics(
        pg, ecfg, it, commits, relax, classes, active, fallbacks, streak
    )
    return pg.unpermute(np.asarray(D).reshape(-1)), m


# ---------------------------------------------------------------------
# Solution + Solver
# ---------------------------------------------------------------------


@dataclasses.dataclass(eq=False)
class Solution:
    """Result of one query: the committed state (in original vertex
    ids) plus what ``resolve`` needs to warm-restart from it
    (``padded`` is in the partition's relabeled slot space, so the
    producing :class:`PartitionedGraph` rides along for the layout-
    compatibility check)."""

    state: np.ndarray          # (n,) committed per-vertex state
    metrics: WorkMetrics
    problem: Problem
    config: SolverConfig
    padded: np.ndarray         # (P, n_local) committed state, padded
    pg: Optional[PartitionedGraph] = None
    # per-superstep flight record (config.trace / '/trace' specs only)
    trace: Optional[SolveTrace] = None

    @property
    def graph(self):
        return self.problem.graph

    @property
    def source(self) -> Optional[int]:
        """The single source vertex, if this solution has exactly one
        (the serving tier's cache key); None for multi-source/CC."""
        items = self.problem.source_items()
        if len(items) == 1:
            return int(items[0][0])
        return None

    @property
    def nbytes(self) -> int:
        """Resident bytes of this solution's state arrays — the unit
        the serving tier's byte-budget cache accounts in."""
        return int(self.state.nbytes) + int(self.padded.nbytes)

    def distance_to(self, v: int) -> float:
        """Committed state at vertex ``v`` (for SSSP: the distance
        source → v) — the point-to-point read the router serves."""
        if not 0 <= int(v) < self.state.shape[0]:
            raise ValueError(
                f"vertex {v} outside [0, {self.state.shape[0]})"
            )
        return float(self.state[int(v)])


class Solver:
    """Compile-once / solve-many facade over the distributed EAGM
    engine.  One Solver = one (mesh, SolverConfig); problems supply
    graph + sources + processing.  Raw :class:`Graph` inputs are
    partitioned over the mesh once and memoized."""

    def __init__(
        self,
        config: Union[str, SolverConfig, None] = None,
        mesh=None,
    ):
        self.config = as_config(config)
        if mesh is None:
            mesh = jax.make_mesh((jax.device_count(),), ("data",))
        self.mesh = mesh
        self.n_devices = int(np.prod(tuple(mesh.devices.shape)))
        # id(graph) -> (graph, fingerprint, PartitionedGraph); bounded
        # LRU so a stream of distinct graphs can't grow it unboundedly
        self._pg_cache: "OrderedDict[int, tuple]" = OrderedDict()
        self._pg_cache_size = 8
        # adaptive-solve counters (config.adapt specs only)
        self._adapt_stats = dict(
            solves=0, segments=0, retraces=0, cap_growths=0
        )

    # -- graph handling ------------------------------------------------

    def partition(self, graph: Union[Graph, PartitionedGraph]) -> PartitionedGraph:
        if isinstance(graph, PartitionedGraph):
            if graph.n_parts != self.n_devices:
                raise ValueError(
                    f"graph partitioned for {graph.n_parts} parts but "
                    f"mesh has {self.n_devices} devices"
                )
            if graph.partitioner != self.config.partition:
                raise ValueError(
                    f"graph pre-partitioned with "
                    f"{graph.partitioner!r} but config requests "
                    f"{self.config.partition!r}; re-partition with "
                    "repro.graph.partition_graph or pass the raw Graph"
                )
            return graph
        fp = graph_fingerprint(graph)
        hit = self._pg_cache.get(id(graph))
        if hit is not None and hit[0] is graph and hit[1] == fp:
            self._pg_cache.move_to_end(id(graph))
            obs.event("partition_memo_hit", n=graph.n)
            return hit[2]
        with obs.span("solver.partition", n=graph.n, m=graph.m,
                      partitioner=self.config.partition,
                      n_parts=self.n_devices):
            pg = partition_graph(
                graph, self.n_devices, partitioner=self.config.partition
            )
        self._pg_cache[id(graph)] = (graph, fp, pg)
        if len(self._pg_cache) > self._pg_cache_size:
            self._pg_cache.popitem(last=False)
        return pg

    def stats(self) -> dict:
        """Serving-tier observability: this solver's partition-memo
        occupancy plus the process-wide engine-cache stats."""
        return dict(
            partition_memo_size=len(self._pg_cache),
            partition_memo_capacity=self._pg_cache_size,
            engine_cache=engine_cache_info(),
            adapt=dict(self._adapt_stats),
        )

    # -- engine access -------------------------------------------------

    def compiled(
        self,
        n_parts: int,
        n_local: int,
        processing: Union[str, ProcessingFn] = "sssp",
        batch: Optional[int] = None,
    ):
        """The jitted engine callable for a partition shape — for AOT
        lowering (dry-run cells) and power users."""
        ecfg = self.config.engine_config(get_processing(processing))
        return compiled_engine(self.mesh, ecfg, n_parts, n_local, batch)

    # -- solving -------------------------------------------------------

    def solve(self, problem: Problem) -> Solution:
        with obs.span("solver.solve", spec=self.config.name) as sp:
            pg = self.partition(problem.graph)
            p = problem.processing_fn
            ecfg = self.config.engine_config(p)
            D0, T0, L0 = initial_state(pg, p, problem.source_items())
            if ecfg.adapt_window > 0:
                sol = self._solve_adaptive(problem, pg, ecfg, D0, T0, L0)
            elif ecfg.payload != "exact":
                sol = self._solve_quantized(problem, pg, ecfg, D0, T0, L0)
            else:
                fn = compiled_engine(
                    self.mesh, ecfg, pg.n_parts, pg.n_local
                )
                with obs.span("solver.engine"):
                    out = fn(pg.row_src, pg.col, pg.wgt, D0, T0, L0)
                sol = self._pack(problem, pg, ecfg, *out)
            sp.set(supersteps=sol.metrics.supersteps,
                   converged=sol.metrics.converged)
            return sol

    def solve_batch(self, problems: Sequence[Problem]) -> list[Solution]:
        """Solve B same-shaped queries in one engine invocation: state
        arrays gain a leading batch axis over sources and the superstep
        loop is vmapped, so the graph is resident once and every
        collective amortizes over the batch.  All problems must share
        the graph and the processing function; per-query supersteps
        may report the batch maximum (converged elements idle
        harmlessly — monotonicity).

        The batch is padded to the next power of two (duplicating the
        last problem) so varying serving batch sizes bucket onto a
        handful of compiled engines instead of retracing per size; the
        padding lanes are solved and discarded (monotone no-ops for
        the caller)."""
        if not problems:
            return []
        if len(problems) == 1:
            return [self.solve(problems[0])]
        if self.config.adapt is not None:
            raise ValueError(
                "solve_batch does not support adaptive specs (/adapt): "
                "the controller would steer every lane with one "
                "shared schedule; use a static spec for batches or "
                "solve adaptive queries one at a time"
            )
        if self.config.payload != "exact":
            raise ValueError(
                "solve_batch does not support quantized payloads "
                "(/q:...): the exact repair loop re-verifies and "
                "restarts per query; use an exact payload for batches "
                "or solve quantized queries one at a time"
            )
        if self.config.trace:
            raise ValueError(
                "solve_batch does not support the flight recorder "
                "(/trace): the batched engine publishes no per-lane "
                "superstep windows; trace queries one at a time"
            )
        g0 = problems[0].graph
        p = problems[0].processing_fn
        for q in problems[1:]:
            if q.graph is not g0:
                raise ValueError("solve_batch: all problems must share a graph")
            if q.processing_fn is not p:
                raise ValueError(
                    "solve_batch: all problems must share a processing fn"
                )
        pg = self.partition(g0)
        B = len(problems)
        Bpad = batch_bucket(B)
        items = [q.source_items() for q in problems]
        items += [items[-1]] * (Bpad - B)
        ecfg = self.config.engine_config(p)
        fn = compiled_engine(
            self.mesh, ecfg, pg.n_parts, pg.n_local, batch=Bpad
        )
        D0, T0, L0 = initial_state_batch(pg, p, items)
        with obs.span("solver.solve_batch", spec=self.config.name,
                      batch=B, batch_padded=Bpad):
            D, *rest = fn(pg.row_src, pg.col, pg.wgt, D0, T0, L0)
        D = np.asarray(D)  # (P, Bpad, n_local)
        rest = [np.asarray(r) for r in rest]  # each (Bpad,)
        return [
            self._pack(
                problems[b], pg, ecfg, D[:, b], *(r[b] for r in rest)
            )
            for b in range(B)
        ]

    def resolve(
        self,
        prev: Solution,
        new_sources=None,
        *,
        graph: Union[Graph, PartitionedGraph, None] = None,
    ) -> Solution:
        """Warm restart from a prior solution (paper §II: the kernel is
        self-stabilizing, so any state pointwise no better than the new
        fixpoint is a correct start).  ``graph`` supplies the perturbed
        graph (defaults to the previous one); ``new_sources`` adds
        initial workitems (e.g. an extra source).

        One host-side bootstrap sweep — Algorithm 1's re-verification
        step — relaxes every out-edge of the committed prior state to
        regenerate exactly the candidates the perturbation improved;
        the engine then drains only those, which is a handful of
        supersteps on a localized change instead of a full solve.

        Correct whenever the prior state dominates the new fixpoint
        (edge-weight decreases, edge/source additions).  Weight
        increases or deletions can put the fixpoint above the prior
        state, which a monotone engine cannot reach — cold-solve those.
        """
        with obs.span("solver.resolve", spec=self.config.name) as sp:
            return self._resolve(prev, new_sources, graph, sp)

    def _resolve(self, prev, new_sources, graph, sp) -> Solution:
        graph = prev.problem.graph if graph is None else graph
        p = prev.problem.processing_fn
        spec = (
            as_source_spec(new_sources)
            if new_sources is not None
            else ExplicitSources(())
        )
        problem = Problem(
            graph=graph, sources=spec, processing=prev.problem.processing
        )
        pg = self.partition(graph)
        if prev.padded.shape != (pg.n_parts, pg.n_local):
            raise ValueError(
                "resolve: previous solution was computed on a different "
                f"partition shape {prev.padded.shape} != "
                f"{(pg.n_parts, pg.n_local)}"
            )
        if prev.pg is not None and not prev.pg.same_layout(pg):
            # perm composes with warm restarts only when it is the SAME
            # perm: `padded` is in the relabeled slot space, so a
            # changed ownership map (different partitioner/seed, or a
            # perturbation that moved ebal's degree boundaries) would
            # silently seed the wrong vertices
            raise ValueError(
                "resolve: the partition layout changed between the "
                f"previous solution ({prev.pg.partitioner}) and the "
                f"new graph ({pg.partitioner}); cold-solve instead"
            )
        ecfg = self.config.engine_config(p)
        worst = np.float32(p.worst)

        # committed prior state, with the per-rank dummy slot restored
        D0 = np.concatenate(
            [prev.padded.astype(np.float32),
             np.full((pg.n_parts, 1), worst, np.float32)],
            axis=1,
        )
        with obs.span("solver.bootstrap_sweep", m=pg.m):
            T_full = _bootstrap_candidates(pg, p, prev.padded)
        for v, s, _ in problem.source_items():
            pid = int(pg.padded_id(int(v)))  # owner map: original -> slot
            T_full[pid] = p.reduce(np.float32(T_full[pid]), np.float32(s))
        T0 = np.concatenate(
            [T_full.reshape(pg.n_parts, pg.n_local),
             np.full((pg.n_parts, 1), worst, np.float32)],
            axis=1,
        )
        # warm items restart the KLA level attribute at 0 (a fresh wave)
        L0 = np.where(
            np.asarray(p.better(T0, D0)), np.float32(0.0), np.float32(np.inf)
        ).astype(np.float32)

        if ecfg.adapt_window > 0:
            sol = self._solve_adaptive(problem, pg, ecfg, D0, T0, L0)
        elif ecfg.payload != "exact":
            sol = self._solve_quantized(problem, pg, ecfg, D0, T0, L0)
        else:
            fn = compiled_engine(self.mesh, ecfg, pg.n_parts, pg.n_local)
            out = fn(pg.row_src, pg.col, pg.wgt, D0, T0, L0)
            sol = self._pack(problem, pg, ecfg, *out)
        # account for the bootstrap sweep: one superstep's worth of
        # full-graph relaxation done host-side
        sol.metrics.relaxations += pg.m
        sol.metrics.supersteps += 1
        if sol.trace is not None:
            # the host sweep has no engine superstep window; count it
            # so SolveTrace.reconcile still balances against metrics
            sol.trace.host_sweeps += 1
        sp.set(supersteps=sol.metrics.supersteps,
               converged=sol.metrics.converged)
        return sol

    # -- internals -----------------------------------------------------

    def _solve_adaptive(
        self, problem, pg, ecfg: EngineConfig, D0, T0, L0
    ) -> Solution:
        """Segmented solve: ``/adapt`` (the repro.tune controller runs
        the segmented engine, retuning tunables between segments; a
        fresh policy instance per solve keeps controller state from
        leaking across queries), ``/trace`` (same segment engine under
        the no-op StaticPolicy, purely to publish superstep windows —
        the flight recorder collects them into ``Solution.trace``), or
        both composed."""
        from repro.tune.controller import run_adaptive
        from repro.tune.policies import StaticPolicy, make_tune_policy

        if self.config.adapt is not None:
            policy = make_tune_policy(self.config.adapt)
        else:  # pure /trace: observe without intervening
            policy = StaticPolicy()
        recorder = (
            FlightRecorder(self.config.name) if self.config.trace else None
        )
        D, m, report = run_adaptive(
            self.mesh, ecfg, pg, policy, D0, T0, L0,
            on_window=recorder.on_window if recorder is not None else None,
        )
        if self.config.adapt is not None:
            st = self._adapt_stats
            st["solves"] += 1
            st["segments"] += report.segments
            st["retraces"] += report.retraces
            st["cap_growths"] += report.cap_growths
        padded = np.asarray(D).reshape(pg.n_parts, pg.n_local)
        return Solution(
            state=pg.unpermute(padded.reshape(-1)),
            metrics=m,
            problem=problem,
            config=self.config,
            padded=padded,
            pg=pg,
            trace=recorder.finish(m) if recorder is not None else None,
        )

    def _solve_quantized(
        self, problem, pg, ecfg: EngineConfig, D0, T0, L0
    ) -> Solution:
        """Quantized-payload (``/q:...``) solve + exact repair loop.

        The quantized exchange only ever *inflates* candidate values
        (round-up codes; verify-failed codes decode to +inf), so the
        state the engine converges to is pointwise >= the exact
        fixpoint, with the initial workitems committed exactly.  One
        host-side re-verification sweep (the same
        ``_bootstrap_candidates`` that powers ``resolve``) then either
        certifies the fixpoint — no edge improves any committed value,
        which with exact initial commits pins the state to the least
        fixpoint — or seeds an exact warm restart from the improving
        candidates.  Every restart strictly lowers some committed
        value (monotone commits), so the loop terminates; final states
        are bit-identical to an exact-payload solve.
        """
        p = problem.processing_fn
        fn = compiled_engine(self.mesh, ecfg, pg.n_parts, pg.n_local)
        worst = np.float32(p.worst)
        D, it, commits, relax, classes, active, fallbacks, streak = fn(
            pg.row_src, pg.col, pg.wgt, D0, T0, L0
        )
        it_t, commits_t = int(it), int(commits)
        relax_t, classes_t = int(relax), int(classes)
        fallbacks_t, streak_max = int(fallbacks), int(streak)
        sweeps = verifies = 0
        while int(active) == 0:  # truncated runs skip repair (warned)
            padded = np.asarray(D).reshape(pg.n_parts, pg.n_local)
            T_full = _bootstrap_candidates(pg, p, padded)
            verifies += 1
            if not bool(np.asarray(p.better(T_full, padded.reshape(-1))).any()):
                break  # certified: the exact least fixpoint
            if sweeps >= QUANT_REPAIR_MAX_SWEEPS:
                import warnings

                warnings.warn(
                    f"quantized repair loop hit "
                    f"{QUANT_REPAIR_MAX_SWEEPS} restarts without "
                    "certifying the exact fixpoint; the returned state "
                    "may retain inflated values",
                    RuntimeWarning,
                    stacklevel=3,
                )
                break
            sweeps += 1
            obs.event("repair_sweep", sweep=sweeps)
            D0r = np.concatenate(
                [padded, np.full((pg.n_parts, 1), worst, np.float32)],
                axis=1,
            )
            T0r = np.concatenate(
                [T_full.reshape(pg.n_parts, pg.n_local),
                 np.full((pg.n_parts, 1), worst, np.float32)],
                axis=1,
            )
            L0r = np.where(
                np.asarray(p.better(T0r, D0r)),
                np.float32(0.0), np.float32(np.inf),
            ).astype(np.float32)
            D, it, commits, relax, classes, active, fallbacks, streak = fn(
                pg.row_src, pg.col, pg.wgt, D0r, T0r, L0r
            )
            it_t += int(it)
            commits_t += int(commits)
            relax_t += int(relax)
            classes_t += int(classes)
            fallbacks_t += int(fallbacks)
            streak_max = max(streak_max, int(streak))
        m = _finish_metrics(
            pg, ecfg, it_t, commits_t, relax_t, classes_t, active,
            fallbacks_t, streak_max,
        )
        # each host-side re-verification sweep is one superstep's worth
        # of full-graph relaxation, moving no exchange bytes
        m.relaxations += pg.m * verifies
        m.supersteps += verifies
        m.repair_sweeps = sweeps
        padded = np.asarray(D).reshape(pg.n_parts, pg.n_local)
        return Solution(
            state=pg.unpermute(padded.reshape(-1)),
            metrics=m,
            problem=problem,
            config=self.config,
            padded=padded,
            pg=pg,
        )

    def _pack(
        self, problem, pg, ecfg, D, it, commits, relax, classes,
        active=None, fallbacks=0, overflow_streak=0,
    ) -> Solution:
        padded = np.asarray(D).reshape(pg.n_parts, pg.n_local)
        m = _finish_metrics(
            pg, ecfg, it, commits, relax, classes, active, fallbacks,
            overflow_streak,
        )
        return Solution(
            state=pg.unpermute(padded.reshape(-1)),
            metrics=m,
            problem=problem,
            config=self.config,
            padded=padded,
            pg=pg,
        )


# back-compat alias; the canonical helper lives in the graph layer so
# other derived-buffer memos (e.g. selfstab's transpose-ELL cache) can
# share it
_graph_fingerprint = graph_fingerprint


def _bootstrap_candidates(
    pg: PartitionedGraph, p: ProcessingFn, committed: np.ndarray
) -> np.ndarray:
    """One synchronous relaxation of every out-edge of ``committed``
    ((P, n_local)) — the self-stabilizing kernel's re-verification
    sweep, done host-side over the partitioned ELL buffers.  Returns
    the (n_pad,) candidate array to seed T with."""
    worst = np.float32(p.worst)
    # per-rank row states with the dummy slot (row_src == n_local)
    state_ext = np.concatenate(
        [committed.astype(np.float32),
         np.full((pg.n_parts, 1), worst, np.float32)],
        axis=1,
    )  # (P, n_local+1)
    src_state = np.take_along_axis(state_ext, pg.row_src, axis=1)  # (P, R)
    cand = np.asarray(
        p.edge_update(src_state[:, :, None], pg.wgt), dtype=np.float32
    )
    cand = np.broadcast_to(cand, pg.wgt.shape)
    buf = np.full(pg.n_pad + 1, worst, np.float32)  # slot n_pad: padding
    if p.reduce is jnp.minimum:
        np.minimum.at(buf, pg.col.reshape(-1), cand.reshape(-1))
    else:
        np.maximum.at(buf, pg.col.reshape(-1), cand.reshape(-1))
    return buf[: pg.n_pad]


def solve(
    problem: Problem,
    config: Union[str, SolverConfig, None] = None,
    mesh=None,
) -> Solution:
    """One-shot convenience: ``Solver(config, mesh).solve(problem)``
    (still hits the process-wide engine cache)."""
    return Solver(config, mesh=mesh).solve(problem)
