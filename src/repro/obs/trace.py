"""Low-overhead span tracer — the observability seam of the stack.

The paper argues through *observed* work/ordering trade-offs, and the
AGM superstep is the natural unit of observation; this module supplies
the wall-clock half of that observation: nested spans and point events
with monotonic timestamps, recorded by every layer of the stack
(``Solver.solve`` → partition → engine → repair loop, the
``repro.tune`` segment loop, the serving tier's admission → flush →
solve path).  Design constraints, in order:

* **near-zero cost when off** — no tracer installed means one module-
  global read per ``span()``/``event()`` call and a shared no-op
  context manager; no allocation, no locking, no clock read.
* **thread-safe when on** — the serving tier may pump the router from
  a different thread than the one building landmark indexes; records
  append under a lock and the span *stack* (parent attribution) is
  thread-local.
* **testable time** — the clock is injected (``Tracer(clock=...)``),
  so tests assert exact durations instead of sleeping.
* **bounded** — a flight recorder must not OOM the process it
  observes; past ``max_records`` new records are dropped and counted.

Usage::

    from repro.obs import trace as obs

    tracer = obs.Tracer()
    with obs.use_tracer(tracer):
        with obs.span("solve", spec="delta:5/sparse") as sp:
            obs.event("engine_cache_miss")
            sp.set(supersteps=17)
    tracer.spans[0].duration_s

Spans carry a ``span_id``/``parent_id`` so exporters can rebuild the
tree, and free-form ``attrs`` — the serving tier records the
query-id → flush → solve correlation key there, which is what lets a
p99 outlier be traced to the batch and spec that served it.
"""

from __future__ import annotations

import contextlib
import dataclasses
import itertools
import threading
import time
from typing import Any, Callable, Iterator, Optional

__all__ = [
    "Event",
    "Span",
    "SpanHandle",
    "Tracer",
    "current_tracer",
    "event",
    "now",
    "set_tracer",
    "span",
    "use_tracer",
]


@dataclasses.dataclass
class Span:
    """One closed span: a named wall-clock interval with attributes."""

    name: str
    t0: float
    t1: float
    attrs: dict[str, Any]
    span_id: int
    parent_id: Optional[int]
    thread: str

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0

    def as_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Event:
    """One point-in-time record, attributed to the enclosing span."""

    name: str
    t: float
    attrs: dict[str, Any]
    span_id: Optional[int]
    thread: str

    def as_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


class SpanHandle:
    """Context manager for one open span.  ``set(**attrs)`` adds
    attributes any time before exit (the tune controller records its
    per-segment decision on the already-open segment span)."""

    __slots__ = ("_tracer", "name", "attrs", "t0", "span_id", "parent_id")

    def __init__(
        self,
        tracer: "Tracer",
        name: str,
        attrs: dict[str, Any],
        parent_id: Optional[int],
    ):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.t0 = tracer.clock()
        self.span_id = tracer._next_id()
        self.parent_id = parent_id

    def set(self, **attrs: Any) -> "SpanHandle":
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "SpanHandle":
        self._tracer._push(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        self._tracer._pop(self)


class _NoopSpan:
    """Shared do-nothing span for the tracer-off fast path."""

    __slots__ = ()

    def set(self, **attrs: Any) -> "_NoopSpan":
        return self

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        return None


_NOOP = _NoopSpan()


class Tracer:
    """Thread-safe span/event recorder with an injectable monotonic
    clock and a bounded record buffer.

    ``registry`` (optional, a :class:`repro.obs.export.MetricsRegistry`)
    receives every closed span as a ``repro_span_seconds{span=...}``
    histogram observation and every event as a
    ``repro_events_total{event=...}`` counter increment — the live
    metrics surface is fed by the same instrumentation as the trace.
    """

    def __init__(
        self,
        clock: Callable[[], float] = time.perf_counter,
        registry: Optional[Any] = None,
        max_records: int = 200_000,
    ):
        if max_records <= 0:
            raise ValueError(f"max_records must be positive: {max_records}")
        self.clock = clock
        self.registry = registry
        self.max_records = int(max_records)
        self.spans: list[Span] = []
        self.events: list[Event] = []
        self.dropped = 0
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._local = threading.local()

    # -- record plumbing ----------------------------------------------

    def _next_id(self) -> int:
        return next(self._ids)

    def _stack(self) -> list[SpanHandle]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = []
            self._local.stack = st
        return st

    def current_span_id(self) -> Optional[int]:
        st = self._stack()
        return st[-1].span_id if st else None

    def _push(self, handle: SpanHandle) -> None:
        self._stack().append(handle)

    def _pop(self, handle: SpanHandle) -> None:
        t1 = self.clock()
        st = self._stack()
        if st and st[-1] is handle:
            st.pop()
        rec = Span(
            name=handle.name,
            t0=handle.t0,
            t1=t1,
            attrs=handle.attrs,
            span_id=handle.span_id,
            parent_id=handle.parent_id,
            thread=threading.current_thread().name,
        )
        with self._lock:
            if len(self.spans) + len(self.events) >= self.max_records:
                self.dropped += 1
            else:
                self.spans.append(rec)
        if self.registry is not None:
            self.registry.histogram(
                "repro_span_seconds",
                help="wall seconds per traced span",
                labels={"span": handle.name},
            ).observe(rec.duration_s)

    # -- public API ----------------------------------------------------

    def span(self, name: str, **attrs: Any) -> SpanHandle:
        return SpanHandle(self, name, attrs, self.current_span_id())

    def event(self, name: str, **attrs: Any) -> None:
        rec = Event(
            name=name,
            t=self.clock(),
            attrs=attrs,
            span_id=self.current_span_id(),
            thread=threading.current_thread().name,
        )
        with self._lock:
            if len(self.spans) + len(self.events) >= self.max_records:
                self.dropped += 1
            else:
                self.events.append(rec)
        if self.registry is not None:
            self.registry.counter(
                "repro_events_total",
                help="traced point events",
                labels={"event": name},
            ).inc()

    def clear(self) -> None:
        with self._lock:
            self.spans.clear()
            self.events.clear()
            self.dropped = 0

    def find(self, name: str) -> list[Span]:
        """Closed spans with this name (test convenience)."""
        with self._lock:
            return [s for s in self.spans if s.name == name]

    def children_of(self, span_id: int) -> list[Span]:
        with self._lock:
            return [s for s in self.spans if s.parent_id == span_id]


# ---------------------------------------------------------------------
# module-level current tracer (the instrumentation call sites)
# ---------------------------------------------------------------------

_TRACER: Optional[Tracer] = None


def current_tracer() -> Optional[Tracer]:
    return _TRACER


def set_tracer(tracer: Optional[Tracer]) -> Optional[Tracer]:
    """Install ``tracer`` as the process-wide current tracer; returns
    the previous one.  ``None`` disables tracing (the fast path)."""
    global _TRACER
    prev = _TRACER
    _TRACER = tracer
    return prev


@contextlib.contextmanager
def use_tracer(tracer: Optional[Tracer]) -> Iterator[Optional[Tracer]]:
    """Scoped :func:`set_tracer` — restores the previous tracer on
    exit, so tests and CLIs never leak instrumentation state."""
    prev = set_tracer(tracer)
    try:
        yield tracer
    finally:
        set_tracer(prev)


def span(name: str, **attrs: Any):
    """Open a span on the current tracer (no-op when tracing is off).
    Usable as a context manager; the yielded handle accepts
    ``.set(**attrs)``."""
    t = _TRACER
    if t is None:
        return _NOOP
    return t.span(name, **attrs)


def event(name: str, **attrs: Any) -> None:
    """Record a point event on the current tracer (no-op when off)."""
    t = _TRACER
    if t is not None:
        t.event(name, **attrs)


def now() -> float:
    """The current tracer's clock (``time.perf_counter`` when tracing
    is off) — lets instrumented code stamp records consistently with
    the spans around them."""
    t = _TRACER
    return t.clock() if t is not None else time.perf_counter()
