"""Exporters: Chrome-trace JSON, JSONL flight records, and a
Prometheus-style metrics registry.

Three consumers of the same instrumentation, in decreasing order of
fidelity:

* :func:`chrome_trace` — the full span tree plus per-superstep counter
  tracks as a Chrome trace-event JSON (load in Perfetto / chrome://
  tracing).  Spans become ``ph:"X"`` complete events; each
  :class:`~repro.obs.recorder.SolveTrace` contributes ``ph:"C"``
  counter tracks (pending / eligible / bytes per superstep) with
  timestamps interpolated inside the segment spans that produced them.
* :func:`flight_jsonl` — one JSON object per line (spans, events,
  supersteps) for offline analysis; ``launch/obs.py summarize``
  re-reads these.
* :class:`MetricsRegistry` — live counters / gauges / histograms with
  Prometheus text exposition (format 0.0.4), served by
  :func:`serve_metrics` for ``launch/serve.py --metrics-port``.

Everything here is stdlib-only; no Prometheus client library is
assumed (the container has none).
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Iterable, Optional

from repro.obs.trace import Tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "chrome_trace",
    "flight_jsonl",
    "serve_metrics",
    "write_chrome_trace",
    "write_flight_jsonl",
]


# ---------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------

def _fmt_labels(labels: dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(
        '%s="%s"' % (k, str(v).replace("\\", "\\\\").replace('"', '\\"'))
        for k, v in sorted(labels.items())
    )
    return "{%s}" % inner


def _fmt_value(v: float) -> str:
    # Prometheus wants decimal floats; integers render without the
    # trailing .0 for readability.
    if float(v).is_integer():
        return str(int(v))
    return repr(float(v))


class Counter:
    """Monotonically increasing value."""

    kind = "counter"

    def __init__(self) -> None:
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter increment must be >= 0: {amount}")
        with self._lock:
            self.value += amount

    def samples(self, name: str, labels: dict[str, str]) -> list[tuple[str, float]]:
        return [(name + _fmt_labels(labels), self.value)]


class Gauge:
    """Set-to-current value; optionally backed by a callback so the
    exposition always reflects live state (e.g. cache bytes)."""

    kind = "gauge"

    def __init__(self, fn: Optional[Callable[[], float]] = None) -> None:
        self.value = 0.0
        self.fn = fn
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self.value += amount

    def samples(self, name: str, labels: dict[str, str]) -> list[tuple[str, float]]:
        v = self.value if self.fn is None else float(self.fn())
        return [(name + _fmt_labels(labels), v)]


# Latency-oriented default: 1ms .. ~16s, powers of 4.
_DEFAULT_BUCKETS = (0.001, 0.004, 0.016, 0.064, 0.256, 1.024, 4.096, 16.384)


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics: each bucket
    counts observations <= its upper bound; +Inf bucket == count)."""

    kind = "histogram"

    def __init__(self, buckets: Iterable[float] = _DEFAULT_BUCKETS) -> None:
        self.bounds = tuple(sorted(float(b) for b in buckets))
        if not self.bounds:
            raise ValueError("histogram needs at least one bucket bound")
        self.counts = [0] * len(self.bounds)
        self.count = 0
        self.total = 0.0
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        with self._lock:
            self.count += 1
            self.total += value
            for i, b in enumerate(self.bounds):
                if value <= b:
                    self.counts[i] += 1

    def samples(self, name: str, labels: dict[str, str]) -> list[tuple[str, float]]:
        out: list[tuple[str, float]] = []
        for b, c in zip(self.bounds, self.counts):
            lb = dict(labels)
            lb["le"] = _fmt_value(b)
            out.append((name + "_bucket" + _fmt_labels(lb), float(c)))
        lb = dict(labels)
        lb["le"] = "+Inf"
        out.append((name + "_bucket" + _fmt_labels(lb), float(self.count)))
        out.append((name + "_sum" + _fmt_labels(labels), self.total))
        out.append((name + "_count" + _fmt_labels(labels), float(self.count)))
        return out


class MetricsRegistry:
    """Named, labeled metric families with text exposition.

    ``counter``/``gauge``/``histogram`` are get-or-create: repeat calls
    with the same name+labels return the same instrument, so call sites
    never need to pre-register anything.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        # name -> (kind, help, {sorted-label-items -> instrument})
        self._families: dict[str, tuple[str, str, dict[tuple, Any]]] = {}

    def _get(self, name: str, kind: str, help: str,
             labels: Optional[dict[str, str]], factory: Callable[[], Any]) -> Any:
        key = tuple(sorted((labels or {}).items()))
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = (kind, help, {})
                self._families[name] = fam
            elif fam[0] != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {fam[0]}, not {kind}")
            series = fam[2]
            inst = series.get(key)
            if inst is None:
                inst = factory()
                series[key] = inst
            return inst

    def counter(self, name: str, help: str = "",
                labels: Optional[dict[str, str]] = None) -> Counter:
        return self._get(name, "counter", help, labels, Counter)

    def gauge(self, name: str, help: str = "",
              labels: Optional[dict[str, str]] = None,
              fn: Optional[Callable[[], float]] = None) -> Gauge:
        g = self._get(name, "gauge", help, labels, lambda: Gauge(fn))
        if fn is not None:
            g.fn = fn
        return g

    def histogram(self, name: str, help: str = "",
                  labels: Optional[dict[str, str]] = None,
                  buckets: Iterable[float] = _DEFAULT_BUCKETS) -> Histogram:
        return self._get(name, "histogram", help, labels,
                         lambda: Histogram(buckets))

    def expose(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        lines: list[str] = []
        with self._lock:
            families = sorted(self._families.items())
        for name, (kind, help, series) in families:
            if help:
                lines.append(f"# HELP {name} {help}")
            lines.append(f"# TYPE {name} {kind}")
            for key in sorted(series):
                inst = series[key]
                for sample, value in inst.samples(name, dict(key)):
                    lines.append(f"{sample} {_fmt_value(value)}")
        return "\n".join(lines) + "\n"

    def as_dict(self) -> dict[str, Any]:
        """JSON-friendly snapshot (the ``/stats`` endpoint)."""
        out: dict[str, Any] = {}
        with self._lock:
            families = sorted(self._families.items())
        for name, (kind, _help, series) in families:
            rows = []
            for key in sorted(series):
                inst = series[key]
                for sample, value in inst.samples(name, dict(key)):
                    rows.append({"series": sample, "value": value})
            out[name] = {"type": kind, "samples": rows}
        return out


# ---------------------------------------------------------------------
# Chrome trace-event export
# ---------------------------------------------------------------------

def _us(t: float, t_base: float) -> float:
    return (t - t_base) * 1e6


def chrome_trace(tracer: Tracer,
                 solve_traces: Iterable[Any] = (),
                 process_name: str = "repro") -> dict[str, Any]:
    """Build a Chrome trace-event JSON object from a tracer's records
    plus any :class:`~repro.obs.recorder.SolveTrace` objects.

    Spans map to ``ph:"X"`` complete events (one track per thread);
    events to ``ph:"i"`` instants; each solve trace contributes
    ``ph:"C"`` counter tracks (pending / eligible / bytes_moved per
    superstep).  Counter timestamps interpolate uniformly inside the
    wall-clock window of the segment that produced the superstep, so
    the convergence curve lines up with the segment spans above it.
    """
    spans = list(tracer.spans)
    events = list(tracer.events)
    t_base = min(
        [s.t0 for s in spans] + [e.t for e in events],
        default=0.0,
    )
    tids: dict[str, int] = {}

    def tid(thread: str) -> int:
        if thread not in tids:
            tids[thread] = len(tids) + 1
        return tids[thread]

    out: list[dict[str, Any]] = [{
        "name": "process_name", "ph": "M", "pid": 1, "tid": 0,
        "args": {"name": process_name},
    }]
    for s in spans:
        out.append({
            "name": s.name, "ph": "X", "pid": 1, "tid": tid(s.thread),
            "ts": _us(s.t0, t_base), "dur": (s.t1 - s.t0) * 1e6,
            "args": dict(s.attrs, span_id=s.span_id,
                         parent_id=s.parent_id),
        })
    for e in events:
        out.append({
            "name": e.name, "ph": "i", "pid": 1, "tid": tid(e.thread),
            "ts": _us(e.t, t_base), "s": "t",
            "args": dict(e.attrs, span_id=e.span_id),
        })
    for tr_i, tr in enumerate(solve_traces):
        label = getattr(tr, "config_name", None) or f"solve{tr_i}"
        step0 = 0
        for seg in tr.segments:
            n_steps = seg["supersteps"]
            if n_steps <= 0:
                continue
            t0, t1 = seg["t0"], seg["t1"]
            dt = (t1 - t0) / n_steps
            for j in range(n_steps):
                k = step0 + j
                ts = _us(t0 + j * dt, t_base)
                out.append({
                    "name": f"{label} frontier", "ph": "C", "pid": 1,
                    "tid": 0, "ts": ts,
                    "args": {"pending": tr.pending[k],
                             "eligible": tr.eligible[k]},
                })
                out.append({
                    "name": f"{label} bytes", "ph": "C", "pid": 1,
                    "tid": 0, "ts": ts,
                    "args": {"bytes_moved": tr.bytes_moved[k]},
                })
            step0 += n_steps
    for thread, t in tids.items():
        out.append({
            "name": "thread_name", "ph": "M", "pid": 1, "tid": t,
            "args": {"name": thread},
        })
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, tracer: Tracer,
                       solve_traces: Iterable[Any] = ()) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(tracer, solve_traces), f)


# ---------------------------------------------------------------------
# JSONL flight records
# ---------------------------------------------------------------------

def flight_jsonl(tracer: Optional[Tracer] = None,
                 solve_traces: Iterable[Any] = ()) -> list[str]:
    """Serialize records as JSON lines: ``{"kind": "span"|"event"|
    "superstep"|"solve", ...}``.  Order: solve headers, supersteps,
    spans, events."""
    lines: list[str] = []
    for tr in solve_traces:
        lines.append(json.dumps({"kind": "solve", **tr.as_dict()}))
        for rec in tr.superstep_records():
            lines.append(json.dumps({"kind": "superstep", **rec}))
    if tracer is not None:
        for s in tracer.spans:
            lines.append(json.dumps({"kind": "span", **s.as_dict()}))
        for e in tracer.events:
            lines.append(json.dumps({"kind": "event", **e.as_dict()}))
    return lines


def write_flight_jsonl(path: str, tracer: Optional[Tracer] = None,
                       solve_traces: Iterable[Any] = ()) -> None:
    with open(path, "w") as f:
        for line in flight_jsonl(tracer, solve_traces):
            f.write(line + "\n")


# ---------------------------------------------------------------------
# HTTP exposition
# ---------------------------------------------------------------------

def serve_metrics(registry: MetricsRegistry, port: int,
                  host: str = "127.0.0.1") -> ThreadingHTTPServer:
    """Serve ``/metrics`` (Prometheus text) and ``/stats`` (JSON) on a
    daemon thread; returns the server (call ``.shutdown()`` to stop).
    Port 0 picks a free port — read it back from
    ``server.server_address[1]``."""

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self) -> None:  # noqa: N802 (http.server API)
            if self.path.split("?")[0] == "/metrics":
                body = registry.expose().encode()
                ctype = "text/plain; version=0.0.4; charset=utf-8"
            elif self.path.split("?")[0] == "/stats":
                body = json.dumps(registry.as_dict(), indent=2).encode()
                ctype = "application/json"
            else:
                self.send_response(404)
                self.end_headers()
                return
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, format: str, *args: Any) -> None:
            pass  # silence per-request stderr noise

    server = ThreadingHTTPServer((host, port), Handler)
    thread = threading.Thread(target=server.serve_forever,
                              name="obs-metrics", daemon=True)
    thread.start()
    return server
