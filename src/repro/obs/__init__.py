"""repro.obs — observability: span tracing, per-superstep flight
recording, and metrics exposition.

Three layers (see README §Observability):

* :mod:`repro.obs.trace` — low-overhead, thread-safe span tracer
  instrumented through ``Solver``/``tune``/``serve``; no-op unless a
  :class:`Tracer` is installed via :func:`use_tracer`.
* :mod:`repro.obs.recorder` — the ``/trace`` flight recorder: any
  solve runs through the ``/adapt`` segment engine purely to publish
  per-superstep windows (bit-identical to the untraced solve), which
  accumulate into a :class:`SolveTrace` on ``Solution.trace``.
* :mod:`repro.obs.export` — Chrome-trace/Perfetto JSON, JSONL flight
  records, and a Prometheus-style :class:`MetricsRegistry` with text
  exposition (``launch/serve.py --metrics-port``).
"""

from repro.obs.export import (
    MetricsRegistry,
    chrome_trace,
    flight_jsonl,
    serve_metrics,
    write_chrome_trace,
    write_flight_jsonl,
)
from repro.obs.recorder import FlightRecorder, SolveTrace
from repro.obs.trace import (
    Event,
    Span,
    Tracer,
    current_tracer,
    event,
    set_tracer,
    span,
    use_tracer,
)

__all__ = [
    "Event",
    "FlightRecorder",
    "MetricsRegistry",
    "SolveTrace",
    "Span",
    "Tracer",
    "chrome_trace",
    "current_tracer",
    "event",
    "flight_jsonl",
    "serve_metrics",
    "set_tracer",
    "span",
    "use_tracer",
    "write_chrome_trace",
    "write_flight_jsonl",
]
