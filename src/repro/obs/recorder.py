"""Per-superstep flight recorder: `SolveTrace` + the controller tap.

The ``/adapt`` seam (``EngineConfig.adapt_window > 0``) already makes
the engine publish per-superstep metrics windows so a policy can
retune between segments.  The flight recorder generalizes that seam to
*observation without intervention*: a ``/trace`` solve runs through
the same segment engine under the no-op ``StaticPolicy`` — by the
self-stabilization argument PR 7 machine-checked, segmenting the
schedule cannot move the fixpoint, so the traced solve is bit-identical
(state **and** WorkMetrics) to the untraced one — and a
:class:`FlightRecorder` collects every segment's
:class:`~repro.core.metrics.SuperstepWindow` into a :class:`SolveTrace`
attached to ``Solution.trace``.

The trace is exact, not sampled: Σ ``bytes_moved`` equals the
aggregate ``WorkMetrics.exchange_bytes`` (each superstep's bytes are
derived from its sparse/dense choice and its segment's static
capacities — the same arithmetic ``api.solver.exchange_words`` uses
for the aggregate), Σ ``eligible`` equals ``commits``, and the last
``pending`` entry is 0 iff the solve converged.
:meth:`SolveTrace.reconcile` machine-checks all of this against a
``WorkMetrics``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.core.metrics import SuperstepWindow, WorkMetrics
from repro.obs import trace as obs_trace

__all__ = ["FlightRecorder", "SolveTrace"]


@dataclasses.dataclass
class SolveTrace:
    """Per-superstep record of one solve.

    The five per-superstep lists are indexed by superstep (0-based,
    concatenated across segments, length == engine supersteps).
    ``segments`` holds one dict per segment-engine invocation:
    ``{"segment", "supersteps", "t0", "t1", "frontier_cap", "delta",
    "force", "fallbacks", "retraced"}`` — wall timestamps come from
    the active tracer's clock, so exporters can place superstep
    counter samples inside the segment spans that produced them.

    ``host_sweeps`` counts supersteps performed host-side *outside*
    the segment engine (the ``resolve`` bootstrap sweep); they appear
    in the aggregate ``WorkMetrics.supersteps`` but have no
    per-superstep window.
    """

    config_name: str = ""
    n: int = 0                       # global padded vertex count
    rows_per_rank: int = 0
    sparse_capable: bool = False
    pending: list = dataclasses.field(default_factory=list)
    eligible: list = dataclasses.field(default_factory=list)
    rows: list = dataclasses.field(default_factory=list)
    sparse_used: list = dataclasses.field(default_factory=list)
    bytes_moved: list = dataclasses.field(default_factory=list)
    segments: list = dataclasses.field(default_factory=list)
    host_sweeps: int = 0
    repair_sweeps: int = 0

    @property
    def supersteps(self) -> int:
        return len(self.pending)

    def total_bytes(self) -> int:
        return int(sum(self.bytes_moved))

    def reconcile(self, m: WorkMetrics) -> None:
        """Assert this trace sums exactly to the aggregate metrics.
        Raises ``AssertionError`` naming the first mismatched quantity."""
        assert self.supersteps + self.host_sweeps == m.supersteps, (
            f"supersteps: trace {self.supersteps} + host {self.host_sweeps} "
            f"!= aggregate {m.supersteps}")
        assert self.total_bytes() == m.exchange_bytes, (
            f"bytes: trace Σ{self.total_bytes()} != "
            f"aggregate {m.exchange_bytes}")
        assert sum(self.eligible) == m.commits, (
            f"commits: trace Σeligible {sum(self.eligible)} != "
            f"aggregate {m.commits}")
        n_fallback = sum(
            1 for s in self.sparse_used if not s
        ) if self.sparse_capable else 0
        assert n_fallback == m.sparse_fallbacks, (
            f"sparse_fallbacks: trace {n_fallback} != "
            f"aggregate {m.sparse_fallbacks}")
        if m.converged and self.pending:
            assert self.pending[-1] == 0, (
                f"converged solve ended with pending={self.pending[-1]}")
        assert self.repair_sweeps == m.repair_sweeps, (
            f"repair_sweeps: trace {self.repair_sweeps} != "
            f"aggregate {m.repair_sweeps}")

    def table(self) -> str:
        """Fixed-width per-superstep convergence table — the paper's
        work-vs-ordering narrative, one row per superstep."""
        head = (f"{'step':>5} {'pending':>10} {'eligible':>10} "
                f"{'rows':>8} {'exch':>7} {'bytes':>12}")
        lines = [head, "-" * len(head)]
        for i in range(self.supersteps):
            exch = ("sparse" if self.sparse_used[i] else "dense") \
                if self.sparse_capable else "dense"
            lines.append(
                f"{i:>5} {self.pending[i]:>10} {self.eligible[i]:>10} "
                f"{self.rows[i]:>8} {exch:>7} {self.bytes_moved[i]:>12}")
        lines.append(
            f"total supersteps={self.supersteps} (+{self.host_sweeps} host) "
            f"bytes={self.total_bytes()} segments={len(self.segments)}")
        return "\n".join(lines)

    def as_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    def superstep_records(self) -> list[dict[str, Any]]:
        """One flat dict per superstep (JSONL flight-record rows)."""
        return [
            {
                "step": i,
                "pending": self.pending[i],
                "eligible": self.eligible[i],
                "rows": self.rows[i],
                "sparse_used": int(self.sparse_used[i]),
                "bytes_moved": self.bytes_moved[i],
                "config": self.config_name,
            }
            for i in range(self.supersteps)
        ]


class FlightRecorder:
    """Collects segment windows into a :class:`SolveTrace`.

    An instance's :meth:`on_window` is handed to
    :func:`repro.tune.controller.run_adaptive` as its ``on_window``
    callback; the controller invokes it once per segment (including
    the final one) *before* consulting the policy, so recording works
    both for pure ``/trace`` solves (StaticPolicy — no intervention)
    and for ``/trace``-composed ``/adapt`` solves (the record then
    reflects the retuned schedule, not the static spec).
    """

    def __init__(self, config_name: str = ""):
        self.trace = SolveTrace(config_name=config_name)
        self._n_segments = 0

    def on_window(self, window: SuperstepWindow,
                  seg: Optional[dict[str, Any]] = None) -> None:
        tr = self.trace
        if self._n_segments == 0:
            tr.n = window.n
            tr.rows_per_rank = window.rows_per_rank
            tr.sparse_capable = window.sparse_capable
        tr.pending.extend(window.pending)
        tr.eligible.extend(window.eligible)
        tr.rows.extend(window.rows)
        tr.sparse_used.extend(window.sparse_used)
        tr.bytes_moved.extend(window.bytes_moved)
        rec = {"segment": self._n_segments,
               "supersteps": len(window.pending)}
        if seg:
            rec.update(seg)
        rec.setdefault("t0", obs_trace.now())
        rec.setdefault("t1", rec["t0"])
        tr.segments.append(rec)
        self._n_segments += 1

    def finish(self, m: WorkMetrics) -> SolveTrace:
        """Seal the trace against the solve's aggregate metrics."""
        self.trace.repair_sweeps = m.repair_sweeps
        return self.trace
