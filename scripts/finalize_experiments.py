"""Inject the final roofline table + perf summary into EXPERIMENTS.md
(run after the full dry-run sweep).

    PYTHONPATH=src python scripts/finalize_experiments.py
"""

import glob
import json
import subprocess
import sys

sys.path.insert(0, "src")

from repro.roofline import from_record  # noqa: E402


def table(mesh: str) -> str:
    rows = []
    for f in sorted(glob.glob(f"experiments/dryrun/*__{mesh}.json")):
        rec = json.load(open(f))
        if not rec.get("ok"):
            rows.append(f"| {rec['arch']} | {rec['cell']} | FAILED |")
            continue
        r = from_record(rec)
        mem_gb = rec["memory"].get("temp_size_in_bytes", 0) / 1e9
        arg_gb = rec["memory"].get("argument_size_in_bytes", 0) / 1e9

        def fm(x):
            return f"{x:.2e}" if (x != 0 and (x < 1e-3 or x >= 1e4)) \
                else f"{x:.4f}"

        rows.append(
            f"| {r.arch} | {r.cell} | {fm(r.t_compute)} | "
            f"{fm(r.t_memory)} | {fm(r.t_collective)} | {r.dominant} | "
            f"{r.useful_ratio:.3f} | {r.roofline_fraction:.4f} | "
            f"{mem_gb:.1f} | {arg_gb:.2f} |"
        )
    hdr = ("| arch | cell | t_comp (s) | t_mem (s) | t_coll (s) | "
           "dominant | useful | frac | temp GB/dev | args GB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def perf_summary() -> str:
    def load(path):
        rec = json.load(open(path))
        r = from_record(rec)
        return rec, r

    lines = [
        "| cell | metric | before | after | gain |",
        "|---|---|---|---|---|",
    ]
    # H1
    b, rb = load("experiments/perf_iter0_minicpm3_prefill.json")
    a, ra = load(
        "experiments/dryrun/minicpm3-4b__prefill_32k__pod16x16.json"
    )
    lines.append(
        f"| minicpm3 prefill_32k | t_coll (s) | {rb.t_collective:.1f} "
        f"| {ra.t_collective:.2f} | "
        f"{rb.t_collective/max(ra.t_collective,1e-9):.0f}x |"
    )
    lines.append(
        f"| minicpm3 prefill_32k | temp GB/dev | "
        f"{b['memory']['temp_size_in_bytes']/1e9:.0f} | "
        f"{a['memory']['temp_size_in_bytes']/1e9:.0f} | "
        f"{b['memory']['temp_size_in_bytes']/max(a['memory']['temp_size_in_bytes'],1):.0f}x |"
    )
    # H2
    b, rb = load("experiments/perf_dimenet/baseline.json")
    a, ra = load("experiments/dryrun/dimenet__ogb_products__pod16x16.json")
    lines.append(
        f"| dimenet ogb_products | t_coll (s) | {rb.t_collective:.2f} "
        f"| {ra.t_collective:.2f} | "
        f"{rb.t_collective/max(ra.t_collective,1e-9):.2f}x |"
    )
    # H3
    b, rb = load(
        "experiments/dryrun/sssp__rmat26_delta_buffer_pmin__pod16x16.json"
    )
    a, ra = load(
        "experiments/dryrun/sssp__rmat26_delta_buffer_a2a__pod16x16.json"
    )
    lines.append(
        f"| sssp Δ-stepping exchange | coll bytes/superstep/dev | "
        f"{b['collectives']['total_bytes']/1e6:.0f} MB | "
        f"{a['collectives']['total_bytes']/1e6:.0f} MB | "
        f"{b['collectives']['total_bytes']/max(a['collectives']['total_bytes'],1):.2f}x |"
    )
    return "\n".join(lines)


def main():
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    text = text.replace("<!-- ROOFLINE_TABLE -->", table("pod16x16"))
    text = text.replace("<!-- PERF_SUMMARY -->", perf_summary())
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    with open("experiments/roofline_single.md", "w") as f:
        f.write(table("pod16x16"))
    with open("experiments/roofline_multi.md", "w") as f:
        f.write(table("pod2x16x16"))
    print("EXPERIMENTS.md finalized")


if __name__ == "__main__":
    main()
