"""Generate the EXPERIMENTS.md §Roofline table from dry-run records.

    PYTHONPATH=src python scripts/make_roofline_table.py [--mesh pod16x16]
"""

import argparse
import glob
import json
import sys

sys.path.insert(0, "src")

from repro.roofline import from_record  # noqa: E402


def fmt(x, digits=4):
    if x == 0:
        return "0"
    if x < 1e-3 or x >= 1e4:
        return f"{x:.2e}"
    return f"{x:.{digits}f}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()

    rows = []
    for f in sorted(glob.glob(f"{args.dir}/*__{args.mesh}.json")):
        rec = json.load(open(f))
        if not rec.get("ok"):
            rows.append((rec["arch"], rec["cell"], "FAILED"))
            continue
        r = from_record(rec)
        mem_gb = rec["memory"].get("temp_size_in_bytes", 0) / 1e9
        arg_gb = rec["memory"].get("argument_size_in_bytes", 0) / 1e9
        rows.append((
            r.arch, r.cell, fmt(r.t_compute), fmt(r.t_memory),
            fmt(r.t_collective), r.dominant, fmt(r.useful_ratio, 3),
            fmt(r.roofline_fraction, 4), f"{mem_gb:.1f}",
            f"{arg_gb:.2f}",
        ))

    hdr = ("| arch | cell | t_comp (s) | t_mem (s) | t_coll (s) | "
           "dominant | useful | frac | temp GB/dev | args GB/dev |")
    sep = "|" + "---|" * 10
    print(hdr)
    print(sep)
    for r in rows:
        print("| " + " | ".join(str(x) for x in r) + " |")


if __name__ == "__main__":
    main()
