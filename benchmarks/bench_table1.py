"""Paper Table I: real-world graphs × (Δ-stepping, KLA, Chaotic) ×
(buffer, threadq, nodeq, numaq).

The container has no network access, so each SNAP graph is replaced by
a stand-in with matching structural character (documented in
EXPERIMENTS.md): social graphs → small-world / R-MAT (low diameter,
skewed degrees); roadNet-CA → 2D grid (high diameter).  Per-graph
algorithm parameters follow the paper (e.g. Δ=1200 on the road
network, KLA K=10)."""

from __future__ import annotations

import json
import os
import subprocess
import sys

CHILD = r"""
import json
import numpy as np, jax
from repro.graph import rmat1, small_world_graph, grid_road_graph
from repro.api import Problem, SingleSource, Solver, SolverConfig
from repro.core import dijkstra_reference, model_time_s

GRAPHS = [
    # (table-I stand-in, generator, AGM parameters)
    ("soc-live-proxy", small_world_graph(1 << 12, k=16, p=0.05, seed=1),
     [("delta:3", None), ("kla:1", None), ("chaotic", None)]),
    ("wiki-talk-proxy", rmat1(11, seed=3),
     [("delta:3", None), ("kla:1", None), ("chaotic", None)]),
    ("roadnet-proxy", grid_road_graph(64, seed=2),
     [("delta:1200", None), ("kla:10", None), ("chaotic", None)]),
    ("orkut-proxy", rmat1(11, seed=9, edge_factor=32),
     [("delta:10", None), ("kla:5", None), ("chaotic", None)]),
]
rows = []
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
for gname, g, algs in GRAPHS:
    ref = dijkstra_reference(g, 0)
    for root, _ in algs:
        for variant in ["buffer", "threadq", "nodeq", "numaq"]:
            solver = Solver(
                SolverConfig(root=root, variant=variant, exchange="a2a",
                             chunk_size=256),
                mesh=mesh)
            sol = solver.solve(Problem(g, SingleSource(0)))
            m = sol.metrics
            ok = np.allclose(np.where(np.isinf(ref), -1, ref),
                             np.where(np.isinf(sol.state), -1, sol.state))
            rows.append(dict(graph=gname, n=g.n, m=g.m, root=root,
                             variant=variant, ok=bool(ok),
                             model_ms=model_time_s(m, 64) * 1e3,
                             **m.as_dict()))
print(json.dumps(rows))
"""


def run() -> list:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", CHILD], env=env,
                       capture_output=True, text=True, timeout=3000)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-3000:])
    return json.loads(r.stdout.splitlines()[-1])


def main() -> list[str]:
    rows = run()
    out = []
    for r in rows:
        assert r["ok"], r
        name = f"table1/{r['graph']}/{r['root']}+{r['variant']}"
        derived = (
            f"relax={r['relaxations']};steps={r['supersteps']};"
            f"commits={r['commits']};waste={r['relaxations']/max(1,r['commits']):.1f}"
        )
        out.append(f"{name},{r['model_ms']*1e3:.1f},{derived}")
    return out


if __name__ == "__main__":
    for line in main():
        print(line)
