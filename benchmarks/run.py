"""Benchmark harness entry point: one suite per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV lines.  The suites:

  fig5-7/     Δ/KLA/Chaotic × EAGM variants, RMAT1+RMAT2 (Figs 5-7)
  table1/     real-world-shaped graphs × variants       (Table I)
  weakscale/  per-rank-constant scaling P=1..8          (§VI-A)
  kernel/     Pallas-target kernel hot loops (XLA ref timings)
"""

from __future__ import annotations

import sys


def main() -> None:
    fast = "--fast" in sys.argv
    from benchmarks import (
        bench_kernels, bench_scaling, bench_table1, bench_variants,
    )

    lines = ["name,us_per_call,derived"]
    lines += bench_kernels.main()
    lines += bench_variants.main(scale=9 if fast else 10)
    if not fast:
        lines += bench_table1.main()
        lines += bench_scaling.main()
    for ln in lines:
        print(ln)


if __name__ == "__main__":
    main()
