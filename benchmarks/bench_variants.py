"""Paper Figures 5-7: Δ-stepping / KLA / Chaotic AGMs × EAGM variants
(buffer, threadq, nodeq, numaq) on RMAT1 and RMAT2.

The container cannot time a Cray, so each variant reports the
work/synchronization quantities its wall-clock decomposes into
(relaxations, commits, supersteps, exchange bytes) plus the calibrated
cost model over 256 chips (metrics.model_time_s) — reproducing the
*shape* of the paper's comparisons.  Runs on 8 placeholder devices in
a subprocess so pod/device/chunk-scoped orderings are distinct.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

CHILD = r"""
import json
import numpy as np, jax
from repro.graph import rmat1, rmat2
from repro.api import Problem, SingleSource, Solver, SolverConfig
from repro.core import dijkstra_reference, model_time_s

SCALE = %(scale)d
rows = []
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
for gname, gen in [("rmat1", rmat1), ("rmat2", rmat2)]:
    g = gen(SCALE, seed=7)
    ref = dijkstra_reference(g, 0)
    for root in ["delta:3", "delta:5", "delta:7", "kla:1", "kla:2",
                 "kla:3", "chaotic"]:
        for variant in ["buffer", "threadq", "nodeq", "numaq"]:
            solver = Solver(
                SolverConfig(root=root, variant=variant, exchange="a2a",
                             chunk_size=256),
                mesh=mesh)
            sol = solver.solve(Problem(g, SingleSource(0)))
            m = sol.metrics
            ok = np.allclose(np.where(np.isinf(ref), -1, ref),
                             np.where(np.isinf(sol.state), -1, sol.state))
            rows.append(dict(
                graph=gname, scale=SCALE, root=root, variant=variant,
                ok=bool(ok), model_ms=model_time_s(m, 256) * 1e3,
                **m.as_dict()))
print(json.dumps(rows))
"""


def run(scale: int = 10) -> list:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", CHILD % {"scale": scale}], env=env,
        capture_output=True, text=True, timeout=3000,
    )
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-3000:])
    return json.loads(r.stdout.splitlines()[-1])


def main(scale: int = 10) -> list[str]:
    rows = run(scale)
    out = []
    for r in rows:
        assert r["ok"], r
        name = f"fig5-7/{r['graph']}_s{r['scale']}/{r['root']}+{r['variant']}"
        derived = (
            f"relax={r['relaxations']};steps={r['supersteps']};"
            f"commits={r['commits']};xbytes={r['exchange_bytes']}"
        )
        out.append(f"{name},{r['model_ms']*1e3:.1f},{derived}")
    return out


if __name__ == "__main__":
    for line in main():
        print(line)
