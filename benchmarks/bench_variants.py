"""Paper Figures 5-7: Δ-stepping / KLA / Chaotic AGMs × EAGM variants
(buffer, threadq, nodeq, numaq) × candidate-exchange strategies
(dense a2a vs frontier-sparse vs auto) on RMAT1 and RMAT2.

The container cannot time a Cray, so each variant reports the
work/synchronization quantities its wall-clock decomposes into
(relaxations, commits, supersteps, actually-exchanged bytes) plus the
calibrated cost model over 256 chips (metrics.model_time_s) and the
measured wall time of one warm (compile-excluded) solve — reproducing
the *shape* of the paper's comparisons and tracking the sparse-
exchange win (per-superstep bytes scaling with the frontier capacity,
not |V|).  Runs on 8 placeholder devices in a subprocess so
pod/device/chunk-scoped orderings are distinct.

CLI:  PYTHONPATH=src python benchmarks/bench_variants.py \
          [--quick] [--scale N] [--json BENCH_variants.json] \
          [--json-partition BENCH_partition.json]

``--quick`` shrinks the grid (CI trajectory job); the JSON rows carry
supersteps, bytes, bytes/superstep, fallbacks and wall time per
variant × exchange so the perf trajectory accumulates across PRs.
Besides the preset grid, ``HIERARCHY_SPECS`` adds composed multi-level
hierarchy points (grammar v2, e.g. ``delta:5 > pod:dijkstra >
chunk:delta:1``) so the beyond-paper family space is tracked too —
including in ``--quick``.  ``--json-partition`` additionally runs the
partition dimension (``PARTITIONS``: relabeling partitioners on one
skewed RMAT at W=8, tracking the stacked row count R, straggler ratio
and exchanged bytes per strategy).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

EXCHANGES = ["a2a", "sparse", "auto"]

#: beyond-paper multi-level hierarchy family points (grammar v2) so
#: BENCH_variants.json tracks them alongside the preset grid
HIERARCHY_SPECS = [
    "delta:5 > pod:dijkstra > chunk:delta:1",
]

#: the partition dimension (BENCH_partition.json): relabeling
#: partitioners on one skewed RMAT under a fixed ordering, tracking
#: stacked row count R / straggler ratio / bytes / wall per strategy
PARTITIONS = ["block", "shuffle:7", "ebal", "degree"]

CHILD = r"""
import json, time
import numpy as np, jax
from repro.graph import rmat1, rmat2
from repro.api import Problem, SingleSource, Solver, SolverConfig
from repro.core import dijkstra_reference, model_time_s

SCALE = %(scale)d
QUICK = %(quick)d
rows = []
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
graphs = [("rmat1", rmat1)] if QUICK else [("rmat1", rmat1),
                                           ("rmat2", rmat2)]
if QUICK:
    roots = ["delta:5", "kla:2", "dijkstra", "chaotic"]
    variants = ["buffer", "threadq"]
else:
    roots = ["delta:3", "delta:5", "delta:7", "kla:1", "kla:2", "kla:3",
             "chaotic", "dijkstra"]
    variants = ["buffer", "threadq", "nodeq", "numaq"]
# (root, variant) preset points + composed multi-level hierarchies —
# a hierarchy config rides the same solve/measure path with
# variant='hierarchy' and the grammar-v2 spec as its root
points = [(root, variant) for root in roots for variant in variants]
points += [(spec, "hierarchy") for spec in %(hier_specs)s]
for gname, gen in graphs:
    g = gen(SCALE, seed=7)
    ref = dijkstra_reference(g, 0)
    for root, variant in points:
        for exchange in %(exchanges)s:
            if variant == "hierarchy":
                cfg = SolverConfig.from_spec(
                    root, exchange=exchange, chunk_size=256,
                    frontier_cap=%(frontier_cap)s)
            else:
                cfg = SolverConfig(root=root, variant=variant,
                                   exchange=exchange, chunk_size=256,
                                   frontier_cap=%(frontier_cap)s)
            solver = Solver(cfg, mesh=mesh)
            prob = Problem(g, SingleSource(0))
            sol = solver.solve(prob)          # compile + warm
            t0 = time.perf_counter()
            sol = solver.solve(prob)
            wall_s = time.perf_counter() - t0
            m = sol.metrics
            ok = np.allclose(np.where(np.isinf(ref), -1, ref),
                             np.where(np.isinf(sol.state), -1,
                                      sol.state))
            rows.append(dict(
                graph=gname, scale=SCALE, root=root, variant=variant,
                exchange=exchange, ok=bool(ok), wall_s=wall_s,
                model_ms=model_time_s(m, 256) * 1e3,
                bytes_per_superstep=(
                    m.exchange_bytes / max(1, m.supersteps)),
                **m.as_dict()))
print(json.dumps(rows))
"""


CHILD_PART = r"""
import json, time
import numpy as np, jax
from repro.graph import rmat1, partition_graph
from repro.api import Problem, SingleSource, Solver, SolverConfig
from repro.core import dijkstra_reference

SCALE = %(scale)d
WIDTH = 8  # narrow ELL => fat-row chunking dominates => skew visible
rows = []
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
g = rmat1(SCALE, seed=7)
ref = dijkstra_reference(g, 0)
for part in %(partitions)s:
    pg = partition_graph(g, 8, width=WIDTH, partitioner=part)
    st = pg.load_stats()
    for exchange in %(exchanges)s:
        cfg = SolverConfig.from_spec(
            "delta:5+threadq", exchange=exchange, chunk_size=256,
            partition=part, frontier_cap=%(frontier_cap)s)
        solver = Solver(cfg, mesh=mesh)
        prob = Problem(pg, SingleSource(0))
        sol = solver.solve(prob)          # compile + warm
        t0 = time.perf_counter()
        sol = solver.solve(prob)
        wall_s = time.perf_counter() - t0
        m = sol.metrics
        ok = np.allclose(np.where(np.isinf(ref), -1, ref),
                         np.where(np.isinf(sol.state), -1, sol.state))
        rows.append(dict(
            graph="rmat1", scale=SCALE, partition=part,
            exchange=exchange, ok=bool(ok), wall_s=wall_s,
            max_rows=st["max_rows"], n_local=pg.n_local,
            straggler_rows=st["straggler_rows"],
            ell_occupancy=st["ell_occupancy"],
            **m.as_dict()))
print(json.dumps(rows))
"""


CHILD_ADAPT = r"""
import json, time, warnings
import numpy as np, jax
from repro.graph import rmat1, grid_road_graph
from repro.api import Problem, SingleSource, Solver, SolverConfig
from repro.core import dijkstra_reference
from repro.tune import AutoTuner

SCALE = %(scale)d
QUICK = %(quick)d
rows = []
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
graphs = [("rmat1", rmat1(SCALE, seed=7)),
          ("road", grid_road_graph(int(2 ** (SCALE / 2)), 7))]
warnings.simplefilter("ignore", RuntimeWarning)
for gname, g in graphs:
    ref = dijkstra_reference(g, 0)
    # full delta grid even under --quick: the point of this cell is
    # that the tuner finds a better bucket width than the static
    # delta:5 baseline on the skewed family
    tuner = AutoTuner(
        mesh,
        orderings=("delta:3", "delta:5", "delta:10", "dijkstra"),
        partitions=("block",) if QUICK else ("block", "ebal"),
    )
    tuned = tuner.tune(g)
    points = [
        ("static", SolverConfig.from_spec("delta:5+buffer/a2a")),
        ("tuned", tuned),
        # adaptive controller from a deliberately tiny cap: rho must
        # grow it (retraces > 0) and retune delta mid-solve
        ("adaptive", SolverConfig.from_spec(
            "delta:5/sparse/adapt:rho", frontier_cap=4)),
    ]
    for kind, cfg in points:
        solver = Solver(cfg, mesh=mesh)
        prob = Problem(g, SingleSource(0))
        sol = solver.solve(prob)          # compile + warm
        t0 = time.perf_counter()
        sol = solver.solve(prob)
        wall_s = time.perf_counter() - t0
        m = sol.metrics
        ok = np.allclose(np.where(np.isinf(ref), -1, ref),
                         np.where(np.isinf(sol.state), -1, sol.state))
        rows.append(dict(
            graph=gname, scale=SCALE, kind=kind, spec=cfg.name,
            ok=bool(ok), wall_s=wall_s,
            bytes_per_superstep=(
                m.exchange_bytes / max(1, m.supersteps)),
            pilots=tuner.pilots_run, **m.as_dict()))
print(json.dumps(rows))
"""


CHILD_ROOFLINE = r"""
import json, time, warnings
import numpy as np, jax
from repro.graph import rmat1
from repro.api import Problem, SingleSource, Solver, SolverConfig
from repro.api.problem import get_processing
from repro.core import dijkstra_reference
from repro.roofline import superstep_profile

SCALE = %(scale)d
rows = []
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
g = rmat1(SCALE, seed=7)
ref = dijkstra_reference(g, 0)
warnings.simplefilter("ignore", RuntimeWarning)
base_state = None
base_metrics = None
for spec in ["delta:5/sparse", "delta:5/sparse/fused",
             "delta:5/sparse/q:bf16"]:
    cfg = SolverConfig.from_spec(spec, chunk_size=256)
    solver = Solver(cfg, mesh=mesh)
    prob = Problem(g, SingleSource(0))
    sol = solver.solve(prob)          # compile + warm
    t0 = time.perf_counter()
    sol = solver.solve(prob)
    wall_s = time.perf_counter() - t0
    m = sol.metrics
    ok = np.allclose(np.where(np.isinf(ref), -1, ref),
                     np.where(np.isinf(sol.state), -1, sol.state))
    assert ok, spec
    if base_state is None:
        base_state, base_metrics = np.asarray(sol.state), m.as_dict()
    else:
        # both the fused kernel and the quantized+repaired payload
        # must reproduce the exact baseline bit-for-bit
        assert np.array_equal(base_state, np.asarray(sol.state)), spec
    if spec == "delta:5/sparse/fused":
        assert m.as_dict() == base_metrics, (spec, m.as_dict())
    rows.append(dict(graph="rmat1", scale=SCALE, spec=spec,
                     ok=bool(ok), wall_s=wall_s,
                     bytes_per_superstep=(
                         m.exchange_bytes / max(1, m.supersteps)),
                     **m.as_dict()))
# the quantized payload must move strictly fewer bytes per superstep
assert (rows[2]["bytes_per_superstep"]
        < rows[0]["bytes_per_superstep"]), rows
# op-wise per-superstep roofline: fusion must cut HBM bytes
proc = get_processing("sssp")
prof = {}
for key, spec in [("unfused", "delta:5/sparse"),
                  ("fused", "delta:5/sparse/fused")]:
    ecfg = SolverConfig.from_spec(spec).engine_config(proc)
    prof[key] = superstep_profile(ecfg)
assert (prof["fused"]["hbm_bytes_per_superstep"]
        < prof["unfused"]["hbm_bytes_per_superstep"]), prof
print(json.dumps({"rows": rows, "roofline": prof, "ok": True}))
"""


def _run_child(child: str, timeout: int = 3000) -> list:
    """Run a benchmark child on 8 placeholder devices and parse its
    JSON rows (last stdout line)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", child], env=env,
        capture_output=True, text=True, timeout=timeout,
    )
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-3000:])
    return json.loads(r.stdout.splitlines()[-1])


def run(
    scale: int = 10,
    quick: bool = False,
    exchanges=None,
    frontier_cap: int | None = 4,
) -> list:
    return _run_child(CHILD % {
        "scale": scale,
        "quick": int(quick),
        "exchanges": repr(exchanges or EXCHANGES),
        "frontier_cap": repr(frontier_cap),
        "hier_specs": repr(HIERARCHY_SPECS),
    })


def run_partition(
    scale: int = 10,
    partitions=None,
    exchanges=None,
    frontier_cap: int | None = 16,
) -> list:
    """The partition-dimension cell: one skewed RMAT, one ordering,
    every relabeling partitioner × {a2a, sparse}."""
    return _run_child(CHILD_PART % {
        "scale": scale,
        "partitions": repr(partitions or PARTITIONS),
        "exchanges": repr(exchanges or ["a2a", "sparse"]),
        "frontier_cap": repr(frontier_cap),
    })


def run_adaptive(scale: int = 10, quick: bool = False) -> list:
    """The autotune cell: static baseline vs offline-tuned spec vs
    runtime /adapt:rho controller on a skewed RMAT and a road grid."""
    return _run_child(CHILD_ADAPT % {
        "scale": scale,
        "quick": int(quick),
    })


def run_roofline(scale: int = 10) -> dict:
    """The kernel-fusion / quantized-exchange cell: exact sparse
    baseline vs '/fused' vs '/q:bf16' on one RMAT (bit-identity
    asserted in the child), plus the op-wise per-superstep HBM
    roofline for the unfused and fused programs."""
    return _run_child(CHILD_ROOFLINE % {"scale": scale})


def main_roofline(
    scale: int = 10, json_path: str | None = None
) -> list[str]:
    res = run_roofline(scale)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(res, f, indent=1, sort_keys=True)
    out = []
    for r in res["rows"]:
        name = f"roofline/{r['graph']}_s{r['scale']}/{r['spec']}"
        derived = (
            f"steps={r['supersteps']};xbytes={r['exchange_bytes']};"
            f"bps={r['bytes_per_superstep']:.0f};"
            f"repairs={r['repair_sweeps']}"
        )
        out.append(f"{name},{r['wall_s']*1e6:.1f},{derived}")
    pu = res["roofline"]["unfused"]["hbm_bytes_per_superstep"]
    pf = res["roofline"]["fused"]["hbm_bytes_per_superstep"]
    out.append(
        f"roofline/superstep_hbm_bytes,unfused={pu},fused={pf},"
        f"saved={pu - pf}"
    )
    return out


def main_adaptive(
    scale: int = 10,
    quick: bool = False,
    json_path: str | None = None,
) -> list[str]:
    rows = run_adaptive(scale, quick=quick)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=1, sort_keys=True)
    out = []
    for r in rows:
        assert r["ok"], r
        name = f"autotune/{r['graph']}_s{r['scale']}/{r['kind']}"
        derived = (
            f"spec={r['spec']};steps={r['supersteps']};"
            f"bps={r['bytes_per_superstep']:.0f};"
            f"retraces={r['retraces']};fallbacks={r['sparse_fallbacks']}"
        )
        out.append(f"{name},{r['wall_s']*1e6:.1f},{derived}")
    return out


def main_partition(
    scale: int = 10, json_path: str | None = None
) -> list[str]:
    rows = run_partition(scale)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=1, sort_keys=True)
    out = []
    for r in rows:
        assert r["ok"], r
        name = (
            f"partition/{r['graph']}_s{r['scale']}/"
            f"{r['partition']}/{r['exchange']}"
        )
        derived = (
            f"R={r['max_rows']};straggler={r['straggler_rows']:.3f};"
            f"steps={r['supersteps']};xbytes={r['exchange_bytes']};"
            f"relax={r['relaxations']}"
        )
        out.append(f"{name},{r['wall_s']*1e6:.1f},{derived}")
    return out


def main(
    scale: int = 10,
    quick: bool = False,
    json_path: str | None = None,
) -> list[str]:
    rows = run(scale, quick=quick)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=1, sort_keys=True)
    out = []
    for r in rows:
        assert r["ok"], r
        if r["variant"] == "hierarchy":
            point = r["root"].replace(" ", "")  # grammar-v2 spec
            name = (
                f"family/{r['graph']}_s{r['scale']}/"
                f"{point}/{r['exchange']}"
            )
        else:
            name = (
                f"fig5-7/{r['graph']}_s{r['scale']}/"
                f"{r['root']}+{r['variant']}/{r['exchange']}"
            )
        derived = (
            f"relax={r['relaxations']};steps={r['supersteps']};"
            f"commits={r['commits']};xbytes={r['exchange_bytes']};"
            f"bps={r['bytes_per_superstep']:.0f};"
            f"fallbacks={r['sparse_fallbacks']}"
        )
        out.append(f"{name},{r['wall_s']*1e6:.1f},{derived}")
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small grid + scale 9 (CI trajectory job)")
    ap.add_argument("--scale", type=int, default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump the raw rows as JSON")
    ap.add_argument("--json-partition", default=None, metavar="PATH",
                    help="also run the partition-dimension cell "
                         "(block vs shuffle vs ebal vs degree on one "
                         "RMAT) and dump its rows as JSON")
    ap.add_argument("--adaptive", nargs="?", const="BENCH_autotune.json",
                    default=None, metavar="PATH",
                    help="run ONLY the autotune cell (static vs "
                         "offline-tuned vs /adapt:rho on rmat1 + road) "
                         "and dump its rows as JSON "
                         "(default PATH: %(const)s)")
    ap.add_argument("--roofline", nargs="?", const="BENCH_roofline.json",
                    default=None, metavar="PATH",
                    help="run ONLY the fusion/quantization cell "
                         "(exact sparse vs /fused vs /q:bf16 on rmat1, "
                         "bit-identity asserted, + per-superstep HBM "
                         "roofline) and dump it as JSON "
                         "(default PATH: %(const)s)")
    a = ap.parse_args()
    scale = a.scale if a.scale is not None else (9 if a.quick else 10)
    if a.roofline:
        for line in main_roofline(scale, json_path=a.roofline):
            print(line)
        sys.exit(0)
    if a.adaptive:
        for line in main_adaptive(scale, quick=a.quick,
                                  json_path=a.adaptive):
            print(line)
        sys.exit(0)
    for line in main(scale, quick=a.quick, json_path=a.json):
        print(line)
    if a.json_partition:
        for line in main_partition(scale, json_path=a.json_partition):
            print(line)
