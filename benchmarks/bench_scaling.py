"""Paper §VI-A weak-scaling analogue: the same per-rank problem size
at P = 1, 2, 4, 8 ranks; reports how supersteps (sync) and exchanged
bytes grow with P per EAGM variant — the quantities whose balance
produces the paper's weak-scaling curves."""

from __future__ import annotations

import json
import os
import subprocess
import sys

CHILD = r"""
import json
import numpy as np, jax
from repro.graph import rmat2
from repro.api import Problem, SingleSource, Solver, SolverConfig
from repro.core import model_time_s

rows = []
for P, scale in [(1, 8), (2, 9), (4, 10), (8, 11)]:  # weak scaling
    g = rmat2(scale, seed=11)
    if P == 1:
        mesh = jax.make_mesh((1,), ("data",))
    elif P == 2:
        mesh = jax.make_mesh((2,), ("data",))
    elif P == 4:
        mesh = jax.make_mesh((2, 2), ("data", "model"))
    else:
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    for root, variant in [("delta:5", "buffer"), ("delta:5", "threadq"),
                          ("chaotic", "threadq"), ("kla:1", "nodeq")]:
        solver = Solver(
            SolverConfig(root=root, variant=variant, exchange="a2a",
                         chunk_size=256),
            mesh=mesh)
        sol = solver.solve(Problem(g, SingleSource(0)))
        rows.append(dict(P=P, scale=scale, root=root, variant=variant,
                         model_ms=model_time_s(sol.metrics, P) * 1e3,
                         **sol.metrics.as_dict()))
print(json.dumps(rows))
"""


def run() -> list:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", CHILD], env=env,
                       capture_output=True, text=True, timeout=3000)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-3000:])
    return json.loads(r.stdout.splitlines()[-1])


def main() -> list[str]:
    out = []
    for r in run():
        name = (f"weakscale/P{r['P']}_s{r['scale']}/"
                f"{r['root']}+{r['variant']}")
        derived = (f"relax={r['relaxations']};steps={r['supersteps']};"
                   f"xbytes={r['exchange_bytes']}")
        out.append(f"{name},{r['model_ms']*1e3:.1f},{derived}")
    return out


if __name__ == "__main__":
    for line in main():
        print(line)
