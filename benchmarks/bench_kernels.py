"""Kernel micro-benchmarks: wall time of the jitted XLA reference
path on CPU (the Pallas kernels are TPU-target; interpret mode is a
correctness harness, not a performance surface), plus derived
bandwidth estimates."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import aggregate_neighbors, bag_pool, mha, relax_rows


def timeit(fn, *args, iters=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) \
        else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def main() -> list[str]:
    rng = np.random.default_rng(0)
    out = []

    # relax_ell: SSSP hot loop
    n_pad, R, W = 1 << 16, 1 << 16, 16
    dist = jnp.concatenate([
        jnp.asarray(rng.exponential(10, n_pad), jnp.float32),
        jnp.array([jnp.inf]),
    ])
    col = jnp.asarray(rng.integers(0, n_pad, (R, W)), jnp.int32)
    wgt = jnp.asarray(rng.uniform(1, 100, (R, W)), jnp.float32)
    f = jax.jit(lambda d, c, w: relax_rows(d, c, w, impl="ref"))
    us = timeit(f, dist, col, wgt)
    edges_per_s = R * W / (us / 1e6)
    out.append(f"kernel/relax_ell_64k_rows,{us:.1f},"
               f"edges_per_s={edges_per_s:.3e}")

    # spmm_ell: GNN aggregation
    x = jnp.asarray(rng.normal(size=(n_pad, 64)), jnp.float32)
    f = jax.jit(lambda x, c, w: aggregate_neighbors(
        x, c, w, op="sum", impl="ref"))
    us = timeit(f, x, col, wgt)
    gb = R * W * 64 * 4 / 1e9
    out.append(f"kernel/spmm_ell_64k_rows_d64,{us:.1f},"
               f"gather_GBps={gb/(us/1e6):.1f}")

    # flash attention (xla ref)
    B, H, KV, S, D = 1, 8, 2, 1024, 64
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, KV, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, KV, S, D)), jnp.float32)
    f = jax.jit(lambda q, k, v: mha(q, k, v, causal=True, impl="ref"))
    us = timeit(f, q, k, v, iters=5)
    fl = 4 * B * H * S * S * D / 2
    out.append(f"kernel/attention_1k_h8,{us:.1f},"
               f"gflops={fl/(us/1e6)/1e9:.1f}")

    # embedding bag
    V, d, Bb, L = 1 << 18, 64, 4096, 50
    table = jnp.asarray(rng.normal(size=(V, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, V, (Bb, L)), jnp.int32)
    mask = jnp.ones((Bb, L), bool)
    f = jax.jit(lambda t, i, m: bag_pool(t, i, m, mode="mean",
                                         impl="ref"))
    us = timeit(f, table, idx, mask, iters=5)
    out.append(f"kernel/embedding_bag_4k_bags,{us:.1f},"
               f"lookups_per_s={Bb*L/(us/1e6):.3e}")
    return out


if __name__ == "__main__":
    for line in main():
        print(line)
