"""Serving SLO benchmark: the persistent query service under a
Zipf-skewed mix on the 8-device mesh → ``BENCH_serving.json``.

Two scenarios, run in a subprocess with 8 placeholder host devices:

* **query mix** — N queries (70% single-source, 20% point-to-point
  exact, 10% landmark-estimated), sources Zipf-skewed so the solution
  cache has a hot set.  Reports queries/sec, p50/p90/p99 latency,
  cache hit rate, admission-batch count and landmark serve count.
  Engine-compile time is excluded by pre-warming the power-of-two
  batch buckets (a deployed service pre-warms at rollout).
* **streamed updates** — improving edge updates (weight drops + an
  insertion) applied through the UpdateFeed while answers stay cached:
  every warm-restart-refreshed entry must be *bit-identical* to a
  from-scratch cold solve of the updated graph while spending strictly
  fewer engine supersteps (the self-stabilization dividend the paper
  promises).  A non-improving update is also applied to exercise the
  stale-detection → cold-solve path.

CLI:  PYTHONPATH=src python benchmarks/bench_serving.py \
          [--quick] [--scale N] [--json BENCH_serving.json]
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

CHILD = r"""
import json, time
import numpy as np, jax
assert len(jax.devices()) == 8, jax.devices()
from repro.api import Problem, SingleSource, Solver
from repro.core import dijkstra_reference
from repro.graph import rmat1, graph_fingerprint
from repro.serve import (EdgeUpdate, LandmarkIndex, Query, Router,
                         SolutionCache, UpdateFeed, serve_latency_stats)

SCALE = %(scale)d
QUICK = %(quick)d
N_QUERIES = 120 if QUICK else 400
N_UPDATES = 3 if QUICK else 6
K = 4 if QUICK else 8
MAX_BATCH = 8
ZIPF_A = 1.3

g = rmat1(SCALE, seed=7)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
solver = Solver("%(spec)s", mesh=mesh)
cache = SolutionCache(byte_budget=256 << 20)
t0 = time.perf_counter()
lm = LandmarkIndex(solver, g, k=K, symmetric=True)
landmark_build_s = time.perf_counter() - t0
router = Router(solver, g, cache=cache, landmarks=lm,
                max_batch=MAX_BATCH, max_wait_s=0.01)

rng = np.random.default_rng(0)
ranks = np.minimum(rng.zipf(ZIPF_A, size=N_QUERIES) - 1, g.n - 1)
perm = np.random.default_rng(1).permutation(g.n)
srcs = perm[ranks]
tgts = rng.integers(0, g.n, size=N_QUERIES)
kinds = rng.random(N_QUERIES)
queries = []
for s, t, k in zip(srcs, tgts, kinds):
    if k < 0.7:
        queries.append(Query(int(s)))
    elif k < 0.9:
        queries.append(Query(int(s), target=int(t)))
    else:
        queries.append(Query(int(s), target=int(t), exact=False))

# pre-warm the batch buckets (compile time out of the SLO window)
router.serve(queries[:MAX_BATCH])
router.serve([queries[0]])
cache.clear()
cache.stats.hits = cache.stats.misses = 0

t0 = time.perf_counter()
tickets = [router.submit(q) for q in queries]
router.flush()
wall_s = time.perf_counter() - t0
answers = [t.result() for t in tickets]
lat = serve_latency_stats(answers)

# correctness spot check: exact answers vs the Dijkstra oracle,
# estimates sandwiched by their bounds
checked = 0
for a in answers[:50]:
    ref = dijkstra_reference(g, a.query.source)
    if a.served_by == "landmark":
        d = ref[a.query.target]
        assert a.lower <= d <= a.upper or (
            np.isinf(d) and np.isinf(a.upper)), (a, d)
    elif a.query.target is not None:
        r = ref[a.query.target]
        assert a.distance == r or (np.isinf(a.distance) and np.isinf(r))
    else:
        assert np.allclose(np.where(np.isinf(ref), -1, ref),
                           np.where(np.isinf(a.solution.state), -1,
                                    a.solution.state))
    checked += 1

serving = dict(
    ok=True, n_queries=len(answers), wall_s=wall_s,
    qps=len(answers) / wall_s,
    p50_ms=lat.p50_s * 1e3, p90_ms=lat.p90_s * 1e3,
    p99_ms=lat.p99_s * 1e3,
    hit_rate=cache.stats.hit_rate(),
    cache=cache.stats.as_dict(), router=router.stats.as_dict(),
    landmark_build_s=landmark_build_s, spot_checked=checked,
)

# ---- streamed-update scenario ------------------------------------
# small resident set so each update's eager refresh cost is visible
cache.clear()
hot = sorted({int(v) for v in srcs[:10]})[:6]
router.serve([Query(v) for v in hot])
feed = UpdateFeed(g, solver, cache=cache, landmarks=lm)
update_rows = []
for i in range(N_UPDATES):
    if i == 1:
        # an insertion: a brand-new cheap edge (improving by definition)
        u, v = int(perm[0]), int(perm[1])
        while v == u or ((g.src == u) & (g.dst == v)).any():
            v = int(rng.integers(0, g.n))
        upd = EdgeUpdate(u, v, 1.0)
    else:
        e = int(rng.integers(0, g.m))
        upd = EdgeUpdate(int(g.src[e]), int(g.dst[e]),
                         float(g.weight[e]) * 0.25)
    res = feed.apply(upd)
    fp = graph_fingerprint(g)
    cold_supersteps = 0
    identical = True
    for key, sol in cache.entries_for(fp):
        cold = solver.solve(Problem(g, SingleSource(key[1])))
        identical &= bool(np.array_equal(sol.state, cold.state))
        cold_supersteps += cold.metrics.supersteps
    update_rows.append(dict(
        improving=res.improving, inserted=res.inserted,
        warm_refreshes=res.warm_refreshes,
        warm_supersteps=res.warm_supersteps,
        cold_supersteps=cold_supersteps,
        bit_identical=identical,
        ok=bool(identical and res.improving
                and res.warm_supersteps < cold_supersteps),
    ))

# non-improving update: stale answers must be detected and re-solved
e = int(rng.integers(0, g.m))
res = feed.apply(EdgeUpdate(int(g.src[e]), int(g.dst[e]), 1e6))
fp = graph_fingerprint(g)
identical = True
for key, sol in cache.entries_for(fp):
    cold = solver.solve(Problem(g, SingleSource(key[1])))
    identical &= bool(np.array_equal(sol.state, cold.state))
nonimp = dict(
    improving=res.improving, invalidated=res.invalidated,
    cold_refreshes=res.cold_refreshes, bit_identical=identical,
    ok=bool(identical and not res.improving and res.cold_refreshes > 0),
)

out = dict(
    scale=SCALE, spec="%(spec)s", n_devices=8,
    serving=serving, updates=update_rows, non_improving=nonimp,
    ok=bool(serving["ok"] and all(r["ok"] for r in update_rows)
            and nonimp["ok"]),
)
print(json.dumps(out))
"""


def _run_child(child: str, timeout: int = 3000) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", child], env=env,
        capture_output=True, text=True, timeout=timeout,
    )
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-3000:])
    return json.loads(r.stdout.splitlines()[-1])


def run(
    scale: int = 10, quick: bool = False,
    spec: str = "delta:5+threadq/a2a",
) -> dict:
    return _run_child(CHILD % {
        "scale": scale, "quick": int(quick), "spec": spec,
    })


def main(
    scale: int = 10, quick: bool = False, json_path: str | None = None,
    spec: str = "delta:5+threadq/a2a",
) -> list[str]:
    out = run(scale, quick=quick, spec=spec)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1, sort_keys=True)
    assert out["ok"], out
    s = out["serving"]
    lines = [
        f"serving/rmat1_s{out['scale']}/{out['spec']}"
        f",{s['qps']:.1f}qps"
        f",p50={s['p50_ms']:.1f}ms,p90={s['p90_ms']:.1f}ms"
        f",p99={s['p99_ms']:.1f}ms,hit_rate={s['hit_rate']:.3f}"
        f",landmark={s['router']['landmark_served']}"
    ]
    for i, u in enumerate(out["updates"]):
        lines.append(
            f"serving/update{i}/"
            f"{'insert' if u['inserted'] else 'drop'}"
            f",warm_steps={u['warm_supersteps']}"
            f",cold_steps={u['cold_supersteps']}"
            f",identical={u['bit_identical']}"
        )
    n = out["non_improving"]
    lines.append(
        f"serving/non_improving,invalidated={n['invalidated']}"
        f",cold={n['cold_refreshes']},identical={n['bit_identical']}"
    )
    return lines


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small mix + scale 9 (CI trajectory job)")
    ap.add_argument("--scale", type=int, default=None)
    ap.add_argument("--spec", default="delta:5+threadq/a2a")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump the raw scenario dict as JSON")
    a = ap.parse_args()
    scale = a.scale if a.scale is not None else (9 if a.quick else 10)
    for line in main(scale, quick=a.quick, json_path=a.json, spec=a.spec):
        print(line)
