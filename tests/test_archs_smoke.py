"""Per-assigned-architecture smoke tests (deliverable f): a REDUCED
config of the same family runs one forward/train step on CPU with
correct output shapes and no NaNs.  Full configs are exercised only
via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, REGISTRY, get_arch
from repro.data import lm_batch, mind_batch, molecule_batch

LM_ARCHS = [a for a in ASSIGNED if REGISTRY[a].FAMILY == "lm"]
GNN_ARCHS = [a for a in ASSIGNED if REGISTRY[a].FAMILY == "gnn"]


def finite_tree(t):
    return all(bool(jnp.all(jnp.isfinite(x)))
               for x in jax.tree_util.tree_leaves(t)
               if jnp.issubdtype(x.dtype, jnp.floating))


def test_registry_complete():
    assert len(ASSIGNED) == 10
    assert "sssp" in REGISTRY
    for a in ASSIGNED:
        assert len(REGISTRY[a].SHAPES) == 4, a


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_arch_smoke(arch, key, topo1):
    from repro.models.lm import (
        decode_step, init_params, lm_loss, prefill_step,
    )

    cfg = get_arch(arch).make_config(reduced=True)
    p = init_params(key, cfg)
    batch = {k: jnp.asarray(v) for k, v in
             lm_batch(0, 4, 16, cfg.vocab).items()}
    loss, g = jax.value_and_grad(
        lambda pp: lm_loss(pp, batch, cfg, topo1)
    )(p)
    assert np.isfinite(float(loss)) and 1 < float(loss) < 10, arch
    assert finite_tree(g)
    # serve path: prefill + one decode step
    cache, logits = prefill_step(p, batch["tokens"], cfg, topo1, 32)
    assert logits.shape == (4, cfg.vocab)
    lg, cache2 = decode_step(
        p, cache, batch["tokens"][:, -1], 16, cfg, topo1
    )
    assert lg.shape == (4, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(lg, np.float32)))
    # full-config parameter accounting sanity (the assignment's sizes)
    full = get_arch(arch).make_config(reduced=False)
    declared = {
        "phi3.5-moe-42b-a6.6b": 41.9e9, "dbrx-132b": 131.6e9,
        "phi3-mini-3.8b": 3.8e9, "minitron-8b": 7.7e9,
        "minicpm3-4b": 4.1e9,
    }[arch]
    assert abs(full.n_params() - declared) / declared < 0.03, (
        arch, full.n_params()
    )


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_arch_smoke_molecule(arch, key):
    mod = get_arch(arch)
    cfg = mod.make_config(reduced=True, cell="molecule")
    batch = {k: jnp.asarray(v) for k, v in molecule_batch(
        0, 4, 10, 20, triplets=True, triplet_pad=128).items()}
    from repro.models.gnn import dimenet, egnn, gin, mace

    impl = {"mace": mace, "egnn": egnn, "dimenet": dimenet,
            "gin-tu": gin}[arch]
    p = impl.init_params(key, cfg)
    if arch == "gin-tu":
        from repro.configs.gin_tu import _molecule_loss

        loss_fn = lambda pp: _molecule_loss(pp, batch, cfg)
    else:
        loss_fn = lambda pp: impl.regression_loss(pp, batch, cfg)
    loss, g = jax.value_and_grad(loss_fn)(p)
    assert np.isfinite(float(loss)), arch
    assert finite_tree(g), arch


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_arch_smoke_flat(arch, key):
    """Node-classification on a small real topology."""
    from repro.data import gnn_flat_batch
    from repro.graph import small_world_graph
    from repro.models.gnn import dimenet, egnn, gin, mace

    mod = get_arch(arch)
    cfg = mod.make_config(reduced=True, cell="full_graph_sm")
    g = small_world_graph(120, seed=1)
    need_coords = arch != "gin-tu"
    need_tri = arch == "dimenet"
    batch = {k: jnp.asarray(v) for k, v in gnn_flat_batch(
        g, d_feat=cfg.d_in, n_classes=max(cfg.n_classes, 2),
        coords=need_coords, triplets=need_tri).items()}
    impl = {"mace": mace, "egnn": egnn, "dimenet": dimenet,
            "gin-tu": gin}[arch]
    p = impl.init_params(key, cfg)
    loss = impl.node_classification_loss(p, batch, cfg)
    assert np.isfinite(float(loss)), arch


def test_mind_arch_smoke(key):
    from repro.models import mind as mind_mod

    cfg = get_arch("mind").make_config(reduced=True)
    p = mind_mod.init_params(key, cfg)
    batch = {k: jnp.asarray(v) for k, v in
             mind_batch(0, 8, cfg).items()}
    loss, g = jax.value_and_grad(
        lambda pp: mind_mod.sampled_softmax_loss(pp, batch, cfg)
    )(p)
    assert np.isfinite(float(loss))
    assert abs(float(loss) - np.log(1 + cfg.n_negatives)) < 1.0
    assert finite_tree(g)
    caps = mind_mod.serve_interests(p, batch, cfg)
    assert caps.shape == (8, cfg.n_interests, cfg.embed_dim)
    # squash keeps capsule norms < 1
    assert float(jnp.max(jnp.linalg.norm(caps, axis=-1))) <= 1.0 + 1e-5
    sc = mind_mod.retrieval_scores(
        p, batch, jnp.arange(100, dtype=jnp.int32), cfg
    )
    assert sc.shape == (8, 100)
    # retrieval score == max over interests of dot products
    cand = jnp.take(p["item_table"], jnp.arange(100), axis=0)
    manual = jnp.max(jnp.einsum("bkd,nd->bkn", caps, cand), axis=1)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(manual),
                               rtol=1e-5)


def test_all_cells_constructible_single_device():
    """Every (arch × shape) cell builds: abstract args and sharding
    trees are structurally compatible (full lowering happens in the
    512-device dry-run)."""
    from repro.configs import all_cells
    from repro.launch.mesh import make_cpu_topology

    topo = make_cpu_topology(1)
    built = 0
    for arch, cell in all_cells():
        prog = get_arch(arch).make_cell(cell, topo)
        jax.tree_util.tree_map(lambda a, s: None, prog.args,
                               prog.in_shardings)
        built += 1
    assert built == 47  # 10 archs x 4 shapes + 7 sssp cells
