"""Fused superstep kernel + quantized sparse-exchange payloads.

Acceptance for the '/fused' and '/q:<dtype>' spec surface:

  * the Pallas kernel (interpret mode) is bit-identical to its
    pure-jnp oracle over randomized frontiers, including clipped fill
    rows and the ELL padding column;
  * '/fused' solves are bit-identical — state AND metrics — to the
    reference relax across the paper variant grid × {a2a, sparse};
  * quantized payloads ('/q:bf16', '/q:u16') converge to the exact
    least fixpoint bit-for-bit (the host repair loop certifies it),
    with the round-up-only encode invariant pinned at the primitive
    level;
  * the spec grammar round-trips both segments and rejects the
    compositions the engine cannot honor.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Problem, SingleSource, Solver, SolverConfig
from repro.core import dijkstra_reference, paper_variant_specs
from repro.core.frontier import (
    payload_plane_words,
    sparse_payload,
    unpack_combine,
)
from repro.kernels.superstep_fused import fused_superstep, fused_superstep_ref


def close(a, b):
    return np.allclose(
        np.where(np.isinf(a), -1, a), np.where(np.isinf(b), -1, b)
    )


@pytest.fixture(scope="module")
def mesh1():
    return jax.make_mesh((1,), ("data",))


# ------------------------------------------------------------- kernel


@pytest.mark.parametrize("trial", range(10))
def test_fused_kernel_matches_ref(trial):
    """Interpret-mode kernel vs the pure-jnp oracle over randomized
    frontiers: partial live counts, fill sentinels, +inf padding
    weights and the out-of-local padding column n_out."""
    r = np.random.default_rng(trial)
    R, W, n_local, n_out, F = 24, 4, 32, 48, 8
    dist = np.full(n_local + 1, np.inf, np.float32)
    hot = r.choice(n_local, 10, replace=False)
    dist[hot] = r.uniform(0.0, 9.0, 10).astype(np.float32)
    row_src = r.integers(0, n_local, R).astype(np.int32)
    col = r.integers(0, n_out + 1, (R, W)).astype(np.int32)
    wgt = np.where(
        r.random((R, W)) < 0.3, np.inf, r.uniform(0.1, 5.0, (R, W))
    ).astype(np.float32)
    k = int(r.integers(0, F + 1))
    row_idx = np.full(F, R, np.int32)  # compaction fill sentinel
    row_idx[:k] = r.choice(R, k, replace=False).astype(np.int32)
    out = fused_superstep(
        jnp.asarray(dist), jnp.asarray(row_idx), jnp.int32(k),
        jnp.asarray(row_src), jnp.asarray(col), jnp.asarray(wgt),
        n_out, interpret=True,
    )
    ref = fused_superstep_ref(
        jnp.asarray(dist), jnp.asarray(row_idx), jnp.asarray(row_src),
        jnp.asarray(col), jnp.asarray(wgt), n_out,
    )
    assert np.array_equal(np.asarray(out), np.asarray(ref)), trial


def test_fused_kernel_masks_rows_past_count():
    """Entries of row_idx past `count` point at real rows (post-clip)
    but must contribute nothing — the live-count mask, not the clip,
    is the correctness mechanism."""
    R, W, n_local, n_out = 4, 2, 4, 4
    dist = jnp.asarray([0.0, 1.0, 2.0, 3.0, np.inf], jnp.float32)
    row_src = jnp.asarray([0, 1, 2, 3], jnp.int32)
    col = jnp.asarray([[1, 2], [2, 3], [0, 4], [0, 1]], jnp.int32)
    wgt = jnp.ones((R, W), jnp.float32)
    # rows 2, 3 sit in the buffer past count=1 — only row 0 may land
    row_idx = jnp.asarray([0, 2, 3], jnp.int32)
    out = np.asarray(fused_superstep(
        dist, row_idx, jnp.int32(1), row_src, col, wgt, n_out,
        interpret=True,
    ))
    assert out[1] == 1.0 and out[2] == 1.0
    assert np.isinf(out[0]) and np.isinf(out[3])


# --------------------------------------------------- quantized payload


@pytest.mark.parametrize("payload", ["bf16", "u16"])
def test_quantized_payload_roundup_only(payload):
    """The encode invariant behind the repair loop's termination:
    decoded candidates are never below the exact candidate (errors
    are inflationary-only) and each destination segment's minimum
    survives bit-exactly."""
    r = np.random.default_rng(17)
    P_, n_local, slot_cap = 4, 16, 8
    for _ in range(50):
        C = np.full(P_ * n_local, np.inf, np.float32)
        # <= slot_cap hot candidates per destination segment: this
        # test pins the codec, not the overflow fallback
        for p in range(P_):
            k = int(r.integers(1, slot_cap + 1))
            hot = p * n_local + r.choice(n_local, k, replace=False)
            C[hot] = r.uniform(1.0, 50.0, k).astype(np.float32)
        exact, ov1 = sparse_payload(jnp.asarray(C), [], P_, slot_cap,
                                    np.float32(np.inf))
        quant, ov2 = sparse_payload(jnp.asarray(C), [], P_, slot_cap,
                                    np.float32(np.inf), payload=payload)
        assert not bool(ov1) and not bool(ov2)
        mine_e, _ = unpack_combine(
            jnp.asarray(exact), n_local, slot_cap, True,
            np.float32(np.inf), False)
        mine_q, _ = unpack_combine(
            jnp.asarray(quant), n_local, slot_cap, True,
            np.float32(np.inf), False, payload=payload)
        mine_e, mine_q = np.asarray(mine_e), np.asarray(mine_q)
        assert np.all(mine_q >= mine_e)               # round-up only
        assert mine_q.min() == mine_e.min()           # segment min exact
        assert quant.dtype == jnp.uint32


def test_payload_plane_words_quantized_fewer():
    """The words-per-destination accounting exchange_words stands on:
    both 16-bit codecs beat the exact (idx,val) planes, and the KLA
    level plane rides along un-quantized."""
    for slot_cap in (4, 8, 33):
        exact = payload_plane_words(slot_cap, False, "exact")
        bf16 = payload_plane_words(slot_cap, False, "bf16")
        u16 = payload_plane_words(slot_cap, False, "u16")
        assert exact == 2 * slot_cap
        assert bf16 == slot_cap + (slot_cap + 1) // 2 + 1
        assert u16 == slot_cap + (slot_cap + 1) // 2 + 2
        assert bf16 < exact
        # u16 carries one extra scale word, so it only wins once the
        # packed codes amortize it (any real slot_cap; ties at 4)
        assert u16 <= exact
        if slot_cap > 4:
            assert u16 < exact
        # level-bearing hierarchies add one exact f32 plane either way
        assert (payload_plane_words(slot_cap, True, "bf16")
                == bf16 + slot_cap)


@pytest.mark.parametrize("payload", ["bf16", "u16"])
def test_quantized_solve_exact_fixpoint(tiny_graphs, mesh1, payload):
    """/q:* solves certify the exact least fixpoint: final state is
    bit-identical to the exact-payload solver on every tiny graph."""
    for g in tiny_graphs:
        base = Solver(
            SolverConfig.from_spec("delta:5/sparse", chunk_size=64),
            mesh=mesh1,
        ).solve(Problem(g, SingleSource(0)))
        quant = Solver(
            SolverConfig.from_spec(
                f"delta:5/sparse/q:{payload}", chunk_size=64),
            mesh=mesh1,
        ).solve(Problem(g, SingleSource(0)))
        assert np.array_equal(base.state, quant.state)
        assert quant.metrics.converged
        assert quant.metrics.repair_sweeps >= 0
        assert base.metrics.repair_sweeps == 0


# ------------------------------------------------- engine equivalence


@pytest.mark.slow
@pytest.mark.parametrize("spec", paper_variant_specs())
def test_fused_bit_identical_across_grid(tiny_graphs, mesh1, spec):
    """Acceptance: '/fused' produces state AND metrics identical to
    the reference relax for every paper variant × {a2a, sparse}."""
    g = tiny_graphs[0]
    for exchange in ("a2a", "sparse"):
        ref = Solver(
            SolverConfig.from_spec(spec, exchange=exchange, chunk_size=64),
            mesh=mesh1,
        ).solve(Problem(g, SingleSource(0)))
        fused = Solver(
            SolverConfig.from_spec(
                spec, exchange=exchange, chunk_size=64,
                relax_impl="fused"),
            mesh=mesh1,
        ).solve(Problem(g, SingleSource(0)))
        assert np.array_equal(ref.state, fused.state), (spec, exchange)
        assert (ref.metrics.as_dict() == fused.metrics.as_dict()), (
            spec, exchange
        )
    assert close(dijkstra_reference(g, 0), ref.state), spec


def test_fused_quantized_compose(tiny_graphs, mesh1):
    """The two tentpole halves compose: '/fused/q:bf16' still lands on
    the exact fixpoint."""
    g = tiny_graphs[0]
    base = Solver(
        SolverConfig.from_spec("delta:5/sparse", chunk_size=64),
        mesh=mesh1,
    ).solve(Problem(g, SingleSource(0)))
    both = Solver(
        SolverConfig.from_spec("delta:5/sparse/fused/q:bf16",
                               chunk_size=64),
        mesh=mesh1,
    ).solve(Problem(g, SingleSource(0)))
    assert np.array_equal(base.state, both.state)
    assert close(dijkstra_reference(g, 0), both.state)


# -------------------------------------------------- property (hypothesis)


def test_quantized_property_random_graphs(mesh1):
    """Hypothesis sweep: on arbitrary random graphs the bf16-quantized
    solve equals the exact solve bit-for-bit (one fixed engine shape,
    compiled once — the test_frontier_property idiom)."""
    hyp = pytest.importorskip(
        "hypothesis", reason="optional dev dependency"
    )
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    from repro.graph.formats import Graph

    N, maxdeg = 24, 4

    @settings(
        max_examples=25, deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(st.data())
    def run(data):
        edges = data.draw(st.lists(
            st.tuples(
                st.integers(0, N - 1), st.integers(0, N - 1),
                st.integers(1, 31),
            ),
            min_size=1, max_size=N * maxdeg, unique_by=lambda e: e[:2],
        ))
        src = np.array([e[0] for e in edges], np.int64)
        dst = np.array([e[1] for e in edges], np.int64)
        w = np.array([e[2] for e in edges], np.float32)
        g = Graph(N, src, dst, w)
        base = Solver(
            SolverConfig.from_spec("delta:5/sparse", chunk_size=32),
            mesh=mesh1,
        ).solve(Problem(g, SingleSource(0)))
        quant = Solver(
            SolverConfig.from_spec("delta:5/sparse/q:bf16",
                                   chunk_size=32),
            mesh=mesh1,
        ).solve(Problem(g, SingleSource(0)))
        assert np.array_equal(base.state, quant.state)

    del hyp
    run()


# ------------------------------------------------------------ grammar


def test_spec_grammar_fused_and_quantized_roundtrip():
    cfg = SolverConfig.from_spec("delta:5/sparse/fused/q:bf16")
    assert cfg.relax_impl == "fused" and cfg.payload == "bf16"
    assert cfg.name == "delta:5+buffer/sparse/fused/q:bf16"
    assert SolverConfig.from_spec(cfg.name).name == cfg.name
    # bare /q defaults to bf16
    assert SolverConfig.from_spec("delta:5/sparse/q").payload == "bf16"
    # exact payload and ref impl stay silent in the name
    assert "/q" not in SolverConfig.from_spec("delta:5/sparse").name
    assert "/fused" not in SolverConfig.from_spec("delta:5/sparse").name


@pytest.mark.parametrize("bad", [
    "delta:5/sparse/fused/fused",      # duplicate segment
    "delta:5/sparse/fused:yes",        # /fused takes no argument
    "delta:5/sparse/q:",               # empty dtype
    "delta:5/sparse/q:f8",             # unknown codec
    "delta:5/sparse/q:bf16/q:u16",     # duplicate payload
])
def test_spec_grammar_rejects(bad):
    with pytest.raises(ValueError):
        SolverConfig.from_spec(bad)


def test_quantized_rejects_non_min_and_adapt_and_batch(tiny_graphs, mesh1):
    # engine level: only min-reduce processings may quantize
    from repro.api.problem import get_processing

    cfg = SolverConfig.from_spec("delta:5/sparse/q:u16")
    with pytest.raises(ValueError, match="min"):
        cfg.engine_config(get_processing("sswp"))
    # config level: /adapt and /q do not compose
    with pytest.raises(ValueError, match="adapt"):
        SolverConfig.from_spec("delta:5/sparse/adapt:rho/q:bf16")
    # solver level: batched solves bypass the repair loop -> rejected
    solver = Solver(
        SolverConfig.from_spec("delta:5/sparse/q:bf16", chunk_size=64),
        mesh=mesh1,
    )
    with pytest.raises(ValueError, match="quantized"):
        solver.solve_batch([
            Problem(tiny_graphs[0], SingleSource(0)),
            Problem(tiny_graphs[0], SingleSource(1)),
        ])


# ------------------------------------------------------ 8-device smoke

CHILD_FUSED = r"""
import numpy as np, jax
from repro.api import Problem, SingleSource, Solver, SolverConfig
from repro.core import dijkstra_reference
from repro.graph import rmat1

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
g = rmat1(9, seed=7)
ref = dijkstra_reference(g, 0)
base = Solver(SolverConfig.from_spec("delta:5/sparse", chunk_size=256),
              mesh=mesh).solve(Problem(g, SingleSource(0)))
fq = Solver(SolverConfig.from_spec("delta:5/sparse/fused/q:bf16",
                                   chunk_size=256),
            mesh=mesh).solve(Problem(g, SingleSource(0)))
assert np.allclose(np.where(np.isinf(ref), -1, ref),
                   np.where(np.isinf(base.state), -1, base.state))
assert np.array_equal(np.asarray(base.state), np.asarray(fq.state))
assert fq.metrics.exchange_bytes < base.metrics.exchange_bytes, (
    fq.metrics.exchange_bytes, base.metrics.exchange_bytes)
print("OK", base.metrics.exchange_bytes, fq.metrics.exchange_bytes)
"""


@pytest.mark.slow
def test_fused_quantized_8_devices():
    """8-rank smoke: '/fused/q:bf16' matches the exact sparse baseline
    bit-for-bit and moves strictly fewer exchange bytes."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", CHILD_FUSED], env=env,
        capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert r.stdout.startswith("OK")
