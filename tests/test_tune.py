"""repro.tune: the /adapt spec grammar, controller policies, adaptive
vs static bit-identity, retrace accounting, the offline auto-tuner +
tuned-spec cache, Router admission, and the launch CLI.

Single-device fast tests here; the 8-device adaptive smoke runs in a
subprocess (marked slow) like the other multi-device coverage.
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

import repro.api.solver as fac
from repro.api import Problem, SingleSource, Solver, SolverConfig
from repro.core import dijkstra_reference
from repro.graph import chain_fingerprint, graph_fingerprint, rmat1
from repro.serve import EdgeUpdate, Query, Router
from repro.tune import (
    AutoTuner,
    StaticPolicy,
    TunedRecord,
    TunedSpecCache,
    canonical_policy,
    make_tune_policy,
    policy_traits,
    register_tune_policy,
)


def close(a, b):
    return np.allclose(
        np.where(np.isinf(a), -1, a), np.where(np.isinf(b), -1, b)
    )


@pytest.fixture(scope="module")
def mesh1():
    return jax.make_mesh((1,), ("data",))


@pytest.fixture(scope="module")
def g8():
    return rmat1(8, seed=3)


# ------------------------------------------------------------- grammar


def test_adapt_spec_parses_and_round_trips():
    cfg = SolverConfig.from_spec("delta:5/sparse/adapt")
    assert cfg.adapt == "rho"  # bare /adapt defaults to rho
    assert cfg.exchange == "sparse"
    assert cfg.name == "delta:5+buffer/sparse/adapt:rho"
    assert SolverConfig.from_spec(cfg.name) == cfg

    cfg = SolverConfig.from_spec("delta:5/adapt:static")
    assert cfg.adapt == "static" and cfg.exchange == "a2a"
    assert SolverConfig.from_spec(cfg.name) == cfg

    # policy args canonicalize and survive the round trip
    cfg = SolverConfig.from_spec("delta:5/auto/adapt:rho:0.05@ebal")
    assert cfg.adapt == "rho:0.05" and cfg.partition == "ebal"
    assert cfg.name == "delta:5+buffer/auto/adapt:rho:0.05@ebal"
    assert SolverConfig.from_spec(cfg.name) == cfg

    # segment order is free: /adapt before the exchange parses too
    assert (SolverConfig.from_spec("delta:5/adapt:rho/sparse")
            == SolverConfig.from_spec("delta:5/sparse/adapt:rho"))


def test_adapt_spec_errors():
    with pytest.raises(ValueError, match="duplicate adapt"):
        SolverConfig.from_spec("delta:5/adapt/adapt:static")
    with pytest.raises(ValueError, match="empty adapt policy"):
        SolverConfig.from_spec("delta:5/adapt:")
    # typo'd segment gets a did-you-mean pointing at 'adapt'
    with pytest.raises(ValueError, match="did you mean 'adapt'"):
        SolverConfig.from_spec("delta:5/adpat")
    # unknown policy: did-you-mean from the policy registry
    with pytest.raises(ValueError, match="did you mean 'rho'"):
        SolverConfig.from_spec("delta:5/adapt:rh")
    with pytest.raises(ValueError, match="takes no argument"):
        SolverConfig.from_spec("delta:5/adapt:static:1")
    with pytest.raises(ValueError, match="adapt_window"):
        SolverConfig(adapt="rho", adapt_window=0)
    # adapt_window is engine-relevant only under /adapt: equality
    assert (SolverConfig.from_spec("delta:5/adapt", adapt_window=2)
            != SolverConfig.from_spec("delta:5/adapt", adapt_window=8))


def test_policy_registry():
    assert canonical_policy("rho") == "rho"
    assert canonical_policy(" rho:0.25 ") == "rho:0.25"
    assert policy_traits("rho") == dict(grows_cap=True,
                                        retunes_delta=True)
    assert policy_traits("static")["grows_cap"] is False
    # fresh instance per solve (policies may carry state)
    assert make_tune_policy("static") is not make_tune_policy("static")
    with pytest.raises(ValueError, match="target_frac"):
        make_tune_policy("rho:7.0")
    with pytest.raises(ValueError, match="float target fraction"):
        make_tune_policy("rho:wide")
    with pytest.raises(ValueError, match="invalid policy name"):
        register_tune_policy("a/b", lambda arg: StaticPolicy())


# ------------------------------------------- adaptive == static exact


@pytest.mark.parametrize("spec", [
    "delta:5/a2a",
    "delta:5+threadq/sparse",
    "delta:3/auto",
    "dijkstra/a2a",
    "delta:5 > chunk:delta:1 /sparse",
])
def test_adaptive_static_policy_is_bit_identical(g8, mesh1, spec):
    """/adapt:static runs the segmented engine with an unchanged
    schedule: state AND work metrics must match the classic loop."""
    prob = Problem(g8, SingleSource(0))
    st = Solver(spec, mesh=mesh1).solve(prob)
    ad = Solver(
        SolverConfig.from_spec(f"{spec}/adapt:static", adapt_window=3),
        mesh=mesh1,
    ).solve(prob)
    assert np.array_equal(st.state, ad.state)  # bit-identical
    assert st.metrics.supersteps == ad.metrics.supersteps
    assert st.metrics.commits == ad.metrics.commits
    assert st.metrics.relaxations == ad.metrics.relaxations
    assert ad.metrics.retraces == 0


def test_adaptive_rho_grows_cap_and_stays_exact(g8, mesh1):
    """From a deliberately tiny frontier_cap, rho must double its way
    out (retraces > 0) and still land on the exact fixpoint."""
    ref = dijkstra_reference(g8, 0)
    cfg = SolverConfig.from_spec(
        "delta:5/sparse/adapt:rho", frontier_cap=1
    )
    solver = Solver(cfg, mesh=mesh1)
    sol = solver.solve(Problem(g8, SingleSource(0)))
    assert close(ref, sol.state)
    assert sol.metrics.retraces > 0
    assert sol.metrics.converged
    st = solver.stats()["adapt"]
    assert st["solves"] == 1
    assert st["cap_growths"] > 0 and st["retraces"] > 0


def test_adaptive_solve_batch_raises(g8, mesh1):
    solver = Solver("delta:5/adapt", mesh=mesh1)
    probs = [Problem(g8, SingleSource(v)) for v in (0, 5)]
    with pytest.raises(ValueError, match="adaptive specs"):
        solver.solve_batch(probs)
    # a singleton batch routes through solve() and is fine
    (sol,) = solver.solve_batch(probs[:1])
    assert close(dijkstra_reference(g8, 0), sol.state)


# ------------------------------------------------- retrace accounting


def test_adaptive_solves_do_not_retrace_per_superstep(g8, mesh1):
    """The compile-once contract under /adapt: one solve traces at
    most a handful of segment engines (one per distinct frontier_cap),
    never one per superstep, and a repeat solve traces nothing."""
    cfg = SolverConfig.from_spec(
        "delta:5/sparse/adapt:rho", frontier_cap=2, adapt_window=2
    )
    solver = Solver(cfg, mesh=mesh1)
    prob = Problem(g8, SingleSource(0))
    t0 = fac.trace_count()
    sol = solver.solve(prob)
    first = fac.trace_count() - t0
    assert sol.metrics.supersteps > 4  # multiple segments ran
    assert 1 <= first <= 1 + sol.metrics.retraces
    assert first < sol.metrics.supersteps
    t1 = fac.trace_count()
    sol2 = solver.solve(prob)
    assert fac.trace_count() == t1  # warm: zero new traces
    assert np.array_equal(sol.state, sol2.state)


def test_engine_cache_info_counters(g8, mesh1, monkeypatch):
    info0 = fac.engine_cache_info()
    assert info0["capacity"] == fac._ENGINE_CACHE_SIZE
    # adaptive cap growth shows up in the process-wide counter
    Solver(
        SolverConfig.from_spec("delta:5/sparse/adapt:rho",
                               frontier_cap=1),
        mesh=mesh1,
    ).solve(Problem(g8, SingleSource(0)))
    assert fac.engine_cache_info()["adapt_retraces"] \
        > info0["adapt_retraces"]
    # shrink the cache: distinct static configs must evict LRU-style
    monkeypatch.setattr(fac, "_ENGINE_CACHE_SIZE", 2)
    fac.engine_cache_clear()
    ev0 = fac.engine_cache_info()["evictions"]
    for delta in (2, 3, 5, 7):
        Solver(f"delta:{delta}/a2a", mesh=mesh1).solve(
            Problem(g8, SingleSource(0))
        )
        assert fac.engine_cache_info()["size"] <= 2
    assert fac.engine_cache_info()["evictions"] > ev0


def test_engine_cache_key_covers_controller_config(g8, mesh1):
    """Same spec with and without /adapt must be distinct engines —
    the cache key includes adapt_window via EngineConfig."""
    fac.engine_cache_clear()
    Solver("delta:5/a2a", mesh=mesh1).solve(
        Problem(g8, SingleSource(0))
    )
    size_static = fac.engine_cache_info()["size"]
    Solver("delta:5/a2a/adapt:static", mesh=mesh1).solve(
        Problem(g8, SingleSource(0))
    )
    assert fac.engine_cache_info()["size"] > size_static


# ------------------------------------------------------- spec lint


def test_spec_check_adaptive_rules():
    from repro.analyze.spec_check import check_config, explain_config

    rules = {f.rule for f in check_config("delta:5/sparse/adapt:static")}
    assert "adapt-no-cap-growth" in rules
    rules = {f.rule for f in check_config("dijkstra/a2a/adapt:rho")}
    assert "adapt-nothing-to-tune" in rules
    rules = {f.rule for f in check_config(
        "delta:5 > chunk:topk:4 /a2a/adapt:rho"
    )}
    assert "adapt-topk-drain" in rules
    # a sensible adaptive spec trips none of the adapt rules
    rules = {f.rule for f in check_config("delta:5/sparse/adapt:rho")}
    assert not {r for r in rules if r.startswith("adapt-")}
    plan = explain_config("delta:5/sparse/adapt:rho")
    assert "controller: adapt:rho" in plan
    assert "frontier_cap" in plan


# ------------------------------------------------------- auto-tuner


def test_autotuner_search_and_cache(g8, mesh1):
    tuner = AutoTuner(mesh1, quick=True, pilot_iters=400)
    rec = tuner.search(g8)
    assert rec.spec and rec.objective == "model"
    # leaderboard is score-sorted with the winner on top
    scores = [r["score"] for r in rec.leaderboard]
    assert scores == sorted(scores)
    assert rec.leaderboard[0]["spec"] == rec.spec
    assert tuner.pilots_run == len(rec.leaderboard)
    # tune() is a cache hit: no new pilots, production config returned
    n = tuner.pilots_run
    cfg = tuner.tune(g8)
    assert tuner.pilots_run == n
    assert cfg == SolverConfig.from_spec(rec.spec)
    assert cfg.max_iters == SolverConfig().max_iters  # not pilot cap


def test_autotuner_objective_validation(mesh1):
    with pytest.raises(ValueError, match="did you mean 'supersteps'"):
        AutoTuner(mesh1, objective="superstep")


def test_tuned_cache_chain_fingerprint_invalidation(mesh1):
    """A streamed update moves the graph's fingerprint, so the tuned
    record stops matching and the next tune() re-searches."""
    g = rmat1(8, seed=9)  # private: chain_fingerprint mutates registry
    tuner = AutoTuner(mesh1, quick=True, pilot_iters=400)
    tuner.search(g)
    assert graph_fingerprint(g) in tuner.cache
    chain_fingerprint(g, EdgeUpdate(0, 1, 0.5).record())
    assert graph_fingerprint(g) not in tuner.cache
    n = tuner.pilots_run
    tuner.tune(g)
    assert tuner.pilots_run > n  # cache miss -> fresh search


def test_tuned_cache_save_load_invalidate(tmp_path):
    cache = TunedSpecCache()
    rec = TunedRecord(
        spec="delta:10/sparse", objective="model", score=1.5,
        fingerprint=(1, 2, 3),
        leaderboard=[dict(spec="delta:10/sparse", score=1.5)],
    )
    cache.put(rec)
    path = str(tmp_path / "tuned.json")
    cache.save(path)
    back = TunedSpecCache.load(path)
    assert len(back) == 1 and (1, 2, 3) in back
    got = back.get((1, 2, 3))
    assert got.spec == rec.spec and got.fingerprint == (1, 2, 3)
    assert back.invalidate((1, 2, 3)) and len(back) == 0
    assert not back.invalidate((1, 2, 3))


# ------------------------------------------------------- serve + CLI


def test_router_consults_tuned_cache(g8, mesh1):
    ref = dijkstra_reference(g8, 0)
    solver = Solver("delta:5+threadq/a2a", mesh=mesh1)
    tuned = TunedSpecCache()
    tuned.put(TunedRecord(
        spec="delta:10+threadq/a2a", objective="model", score=0.0,
        fingerprint=tuple(graph_fingerprint(g8)),
    ))
    router = Router(solver, g8, tuned=tuned, max_batch=4)
    answers = router.serve([Query(0), Query(0, target=5), Query(7)])
    assert router.stats.tuned_batches == 1
    assert close(ref, answers[0].solution.state)
    assert answers[1].distance == answers[0].solution.distance_to(5)
    # the tuned solver is memoized, and cache keys carry its name —
    # a second flush is a tuned-solver cache hit, not a re-solve
    n = router.stats.batched_solves
    answers = router.serve([Query(0)])
    assert router.stats.batched_solves == n
    assert answers[0].served_by == "cache"
    # a record matching the default spec routes to the default solver
    tuned.put(TunedRecord(
        spec=solver.config.name, objective="model", score=0.0,
        fingerprint=tuple(graph_fingerprint(g8)),
    ))
    t = router.stats.tuned_batches
    router.serve([Query(3)])
    assert router.stats.tuned_batches == t


def test_router_without_tuned_cache_unchanged(g8, mesh1):
    solver = Solver("delta:5+threadq/a2a", mesh=mesh1)
    router = Router(solver, g8)
    answers = router.serve([Query(0)])
    assert answers[0].served_by == "batch"
    assert router.stats.tuned_batches == 0


def test_launch_tune_cli_roundtrip(tmp_path, capsys):
    from repro.launch.tune import main

    cache = str(tmp_path / "TUNE_cache.json")
    export = str(tmp_path / "export.json")
    main(["--search", "--quick", "--graph", "rmat1", "--scale", "8",
          "--pilot-iters", "400", "--cache", cache])
    main(["--inspect", "--export", export, "--cache", cache])
    out = capsys.readouterr().out
    assert "[tune] searching" in out and "exported 1 records" in out
    back = TunedSpecCache.load(export)
    assert len(back) == 1
    rec = back.records()[0]
    assert SolverConfig.from_spec(rec.spec)  # parseable winner


# ------------------------------------------------- 8-device subprocess

CHILD_ADAPT = r"""
import numpy as np, jax, warnings
from repro.api import Problem, SingleSource, Solver, SolverConfig
from repro.core import dijkstra_reference
from repro.graph import rmat1

warnings.simplefilter("ignore", RuntimeWarning)
assert jax.device_count() == 8, jax.device_count()
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
g = rmat1(8, seed=7)
ref = dijkstra_reference(g, 0)
prob = Problem(g, SingleSource(0))
static = Solver("delta:5/sparse", mesh=mesh).solve(prob)
solver = Solver(
    SolverConfig.from_spec("delta:5/sparse/adapt:rho", frontier_cap=2),
    mesh=mesh,
)
sol = solver.solve(prob)
assert np.allclose(np.where(np.isinf(ref), -1, ref),
                   np.where(np.isinf(sol.state), -1, sol.state))
assert sol.metrics.converged
assert sol.metrics.retraces > 0, sol.metrics.retraces
# exactness across ranks: adaptive fixpoint == static fixpoint, bitwise
assert np.array_equal(sol.state, static.state)
eq = Solver("delta:5/sparse/adapt:static", mesh=mesh).solve(prob)
assert np.array_equal(eq.state, static.state)
assert eq.metrics.supersteps == static.metrics.supersteps
print("ADAPT8_OK", sol.metrics.supersteps, sol.metrics.retraces)
"""


@pytest.mark.slow
def test_adaptive_eight_device_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", CHILD_ADAPT], env=env,
        capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "ADAPT8_OK" in r.stdout
