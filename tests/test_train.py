"""Training substrate: AdamW, schedules, clipping, compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="optional dev dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.train import (
    AdamWConfig, TrainConfig, apply_updates, build_train_step,
    clip_by_global_norm, compression, global_norm, init_state,
    init_train_state, warmup_cosine,
)


def test_adamw_first_step_analytic():
    """After one step with wd=0, update = -lr * sign-ish(g):
    m_hat/(sqrt(v_hat)+eps) == g/(|g|+eps)."""
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=1e9)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, -0.25])}
    st_ = init_state(p, cfg)
    p2, _, _ = apply_updates(p, g, st_, cfg, jnp.float32(1.0))
    expected = np.asarray([1.0, -2.0]) - 0.1 * np.sign([0.5, -0.25])
    np.testing.assert_allclose(np.asarray(p2["w"]), expected, atol=1e-5)


def test_weight_decay_direction():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5, clip_norm=1e9)
    p = {"w": jnp.asarray([10.0])}
    g = {"w": jnp.asarray([0.0])}
    st_ = init_state(p, cfg)
    p2, _, _ = apply_updates(p, g, st_, cfg, jnp.float32(1.0))
    assert float(p2["w"][0]) < 10.0  # decays toward zero


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    norm = float(global_norm(g))
    np.testing.assert_allclose(norm, 10.0, rtol=1e-6)
    clipped, n = clip_by_global_norm(g, 5.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 5.0,
                               rtol=1e-5)
    clipped2, _ = clip_by_global_norm(g, 100.0)
    np.testing.assert_allclose(np.asarray(clipped2["a"]),
                               np.asarray(g["a"]))


def test_warmup_cosine_shape():
    s = lambda t: float(warmup_cosine(jnp.int32(t), warmup_steps=10,
                                      total_steps=100))
    assert s(0) < s(5) < s(9)                 # warming up
    assert abs(s(10) - 1.0) < 0.1             # peak
    assert s(50) < s(10) and s(99) < s(50)    # decaying
    assert s(99) >= 0.1 * 0.9                 # floor


def test_master_fp32_roundtrip(key):
    """bf16 params keep an fp32 master: tiny updates accumulate."""
    cfg = AdamWConfig(lr=1e-5, weight_decay=0.0, clip_norm=1e9)
    p = {"w": jnp.ones((8,), jnp.bfloat16)}
    st_ = init_state(p, cfg)
    g = {"w": jnp.full((8,), 1e-3, jnp.bfloat16)}
    master0 = float(st_["master"]["w"][0])
    for _ in range(3):
        p, st_, _ = apply_updates(p, g, st_, cfg, jnp.float32(1.0))
    assert float(st_["master"]["w"][0]) != master0
    assert p["w"].dtype == jnp.bfloat16


@given(
    vals=st.lists(
        st.floats(-100, 100, allow_nan=False, width=32),
        min_size=2, max_size=32,
    )
)
@settings(max_examples=40, deadline=None)
def test_int8_quantization_error_bound(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, scale = compression.quantize_int8(x)
    err = np.abs(np.asarray(compression.dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_error_feedback_preserves_signal():
    """Sum over steps of (dequantized + final error) == sum of inputs:
    the EF compressor never loses mass, only delays it."""
    rng = np.random.default_rng(0)
    grads = [jnp.asarray(
        rng.normal(size=(64,)) * 10.0 ** float(rng.integers(-3, 2)),
        jnp.float32) for _ in range(20)]
    err = jnp.zeros((64,), jnp.float32)
    total_sent = jnp.zeros((64,), jnp.float32)
    for g in grads:
        q, s, err = compression.ef_compress(g, err)
        total_sent = total_sent + compression.dequantize_int8(q, s)
    true_total = sum(np.asarray(g) for g in grads)
    np.testing.assert_allclose(
        np.asarray(total_sent + err), true_total, rtol=1e-4, atol=1e-4
    )


def test_microbatch_accumulation_equivalence(key, topo1):
    """1 batch of 8 == 4 microbatches of 2 (up to accumulation fp)."""
    from repro.models.lm import LMConfig, init_params, lm_loss

    cfg = LMConfig(name="t", n_layers=1, d_model=32, n_heads=2,
                   n_kv_heads=1, d_ff=64, vocab=61,
                   param_dtype="float32", loss_chunk=8)
    p0 = init_params(key, cfg)
    toks = jax.random.randint(key, (8, 17), 0, 61)
    batch = {"tokens": toks[:, :16], "labels": toks[:, 1:]}
    outs = []
    for mb in (1, 4):
        tc = TrainConfig(adamw=AdamWConfig(lr=1e-2), microbatches=mb,
                         warmup_steps=1, total_steps=10)
        fn = build_train_step(
            lambda pp, b: lm_loss(pp, b, cfg, topo1), tc
        )
        p, _, m = fn(p0, init_train_state(p0, tc), batch, jnp.int32(0))
        outs.append((p, float(m["loss"])))
    (p1, l1), (p4, l4) = outs
    assert abs(l1 - l4) < 1e-3
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


def test_compressed_accum_close_to_exact(key, topo1):
    from repro.models.lm import LMConfig, init_params, lm_loss

    cfg = LMConfig(name="t", n_layers=1, d_model=32, n_heads=2,
                   n_kv_heads=1, d_ff=64, vocab=61,
                   param_dtype="float32", loss_chunk=8)
    p0 = init_params(key, cfg)
    toks = jax.random.randint(key, (8, 17), 0, 61)
    batch = {"tokens": toks[:, :16], "labels": toks[:, 1:]}
    ps = []
    for comp in (False, True):
        tc = TrainConfig(adamw=AdamWConfig(lr=1e-2), microbatches=4,
                         compress_accum=comp, warmup_steps=1,
                         total_steps=10)
        fn = build_train_step(
            lambda pp, b: lm_loss(pp, b, cfg, topo1), tc
        )
        p, _, _ = fn(p0, init_train_state(p0, tc), batch, jnp.int32(0))
        ps.append(p)
    # int8 accumulation stays close to exact accumulation (atol covers
    # quantization noise on near-zero AdamW sign-like updates)
    for a, b in zip(jax.tree_util.tree_leaves(ps[0]),
                    jax.tree_util.tree_leaves(ps[1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0.2, atol=2.5e-2)
