"""Distributed EAGM engine, driven through the repro.api facade
(single-device mesh; the multi-device semantics run in
tests/test_distributed_subprocess.py)."""

import jax
import numpy as np
import pytest

from repro.api import (
    EveryVertex, ExplicitSources, Problem, SingleSource, Solver,
    SolverConfig,
)
from repro.core import dijkstra_reference
from repro.graph import partition_1d


def close(a, b):
    return np.allclose(
        np.where(np.isinf(a), -1, a), np.where(np.isinf(b), -1, b)
    )


@pytest.fixture(scope="module")
def mesh1():
    return jax.make_mesh((1,), ("data",))


VARIANTS = [
    ("chaotic", "buffer"), ("chaotic", "threadq"), ("chaotic", "numaq"),
    ("delta:5", "buffer"), ("delta:5", "threadq"), ("delta:20", "numaq"),
    ("kla:1", "buffer"), ("kla:2", "threadq"), ("kla:2", "numaq"),
    ("dijkstra", "buffer"),
]


@pytest.mark.parametrize("root,variant", VARIANTS)
def test_sssp_variants_match_oracle(tiny_graphs, mesh1, root, variant):
    g = tiny_graphs[0]
    ref = dijkstra_reference(g, 0)
    solver = Solver(
        SolverConfig(root=root, variant=variant, chunk_size=64), mesh=mesh1
    )
    sol = solver.solve(Problem(g, SingleSource(0)))
    assert close(ref, sol.state), f"{root}+{variant}"
    assert sol.metrics.supersteps > 0 and sol.metrics.commits > 0


@pytest.mark.parametrize("exchange", ["a2a", "pmin", "sparse", "auto"])
def test_exchange_paths_agree(tiny_graphs, mesh1, exchange):
    g = tiny_graphs[1]
    ref = dijkstra_reference(g, 0)
    solver = Solver(f"delta:5+buffer/{exchange}", mesh=mesh1)
    sol = solver.solve(Problem(g, SingleSource(0)))
    assert close(ref, sol.state)


def test_stale_workitems_are_harmless(tiny_graphs, mesh1):
    """Monotonicity (paper §II): duplicate/overestimated workitems in
    the initial set cost work but cannot corrupt the fixpoint."""
    g = tiny_graphs[0]
    ref = dijkstra_reference(g, 0)
    rng = np.random.default_rng(1)
    extras = [
        (int(v), float(ref[v] + rng.uniform(0.5, 50)), 0)
        for v in rng.integers(0, g.n, 10)
        if np.isfinite(ref[v])
    ]
    solver = Solver("delta:5+buffer", mesh=mesh1)
    sol = solver.solve(
        Problem(g, ExplicitSources([(0, 0.0, 0)] + extras))
    )
    assert close(ref, sol.state)


def test_bfs(tiny_graphs, mesh1):
    g = tiny_graphs[3]
    # BFS oracle: Dijkstra on unit weights
    from repro.graph.formats import Graph

    g1 = Graph(g.n, g.src, g.dst, np.ones(g.m, np.float32))
    ref = dijkstra_reference(g1, 0)
    solver = Solver("delta:1+buffer", mesh=mesh1)
    sol = solver.solve(Problem(g, SingleSource(0), processing="bfs"))
    assert close(ref, sol.state)


def test_connected_components(mesh1):
    """CC by min-label propagation vs union-find."""
    rng = np.random.default_rng(4)
    n, m = 120, 140
    from repro.graph.formats import Graph

    g = Graph(
        n, rng.integers(0, n, m), rng.integers(0, n, m),
        np.ones(m, np.float32),
    ).symmetrized().deduplicated()

    parent = list(range(n))

    def find(a):
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    for u, v in zip(g.src, g.dst):
        ra, rb = find(int(u)), find(int(v))
        if ra != rb:
            parent[ra] = rb
    # canonical label = min vertex id in component
    comp_min = {}
    for v in range(n):
        r = find(v)
        comp_min[r] = min(comp_min.get(r, v), v)
    ref = np.array([comp_min[find(v)] for v in range(n)], np.float64)

    solver = Solver("chaotic+buffer", mesh=mesh1)
    sol = solver.solve(Problem(g, EveryVertex(), processing="cc"))
    assert np.array_equal(
        sol.state.astype(np.int64), ref.astype(np.int64)
    )


def test_widest_path(tiny_graphs, mesh1):
    """SSWP vs max-min Dijkstra oracle."""
    import heapq

    from repro.graph.formats import coo_to_csr

    g = tiny_graphs[0]
    csr = coo_to_csr(g)
    width = np.full(g.n, -np.inf)
    width[0] = np.inf
    visited = np.zeros(g.n, bool)
    heap = [(-np.float64(np.inf), 0)]
    while heap:
        nw, v = heapq.heappop(heap)
        w = -nw
        if visited[v]:
            continue
        visited[v] = True
        nbrs, ws = csr.neighbors(v)
        for u, ew in zip(nbrs, ws):
            cand = min(w, float(ew))
            if cand > width[u]:
                width[u] = cand
                heapq.heappush(heap, (-cand, int(u)))

    solver = Solver("chaotic+buffer", mesh=mesh1)
    sol = solver.solve(Problem(g, SingleSource(0), processing="sswp"))
    assert close(width, sol.state)


def test_metrics_tradeoff(tiny_graphs, mesh1):
    """The paper's central tradeoff on the engine: stronger ordering
    => fewer relaxations, more supersteps."""
    g = tiny_graphs[0]
    res = {}
    for root in ["chaotic", "delta:20", "dijkstra"]:
        solver = Solver(SolverConfig(root=root), mesh=mesh1)
        sol = solver.solve(Problem(g, SingleSource(0)))
        res[root] = sol.metrics
    assert res["dijkstra"].relaxations <= res["delta:20"].relaxations
    assert res["delta:20"].relaxations <= res["chaotic"].relaxations
    assert res["dijkstra"].supersteps >= res["delta:20"].supersteps
    assert res["delta:20"].supersteps >= res["chaotic"].supersteps


def test_legacy_run_distributed_shim(tiny_graphs, mesh1):
    """The deprecated entry point keeps working and agrees with the
    facade."""
    from repro.core import (
        EngineConfig, make_policy, run_distributed, sssp_sources,
    )

    g = tiny_graphs[0]
    ref = dijkstra_reference(g, 0)
    pg = partition_1d(g, 1)
    cfg = EngineConfig(policy=make_policy("delta:5", "buffer"))
    with pytest.deprecated_call():
        d, m = run_distributed(pg, mesh1, cfg, sssp_sources(0))
    assert close(ref, d)
    assert m.supersteps > 0
