"""repro.api facade: compile-once caching, batched sources, warm
restarts, config parsing and the processing registry."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as api
from repro.api import (
    EveryVertex, ExplicitSources, MultiSource, Problem, SingleSource,
    Solver, SolverConfig, as_source_spec, get_processing,
    register_processing,
)
from repro.core import SSSP, dijkstra_reference
from repro.core.processing import ProcessingFn


def close(a, b):
    return np.allclose(
        np.where(np.isinf(a), -1, a), np.where(np.isinf(b), -1, b)
    )


@pytest.fixture(scope="module")
def mesh1():
    return jax.make_mesh((1,), ("data",))


@pytest.fixture(scope="module")
def solver(mesh1):
    return Solver("delta:5+threadq/a2a", mesh=mesh1)


# ---------------------------------------------------------------- config


def test_from_spec_full():
    c = SolverConfig.from_spec("delta:5+threadq/pmin")
    assert (c.root, c.variant, c.exchange) == ("delta:5", "threadq", "pmin")


def test_from_spec_sparse_modes():
    c = SolverConfig.from_spec("delta:5+threadq/sparse", frontier_cap=64)
    assert c.exchange == "sparse" and c.frontier_cap == 64
    c = SolverConfig.from_spec("kla:2/auto")
    assert (c.root, c.variant, c.exchange) == ("kla:2", "buffer", "auto")


def test_from_spec_defaults_and_overrides():
    c = SolverConfig.from_spec("kla:2")
    assert (c.root, c.variant, c.exchange) == ("kla:2", "buffer", "a2a")
    c = SolverConfig.from_spec("chaotic+nodeq", chunk_size=64)
    assert c.variant == "nodeq" and c.chunk_size == 64


@pytest.mark.parametrize(
    "bad",
    [
        dict(root="nosuch:1"),
        dict(variant="warpq"),
        dict(exchange="rdma"),
        dict(chunk_size=0),
        dict(max_iters=0),
        dict(frontier_cap=0),
        dict(relax_impl="cuda"),
    ],
)
def test_config_validation(bad):
    with pytest.raises(ValueError):
        SolverConfig(**bad)


def test_config_is_hashable_and_frozen():
    c = SolverConfig.from_spec("delta:5+threadq/a2a")
    assert hash(c) == hash(SolverConfig.from_spec("delta:5+threadq/a2a"))
    with pytest.raises(dataclasses.FrozenInstanceError):
        c.root = "chaotic"


# ------------------------------------------------------------- sources


def test_source_spec_coercion():
    assert as_source_spec(3) == SingleSource(3)
    assert as_source_spec([1, 2]) == MultiSource((1, 2))
    spec = as_source_spec([(1, 0.5, 0)])
    assert isinstance(spec, ExplicitSources)
    # numpy integers (e.g. drawn from rng.integers) coerce too
    assert as_source_spec(np.int64(3)) == SingleSource(3)
    assert as_source_spec(np.array([1, 2])) == MultiSource((1, 2))
    assert as_source_spec([np.int32(1), np.int32(2)]) == MultiSource((1, 2))


def test_source_defaults_per_processing(tiny_graphs):
    g = tiny_graphs[0]
    assert Problem(g, SingleSource(0)).source_items() == [(0, 0.0, 0)]
    assert Problem(g, SingleSource(4), processing="sswp").source_items() \
        == [(4, float("inf"), 0)]
    cc = Problem(g, EveryVertex(), processing="cc").source_items()
    assert cc[7] == (7, 7.0, 0) and len(cc) == g.n


def test_source_out_of_range(tiny_graphs):
    g = tiny_graphs[0]
    with pytest.raises(ValueError):
        Problem(g, SingleSource(g.n)).source_items()


def test_register_processing(tiny_graphs, mesh1):
    """A user-registered processing fn runs through the same engine:
    SSSP with doubled edge weights == 2x the SSSP distances."""
    from repro.api.problem import _REGISTRY

    doubled = ProcessingFn(
        name="sssp2x",
        edge_update=lambda s, w: s + 2.0 * w,
        better=lambda a, b: a < b,
        reduce=jnp.minimum,
        worst=float("inf"),
    )
    try:
        register_processing(doubled)
        assert get_processing("sssp2x") is doubled
        with pytest.raises(ValueError):
            register_processing(
                ProcessingFn(
                    name="sssp2x",
                    edge_update=lambda s, w: s,
                    better=lambda a, b: a < b,
                    reduce=jnp.minimum,
                    worst=float("inf"),
                )
            )
        g = tiny_graphs[0]
        solver = Solver("delta:5+buffer", mesh=mesh1)
        ref = solver.solve(Problem(g, SingleSource(0))).state
        sol = solver.solve(Problem(g, SingleSource(0), processing="sssp2x"))
        assert close(2.0 * ref, sol.state)
    finally:
        _REGISTRY.pop("sssp2x", None)  # don't leak into other tests


# --------------------------------------------------------- compile-once


def test_solve_compiles_once(tiny_graphs, solver):
    g = tiny_graphs[0]
    solver.solve(Problem(g, SingleSource(0)))  # warm the cache
    before = api.trace_count()
    s1 = solver.solve(Problem(g, SingleSource(1)))
    s2 = solver.solve(Problem(g, SingleSource(2)))
    assert api.trace_count() == before, "re-traced on identical shapes"
    assert close(dijkstra_reference(g, 1), s1.state)
    assert close(dijkstra_reference(g, 2), s2.state)


def test_solve_batch_compiles_once(tiny_graphs, solver):
    g = tiny_graphs[0]
    mk = lambda vs: [Problem(g, SingleSource(v)) for v in vs]
    solver.solve_batch(mk([0, 1, 2]))  # warm the B=3 engine
    before = api.trace_count()
    sols = solver.solve_batch(mk([3, 4, 5]))
    assert api.trace_count() == before, "batched engine re-traced"
    assert len(sols) == 3


def test_engine_cache_shared_across_solvers(tiny_graphs, mesh1):
    g = tiny_graphs[0]
    Solver("delta:7+buffer", mesh=mesh1).solve(Problem(g, SingleSource(0)))
    before = api.trace_count()
    Solver("delta:7+buffer", mesh=mesh1).solve(Problem(g, SingleSource(1)))
    assert api.trace_count() == before


# -------------------------------------------------------------- batching


def test_solve_batch_matches_per_query(tiny_graphs, solver):
    g = tiny_graphs[1]
    vs = [0, 5, 11, 17]
    batched = solver.solve_batch([Problem(g, SingleSource(v)) for v in vs])
    for v, sol in zip(vs, batched):
        single = solver.solve(Problem(g, SingleSource(v)))
        assert close(single.state, sol.state), f"source {v}"
        assert close(dijkstra_reference(g, v), sol.state), f"source {v}"


def test_solve_batch_rejects_mixed_graphs(tiny_graphs, solver):
    with pytest.raises(ValueError):
        solver.solve_batch(
            [Problem(tiny_graphs[0], SingleSource(0)),
             Problem(tiny_graphs[1], SingleSource(0))]
        )


def test_solve_batch_singleton_and_empty(tiny_graphs, solver):
    g = tiny_graphs[0]
    assert solver.solve_batch([]) == []
    [sol] = solver.solve_batch([Problem(g, SingleSource(0))])
    assert close(dijkstra_reference(g, 0), sol.state)


# ---------------------------------------------------------- warm restart


def test_resolve_after_weight_decrease(tiny_graphs, solver):
    """Self-stabilizing warm restart: after cheapening some edges the
    previous solution stabilizes to the new Dijkstra fixpoint in fewer
    supersteps than a cold solve of the perturbed graph."""
    g = tiny_graphs[0]
    sol = solver.solve(Problem(g, SingleSource(0)))

    g2 = dataclasses.replace(g, weight=g.weight.copy(), name="perturbed")
    rng = np.random.default_rng(7)
    g2.weight[rng.integers(0, g2.m, 25)] *= 0.25
    ref2 = dijkstra_reference(g2, 0)

    warm = solver.resolve(sol, graph=g2)
    cold = solver.solve(Problem(g2, SingleSource(0)))
    assert close(ref2, warm.state)
    assert warm.metrics.supersteps < cold.metrics.supersteps, (
        warm.metrics, cold.metrics
    )


def test_resolve_added_source(tiny_graphs, solver):
    g = tiny_graphs[0]
    sol = solver.solve(Problem(g, SingleSource(0)))
    warm = solver.resolve(sol, SingleSource(9))
    ref = np.minimum(dijkstra_reference(g, 0), dijkstra_reference(g, 9))
    assert close(ref, warm.state)


def test_resolve_noop_is_stable(tiny_graphs, solver):
    """Resolving with no perturbation terminates immediately at the
    same fixpoint (the bootstrap sweep finds nothing pending)."""
    g = tiny_graphs[0]
    sol = solver.solve(Problem(g, SingleSource(0)))
    warm = solver.resolve(sol)
    assert close(sol.state, warm.state)
    assert warm.metrics.supersteps <= 2  # bootstrap + empty drain


def test_resolve_sswp(tiny_graphs, mesh1):
    """Warm restart under the max-min semiring: widening an edge can
    only improve capacities, so the prior solution is a valid start."""
    g = tiny_graphs[0]
    solver = Solver("chaotic+buffer", mesh=mesh1)
    sol = solver.solve(Problem(g, SingleSource(0), processing="sswp"))
    g2 = dataclasses.replace(g, weight=g.weight.copy(), name="wider")
    rng = np.random.default_rng(3)
    g2.weight[rng.integers(0, g2.m, 20)] *= 4.0
    warm = solver.resolve(sol, graph=g2)
    cold = solver.solve(Problem(g2, SingleSource(0), processing="sswp"))
    assert close(cold.state, warm.state)


# ------------------------------------------------------------- plumbing


def test_partition_memo_sees_inplace_mutation(tiny_graphs, solver):
    """In-place edge perturbation must invalidate the partition memo
    (same object identity, different content)."""
    g = tiny_graphs[2]
    ref = dijkstra_reference(g, 0)
    assert close(ref, solver.solve(Problem(g, SingleSource(0))).state)
    old = g.weight.copy()
    try:
        g.weight *= 2.0  # mutate in place: id(g) unchanged
        sol = solver.solve(Problem(g, SingleSource(0)))
        assert close(2.0 * ref, sol.state)
    finally:
        g.weight[:] = old  # tiny_graphs is session-scoped


def test_mesh_partition_mismatch_raises(tiny_graphs, mesh1):
    from repro.graph import partition_1d

    pg = partition_1d(tiny_graphs[0], 2)
    with pytest.raises(ValueError):
        Solver(mesh=mesh1).solve(Problem(pg, SingleSource(0)))


def test_one_shot_solve(tiny_graphs, mesh1):
    g = tiny_graphs[0]
    sol = api.solve(Problem(g, SingleSource(0)), "delta:5", mesh=mesh1)
    assert close(dijkstra_reference(g, 0), sol.state)


# ----------------------------------------------------- state-init bugfix


def test_initial_state_combines_duplicate_sources(tiny_graphs):
    """Duplicate initial workitems targeting one vertex must combine
    with processing.reduce (keep the best), not last-write-wins."""
    from repro.core import SSSP, SSWP, initial_state
    from repro.graph import partition_1d

    pg = partition_1d(tiny_graphs[0], 1)
    # min semiring: the smaller state wins regardless of order
    _, T, L = initial_state(
        pg, SSSP, [(5, 3.0, 2), (5, 1.0, 7), (5, 2.0, 0)]
    )
    assert T[0, 5] == 1.0 and L[0, 5] == 7.0
    # equal states keep the smallest level (deterministic tie-break)
    _, T, L = initial_state(pg, SSSP, [(6, 2.0, 9), (6, 2.0, 3)])
    assert T[0, 6] == 2.0 and L[0, 6] == 3.0
    # max semiring (SSWP): the LARGER capacity wins
    _, T, _ = initial_state(pg, SSWP, [(4, 5.0, 0), (4, 2.0, 0)])
    assert T[0, 4] == 5.0


def test_duplicate_sources_end_to_end(tiny_graphs, solver):
    """ExplicitSources with duplicates solves as if only the best
    duplicate existed."""
    g = tiny_graphs[0]
    dup = solver.solve(Problem(
        g, ExplicitSources([(0, 0.0, 0), (9, 8.0, 0), (9, 1.5, 0)])
    ))
    best = solver.solve(Problem(
        g, ExplicitSources([(0, 0.0, 0), (9, 1.5, 0)])
    ))
    assert np.array_equal(dup.state, best.state)


# -------------------------------------------------- truncation detection


def test_max_iters_truncation_warns(tiny_graphs, mesh1):
    g = tiny_graphs[0]
    solver = Solver(
        SolverConfig(root="dijkstra", max_iters=2), mesh=mesh1
    )
    with pytest.warns(RuntimeWarning, match="max_iters"):
        sol = solver.solve(Problem(g, SingleSource(0)))
    assert not sol.metrics.converged
    assert sol.metrics.supersteps == 2
    full = Solver(SolverConfig(root="dijkstra"), mesh=mesh1).solve(
        Problem(g, SingleSource(0))
    )
    assert full.metrics.converged
    assert close(dijkstra_reference(g, 0), full.state)


# ------------------------------------------------ exchange-byte metrics


def test_exchange_bytes_nonzero_and_mode_dependent(tiny_graphs):
    """Regression: the analytic byte model must be nonzero for P > 1
    and distinguish exchange modes (a2a moves (P-1)·n_local·4 per
    device per superstep; pmin ~2x that as a ring all-reduce)."""
    from repro.api.solver import _finish_metrics
    from repro.core import make_policy
    from repro.core.engine import EngineConfig
    from repro.graph import partition_1d

    pg = partition_1d(tiny_graphs[0], 4)
    pol = make_policy("delta:5", "buffer")
    a2a = _finish_metrics(
        pg, EngineConfig(policy=pol, exchange="a2a"), 10, 5, 5, 5
    )
    pmin = _finish_metrics(
        pg, EngineConfig(policy=pol, exchange="pmin"), 10, 5, 5, 5
    )
    assert a2a.exchange_bytes > 0
    assert pmin.exchange_bytes == 2 * a2a.exchange_bytes
    assert a2a.exchange_bytes == 10 * 4 * pg.n_local * 3 * 4  # it·4B·nl·(P-1)·P
    # sparse mode: bytes reconstruct from the dense-step count
    from repro.core import frontier_caps

    scfg = EngineConfig(policy=pol, exchange="sparse", frontier_cap=2)
    sp = _finish_metrics(pg, scfg, 10, 5, 5, 5, active=0, fallbacks=3)
    _, S = frontier_caps(
        pg.rows_per_rank, pg.width, pg.n_local, pg.n_parts, 2
    )
    dense_words = (pg.n_parts - 1) * pg.n_local
    sparse_words = (pg.n_parts - 1) * 2 * S
    assert sp.exchange_bytes == (
        (7 * sparse_words + 3 * dense_words) * 4 * pg.n_parts
    )
    assert sp.sparse_fallbacks == 3
    assert sp.exchange_bytes < a2a.exchange_bytes
    # single device genuinely moves nothing
    pg1 = partition_1d(tiny_graphs[0], 1)
    m1 = _finish_metrics(
        pg1, EngineConfig(policy=pol, exchange="a2a"), 10, 5, 5, 5
    )
    assert m1.exchange_bytes == 0


def test_solution_reports_exchange_bytes_multidev_shapes(tiny_graphs, solver):
    """End-to-end single-device solves report zero exchange bytes (one
    rank moves nothing) but nonzero collective rounds."""
    sol = solver.solve(Problem(tiny_graphs[0], SingleSource(0)))
    assert sol.metrics.exchange_bytes == 0
    assert sol.metrics.collective_rounds > 0
    assert sol.metrics.converged
