"""LM family: losses, gradients, decode-vs-forward consistency,
attention backends, parameter accounting."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.lm import (
    LMConfig, decode_step, forward, init_params, lm_head_weight,
    lm_loss, param_specs, prefill_step,
)
from repro.models.moe import MoEConfig


def tiny_gqa(**kw):
    base = dict(
        name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=97, param_dtype="float32", loss_chunk=8,
    )
    base.update(kw)
    return LMConfig(**base)


CONFIGS = {
    "gqa": tiny_gqa(),
    "mha": tiny_gqa(n_kv_heads=4),
    "relu2": tiny_gqa(mlp_type="relu2"),
    "mla": tiny_gqa(
        attn_type="mla", q_lora_rank=48, kv_lora_rank=32,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        tie_embeddings=True,
    ),
    "moe": tiny_gqa(
        moe=MoEConfig(n_experts=4, top_k=2, d_model=64, d_ff=96,
                      capacity_factor=2.0, min_capacity=64),
    ),
    "unrolled": tiny_gqa(scan_layers=False),
}


@pytest.fixture(scope="module")
def toks(key):
    return jax.random.randint(key, (2, 17), 0, 97)


@pytest.mark.parametrize("name", list(CONFIGS))
def test_loss_and_grads(name, key, toks, topo1):
    cfg = CONFIGS[name]
    p = init_params(key, cfg)
    batch = {"tokens": toks[:, :16], "labels": toks[:, 1:]}
    loss = lm_loss(p, batch, cfg, topo1)
    assert np.isfinite(float(loss))
    assert 3.0 < float(loss) < 7.0  # ~ln(97)=4.57 at init
    g = jax.grad(lambda pp: lm_loss(pp, batch, cfg, topo1))(p)
    leaves = jax.tree_util.tree_leaves(g)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in leaves)
    assert sum(float(jnp.sum(x * x)) for x in leaves) > 0


@pytest.mark.parametrize("name", list(CONFIGS))
def test_decode_matches_forward(name, key, toks, topo1):
    cfg = CONFIGS[name]
    p = init_params(key, cfg)
    cache, logits_prefill = prefill_step(p, toks[:, :16], cfg, topo1, 32)
    lg, _ = decode_step(p, cache, toks[:, 16], 16, cfg, topo1)
    x, _ = forward(p, toks, cfg, topo1)
    ref16 = (x[:, 16] @ lm_head_weight(p, cfg)).astype(jnp.float32)
    ref15 = (x[:, 15] @ lm_head_weight(p, cfg)).astype(jnp.float32)
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(ref16), rtol=1e-3, atol=2e-4
    )
    np.testing.assert_allclose(
        np.asarray(logits_prefill), np.asarray(ref15),
        rtol=1e-3, atol=2e-4,
    )


def test_scan_vs_unrolled_identical(key, toks, topo1):
    cfg = CONFIGS["gqa"]
    p = init_params(key, cfg)
    x1, _ = forward(p, toks, cfg, topo1)
    x2, _ = forward(
        p, toks, dataclasses.replace(cfg, scan_layers=False), topo1
    )
    np.testing.assert_allclose(
        np.asarray(x1), np.asarray(x2), rtol=1e-5, atol=1e-5
    )


def test_xla_flash_matches_xla(key, toks, topo1):
    cfg = dataclasses.replace(
        CONFIGS["gqa"], attn_impl="xla_flash", attn_chunk=8
    )
    p = init_params(key, cfg)
    x1, _ = forward(p, toks[:, :16], cfg, topo1)
    x2, _ = forward(
        p, toks[:, :16], dataclasses.replace(cfg, attn_impl="xla"),
        topo1,
    )
    np.testing.assert_allclose(
        np.asarray(x1), np.asarray(x2), rtol=1e-4, atol=1e-5
    )


def test_pallas_attention_in_model(key, topo1):
    """The model wired to the Pallas flash kernel (interpret)."""
    cfg = dataclasses.replace(
        tiny_gqa(n_layers=1, d_model=128, n_heads=2, n_kv_heads=1),
        attn_impl="pallas_interpret",
    )
    toks = jax.random.randint(key, (1, 128), 0, 97)
    p = init_params(key, cfg)
    x1, _ = forward(p, toks, cfg, topo1)
    x2, _ = forward(
        p, toks, dataclasses.replace(cfg, attn_impl="xla"), topo1
    )
    np.testing.assert_allclose(
        np.asarray(x1), np.asarray(x2), rtol=2e-4, atol=2e-4
    )


def test_param_count_formula(key):
    for name, cfg in CONFIGS.items():
        if name == "unrolled":
            continue
        p = init_params(key, cfg)
        actual = sum(
            int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(p)
        )
        # formula excludes norm scales (2L*d + d) and MLA norms
        norms = 2 * cfg.n_layers * cfg.d_model + cfg.d_model
        if cfg.attn_type == "mla":
            norms += cfg.n_layers * (cfg.q_lora_rank + cfg.kv_lora_rank)
        assert cfg.n_params() == actual - norms, name


def test_param_specs_tree_matches(key, topo1):
    for cfg in CONFIGS.values():
        p = init_params(key, cfg)
        specs = param_specs(cfg, topo1)
        # same tree structure -> zip succeeds
        jax.tree_util.tree_map(
            lambda a, b: None, p, specs,
            is_leaf=lambda x: not isinstance(x, dict),
        )


def test_moe_balance_aux(key, topo1):
    cfg = CONFIGS["moe"]
    p = init_params(key, cfg)
    toks = jax.random.randint(key, (4, 16), 0, 97)
    _, aux = forward(p, toks, cfg, topo1)
    # perfectly balanced router gives aux ~= n_layers (E * (1/E^2) * E)
    assert 0.5 * cfg.n_layers < float(aux) < 3.0 * cfg.n_layers
