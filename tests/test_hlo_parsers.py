"""repro.roofline.hlo text parsers against canned HLO fixtures.

The roofline numbers and the analyze HLO lint both stand on these
parsers, so their behaviors — two-pass operand resolution, async
-start/-done pairing, tuple shapes, fusion/reducer skipping — get
pinned here against hand-computed byte counts.
"""

import pytest

from repro.roofline import collective_bytes, flops_and_bytes, hbm_traffic
from repro.roofline.hlo import _shape_bytes

# ------------------------------------------------------- shape bytes


@pytest.mark.parametrize(
    "expr,nbytes",
    [
        ("f32[64]", 256),
        ("f32[64]{0}", 256),
        ("f32[4,8,2]", 256),
        ("u16[10]", 20),
        ("bf16[8]", 16),
        ("pred[5]", 5),
        ("(f32[64], s32[64])", 512),
        ("f32[]", 4),            # scalar
        ("token[]", 0),          # tokens are free
        ("nosuchtype[8]", 0),    # unknown dtypes ignored, not crashed
    ],
)
def test_shape_bytes(expr, nbytes):
    assert _shape_bytes(expr) == nbytes


# -------------------------------------------------- collective_bytes

_COLL_HLO = """\
HloModule test

ENTRY %main (p0: f32[64]) -> f32[64] {
  %p0 = f32[64]{0} parameter(0)
  %ar = f32[64]{0} all-reduce(%p0), replica_groups={}, to_apply=%add
  %ag = f32[256]{0} all-gather(%ar), dimensions={0}
  %start = (f32[64]{0}, f32[64]{0}) all-reduce-start(%p0), to_apply=%add
  %done = f32[64]{0} all-reduce-done(%start)
  %a2a = f32[64]{0} all-to-all(%mystery), dimensions={0}
  ROOT %out = f32[64]{0} add(%ar, %done)
}
"""


def test_collective_bytes_two_pass_resolution():
    r = collective_bytes(_COLL_HLO)
    # all-reduce: %ar resolves %p0 (256 B); -start counts once more
    # under the base opcode (256 B); -done is not double-counted
    assert r["counts"]["all-reduce"] == 2
    assert r["bytes"]["all-reduce"] == 512
    # all-gather: operand %ar = 256 B (operand, not the 1 KiB result)
    assert r["bytes"]["all-gather"] == 256
    # all-to-all over an unresolvable operand falls back to result size
    assert r["bytes"]["all-to-all"] == 256
    assert r["counts"]["all-to-all"] == 1
    assert r["total_bytes"] == 512 + 256 + 256


def test_collective_bytes_empty_module():
    r = collective_bytes("HloModule empty\n")
    assert r == {"bytes": {}, "counts": {}, "total_bytes": 0}


# ------------------------------------------------------- hbm_traffic

_FUSION_HLO = """\
HloModule m

%fused_comp (param_0: f32[64]) -> f32[64] {
  %param_0 = f32[64]{0} parameter(0)
  %big = f32[4096]{0} broadcast(%param_0), dimensions={0}
  ROOT %mul = f32[64]{0} multiply(%param_0, %param_0)
}

%add_reducer (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %add = f32[] add(%x, %y)
}

ENTRY %main (p0: f32[64]) -> f32[] {
  %p0 = f32[64]{0} parameter(0)
  %fus = f32[64]{0} fusion(%p0), kind=kLoop, calls=%fused_comp
  %c0 = f32[] constant(0)
  ROOT %red = f32[] reduce(%fus, %c0), dimensions={0}, to_apply=%add_reducer
}
"""


def test_hbm_traffic_skips_fused_and_reducer_internals():
    r = hbm_traffic(_FUSION_HLO)
    # entry computation only: parameter/constant are free;
    #   fusion: 256 out + 256 operand = 512, labeled by its ROOT
    #   reduce: 4 out + 256 + 4 operands = 264
    # the 16 KiB broadcast inside the fused computation never counts
    assert r["total_bytes"] == 512 + 264
    assert r["by_op"] == {"fusion(multiply)": 512, "reduce": 264}


_WHILE_HLO = """\
HloModule m

%inner_fused (p: f32[64]) -> f32[64] {
  %p = f32[64]{0} parameter(0)
  ROOT %mul = f32[64]{0} multiply(%p, %p)
}

%true_br (p: f32[64]) -> f32[64] {
  %p = f32[64]{0} parameter(0)
  ROOT %t = f32[64]{0} fusion(%p), kind=kLoop, calls=%inner_fused
}

%false_br (p: f32[64]) -> f32[64] {
  %p = f32[64]{0} parameter(0)
  ROOT %f = f32[64]{0} negate(%p)
}

%body (s: (pred[], f32[64])) -> (pred[], f32[64]) {
  %s = (pred[], f32[64]) parameter(0)
  %g = pred[] get-tuple-element(%s), index=0
  %v = f32[64]{0} get-tuple-element(%s), index=1
  %c = f32[64]{0} conditional(%g, %v, %v), true_computation=%true_br, false_computation=%false_br
  ROOT %tup = (pred[], f32[64]) tuple(%g, %c)
}

%cond (s: (pred[], f32[64])) -> pred[] {
  %s = (pred[], f32[64]) parameter(0)
  ROOT %g = pred[] get-tuple-element(%s), index=0
}

ENTRY %main (p0: f32[64]) -> (pred[], f32[64]) {
  %p0 = f32[64]{0} parameter(0)
  %setup = f32[64]{0} exponential(%p0)
  %ptrue = pred[] constant(true)
  %init = (pred[], f32[64]) tuple(%ptrue, %setup)
  ROOT %w = (pred[], f32[64]) while(%init), condition=%cond, body=%body
}
"""


def test_while_body_computations_closure():
    from repro.roofline.hlo import while_body_computations

    comps = while_body_computations(_WHILE_HLO)
    # the body, both conditional branches, and the fused computation
    # called from the true branch are reachable; the cond and the
    # entry computation are not
    assert comps == {"body", "true_br", "false_br", "inner_fused"}


def test_hbm_traffic_within_filters_setup():
    from repro.roofline.hlo import while_body_computations

    comps = while_body_computations(_WHILE_HLO)
    r = hbm_traffic(_WHILE_HLO, within=comps)
    # hot loop only: the entry's exponential (512 B) and the while op
    # itself are excluded; the conditional branches count —
    #   conditional: 256 out + 1 + 256 + 256 operands = 769
    #   fusion(multiply) in true_br: 256 + 256 = 512
    #   negate in false_br: 256 + 256 = 512
    assert "exponential" not in r["by_op"]
    assert "while" not in r["by_op"]
    assert r["by_op"]["fusion(multiply)"] == 512
    assert r["by_op"]["negate"] == 512
    full = hbm_traffic(_WHILE_HLO)
    assert full["total_bytes"] > r["total_bytes"]


def test_hbm_traffic_counts_unfused_ops():
    hlo = """\
HloModule m
ENTRY %main (p0: f32[64]) -> f32[64] {
  %p0 = f32[64]{0} parameter(0)
  ROOT %neg = f32[64]{0} negate(%p0)
}
"""
    r = hbm_traffic(hlo)
    assert r["total_bytes"] == 512  # 256 out + 256 operand
    assert r["by_op"] == {"negate": 512}


# --------------------------------------------------- flops_and_bytes


def test_flops_and_bytes_extraction():
    assert flops_and_bytes(
        {"flops": 100.0, "bytes accessed": 40.0}
    ) == (100.0, 40.0)
    assert flops_and_bytes({}) == (0.0, 0.0)
    assert flops_and_bytes(None) == (0.0, 0.0)
