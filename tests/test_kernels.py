"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs the
pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import aggregate_neighbors, bag_pool, mha, relax_rows

rng = np.random.default_rng(0)


@pytest.mark.parametrize("n_pad,R,W,block", [
    (256, 128, 8, 64),
    (512, 300, 16, 128),
    (1024, 65, 32, 256),   # R not divisible by block -> padding path
    (128, 1, 4, 128),
])
def test_relax_ell_sweep(n_pad, R, W, block):
    dist = jnp.concatenate([
        jnp.asarray(rng.exponential(10, n_pad), jnp.float32),
        jnp.array([jnp.inf]),
    ])
    col = jnp.asarray(rng.integers(0, n_pad + 1, (R, W)), jnp.int32)
    wgt = jnp.where(
        col == n_pad, jnp.inf,
        jnp.asarray(rng.uniform(1, 100, (R, W)), jnp.float32),
    )
    ref = relax_rows(dist, col, wgt, impl="ref")
    out = relax_rows(dist, col, wgt, impl="pallas_interpret",
                     block_rows=block)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               rtol=1e-6)


@pytest.mark.parametrize("n_local,n_pad,R,W,F", [
    (128, 256, 96, 8, 32),
    (256, 512, 300, 16, 64),
    (64, 128, 40, 4, 64),     # F > R: every row can be listed
    (128, 128, 50, 8, 1),     # single-row frontier
])
def test_relax_push_sweep(n_local, n_pad, R, W, F):
    """Push-mode frontier relax: Pallas (interpret) == jnp oracle ==
    dense pull relax restricted to the listed rows."""
    from repro.kernels import relax_push_rows

    dist = jnp.concatenate([
        jnp.asarray(rng.exponential(10, n_local), jnp.float32),
        jnp.array([jnp.inf]),
    ])
    row_src = jnp.asarray(rng.integers(0, n_local, R), jnp.int32)
    col = jnp.asarray(rng.integers(0, n_pad + 1, (R, W)), jnp.int32)
    wgt = jnp.where(
        col == n_pad, jnp.inf,
        jnp.asarray(rng.uniform(1, 100, (R, W)), jnp.float32),
    )
    k = min(F, max(1, R // 3))
    frontier = np.sort(rng.choice(R, k, replace=False)).astype(np.int32)
    row_idx = jnp.asarray(
        np.concatenate([frontier, np.full(F - k, R, np.int32)])
    )
    ref = relax_push_rows(dist, row_idx, row_src, col, wgt, n_pad,
                          impl="ref")
    out = relax_push_rows(dist, row_idx, row_src, col, wgt, n_pad,
                          impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               rtol=1e-6)
    # numpy oracle: scatter-min the listed rows' min-plus candidates
    oracle = np.full(n_pad + 1, np.inf, np.float32)
    dist_np, col_np = np.asarray(dist), np.asarray(col)
    wgt_np, src_np = np.asarray(wgt), np.asarray(row_src)
    for r in frontier:
        np.minimum.at(oracle, col_np[r], dist_np[src_np[r]] + wgt_np[r])
    np.testing.assert_allclose(np.asarray(ref), oracle[:n_pad], rtol=1e-6)


@pytest.mark.parametrize("op", ["sum", "max"])
@pytest.mark.parametrize("n_x,R,W,d", [
    (100, 64, 4, 32),
    (257, 300, 12, 96),     # non-aligned everything
    (64, 128, 8, 128),
])
def test_spmm_ell_sweep(op, n_x, R, W, d):
    x = jnp.asarray(rng.normal(size=(n_x, d)), jnp.float32)
    x = x.at[n_x - 1].set(0)
    col = jnp.asarray(rng.integers(0, n_x, (R, W)), jnp.int32)
    wgt = jnp.asarray(
        (rng.random((R, W)) > 0.3) * rng.random((R, W)), jnp.float32
    )
    a = aggregate_neighbors(x, col, wgt, op=op, impl="ref")
    b = aggregate_neighbors(x, col, wgt, op=op,
                            impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("B,Hq,Hkv,Sq,Sk,D,dtype", [
    (1, 2, 1, 128, 128, 64, jnp.float32),
    (2, 4, 2, 256, 256, 64, jnp.float32),
    (1, 8, 2, 128, 256, 128, jnp.float32),   # cross (kv longer)
    (2, 4, 4, 128, 128, 64, jnp.bfloat16),   # MHA bf16
])
def test_flash_attention_sweep(causal, B, Hq, Hkv, Sq, Sk, D, dtype):
    if causal and Sq > Sk:
        pytest.skip("causal requires Sq <= Sk")
    q = jnp.asarray(rng.normal(size=(B, Hq, Sq, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Hkv, Sk, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Hkv, Sk, D)), dtype)
    a = mha(q, k, v, causal=causal, impl="ref")
    b = mha(q, k, v, causal=causal, impl="pallas_interpret")
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize("mode", ["sum", "mean"])
@pytest.mark.parametrize("V,d,B,L", [
    (100, 32, 8, 5),
    (1000, 64, 16, 10),
    (50, 128, 4, 20),
])
def test_embedding_bag_sweep(mode, V, d, B, L):
    table = jnp.asarray(rng.normal(size=(V, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, V, (B, L)), jnp.int32)
    mask = jnp.asarray(rng.random((B, L)) > 0.3)
    a = bag_pool(table, idx, mask, mode=mode, impl="ref")
    b = bag_pool(table, idx, mask, mode=mode, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)


def test_flash_attention_matches_jax_sdpa():
    """Third-party cross-check against jax.nn.dot_product_attention."""
    B, H, S, D = 2, 4, 128, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    ref = jax.nn.dot_product_attention(q, k, v, is_causal=True)
    got = mha(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True, impl="pallas_interpret",
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                               rtol=2e-5, atol=2e-5)
