"""Deeper integration coverage: multi-step autoregressive decode vs
teacher-forced forward, and MoE dispatch invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="optional dev dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.models.common import single_device_topology
from repro.models.lm import (
    LMConfig, decode_step, forward, init_params, lm_head_weight,
    prefill_step,
)
from repro.models.moe import MoEConfig, capacity, moe_ffn


def cfg_for(name):
    base = dict(
        name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=89, param_dtype="float32", loss_chunk=8,
    )
    if name == "mla":
        base.update(
            n_kv_heads=4, attn_type="mla", q_lora_rank=48,
            kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
            v_head_dim=16, tie_embeddings=True,
        )
    if name == "moe":
        base["moe"] = MoEConfig(
            n_experts=4, top_k=2, d_model=64, d_ff=96,
            capacity_factor=2.0, min_capacity=64,
        )
    return LMConfig(**base)


@pytest.mark.parametrize("name", ["gqa", "mla", "moe"])
def test_multi_step_greedy_decode_matches_forward(name, key, topo1):
    """Prefill 8 tokens, then decode 6 greedy steps; every step's
    logits must match the teacher-forced full forward on the SAME
    sequence — catches cache position/update bugs that single-step
    tests miss."""
    cfg = cfg_for(name)
    p = init_params(key, cfg)
    prompt = jax.random.randint(key, (2, 8), 0, cfg.vocab)

    cache, logits = prefill_step(p, prompt, cfg, topo1, max_len=16)
    seq = prompt
    for step in range(6):
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (B,)
        seq = jnp.concatenate([seq, nxt[:, None]], axis=1)
        pos = 8 + step
        logits, cache = decode_step(p, cache, nxt, pos, cfg, topo1)
        # teacher-forced reference over the grown sequence
        x, _ = forward(p, seq, cfg, topo1)
        ref = (x[:, -1] @ lm_head_weight(p, cfg)).astype(jnp.float32)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(ref), rtol=2e-3, atol=5e-4
        )


@given(
    n_tokens=st.sampled_from([16, 32, 64]),
    n_experts=st.sampled_from([2, 4, 8]),
    top_k=st.integers(1, 2),
    seed=st.integers(0, 10),
)
@settings(max_examples=12, deadline=None)
def test_moe_dispatch_invariants(n_tokens, n_experts, top_k, seed):
    """Property: with capacity >= tokens·k/E·cf the MoE output is a
    convex-ish combination — for identical expert weights the layer
    reduces to the dense FFN regardless of routing."""
    topo = single_device_topology()
    d, f = 16, 24
    cfg = MoEConfig(n_experts=n_experts, top_k=top_k, d_model=d,
                    d_ff=f, capacity_factor=2.0,
                    min_capacity=n_tokens * top_k)
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(k1, (1, n_tokens, d), jnp.float32)
    router = jax.random.normal(k2, (d, n_experts)) * 0.1
    wg1 = jax.random.normal(k3, (d, f)) / np.sqrt(d)
    wu1 = jax.random.normal(k4, (d, f)) / np.sqrt(d)
    wd1 = jax.random.normal(k1, (f, d)) / np.sqrt(f)
    # all experts identical
    wg = jnp.broadcast_to(wg1, (n_experts, d, f))
    wu = jnp.broadcast_to(wu1, (n_experts, d, f))
    wd = jnp.broadcast_to(wd1, (n_experts, f, d))
    out, aux = moe_ffn(x, router, wg, wu, wd, cfg, topo)
    # dense reference
    from repro.models.common import swiglu

    ref = swiglu(x @ wg1, x @ wu1) @ wd1
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_are_bounded(key, topo1):
    """With tiny capacity, outputs are attenuated (dropped tokens)
    but never NaN, and no token's output exceeds the no-drop case."""
    d, f, E = 16, 24, 4
    cfg_small = MoEConfig(n_experts=E, top_k=2, d_model=d, d_ff=f,
                          capacity_factor=0.1, min_capacity=1)
    cfg_big = MoEConfig(n_experts=E, top_k=2, d_model=d, d_ff=f,
                        capacity_factor=4.0, min_capacity=128)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (1, 32, d), jnp.float32)
    router = jax.random.normal(ks[1], (d, E)) * 0.1
    wg = jax.random.normal(ks[2], (E, d, f)) / np.sqrt(d)
    wu = jax.random.normal(ks[3], (E, d, f)) / np.sqrt(d)
    wd = jax.random.normal(ks[4], (E, f, d)) / np.sqrt(f)
    out_s, _ = moe_ffn(x, router, wg, wu, wd, cfg_small, topo1)
    out_b, _ = moe_ffn(x, router, wg, wu, wd, cfg_big, topo1)
    assert bool(jnp.all(jnp.isfinite(out_s)))
    assert capacity(cfg_small, 32) < capacity(cfg_big, 32)
    # dropped-token rows are zero; kept rows match the full output
    norms_s = jnp.linalg.norm(out_s[0], axis=-1)
    norms_b = jnp.linalg.norm(out_b[0], axis=-1)
    assert float(jnp.sum(norms_s > 1e-9)) < 32  # some tokens dropped
    kept = norms_s > 1e-9
    # tokens fully served by both configs agree (same routing)
    full_match = jnp.where(
        kept[:, None], jnp.abs(out_s[0] - out_b[0]), 0.0
    )
    # at least the non-dropped mass is consistent up to partial drops
    assert float(jnp.max(full_match)) < 1.0
