"""repro.serve: admission batching, solution cache, landmark tier,
streaming updates, and the incremental fingerprint chain.

Single-device fast tests here; the 8-device serving smoke runs in a
subprocess (marked slow) like the other multi-device coverage.
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

import repro.api as api
from repro.api import Problem, SingleSource, Solver, batch_bucket
from repro.core import LatencyStats, dijkstra_reference
from repro.graph import (
    chain_fingerprint, clear_fingerprint_chain, graph_fingerprint, rmat1,
)
from repro.serve import (
    EdgeUpdate, LandmarkIndex, Query, Router, SolutionCache, UpdateFeed,
)


def close(a, b):
    return np.allclose(
        np.where(np.isinf(a), -1, a), np.where(np.isinf(b), -1, b)
    )


@pytest.fixture(scope="module")
def mesh1():
    return jax.make_mesh((1,), ("data",))


@pytest.fixture(scope="module")
def solver(mesh1):
    return Solver("delta:5+threadq/a2a", mesh=mesh1)


def fresh_graph(seed=3):
    """A private graph per test — update tests mutate edges in place,
    which must not leak into session-scoped fixtures."""
    return rmat1(8, seed=seed)


# ------------------------------------------------- fingerprint chain


def test_chain_fingerprint_is_incremental_and_ordered():
    g1, g2 = fresh_graph(), fresh_graph()
    base = graph_fingerprint(g1)
    assert base == graph_fingerprint(g2)
    a = EdgeUpdate(0, 1, 2.0).record()
    b = EdgeUpdate(1, 0, 3.0).record()
    # same update sequence -> same token; different order -> different
    fa1 = chain_fingerprint(g1, a)
    fa2 = chain_fingerprint(g2, a)
    assert fa1 == fa2 and fa1 != base
    fb1 = chain_fingerprint(g1, b)
    g3 = fresh_graph()
    chain_fingerprint(g3, b)
    fb3 = chain_fingerprint(g3, a)
    assert fb1 != fb3  # order-sensitive hash chain
    # the chained token is what lookups now return, O(1)
    assert graph_fingerprint(g1) == fb1
    # full=True bypasses the chain (the O(m) oracle)
    assert graph_fingerprint(g1, full=True) == base
    clear_fingerprint_chain(g1)
    assert graph_fingerprint(g1) == base


def test_chain_fingerprint_tracks_full_rehash_oracle():
    """The chain must distinguish graphs exactly when the full-rehash
    oracle does: after applying an actual mutation + its record, both
    the chain token and the full rehash change."""
    g = fresh_graph()
    full_before = graph_fingerprint(g, full=True)
    upd = EdgeUpdate(int(g.src[5]), int(g.dst[5]),
                     float(g.weight[5]) * 0.5)
    g.weight[5] *= 0.5
    token = chain_fingerprint(g, upd.record())
    assert graph_fingerprint(g, full=True) != full_before  # oracle moved
    assert token != full_before                            # chain moved too
    # chained tokens live in a distinct space from full-rehash tokens
    assert token != graph_fingerprint(g, full=True)


# ------------------------------------------------------------- cache


def _solution_for(solver, g, v):
    return solver.solve(Problem(g, SingleSource(v)))


def test_cache_lru_hit_miss_counters(solver, tiny_graphs):
    g = tiny_graphs[0]
    fp = graph_fingerprint(g)
    cache = SolutionCache(byte_budget=1 << 20)
    key = SolutionCache.key_for(fp, 0, solver.config.name)
    assert cache.get(key) is None
    cache.put(key, _solution_for(solver, g, 0))
    assert cache.get(key) is not None
    assert cache.stats.hits == 1 and cache.stats.misses == 1
    assert cache.stats.bytes > 0
    assert cache.stats.hit_rate() == 0.5
    # peek doesn't skew counters
    assert cache.peek(key) is not None
    assert cache.stats.hits == 1


def test_cache_byte_budget_evicts_lru(solver, tiny_graphs):
    g = tiny_graphs[0]
    fp = graph_fingerprint(g)
    one = _solution_for(solver, g, 0)
    cache = SolutionCache(byte_budget=int(one.nbytes * 2.5))
    keys = [SolutionCache.key_for(fp, v, solver.config.name)
            for v in range(4)]
    for k, v in zip(keys, range(4)):
        cache.put(k, _solution_for(solver, g, v))
    assert len(cache) == 2  # budget fits two solutions
    assert cache.stats.evictions == 2
    assert cache.peek(keys[0]) is None      # oldest evicted
    assert cache.peek(keys[3]) is not None  # newest resident
    assert cache.stats.bytes <= cache.byte_budget
    # an over-budget single entry stays resident alone
    tiny = SolutionCache(byte_budget=1)
    tiny.put(keys[0], one)
    assert len(tiny) == 1


def test_cache_invalidate_graph(solver, tiny_graphs):
    g = tiny_graphs[0]
    fp = graph_fingerprint(g)
    cache = SolutionCache()
    for v in range(3):
        cache.put(SolutionCache.key_for(fp, v, solver.config.name),
                  _solution_for(solver, g, v))
    other = ("other",)
    cache.put(SolutionCache.key_for(other, 0, solver.config.name),
              _solution_for(solver, g, 0))
    assert cache.invalidate_graph(fp) == 3
    assert len(cache) == 1 and cache.stats.invalidations == 3
    assert cache.entries_for(fp) == []


# ------------------------------------------- batch bucketing (solver)


def test_batch_bucket_rounding():
    assert [batch_bucket(b) for b in (1, 2, 3, 4, 5, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 16]
    with pytest.raises(ValueError):
        batch_bucket(0)


def test_solve_batch_bucketing_no_retrace(tiny_graphs, mesh1):
    """Varying batch sizes within one power-of-two bucket must reuse
    the compiled engine — the serving-loop retrace regression."""
    g = tiny_graphs[0]
    solver = Solver("delta:7+threadq/a2a", mesh=mesh1)
    mk = lambda vs: [Problem(g, SingleSource(v)) for v in vs]
    solver.solve_batch(mk([0, 1, 2]))  # warms the bucket-4 engine
    before = api.trace_count()
    solver.solve_batch(mk([3, 4, 5, 6]))      # B=4 -> same bucket
    solver.solve_batch(mk([7, 8]))            # B=2 -> bucket 2: traces
    traced_b2 = api.trace_count() - before
    solver.solve_batch(mk([9, 10]))           # B=2 again: cached
    assert api.trace_count() - before == traced_b2
    assert traced_b2 <= 1
    # padded lanes don't corrupt results
    sols = solver.solve_batch(mk([0, 5, 11]))
    for v, sol in zip([0, 5, 11], sols):
        assert close(dijkstra_reference(g, v), sol.state)
    assert len(sols) == 3


def test_solution_seams(solver, tiny_graphs):
    g = tiny_graphs[0]
    sol = solver.solve(Problem(g, SingleSource(3)))
    assert sol.source == 3
    assert sol.nbytes == sol.state.nbytes + sol.padded.nbytes
    assert sol.distance_to(3) == 0.0
    ref = dijkstra_reference(g, 3)
    assert sol.distance_to(7) == ref[7] or (
        np.isinf(sol.distance_to(7)) and np.isinf(ref[7]))
    with pytest.raises(ValueError):
        sol.distance_to(g.n)
    assert api.engine_cache_info()["size"] > 0
    info = solver.stats()
    assert info["partition_memo_size"] >= 1


# ------------------------------------------------------------ router


def test_router_serves_correct_answers(solver, tiny_graphs):
    g = tiny_graphs[0]
    router = Router(solver, g, max_batch=4)
    ans = router.serve([
        Query(0), Query(5, target=9), Query(0, target=2),
    ])
    ref0, ref5 = dijkstra_reference(g, 0), dijkstra_reference(g, 5)
    assert close(ref0, ans[0].solution.state)
    assert ans[1].distance == ref5[9]
    assert ans[2].distance == ref0[2]
    assert ans[2].served_by in ("cache", "batch")
    assert all(a.latency_s >= 0 for a in ans)


def test_router_cache_hits_and_dedupe(solver, tiny_graphs):
    g = tiny_graphs[0]
    router = Router(solver, g, max_batch=8)
    router.serve([Query(0), Query(0, target=1), Query(0, target=2)])
    # one distinct source -> one solve, and repeats hit the cache
    assert router.stats.batched_solves == 1
    ans = router.serve([Query(0)])
    assert ans[0].served_by == "cache"
    assert router.cache.stats.hits >= 1


def test_router_size_trigger_flushes(solver, tiny_graphs):
    g = tiny_graphs[0]
    router = Router(solver, g, max_batch=2)
    t1 = router.submit(Query(0))
    assert not t1.done
    t2 = router.submit(Query(5))  # fills the batch -> auto flush
    assert t1.done and t2.done


def test_router_timeout_trigger(solver, tiny_graphs):
    """Pad/timeout batching with an injected clock: pump() flushes
    once the oldest pending query exceeds max_wait_s."""
    g = tiny_graphs[0]
    now = [0.0]
    router = Router(solver, g, max_batch=64, max_wait_s=0.5,
                    clock=lambda: now[0])
    t = router.submit(Query(0))
    assert not router.pump() and not t.done
    now[0] = 0.6
    assert router.pump() and t.done
    assert t.answer.latency_s == pytest.approx(0.6)


def test_router_ticket_result_forces_flush(solver, tiny_graphs):
    g = tiny_graphs[0]
    router = Router(solver, g, max_batch=64)
    t = router.submit(Query(7))
    ans = t.result()  # blocking caller is the ultimate latency trigger
    assert close(dijkstra_reference(g, 7), ans.solution.state)


# --------------------------------------------------------- landmarks


def test_landmark_bounds_sandwich_truth(solver, tiny_graphs):
    g = tiny_graphs[0]  # rmat1 is symmetrized by construction
    lm = LandmarkIndex(solver, g, k=4, symmetric=True)
    assert lm.k == 4 and lm.dist.shape == (4, g.n)
    rng = np.random.default_rng(0)
    refs = {}
    for s in rng.integers(0, g.n, 5):
        s = int(s)
        if s not in refs:
            refs[s] = dijkstra_reference(g, s)
        for t in rng.integers(0, g.n, 4):
            est = lm.estimate(s, int(t))
            d = refs[s][int(t)]
            if np.isinf(d):
                assert np.isinf(est.upper)
            else:
                assert est.lower <= d <= est.upper, (s, int(t), d, est)
    est = lm.estimate(3, 3)
    assert est.exact and est.upper == 0.0
    # a landmark as endpoint pinches the bounds to exact
    hub = lm.landmarks[0]
    tgt = int(np.flatnonzero(np.isfinite(lm.dist[0]))[1])
    est = lm.estimate(hub, tgt)
    assert est.exact and est.upper == lm.dist[0, tgt]


def test_router_landmark_tier_and_escalation(solver, tiny_graphs):
    g = tiny_graphs[0]
    lm = LandmarkIndex(solver, g, k=4, symmetric=True)
    router = Router(solver, g, landmarks=lm, max_batch=4)
    a = router.serve([Query(0, target=9, exact=False)])[0]
    assert a.served_by == "landmark" and a.lower <= a.upper
    assert a.distance == a.upper
    assert router.stats.landmark_served == 1
    # exact= escalation goes through the engine and nails the truth
    b = router.serve([Query(0, target=9, exact=True)])[0]
    assert b.served_by in ("cache", "batch")
    ref = dijkstra_reference(g, 0)[9]
    assert b.distance == ref
    assert a.lower <= b.distance <= a.upper
    # without an index, estimate queries silently escalate
    router2 = Router(solver, g, max_batch=4)
    c = router2.serve([Query(0, target=9, exact=False)])[0]
    assert c.served_by in ("cache", "batch") and c.distance == ref


# ---------------------------------------------------- streaming updates


def test_feed_improving_drop_warm_refresh_bit_identical(solver):
    g = fresh_graph()
    router = Router(solver, g, max_batch=4)
    router.serve([Query(0), Query(5)])
    feed = UpdateFeed(g, solver, cache=router.cache)
    e = 17
    res = feed.apply(EdgeUpdate(int(g.src[e]), int(g.dst[e]),
                                float(g.weight[e]) * 0.25))
    assert res.improving and not res.inserted
    assert res.warm_refreshes == 2 and res.cold_refreshes == 0
    fp = graph_fingerprint(g)
    assert res.fingerprint == fp
    entries = router.cache.entries_for(fp)
    assert len(entries) == 2
    cold_steps = 0
    for key, sol in entries:
        cold = solver.solve(Problem(g, SingleSource(key[1])))
        assert np.array_equal(sol.state, cold.state)  # bit-identical
        assert close(dijkstra_reference(g, key[1]), sol.state)
        cold_steps += cold.metrics.supersteps
    assert res.warm_supersteps < cold_steps  # strictly fewer supersteps


def test_feed_insertion_is_improving(solver):
    g = fresh_graph()
    m_before = g.m
    router = Router(solver, g, max_batch=4)
    router.serve([Query(0)])
    feed = UpdateFeed(g, solver, cache=router.cache)
    # a new cheap edge from the source shortens real paths
    src = 0
    dst = (src + 1) % g.n
    while ((g.src == src) & (g.dst == dst)).any():
        dst = (dst + 1) % g.n
    res = feed.apply(EdgeUpdate(src, dst, 0.5))
    assert res.improving and res.inserted
    assert g.m == m_before + 1
    [(key, sol)] = router.cache.entries_for(graph_fingerprint(g))
    cold = solver.solve(Problem(g, SingleSource(0)))
    assert np.array_equal(sol.state, cold.state)
    assert sol.state[dst] <= 0.5  # the new edge is live


def test_feed_non_improving_detected_and_cold_solved(solver):
    """Weight increases and deletions: served results must be detected
    stale and routed to a cold solve, bit-identical to from-scratch."""
    g = fresh_graph()
    router = Router(solver, g, max_batch=4)
    router.serve([Query(0), Query(5)])
    fp_old = graph_fingerprint(g)
    feed = UpdateFeed(g, solver, cache=router.cache)
    e = 3
    res = feed.apply(EdgeUpdate(int(g.src[e]), int(g.dst[e]),
                                float(g.weight[e]) * 100.0))
    assert not res.improving
    assert res.invalidated == 2 and res.cold_refreshes == 2
    # old-fingerprint entries are unreachable, new ones are fresh
    assert router.cache.entries_for(fp_old) == []
    for key, sol in router.cache.entries_for(graph_fingerprint(g)):
        fresh = solver.solve(Problem(g, SingleSource(key[1])))
        assert np.array_equal(sol.state, fresh.state)
        assert close(dijkstra_reference(g, key[1]), sol.state)
    # deletion is non-improving too (weight -> +inf)
    e2 = 9
    res2 = feed.apply(EdgeUpdate(int(g.src[e2]), int(g.dst[e2]),
                                 delete=True))
    assert not res2.improving and res2.cold_refreshes == 2
    assert np.isinf(g.weight[e2])
    for key, sol in router.cache.entries_for(graph_fingerprint(g)):
        assert close(dijkstra_reference(g, key[1]), sol.state)


def test_feed_lazy_mode_invalidates_only(solver):
    g = fresh_graph()
    router = Router(solver, g, max_batch=4)
    router.serve([Query(0)])
    feed = UpdateFeed(g, solver, cache=router.cache, refresh="lazy")
    e = 11
    res = feed.apply(EdgeUpdate(int(g.src[e]), int(g.dst[e]),
                                float(g.weight[e]) * 0.25))
    # lazy: nothing refreshed, entry dropped; next query cold-misses
    assert res.warm_refreshes == 0 and res.invalidated == 1
    assert len(router.cache) == 0
    a = router.serve([Query(0)])[0]
    assert a.served_by == "batch"
    assert close(dijkstra_reference(g, 0), a.solution.state)


def test_feed_layout_change_falls_back_to_cold(mesh1):
    """Under a data-dependent partitioner (ebal) an update can move the
    ownership boundaries; resolve refuses and the feed cold-solves."""
    g = fresh_graph()
    solver = Solver("delta:5+threadq/a2a@ebal", mesh=mesh1)
    router = Router(solver, g, max_batch=4)
    router.serve([Query(0)])
    feed = UpdateFeed(g, solver, cache=router.cache)
    # insertions change degree counts, which is what moves ebal rows
    rng = np.random.default_rng(0)
    res = None
    for _ in range(6):
        u = int(rng.integers(0, g.n))
        v = int(rng.integers(0, g.n))
        if u == v or ((g.src == u) & (g.dst == v)).any():
            continue
        res = feed.apply(EdgeUpdate(u, v, 1.0))
    assert res is not None and res.improving
    # whichever path it took, the cached answer matches the oracle
    [(key, sol)] = router.cache.entries_for(graph_fingerprint(g))
    assert close(dijkstra_reference(g, key[1]), sol.state)


def test_feed_validates_inputs(solver):
    g = fresh_graph()
    feed = UpdateFeed(g, solver)
    with pytest.raises(ValueError):
        feed.apply(EdgeUpdate(g.n, 0, 1.0))
    with pytest.raises(ValueError):
        feed.apply(EdgeUpdate(0, 1, -2.0))
    with pytest.raises(ValueError):
        UpdateFeed(g, solver, refresh="sometimes")


# ----------------------------------------------------- latency stats


def test_latency_stats_nearest_rank():
    xs = [float(i) for i in range(1, 101)]  # 1..100
    st = LatencyStats.from_samples(xs)
    assert st.count == 100 and st.p50_s == 50.0
    assert st.p90_s == 90.0 and st.p99_s == 99.0 and st.max_s == 100.0
    assert LatencyStats.from_samples([]).count == 0
    one = LatencyStats.from_samples([0.25])
    assert one.p50_s == one.p99_s == one.max_s == 0.25


# ------------------------------------------------- 8-device serving


CHILD_SERVE = r"""
import numpy as np, jax
assert len(jax.devices()) == 8, jax.devices()
from repro.api import Solver
from repro.core import dijkstra_reference
from repro.graph import rmat1, graph_fingerprint
from repro.serve import (EdgeUpdate, LandmarkIndex, Query, Router,
                         SolutionCache, UpdateFeed)

g = rmat1(9, seed=5)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
solver = Solver("delta:5+threadq/a2a", mesh=mesh)
lm = LandmarkIndex(solver, g, k=4, symmetric=True)
router = Router(solver, g, cache=SolutionCache(byte_budget=64 << 20),
                landmarks=lm, max_batch=8)

rng = np.random.default_rng(0)
srcs = np.minimum(rng.zipf(1.3, size=100) - 1, g.n - 1)
queries = []
for i, s in enumerate(srcs):
    if i % 10 == 9:
        queries.append(Query(int(s), target=int(rng.integers(0, g.n)),
                             exact=False))
    elif i % 3 == 2:
        queries.append(Query(int(s), target=int(rng.integers(0, g.n))))
    else:
        queries.append(Query(int(s)))
answers = router.serve(queries)
assert len(answers) == 100 and all(a.query is q for a, q in
                                   zip(answers, queries))
refs = {}
for a in answers:
    s = a.query.source
    if s not in refs:
        refs[s] = dijkstra_reference(g, s)
    if a.served_by == "landmark":
        d = refs[s][a.query.target]
        assert a.lower <= d <= a.upper or (
            np.isinf(d) and np.isinf(a.upper)), (a.query, d)
    elif a.query.target is not None:
        r = refs[s][a.query.target]
        assert a.distance == r or (np.isinf(a.distance) and np.isinf(r))
    else:
        assert np.allclose(np.where(np.isinf(refs[s]), -1, refs[s]),
                           np.where(np.isinf(a.solution.state), -1,
                                    a.solution.state))
assert router.cache.stats.hit_rate() > 0.2, router.cache.stats

# streamed improving update keeps answers fresh via warm restarts
feed = UpdateFeed(g, solver, cache=router.cache, landmarks=lm)
e = int(rng.integers(0, g.m))
res = feed.apply(EdgeUpdate(int(g.src[e]), int(g.dst[e]),
                            float(g.weight[e]) * 0.25))
assert res.improving and res.warm_refreshes > 0
from repro.api import Problem, SingleSource
for key, sol in router.cache.entries_for(graph_fingerprint(g))[:3]:
    cold = solver.solve(Problem(g, SingleSource(key[1])))
    assert np.array_equal(sol.state, cold.state), key[1]
print('SERVE-MULTIDEV-OK')
"""


@pytest.mark.slow
def test_router_8_devices_mixed_queries():
    """100 mixed queries through the router on an 8-device mesh, plus
    a streamed improving update with warm-refresh verification."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", CHILD_SERVE], env=env,
        capture_output=True, text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SERVE-MULTIDEV-OK" in r.stdout
