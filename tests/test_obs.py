"""repro.obs: span tracer, /trace flight recorder, exporters, and the
serving metrics surface.

The tentpole gate is the bit-identity grid: a ``/trace`` solve runs
through the segment engine purely to publish per-superstep windows, so
its final state AND its WorkMetrics must equal the untraced solve's
exactly, and the per-superstep sums must reconcile with the aggregate.
The 8-device version runs in a subprocess (marked slow) like the other
multi-device coverage.
"""

import json
import os
import subprocess
import sys
import urllib.request

import numpy as np
import pytest

from repro.api import Problem, SingleSource, Solver, SolverConfig
from repro.core.metrics import LatencyStats, WorkMetrics
from repro.obs import (
    FlightRecorder, MetricsRegistry, SolveTrace, Tracer,
    chrome_trace, flight_jsonl, serve_metrics, use_tracer,
)
from repro.obs import trace as obs


# ------------------------------------------------------------- tracer


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


def test_tracer_nesting_and_injected_clock():
    tr = Tracer(clock=FakeClock())
    with use_tracer(tr):
        with obs.span("outer", a=1) as sp:
            obs.event("tick", k=2)
            with obs.span("inner"):
                pass
            sp.set(b=3)
    # clock: outer.t0=1, event=2, inner.t0=3, inner.t1=4, outer.t1=5
    inner, outer = tr.spans  # inner closes first
    assert inner.name == "inner" and outer.name == "outer"
    assert (outer.t0, outer.t1) == (1.0, 5.0) and outer.duration_s == 4.0
    assert (inner.t0, inner.t1) == (3.0, 4.0)
    assert inner.parent_id == outer.span_id and outer.parent_id is None
    assert outer.attrs == {"a": 1, "b": 3}
    ev, = tr.events
    assert ev.t == 2.0 and ev.span_id == outer.span_id
    assert tr.children_of(outer.span_id) == [inner]


def test_tracer_off_is_noop():
    assert obs.current_tracer() is None
    s1 = obs.span("anything", x=1)
    s2 = obs.span("else")
    assert s1 is s2  # shared no-op handle: zero allocation when off
    with s1 as sp:
        sp.set(ignored=True)
    obs.event("nothing")  # no tracer — must not raise


def test_tracer_error_attr_and_use_tracer_restores():
    tr = Tracer()
    prev = obs.current_tracer()
    with use_tracer(tr):
        with pytest.raises(RuntimeError):
            with obs.span("boom"):
                raise RuntimeError("x")
    assert obs.current_tracer() is prev
    assert tr.find("boom")[0].attrs["error"] == "RuntimeError"


def test_tracer_bounded_drops_counted():
    tr = Tracer(max_records=3)
    with use_tracer(tr):
        for _ in range(5):
            obs.event("e")
        with obs.span("s"):
            pass
    assert len(tr.events) == 3 and len(tr.spans) == 0
    assert tr.dropped == 3
    with pytest.raises(ValueError):
        Tracer(max_records=0)


def test_tracer_feeds_registry():
    reg = MetricsRegistry()
    tr = Tracer(clock=FakeClock(), registry=reg)
    with use_tracer(tr):
        with obs.span("work"):
            obs.event("hit")
        obs.event("hit")
    text = reg.expose()
    assert 'repro_events_total{event="hit"} 2' in text
    assert 'repro_span_seconds_count{span="work"} 1' in text
    # FakeClock ticks: span.t0=1, event=2, span.t1=3 -> duration 2
    assert 'repro_span_seconds_sum{span="work"} 2' in text


# ----------------------------------------------------------- registry


def test_registry_counter_gauge_histogram():
    reg = MetricsRegistry()
    c = reg.counter("c_total", help="h", labels={"k": "v"})
    c.inc()
    c.inc(2)
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("g", help="h")
    g.set(4.5)
    live = reg.gauge("g_live", help="h", fn=lambda: 7)
    h = reg.histogram("h_seconds", help="h", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    text = reg.expose()
    assert 'c_total{k="v"} 3' in text
    assert "# TYPE c_total counter" in text
    assert "g 4.5" in text
    assert "g_live 7" in text
    assert 'h_seconds_bucket{le="0.1"} 1' in text
    assert 'h_seconds_bucket{le="1"} 2' in text  # 1.0 renders as "1"
    assert 'h_seconds_bucket{le="+Inf"} 3' in text
    assert "h_seconds_count 3" in text
    assert live is reg.gauge("g_live", help="h")  # get-or-create


def test_registry_same_name_distinct_labels_and_kind_conflict():
    reg = MetricsRegistry()
    a = reg.counter("n_total", help="h", labels={"x": "1"})
    b = reg.counter("n_total", help="h", labels={"x": "2"})
    assert a is not b
    a.inc()
    assert 'n_total{x="1"} 1' in reg.expose()
    with pytest.raises(ValueError):
        reg.gauge("n_total", help="h")


# ---------------------------------------------------------- exporters


def _tiny_trace():
    tr = Tracer(clock=FakeClock())
    with use_tracer(tr):
        with obs.span("solve", spec="s"):
            obs.event("cache_miss")
    st = SolveTrace(config_name="s", n=8, rows_per_rank=8,
                    sparse_capable=True,
                    pending=[4, 2, 0], eligible=[4, 2, 1],
                    rows=[4, 2, 1], sparse_used=[1, 0, 1],
                    bytes_moved=[0, 64, 0],
                    segments=[{"segment": 0, "supersteps": 3,
                               "t0": 1.0, "t1": 2.0}])
    return tr, st


def test_chrome_trace_shapes():
    tr, st = _tiny_trace()
    doc = chrome_trace(tr, [st])
    evs = doc["traceEvents"]
    xs = [e for e in evs if e["ph"] == "X"]
    assert xs and xs[0]["name"] == "solve" and xs[0]["dur"] > 0
    assert any(e["ph"] == "i" and e["name"] == "cache_miss" for e in evs)
    counters = [e for e in evs if e["ph"] == "C"]
    # 3 supersteps × (frontier + bytes) counter samples
    assert sum("frontier" in e["name"] for e in counters) == 3
    assert sum("bytes" in e["name"] for e in counters) == 3
    json.dumps(doc)  # must be serializable as-is


def test_flight_jsonl_kinds():
    tr, st = _tiny_trace()
    lines = [json.loads(ln) for ln in flight_jsonl(tr, [st])]
    kinds = {ln["kind"] for ln in lines}
    assert kinds == {"solve", "superstep", "span", "event"}
    assert sum(ln["kind"] == "superstep" for ln in lines) == 3
    solve = next(ln for ln in lines if ln["kind"] == "solve")
    rebuilt = SolveTrace(**{k: v for k, v in solve.items()
                            if k != "kind"})
    assert rebuilt.pending == st.pending
    assert rebuilt.total_bytes() == st.total_bytes()


def test_serve_metrics_http():
    reg = MetricsRegistry()
    reg.counter("up_total", help="h").inc()
    server = serve_metrics(reg, port=0)
    try:
        host, port = server.server_address[0], server.server_address[1]
        with urllib.request.urlopen(
                f"http://{host}:{port}/metrics", timeout=10) as r:
            body = r.read().decode()
        assert "up_total 1" in body
        with urllib.request.urlopen(
                f"http://{host}:{port}/stats", timeout=10) as r:
            stats = json.loads(r.read().decode())
        assert stats["up_total"]["type"] == "counter"
        assert stats["up_total"]["samples"][0]["value"] == 1
    finally:
        server.shutdown()


# --------------------------------------------- /trace spec grammar


def test_trace_spec_grammar():
    c = SolverConfig.from_spec("delta:5/sparse/trace")
    assert c.trace and c.adapt is None and c.name.endswith("/trace")
    assert SolverConfig.from_spec(c.name) == c  # round-trip
    assert c.engine_config("sssp").adapt_window == c.adapt_window > 0
    # untraced spec keeps the unsegmented engine
    base = SolverConfig.from_spec("delta:5/sparse")
    assert base.engine_config("sssp").adapt_window == 0
    with pytest.raises(ValueError, match="duplicate trace"):
        SolverConfig.from_spec("delta:5/trace/trace")
    with pytest.raises(ValueError, match="takes no argument"):
        SolverConfig.from_spec("delta:5/trace:4")
    with pytest.raises(ValueError, match="repair loop"):
        SolverConfig.from_spec("delta:5/sparse/q:bf16/trace")
    # /adapt composition: one segmentation serves both
    both = SolverConfig.from_spec("delta:5/sparse/adapt:rho/trace")
    assert both.trace and both.adapt == "rho"


# ------------------------------------------- bit-identity grid


GRID_SPECS = [
    "chaotic",
    "dijkstra",
    "delta:5",
    "delta:5+nodeq",
    "delta:20+threadq",
    "kla:2",
    "delta:5 > chunk:delta:1",
]


@pytest.mark.parametrize("exchange", ["a2a", "sparse"])
@pytest.mark.parametrize("root", GRID_SPECS)
def test_trace_bit_identity(root, exchange, tiny_graphs):
    """A /trace solve must be bit-identical to the untraced solve —
    state AND metrics — and its per-superstep sums must reconcile
    exactly with the aggregate."""
    g = tiny_graphs[0]
    prob = Problem(g, SingleSource(0))
    base = Solver(f"{root}/{exchange}").solve(prob)
    traced = Solver(f"{root}/{exchange}/trace").solve(prob)
    assert np.array_equal(base.state, traced.state)
    assert base.metrics == traced.metrics
    tr = traced.trace
    assert tr is not None and base.trace is None
    tr.reconcile(traced.metrics)
    assert tr.supersteps == traced.metrics.supersteps
    assert tr.segments
    assert tr.config_name == traced.config.name


def test_trace_all_graphs(tiny_graphs):
    """One spec across every fixture graph shape."""
    for g in tiny_graphs:
        prob = Problem(g, SingleSource(1))
        base = Solver("delta:5/sparse").solve(prob)
        traced = Solver("delta:5/sparse/trace").solve(prob)
        assert np.array_equal(base.state, traced.state), g.name
        assert base.metrics == traced.metrics, g.name
        traced.trace.reconcile(traced.metrics)


def test_trace_segments_cover_supersteps(tiny_graphs):
    sol = Solver("delta:5/sparse/trace").solve(
        Problem(tiny_graphs[0], SingleSource(0)))
    tr = sol.trace
    assert sum(s["supersteps"] for s in tr.segments) == tr.supersteps
    assert all(s["t1"] >= s["t0"] for s in tr.segments)
    assert tr.pending[-1] == 0  # converged
    # table renders one row per superstep plus header/footer
    lines = tr.table().splitlines()
    assert len(lines) == tr.supersteps + 3


def test_trace_batch_rejected(tiny_graphs):
    s = Solver("delta:5/sparse/trace")
    probs = [Problem(tiny_graphs[0], SingleSource(i)) for i in (0, 1)]
    with pytest.raises(ValueError, match="flight recorder"):
        s.solve_batch(probs)


def test_trace_resolve_counts_host_sweep(tiny_graphs):
    """resolve()'s host bootstrap sweep has no engine window; the trace
    counts it so the superstep balance stays exact."""
    import copy

    g = copy.deepcopy(tiny_graphs[0])
    s = Solver("delta:5/sparse/trace")
    sol = s.solve(Problem(g, SingleSource(0)))
    g.weight[:] = np.minimum(g.weight, np.float32(0.5))  # improving
    sol2 = s.resolve(sol, graph=g)
    assert sol2.trace is not None
    assert sol2.trace.host_sweeps == 1
    sol2.trace.reconcile(sol2.metrics)
    cold = Solver("delta:5/sparse").solve(Problem(g, SingleSource(0)))
    assert np.array_equal(sol2.state, cold.state)


def test_trace_reconcile_catches_mismatch():
    tr = SolveTrace(pending=[2, 0], eligible=[2, 1], rows=[2, 1],
                    sparse_used=[1, 1], bytes_moved=[0, 0],
                    sparse_capable=True)
    m = WorkMetrics(supersteps=2, commits=5, exchange_bytes=0)
    with pytest.raises(AssertionError, match="commits"):
        tr.reconcile(m)  # Σeligible is 3, not 5
    m = WorkMetrics(supersteps=5, commits=3)
    with pytest.raises(AssertionError, match="supersteps"):
        tr.reconcile(m)


def test_recorder_accumulates_segments():
    from repro.core.metrics import SuperstepWindow

    rec = FlightRecorder("spec")
    w = SuperstepWindow(pending=[3, 1], eligible=[2, 2], rows=[2, 2],
                        sparse_used=[1, 0], bytes_moved=[8, 16],
                        overflow_streak=0, supersteps_total=2, n=16,
                        rows_per_rank=16, sparse_capable=True)
    rec.on_window(w, {"supersteps": 2, "t0": 1.0, "t1": 2.0})
    rec.on_window(w)
    tr = rec.finish(WorkMetrics())
    assert tr.supersteps == 4 and tr.total_bytes() == 48
    assert [s["segment"] for s in tr.segments] == [0, 1]
    assert tr.segments[1]["t1"] >= tr.segments[1]["t0"]


# -------------------------------------------------- solver spans


def test_solver_solve_emits_spans(tiny_graphs):
    tr = Tracer()
    s = Solver("delta:5/sparse/trace")
    with use_tracer(tr):
        sol = s.solve(Problem(tiny_graphs[0], SingleSource(0)))
    solve_span, = tr.find("solver.solve")
    assert solve_span.attrs["supersteps"] == sol.metrics.supersteps
    assert solve_span.attrs["converged"] is True
    assert tr.find("solver.partition")
    segs = tr.find("tune.segment")
    assert len(segs) == len(sol.trace.segments)
    assert all(sp.parent_id is not None for sp in segs)
    names = {e.name for e in tr.events}
    assert "engine_cache_miss" in names or "engine_cache_hit" in names


def test_spec_check_trace_rules():
    from repro.analyze.spec_check import check_config

    fs = check_config("delta:5/sparse/trace")
    rules = {f.rule for f in fs}
    assert "trace-no-batch" in rules
    assert "trace-adapt-composition" not in rules
    fs = check_config("delta:5/sparse/adapt:rho/trace")
    assert any(f.rule == "trace-adapt-composition" and f.severity == "warn"
               for f in fs)
    fs = check_config(SolverConfig.from_spec("delta:5/sparse/trace",
                                             collect_metrics=False))
    assert any(f.rule == "trace-forces-metrics" for f in fs)


# --------------------------------------- serving tier observability


def test_router_latency_ring_and_evictions(tiny_graphs):
    from repro.serve import Router

    g = tiny_graphs[0]
    r = Router(Solver("delta:5/sparse"), g, latency_window=4)
    for ms in (1, 2, 3, 4, 5, 6):
        r._record_latency(ms / 1e3)
    assert r.stats.latency_evictions == 2
    st = r.latency_stats()
    assert st.count == 4
    assert st.min_s == pytest.approx(0.003)
    assert st.max_s == pytest.approx(0.006)
    with pytest.raises(ValueError, match="latency_window"):
        Router(Solver("delta:5/sparse"), g, latency_window=0)


def test_router_flush_span_carries_qids(tiny_graphs):
    from repro.serve import Query, Router

    g = tiny_graphs[0]
    tr = Tracer()
    router = Router(Solver("delta:5/sparse"), g, max_batch=4)
    with use_tracer(tr):
        t1 = router.submit(Query(0))
        t2 = router.submit(Query(0, target=3))
        router.flush()
    assert (t1.qid, t2.qid) == (1, 2)
    flush, = tr.find("router.flush")
    assert flush.attrs["qids"] == [1, 2]
    assert flush.attrs["solved"] == 1  # deduped to one source
    submits = [e for e in tr.events if e.name == "router.submit"]
    assert [e.attrs["qid"] for e in submits] == [1, 2]
    assert any(e.name == "router.cache_fill" for e in tr.events)
    assert router.latency_stats().count == 2


# ---------------------------------------------------- metrics satellites


def test_workmetrics_str_shows_anomalies_only_when_nonzero():
    clean = str(WorkMetrics(supersteps=3, commits=2, relaxations=4))
    for field in ("sparse_fallbacks", "retraces", "repair_sweeps",
                  "overflow_streak"):
        assert field not in clean
    noisy = str(WorkMetrics(supersteps=3, sparse_fallbacks=2, retraces=1,
                            repair_sweeps=4, overflow_streak=5,
                            converged=False))
    assert "sparse_fallbacks=2" in noisy
    assert "retraces=1" in noisy
    assert "repair_sweeps=4" in noisy
    assert "overflow_streak=5" in noisy
    assert noisy.endswith("TRUNCATED")


def test_latency_stats_min_and_merge():
    a = LatencyStats.from_samples([0.001, 0.002, 0.003])
    b = LatencyStats.from_samples([0.010])
    assert a.min_s == 0.001 and b.min_s == 0.010
    m = a.merge(b)
    assert m.count == 4
    assert m.total_s == pytest.approx(0.016)
    assert m.mean_s == pytest.approx(0.004)
    assert m.min_s == 0.001 and m.max_s == 0.010
    # count-weighted percentile approximation
    assert m.p50_s == pytest.approx((a.p50_s * 3 + b.p50_s * 1) / 4)
    # empty windows merge to a copy, not a crash
    empty = LatencyStats()
    assert empty.merge(a) == a and a.merge(empty) == a


# ------------------------------------------------- 8-device subprocess


CHILD_OBS = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.api import Problem, SingleSource, Solver
from repro.graph import rmat1
from repro.obs import Tracer, use_tracer

g = rmat1(9, seed=0)
prob = Problem(g, SingleSource(0))
for spec in ("delta:5/sparse", "delta:20+threadq/a2a"):
    base = Solver(spec).solve(prob)
    tracer = Tracer()
    with use_tracer(tracer):
        traced = Solver(spec + "/trace").solve(prob)
    assert np.array_equal(base.state, traced.state), spec
    assert base.metrics == traced.metrics, spec
    tr = traced.trace
    tr.reconcile(traced.metrics)
    assert tr.supersteps == traced.metrics.supersteps
    # multi-device: the dense/sparse byte accounting is live (P > 1)
    assert traced.metrics.exchange_bytes > 0, spec
    assert tr.total_bytes() == traced.metrics.exchange_bytes, spec
    assert tracer.find("solver.solve") and tracer.find("tune.segment")
print("OBS-MULTIDEV-OK")
"""


@pytest.mark.slow
def test_trace_bit_identity_8_devices():
    """The tentpole claim on a real 8-way mesh: traced state, metrics,
    and per-superstep byte sums all match the untraced solve."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", CHILD_OBS], env=env,
        capture_output=True, text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OBS-MULTIDEV-OK" in r.stdout
