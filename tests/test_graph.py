"""Graph substrate: formats, generators, partitioner, sampler."""

import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="optional dev dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.graph import (
    Graph, coo_to_csr, csr_to_ell, partition_1d, rmat1,
)
from repro.graph.partition import chunk_fat_rows, default_ell_width
from repro.graph.sampler import FanoutSampler


def edge_set(g: Graph):
    return set(zip(g.src.tolist(), g.dst.tolist(), g.weight.tolist()))


def test_csr_roundtrip(tiny_graphs):
    for g in tiny_graphs:
        csr = coo_to_csr(g)
        assert csr.m == g.m
        out = set()
        for v in range(g.n):
            nbrs, ws = csr.neighbors(v)
            out.update(
                (v, int(u), float(w)) for u, w in zip(nbrs, ws)
            )
        assert out == edge_set(g)


def test_ell_padding(tiny_graphs):
    g = tiny_graphs[0]
    csr = coo_to_csr(g)
    ell = csr_to_ell(csr)
    real = int(np.sum(ell.col != ell.pad_col))
    assert real == g.m
    assert np.all(np.isinf(ell.weight[ell.col == ell.pad_col]))


@given(
    n=st.integers(8, 60),
    m=st.integers(1, 300),
    parts=st.sampled_from([1, 2, 4, 8]),
    width=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 5),
)
@settings(max_examples=25, deadline=None)
def test_partition_roundtrip_property(n, m, parts, width, seed):
    rng = np.random.default_rng(seed)
    g = Graph(
        n,
        rng.integers(0, n, m),
        rng.integers(0, n, m),
        rng.uniform(1, 9, m).astype(np.float32),
    ).deduplicated()
    pg = partition_1d(g, parts, width=width)
    got = set()
    for p in range(pg.n_parts):
        for r in range(pg.rows_per_rank):
            src_local = pg.row_src[p, r]
            if src_local == pg.n_local:
                continue
            gsrc = p * pg.n_local + src_local
            for s in range(pg.width):
                d = pg.col[p, r, s]
                if d != pg.n_pad:
                    got.add((int(gsrc), int(d), float(pg.wgt[p, r, s])))
    assert got == edge_set(g)


def test_fat_row_chunking_bounds():
    g = rmat1(9, seed=1)
    csr = coo_to_csr(g)
    w = 8
    row_src, col, wgt = chunk_fat_rows(csr, w, pad_col=g.n)
    # every virtual row has <= w real entries, and the union is exact
    assert col.shape[1] == w
    per_row_real = np.sum(col != g.n, axis=1)
    assert per_row_real.max() <= w
    assert per_row_real.sum() == g.m


def test_default_width_bounds():
    assert 4 <= default_ell_width(0.5) <= 128
    assert default_ell_width(1000) == 128


def test_sampler_block_invariants(tiny_graphs):
    g = tiny_graphs[3]
    s = FanoutSampler(g, [4, 3], seed=0)
    seeds = np.arange(32, dtype=np.int32)
    blk = s.sample(seeds)
    assert blk.n_seeds == 32
    assert np.array_equal(blk.nodes[:32], seeds)
    # edges reference valid block-local nodes
    assert blk.edge_dst[: blk.n_edges].max() < blk.n_nodes
    assert blk.edge_src[: blk.n_edges].max() < blk.n_nodes
    # every sampled edge exists in the graph
    es = edge_set(g)
    pairs = {(int(a), int(b)) for a, b, _ in es}
    for i in range(blk.n_edges):
        u = int(blk.nodes[blk.edge_src[i]])
        v = int(blk.nodes[blk.edge_dst[i]])
        assert (u, v) in pairs
    # padded sizes are static upper bounds
    npad, epad = s.padded_sizes(32)
    assert blk.nodes.shape[0] == npad
    assert blk.edge_src.shape[0] == epad
    assert blk.n_nodes <= npad and blk.n_edges <= epad
