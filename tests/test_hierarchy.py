"""The hierarchical ordering algebra: per-level EAGM annotations
(core/eagm.Hierarchy), the ordering registry + TopK drain, the spec
grammar v2, preset/legacy equivalence, and multi-level hierarchy
solves against the reference CPU solver.

Property-based round-trip tests (hypothesis) live at the bottom and
skip themselves when hypothesis is absent; everything else always
runs.
"""

import jax
import numpy as np
import pytest

from repro.api import Problem, SingleSource, Solver, SolverConfig
from repro.core import (
    Chaotic,
    DeltaStepping,
    Dijkstra,
    EngineConfig,
    Hierarchy,
    KLA,
    TopK,
    dijkstra_reference,
    make_hierarchy,
    make_ordering,
    make_policy,
    paper_variant_grid,
    paper_variant_specs,
)
from repro.graph.formats import Graph


def close(a, b):
    return np.allclose(
        np.where(np.isinf(a), -1, a), np.where(np.isinf(b), -1, b)
    )


@pytest.fixture(scope="module")
def mesh1():
    return jax.make_mesh((1,), ("data",))


# ----------------------------------------------------- ordering registry


def test_ordering_spec_round_trips():
    for spec in ["chaotic", "dijkstra", "delta:3", "delta:7.5", "kla:1",
                 "kla:3", "topk:16", "topk:16:delta:3", "topk:8:chaotic"]:
        o = make_ordering(spec)
        assert make_ordering(o.spec) == o, spec


def test_ordering_protocol_uniform():
    """Every ordering exposes class_key/needs_level/drain/spec."""
    for o in [Chaotic(), Dijkstra(), DeltaStepping(3.0), KLA(2),
              TopK(16), TopK(16, KLA(2))]:
        assert callable(o.class_key)
        assert isinstance(o.needs_level, bool)
        assert o.drain is None or o.drain > 0
        assert isinstance(o.spec, str)
    assert KLA(2).needs_level and TopK(4, KLA(2)).needs_level
    assert not TopK(4).needs_level
    assert TopK(16).drain == 16 and Dijkstra().drain is None


def test_ordering_validation_and_did_you_mean():
    with pytest.raises(ValueError, match="unknown ordering"):
        make_ordering("bogus")
    with pytest.raises(ValueError, match="did you mean 'dijkstra'"):
        make_ordering("dikstra")
    with pytest.raises(ValueError, match="bad argument"):
        make_ordering("delta:abc")
    with pytest.raises(ValueError, match="positive"):
        TopK(0)
    with pytest.raises(ValueError, match="nest"):
        TopK(4, TopK(8))


# ------------------------------------------------- hierarchy value type


def test_hierarchy_construction_and_accessors():
    h = Hierarchy.from_spec("delta:5 > pod:dijkstra > chunk:delta:1")
    assert h.root == DeltaStepping(5.0)
    assert h.sub == (("pod", Dijkstra()), ("chunk", DeltaStepping(1.0)))
    assert h.at("pod") == Dijkstra() and h.at("device") is None
    assert not h.needs_level
    assert Hierarchy.from_spec("delta:5 > device:kla:2").needs_level
    # spec strings accepted directly in annotations
    assert Hierarchy((("global", "delta:5"),)) == Hierarchy.single("delta:5")


def test_hierarchy_spec_round_trips():
    for spec in [
        "chaotic",
        "delta:5 > pod:dijkstra",
        "delta:5 > pod:dijkstra > chunk:delta:1",
        "kla:2 > device:dijkstra > chunk:topk:64",
        "dijkstra > chunk:topk:16:delta:3",
        "global:delta:5 > pod:delta:3",
    ]:
        h = Hierarchy.from_spec(spec)
        assert Hierarchy.from_spec(h.spec) == h, spec
        assert Hierarchy.from_spec(h.name.split("/")[0]) == h, spec


def test_hierarchy_validation():
    # root must be global
    with pytest.raises(ValueError, match="global"):
        Hierarchy((("pod", Dijkstra()),))
    # levels must nest outermost -> innermost, no duplicates
    with pytest.raises(ValueError, match="outermost"):
        Hierarchy.from_spec("delta:5 > chunk:delta:1 > pod:dijkstra")
    with pytest.raises(ValueError, match="outermost"):
        Hierarchy.from_spec("delta:5 > pod:dijkstra > pod:delta:1")
    # TopK is local-only
    with pytest.raises(ValueError, match="device-local"):
        Hierarchy.from_spec("delta:5 > pod:topk:4")
    with pytest.raises(ValueError, match="device-local"):
        Hierarchy((("global", TopK(4)),))
    # malformed segments
    with pytest.raises(ValueError, match="empty annotation"):
        Hierarchy.from_spec("delta:5 > > chunk:topk:4")
    with pytest.raises(ValueError, match="no ordering"):
        Hierarchy.from_spec("delta:5 > pod")
    with pytest.raises(ValueError, match="did you mean 'pod'"):
        Hierarchy.from_spec("delta:5 > pid:dijkstra")
    with pytest.raises(ValueError):
        Hierarchy(())


def test_variant_presets_in_terms_of_hierarchies():
    """buffer/nodeq/numaq/threadq are points of the hierarchy algebra,
    and the legacy EAGMPolicy shim constructs exactly those points."""
    expect = {
        "buffer": (("global", DeltaStepping(5.0)),),
        "nodeq": (("global", DeltaStepping(5.0)), ("pod", Dijkstra())),
        "numaq": (("global", DeltaStepping(5.0)), ("device", Dijkstra())),
        "threadq": (("global", DeltaStepping(5.0)), ("chunk", TopK(64))),
    }
    for variant, annos in expect.items():
        h = make_hierarchy("delta:5", variant, chunk_size=64)
        assert h.annotations == annos, variant
        assert h.variant == variant
        assert make_policy("delta:5", variant, 64).hierarchy == h, variant


def test_policy_and_variant_validation():
    with pytest.raises(ValueError, match="did you mean 'threadq'"):
        make_hierarchy("delta:5", "threadqq")
    with pytest.raises(ValueError, match="variant"):
        make_policy("delta:5", "warpq")


def test_paper_grid_is_subset_of_family_space():
    """Every paper spec parses to a preset hierarchy: the Fig. 4 grid
    is a finite subset of the space Hierarchy spans."""
    specs = paper_variant_specs(deltas=(5.0,), ks=(2,))
    grid = paper_variant_grid(deltas=(5.0,), ks=(2,))
    assert len(specs) == len(grid) == 3 * 4 + 1  # the 13-point Fig. 4 core
    for spec, h in zip(specs, grid):
        assert isinstance(h, Hierarchy)
        assert h.variant is not None, spec              # a preset point
        cfg = SolverConfig.from_spec(spec)
        assert cfg.hierarchy == h, spec                 # spec -> same point
    names = {h.name for h in paper_variant_grid()}
    assert {"chaotic+threadq", "delta:5+buffer", "dijkstra+buffer"} <= names


# ------------------------------------------------------ config grammar


def test_from_spec_hierarchy_grammar():
    c = SolverConfig.from_spec("delta:5 > pod:dijkstra > chunk:delta:1 /sparse")
    assert c.exchange == "sparse"
    assert c.hierarchy == Hierarchy.from_spec(
        "delta:5 > pod:dijkstra > chunk:delta:1"
    )
    assert c.root == "delta:5" and c.variant == "hierarchy"
    # chunk_size flows into a bare chunk:topk
    c = SolverConfig.from_spec("chaotic > chunk:topk", chunk_size=32)
    assert c.hierarchy.at("chunk") == TopK(32)


def test_legacy_and_hierarchy_forms_are_equal():
    """The same family point is the same config (and the same engine
    cache key) no matter which grammar spelled it."""
    pairs = [
        ("delta:5+buffer", "delta:5"),
        ("kla:2+nodeq", "kla:2 > pod:dijkstra"),
        ("chaotic+numaq", "chaotic > device:dijkstra"),
        ("delta:5+threadq", "delta:5 > chunk:topk:1024"),
    ]
    for legacy, v2 in pairs:
        a, b = SolverConfig.from_spec(legacy), SolverConfig.from_spec(v2)
        assert a == b and hash(a) == hash(b), (legacy, v2)
    # and at the EngineConfig layer through the EAGMPolicy shim
    e1 = EngineConfig(policy=make_policy("delta:5", "threadq", 64))
    e2 = EngineConfig(policy=Hierarchy.from_spec("delta:5 > chunk:topk:64"))
    e3 = EngineConfig(policy="delta:5 > chunk:topk:64")
    assert e1 == e2 == e3 and hash(e1) == hash(e2) == hash(e3)


def test_name_round_trips_explicit():
    for spec in [
        "delta:5+threadq/pmin",
        "kla:2+nodeq/sparse",
        "chaotic+buffer/a2a",
        "dijkstra+buffer/auto",
        "delta:5 > pod:dijkstra > chunk:delta:1 /sparse",
        "kla:2 > device:dijkstra/pmin",
        "chaotic > chunk:topk:64/a2a",
    ]:
        cfg = SolverConfig.from_spec(spec)
        assert SolverConfig.from_spec(cfg.name) == cfg, spec


def test_name_prefers_legacy_form_for_presets():
    assert SolverConfig.from_spec("delta:5+threadq").name \
        == "delta:5+threadq/a2a"
    assert SolverConfig.from_spec("delta:5 > pod:dijkstra").name \
        == "delta:5+nodeq/a2a"
    # non-default chunk size cannot hide in the legacy form
    assert SolverConfig(root="delta:5", variant="threadq",
                        chunk_size=64).name \
        == "delta:5 > chunk:topk:64/a2a"


@pytest.mark.parametrize(
    "bad",
    [
        "",
        "   ",
        "delta:5+",          # empty variant
        "delta:5+ ",         # whitespace-only variant
        "+threadq",          # empty root
        "delta:5/",          # empty exchange
        " /a2a",             # empty ordering part
        "delta:5 > ",        # empty trailing annotation
        "delta:5 >  > chunk:topk:4",
        "delta:5 > pod",     # level without ordering
    ],
)
def test_from_spec_rejects_malformed(bad):
    with pytest.raises(ValueError) as ei:
        SolverConfig.from_spec(bad)
    assert repr(bad.strip() or bad) in str(ei.value) or "spec" in str(ei.value)


def test_engine_config_error_messages():
    h = Hierarchy.single("delta:5")
    with pytest.raises(ValueError, match="exchange must be one of"):
        EngineConfig(policy=h, exchange="rdma")
    with pytest.raises(ValueError, match="did you mean 'sparse'"):
        EngineConfig(policy=h, exchange="spars")
    with pytest.raises(ValueError, match="relax_impl must be one of"):
        EngineConfig(policy=h, relax_impl="cuda")
    with pytest.raises(ValueError, match="did you mean 'pallas'"):
        EngineConfig(policy=h, relax_impl="palas")


def test_solver_config_did_you_mean():
    with pytest.raises(ValueError, match="did you mean 'pmin'"):
        SolverConfig(exchange="pmim")
    with pytest.raises(ValueError, match="did you mean 'numaq'"):
        SolverConfig(variant="numq")


# ------------------------------------------- engine: hierarchy solves


# genuinely new >= 2-annotation family points, inexpressible in the
# one-slot variant API
NEW_HIERARCHIES = [
    "delta:5 > pod:dijkstra > chunk:delta:1",
    "delta:7 > pod:delta:3 > chunk:topk:16",
    "chaotic > device:dijkstra > chunk:topk:8",
    "kla:2 > pod:dijkstra > device:dijkstra",
    "delta:5 > pod:delta:2 > device:dijkstra > chunk:topk:4",
]


def _random_graph(seed, n=180, m=900):
    rng = np.random.default_rng(seed)
    return Graph(
        n,
        rng.integers(0, n, m),
        rng.integers(0, n, m),
        rng.uniform(0.1, 4.0, m).astype(np.float32),
        name=f"rand{seed}",
    )


@pytest.mark.parametrize("spec", NEW_HIERARCHIES)
def test_multilevel_hierarchy_matches_reference(mesh1, spec):
    """Beyond-paper >= 2-annotation hierarchies solve SSSP correctly
    on random graphs (vs the reference CPU Dijkstra)."""
    for seed in (0, 1):
        g = _random_graph(seed)
        ref = dijkstra_reference(g, 0)
        sol = Solver(SolverConfig.from_spec(spec), mesh=mesh1).solve(
            Problem(g, SingleSource(0))
        )
        assert close(ref, sol.state), (spec, seed)
        assert sol.metrics.converged


@pytest.mark.parametrize("exchange", ["a2a", "sparse", "auto"])
def test_multilevel_hierarchy_exchange_modes_bit_identical(mesh1, exchange):
    """The sparse/auto exchange modes reproduce the dense result
    bit-for-bit on a multi-level hierarchy (they change HOW candidates
    move, never WHICH candidates exist)."""
    g = _random_graph(7)
    dense = Solver(
        SolverConfig.from_spec(NEW_HIERARCHIES[0], exchange="a2a"),
        mesh=mesh1,
    ).solve(Problem(g, SingleSource(0)))
    sol = Solver(
        SolverConfig.from_spec(NEW_HIERARCHIES[0], exchange=exchange,
                               frontier_cap=32),
        mesh=mesh1,
    ).solve(Problem(g, SingleSource(0)))
    assert np.array_equal(dense.state, sol.state)
    assert sol.metrics.supersteps == dense.metrics.supersteps


def test_refinement_narrows_work(mesh1, tiny_graphs):
    """Adding annotations only refines eligibility: a refined
    hierarchy never relaxes more edges per superstep, and never fewer
    supersteps, than its root alone (the paper's §IV tradeoff)."""
    g = tiny_graphs[0]
    base = Solver(SolverConfig.from_spec("delta:20"), mesh=mesh1).solve(
        Problem(g, SingleSource(0))
    )
    refined = Solver(
        SolverConfig.from_spec("delta:20 > device:dijkstra > chunk:topk:8"),
        mesh=mesh1,
    ).solve(Problem(g, SingleSource(0)))
    assert refined.metrics.relaxations <= base.metrics.relaxations
    assert refined.metrics.supersteps >= base.metrics.supersteps
    ref = dijkstra_reference(g, 0)
    assert close(ref, base.state) and close(ref, refined.state)


def test_legacy_threadq_bit_identical_to_topk_hierarchy(mesh1, tiny_graphs):
    """The acceptance anchor: the preset grid re-expressed on the new
    algebra is the same engine — same config, same cache key, and a
    solve through the EAGMPolicy shim is bit-identical to one through
    an explicitly constructed hierarchy."""
    g = tiny_graphs[1]
    for root, variant in [("delta:5", "threadq"), ("kla:2", "nodeq"),
                          ("chaotic", "numaq"), ("dijkstra", "buffer")]:
        legacy = SolverConfig(root=root, variant=variant, chunk_size=64)
        explicit = SolverConfig(
            hierarchy=make_policy(root, variant, 64).hierarchy,
            chunk_size=64,
        )
        assert legacy == explicit
        a = Solver(legacy, mesh=mesh1).solve(Problem(g, SingleSource(0)))
        b = Solver(explicit, mesh=mesh1).solve(Problem(g, SingleSource(0)))
        assert np.array_equal(a.state, b.state), (root, variant)
        assert a.metrics.supersteps == b.metrics.supersteps


# ------------------------------------------------- list-variants CLI


def test_list_variants_lines():
    from repro.launch.sssp import list_variants_lines

    lines = list_variants_lines()
    text = "\n".join(lines)
    assert "delta:5+threadq" in text
    assert "pmin over intra-pod axes" in text     # scopes are explained
    assert "delta:5 > pod:dijkstra" in text       # beyond-paper examples
    assert len(lines) > 20


# Property-based round-trip tests (hypothesis) live in
# tests/test_hierarchy_property.py so this module always runs.
