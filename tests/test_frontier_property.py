"""Property-based sparse/auto vs dense equivalence on arbitrary random
graphs (hypothesis; skips itself when the optional dep is absent).

Every example partitions its random graph into ONE fixed (P=1,
n_local, R, W) ELL shape — virtual rows padded up to a static cap — so
the whole run reuses a handful of compiled engines instead of
re-tracing per graph."""

import dataclasses

import jax
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="optional dev dependency (requirements-dev.txt)"
)
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.api import Problem, SingleSource, Solver, SolverConfig
from repro.core import dijkstra_reference
from repro.graph import partition_1d
from repro.graph.formats import Graph

SPECS = [
    "delta:5+threadq", "kla:2+buffer", "dijkstra+buffer", "chaotic+numaq",
]
N_FIXED, W_FIXED, R_FIXED = 48, 4, 192


@pytest.fixture(scope="module")
def mesh1():
    return jax.make_mesh((1,), ("data",))


def close(a, b):
    return np.allclose(
        np.where(np.isinf(a), -1, a), np.where(np.isinf(b), -1, b)
    )


def _fixed_shape_pg(m, seed):
    r = np.random.default_rng(seed)
    g = Graph(
        N_FIXED,
        r.integers(0, N_FIXED, m).astype(np.int64),
        r.integers(0, N_FIXED, m).astype(np.int64),
        r.uniform(0.5, 20.0, m).astype(np.float32),
    )
    pg = partition_1d(g, 1, width=W_FIXED)
    R = pg.row_src.shape[1]
    assert R <= R_FIXED, R
    pad = R_FIXED - R
    row_src = np.concatenate(
        [pg.row_src, np.full((1, pad), pg.n_local, np.int32)], axis=1
    )
    col = np.concatenate(
        [pg.col, np.full((1, pad, W_FIXED), pg.n_pad, np.int32)], axis=1
    )
    wgt = np.concatenate(
        [pg.wgt, np.full((1, pad, W_FIXED), np.inf, np.float32)], axis=1
    )
    return g, dataclasses.replace(pg, row_src=row_src, col=col, wgt=wgt)


@pytest.mark.slow
@settings(
    max_examples=20, deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
@given(
    m=st.integers(min_value=10, max_value=120),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    spec=st.sampled_from(SPECS),
    exchange=st.sampled_from(["sparse", "auto"]),
    cap=st.sampled_from([None, 4]),
    source=st.integers(min_value=0, max_value=N_FIXED - 1),
)
def test_property_sparse_matches_dense(
    mesh1, m, seed, spec, exchange, cap, source
):
    """Any sparse/auto family member's state is bit-identical to its
    dense twin, and both match the Dijkstra oracle."""
    g, pg = _fixed_shape_pg(m, seed)
    dense = Solver(
        SolverConfig.from_spec(spec, exchange="a2a", chunk_size=16),
        mesh=mesh1,
    ).solve(Problem(pg, SingleSource(source)))
    sp = Solver(
        SolverConfig.from_spec(
            spec, exchange=exchange, chunk_size=16, frontier_cap=cap
        ),
        mesh=mesh1,
    ).solve(Problem(pg, SingleSource(source)))
    assert np.array_equal(dense.state, sp.state)
    assert close(dijkstra_reference(g, source), sp.state)
