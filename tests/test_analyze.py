"""repro.analyze: the self-stabilization contract verifier, the
jaxpr/HLO engine lint, the spec cross-checks and the CI report gate."""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analyze import (
    Finding,
    check_config,
    explain_config,
    fingerprint,
    lint_engine,
    lint_hlo_text,
    load_baseline,
    payload_capacity,
    run_report,
    split_baselined,
    verify_processing,
    verify_registered,
)
from repro.analyze.contract import reachable_domain
from repro.analyze.findings import baseline_records, gate_failures
from repro.analyze.jaxpr_lint import StepShape, payload_index_capacity
from repro.analyze.report import grid_specs, render_report
from repro.api import SolverConfig, get_processing, processing_names
from repro.core.processing import ProcessingFn

# ------------------------------------------------------------ findings


def test_finding_severity_validated():
    with pytest.raises(ValueError):
        Finding("spec", "r", "fatal", "s", "m")


def test_fingerprint_ignores_message():
    a = Finding("spec", "r", "warn", "s", "one wording", witness="w")
    b = Finding("spec", "r", "warn", "s", "another wording", witness="w")
    c = Finding("spec", "r", "warn", "s", "one wording", witness="x")
    assert fingerprint(a) == fingerprint(b)
    assert fingerprint(a) != fingerprint(c)


def test_baseline_roundtrip(tmp_path):
    f = Finding("jaxpr", "weak-scalar", "warn", "subj", "msg")
    info = Finding("spec", "note", "info", "subj", "msg")
    path = tmp_path / "base.json"
    path.write_text(json.dumps(baseline_records([f, info])))
    base = load_baseline(str(path))
    assert fingerprint(f) in base
    # info findings are never baselined ...
    assert fingerprint(info) not in base
    fresh, old = split_baselined([f, info], base)
    assert old == [f]
    # ... and never gate
    assert fresh == [info] and gate_failures(fresh) == []


def test_baseline_missing_file_is_empty():
    assert load_baseline("/nonexistent/analyze_baseline.json") == set()


def test_baseline_accepts_bare_fingerprint_strings(tmp_path):
    f = Finding("spec", "r", "error", "s", "m")
    path = tmp_path / "b.json"
    path.write_text(json.dumps([fingerprint(f)]))
    assert fingerprint(f) in load_baseline(str(path))


# ------------------------------------------------- contract verifier


def test_registered_processing_fns_satisfy_contract():
    results = verify_registered()
    assert set(results) >= {"sssp", "bfs", "cc", "sswp"}
    bad = {k: [str(v) for v in vs] for k, vs in results.items() if vs}
    assert not bad, f"registered kernels violate the contract: {bad}"


def test_reachable_domain_is_reachable_and_bounded():
    dom = reachable_domain(get_processing("sssp"))
    assert 0.0 in dom and float("inf") in dom
    assert 3 <= len(dom) <= 48


def test_broken_sum_reduce_rejected_with_law_and_witness():
    # additive combine: not idempotent, not selective, not monotone —
    # the classic non-self-stabilizing kernel
    broken = ProcessingFn(
        name="broken-sum",
        edge_update=lambda s, w: s + w,
        better=lambda a, b: a < b,
        reduce=lambda a, b: a + b,
        worst=float("inf"),
    )
    vs = verify_processing(broken)
    laws = {v.law for v in vs}
    assert "reduce-idempotent" in laws
    for v in vs:
        assert v.witness, f"violation without witness: {v}"
        assert v.processing == "broken-sum"
    # each violation renders law + witness for the diagnostic
    msg = str(vs[0])
    assert "law" in msg and "witness" in msg


def test_non_strict_better_rejected():
    lax = ProcessingFn(
        name="broken-le",
        edge_update=lambda s, w: s + w,
        better=lambda a, b: a <= b,  # not irreflexive
        reduce=jnp.minimum,
        worst=float("inf"),
    )
    laws = {v.law for v in verify_processing(lax)}
    assert "better-irreflexive" in laws


def test_deflationary_edge_update_rejected():
    shrink = ProcessingFn(
        name="broken-shrink",
        edge_update=lambda s, w: s - 1.0,  # improves its own source
        better=lambda a, b: a < b,
        reduce=jnp.minimum,
        worst=float("inf"),
    )
    laws = {v.law for v in verify_processing(shrink)}
    assert "relax-inflationary" in laws


def test_wrong_worst_rejected():
    offtop = ProcessingFn(
        name="broken-worst",
        edge_update=lambda s, w: s + w,
        better=lambda a, b: a < b,
        reduce=jnp.minimum,
        worst=0.0,  # not the min-identity, not the top element
    )
    laws = {v.law for v in verify_processing(offtop)}
    assert laws & {"worst-identity", "worst-top", "source-init-improving"}


def test_custom_reduce_array_mismatch_caught():
    # ProcessingFn.reduce_array dispatches on `reduce is jnp.minimum`;
    # a hand-rolled min silently gets jnp.max — the verifier must see
    # the dense sweep and the exchange combine disagree
    handrolled = ProcessingFn(
        name="broken-handmin",
        edge_update=lambda s, w: s + w,
        better=lambda a, b: a < b,
        reduce=lambda a, b: jnp.where(a < b, a, b),
        worst=float("inf"),
    )
    laws = {v.law for v in verify_processing(handrolled)}
    assert "reduce-array-consistent" in laws


def test_violation_cap_per_law():
    broken = ProcessingFn(
        name="broken-cap",
        edge_update=lambda s, w: s + w,
        better=lambda a, b: a < b,
        reduce=lambda a, b: a + b,
        worst=float("inf"),
    )
    vs = verify_processing(broken)
    per_law: dict = {}
    for v in vs:
        per_law[v.law] = per_law.get(v.law, 0) + 1
    assert max(per_law.values()) <= 3 and len(vs) <= 64


# ------------------------------------------------------- jaxpr lint


@pytest.fixture(scope="module")
def lint_of():
    def run(spec, **kw):
        cfg = SolverConfig.from_spec(spec, **kw).engine_config(
            get_processing("sssp")
        )
        return lint_engine(cfg, StepShape())

    return run


@pytest.mark.parametrize(
    "spec",
    [
        "delta:5+buffer/a2a",
        "delta:5+threadq/pmin",
        "delta:5/sparse",
        "kla:2 > chunk:topk:16 /auto",
        "chaotic/a2a",
        "dijkstra/sparse",
        "delta:5/sparse/fused",
        "delta:5/sparse/q:bf16",
        "delta:5/sparse/fused/q:u16",
    ],
)
def test_engine_is_lint_clean(lint_of, spec):
    # the no-retrace regression: core/engine.py + core/frontier.py pin
    # every hot-loop Python constant, so the lint stays at zero
    findings = lint_of(spec)
    gating = [f for f in findings if f.severity != "info"]
    assert not gating, "\n".join(str(f) for f in gating)


def test_lint_survives_metrics_off(lint_of):
    assert not lint_of("delta:5/auto", collect_metrics=False)


def test_payload_index_capacity():
    assert payload_index_capacity(np.float32) == 1 << 24
    assert payload_index_capacity(np.float16) == 1 << 11
    assert payload_index_capacity(np.int32) == np.iinfo(np.int32).max
    assert payload_index_capacity(np.uint16) == 65535
    assert payload_index_capacity(jnp.bfloat16) == 1 << 8
    # the quantized exchange's index plane: u32 addresses any n_local
    assert payload_index_capacity(np.uint32) == (1 << 32) - 1
    assert payload_index_capacity("u32") == (1 << 32) - 1


def test_quantized_payload_plane_passes_overflow_lint(lint_of):
    """The u32-plane quantized payload must sail through the
    payload-overflow and payload-plane jaxpr rules — its index plane
    is exact and its axis-1 extent is the dtype-parametrized word
    count, not the f32 planes x slot_cap layout."""
    for spec in ("delta:5/sparse/q:bf16", "delta:5/sparse/q:u16"):
        findings = lint_of(spec)
        assert not [f for f in findings
                    if f.rule in ("payload-overflow", "payload-plane")]


def test_jaxpr_fused_kernel_escape():
    """A '/fused' spec whose processing is not min-plus silently falls
    back to the ref relax — the trace-level rule must say so."""
    cfg = SolverConfig.from_spec("delta:5/sparse/fused").engine_config(
        get_processing("cc")
    )
    fs = lint_engine(cfg, StepShape())
    assert any(f.rule == "fused-kernel-escape" and f.severity == "warn"
               for f in fs)


def test_payload_capacity_gate():
    ok, cap = payload_capacity("u16", n_local=1024)
    assert ok and cap == 65535
    ok, _ = payload_capacity("bf16", n_local=1024)
    assert not ok  # bf16 indices cannot address 1024 vertices exactly


# --------------------------------------------------------- hlo lint


_HLO_F64 = """
HloModule m
ENTRY %main (p0: f32[4]) -> f64[4] {
  %p0 = f32[4] parameter(0)
  ROOT %c = f64[4] convert(%p0)
}
"""

_HLO_NARROW = """
HloModule m
ENTRY %main (p0: u16[4,8]) -> u16[4,8] {
  %p0 = u16[4,8] parameter(0)
  ROOT %a2a = u16[4,8] all-to-all(%p0), dimensions={0}
}
"""


def test_hlo_lint_flags_f64():
    fs = lint_hlo_text(_HLO_F64, "t")
    assert any(f.rule == "hlo-f64" and f.severity == "error" for f in fs)


def test_hlo_lint_narrow_payload_overflow():
    fs = lint_hlo_text(_HLO_NARROW, "t", shape=StepShape(n_local=100000))
    assert any(f.rule == "hlo-payload-overflow" for f in fs)
    # and with a small enough partition the same payload is fine
    fs = lint_hlo_text(_HLO_NARROW, "t", shape=StepShape(n_local=64))
    assert not any(f.rule == "hlo-payload-overflow" for f in fs)


def test_hlo_lint_collective_plan():
    cfg = SolverConfig.from_spec("delta:5/sparse").engine_config(
        get_processing("sssp")
    )
    # a sparse spec whose module has no all-to-all: plan mismatch
    fs = lint_hlo_text(_HLO_F64, "t", cfg=cfg, n_parts=4)
    assert any(f.rule == "hlo-collective-plan" for f in fs)
    # single-device modules legally compile collectives away
    fs = lint_hlo_text(_HLO_F64, "t", cfg=cfg, n_parts=1)
    assert not any(f.rule == "hlo-collective-plan" for f in fs)


def test_hlo_lint_always_reports_stats():
    fs = lint_hlo_text(_HLO_NARROW, "t")
    stats = [f for f in fs if f.rule == "hlo-payload-bytes"]
    assert len(stats) == 1 and stats[0].severity == "info"
    assert "all-to-all" in stats[0].message


# -------------------------------------------------------- spec check


def test_spec_check_clean_point():
    assert check_config("delta:5+threadq/a2a") == []


def test_spec_check_frontier_cap_dense():
    fs = check_config(SolverConfig.from_spec("delta:5/a2a",
                                             frontier_cap=16))
    assert [f.rule for f in fs] == ["frontier-cap-dense"]


def test_spec_check_topk_exceeds_cap():
    fs = check_config(SolverConfig.from_spec(
        "delta:5 > chunk:topk:64 /sparse", frontier_cap=8))
    assert "topk-exceeds-frontier-cap" in {f.rule for f in fs}


def test_spec_check_partition_drift_is_info():
    fs = check_config("delta:5/sparse@ebal")
    drift = [f for f in fs if f.rule == "partition-layout-drift"]
    assert drift and drift[0].severity == "info"


def test_spec_check_shape_rules():
    shape = dict(n_local=64, rows=80, width=8, n_parts=4)
    fs = check_config(
        SolverConfig.from_spec("delta:5/sparse", frontier_cap=500),
        shape=shape,
    )
    assert "frontier-cap-exceeds-rows" in {f.rule for f in fs}


def test_spec_check_fused_escape_rules():
    # dense exchange: the fused kernel only exists on the sparse path
    fs = check_config("delta:5/a2a/fused")
    assert any(f.rule == "fused-kernel-escape" and f.severity == "warn"
               for f in fs)
    # level-bearing hierarchy: the kernel carries no level plane
    fs = check_config("kla:2/sparse/fused")
    assert any(f.rule == "fused-kernel-escape" for f in fs)
    # the supported point is silent
    fs = check_config("delta:5/sparse/fused")
    assert not any(f.severity != "info" for f in fs)


def test_spec_check_payload_rules():
    # quantized + dense exchange: the codec never runs
    fs = check_config("delta:5/a2a/q:bf16")
    assert any(f.rule == "payload-quantized-dense" for f in fs)
    # quantized + non-min reduce is rejected before the engine is
    fs = check_config(
        SolverConfig.from_spec("delta:5/sparse/q:u16"),
        processing="sswp",
    )
    assert any(f.rule == "payload-processing" and f.severity == "error"
               for f in fs)
    fs = check_config("delta:5/sparse/q:bf16")
    assert not any(f.severity != "info" for f in fs)


def test_solver_config_lint_method():
    cfg = SolverConfig.from_spec("delta:5/a2a", frontier_cap=16)
    assert [f.rule for f in cfg.lint()] == ["frontier-cap-dense"]


def test_explain_mentions_plan():
    txt = explain_config("delta:5 > chunk:delta:1 /sparse",
                         shape=dict(n_local=64, rows=80, width=8,
                                    n_parts=4))
    assert "all_to_all" in txt and "slot_cap" in txt
    assert "collective rounds" in txt
    txt = explain_config("delta:5+buffer/pmin")
    assert "all-reduce" in txt


# ------------------------------------------------------------ report


def test_grid_covers_at_least_100_points():
    assert len(grid_specs()) >= 100
    assert len(grid_specs(quick=True)) >= 100


def test_run_report_quick_gates_ok(tmp_path):
    rep = run_report(quick=True, with_hlo=False)
    assert rep["ok"], render_report(rep)
    assert rep["points"] >= 100
    assert rep["counts"]["error"] == 0 and rep["counts"]["warn"] == 0
    assert set(rep["processing_checked"]) >= {"sssp", "cc", "sswp"}
    # the report is JSON-serializable as-is
    (tmp_path / "r.json").write_text(json.dumps(rep))
    assert "GATE: OK" in render_report(rep)


def test_sparse_engine_no_retrace_after_dtype_pinning(tiny_graphs):
    # the weak-typed fallback-vote scalars (engine.py, pre-fix) could
    # fork the jit cache; with every hot-loop constant pinned the
    # sparse engine must trace exactly once per shape
    import jax

    import repro.api as api

    mesh = jax.make_mesh((1,), ("data",))
    solver = api.Solver("delta:5/sparse", mesh=mesh)
    g = tiny_graphs[0]
    solver.solve(api.Problem(g, api.SingleSource(0)))  # warm
    before = api.trace_count()
    for v in (1, 2, 3):
        solver.solve(api.Problem(g, api.SingleSource(v)))
    assert api.trace_count() == before, "sparse engine re-traced"


def test_registry_enumeration_and_suggestions():
    assert {"sssp", "bfs", "cc", "sswp"} <= set(processing_names())
    with pytest.raises(ValueError) as ei:
        get_processing("ssps")
    assert "did you mean" in str(ei.value)
