"""GNN models: invariance/equivariance properties, permutation
consistency, triplet builder correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.gnn import dimenet, egnn, gin, mace
from repro.models.gnn.batch import build_triplets, random_molecule_batch
from repro.models.gnn.geometry import real_gaunt_table, real_sph_harm_l2


@pytest.fixture(scope="module")
def mol():
    mb = random_molecule_batch(2, 10, 20, with_triplets=True,
                               triplet_pad=128, seed=3)
    return {k: jnp.asarray(v) for k, v in mb.__dict__.items()
            if v is not None}


def rot(theta=0.63, axis="z"):
    c, s = np.cos(theta), np.sin(theta)
    R = np.array([[c, -s, 0], [s, c, 0], [0, 0, 1]], np.float32)
    return jnp.asarray(R)


def test_gaunt_table_symmetry():
    G = real_gaunt_table()
    # fully symmetric in its three slots
    np.testing.assert_allclose(G, np.transpose(G, (1, 0, 2)), atol=1e-6)
    np.testing.assert_allclose(G, np.transpose(G, (0, 2, 1)), atol=1e-6)
    # G[0,a,b] = delta_ab / (2 sqrt(pi)) (Y00 is constant)
    expected = np.eye(9) * 0.5 / np.sqrt(np.pi)
    np.testing.assert_allclose(G[0], expected, atol=1e-6)


def test_sph_harm_orthonormal():
    """Quadrature check: <Y_a, Y_b> = delta_ab."""
    xs, ws = np.polynomial.legendre.leggauss(16)
    theta = np.arccos(xs)
    phi = np.linspace(0, 2 * np.pi, 32, endpoint=False)
    th, ph = np.meshgrid(theta, phi, indexing="ij")
    st = np.sin(th)
    xyz = np.stack(
        [st * np.cos(ph), st * np.sin(ph), np.cos(th)], -1
    ).astype(np.float32)
    Y = np.asarray(real_sph_harm_l2(jnp.asarray(xyz)))
    w = ws[:, None] * (2 * np.pi / 32)
    gram = np.einsum("tpa,tpb,tp->ab", Y, Y, np.broadcast_to(w, th.shape))
    np.testing.assert_allclose(gram, np.eye(9), atol=1e-5)


@pytest.mark.parametrize("model,make_cfg", [
    (egnn, lambda: egnn.EGNNConfig(n_layers=2, d_hidden=24, d_in=10)),
    (mace, lambda: mace.MACEConfig(n_layers=2, d_hidden=12, d_in=10)),
])
def test_e3_invariant_energy(model, make_cfg, mol, key):
    cfg = make_cfg()
    p = model.init_params(key, cfg)
    args = (mol["x"][0], mol["coords"][0], mol["edge_src"][0],
            mol["edge_dst"][0], mol["edge_mask"][0], cfg)
    e1 = model.energy(p, *args[:5], cfg)
    coords2 = mol["coords"][0] @ rot().T + jnp.asarray([3., -1., 0.5])
    e2 = model.energy(p, mol["x"][0], coords2, mol["edge_src"][0],
                      mol["edge_dst"][0], mol["edge_mask"][0], cfg)
    assert abs(float(e1 - e2)) < 1e-3 * max(1.0, abs(float(e1)))


def test_dimenet_e3_invariance(mol, key):
    cfg = dimenet.DimeNetConfig(n_blocks=2, d_hidden=16, d_in=10,
                                n_bilinear=4)
    p = dimenet.init_params(key, cfg)
    a = (mol["x"][0], mol["coords"][0], mol["edge_src"][0],
         mol["edge_dst"][0], mol["edge_mask"][0], mol["tri_kj"][0],
         mol["tri_ji"][0], mol["tri_mask"][0])
    e1 = dimenet.energy(p, *a, cfg)
    coords2 = mol["coords"][0] @ rot(1.2).T - jnp.asarray([1., 2., 3.])
    e2 = dimenet.energy(p, mol["x"][0], coords2, *a[2:], cfg)
    assert abs(float(e1 - e2)) < 1e-3 * max(1.0, abs(float(e1)))


def test_egnn_coordinate_equivariance(mol, key):
    """x' must rotate with the input frame (E(n) equivariance)."""
    cfg = egnn.EGNNConfig(n_layers=2, d_hidden=24, d_in=10)
    p = egnn.init_params(key, cfg)
    R = rot(0.9)
    t = jnp.asarray([0.3, -0.7, 2.0])
    h1, c1 = egnn.forward(p, mol["x"][0], mol["coords"][0],
                          mol["edge_src"][0], mol["edge_dst"][0],
                          mol["edge_mask"][0], cfg)
    h2, c2 = egnn.forward(p, mol["x"][0], mol["coords"][0] @ R.T + t,
                          mol["edge_src"][0], mol["edge_dst"][0],
                          mol["edge_mask"][0], cfg)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(c1 @ R.T + t), np.asarray(c2), rtol=1e-3, atol=1e-3
    )


def test_gin_permutation_equivariance(key):
    """Relabeling nodes permutes GIN outputs identically."""
    from repro.graph import small_world_graph

    g = small_world_graph(60, seed=7)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(g.n, 16)), jnp.float32)
    cfg = gin.GINConfig(n_layers=2, d_hidden=24, d_in=16, n_classes=4)
    p = gin.init_params(key, cfg)
    em = jnp.ones(g.m, bool)
    out1 = gin.forward(p, x, jnp.asarray(g.src), jnp.asarray(g.dst),
                       em, cfg)
    perm = rng.permutation(g.n)
    inv = np.argsort(perm)
    out2 = gin.forward(
        p, x[perm], jnp.asarray(inv[g.src]), jnp.asarray(inv[g.dst]),
        em, cfg,
    )
    np.testing.assert_allclose(
        np.asarray(out1[perm]), np.asarray(out2), rtol=2e-4, atol=2e-4
    )


def test_triplet_builder_exact():
    # path graph 0->1->2 plus 3->1: triplets for edge (1->2) are
    # incoming edges of 1 excluding backtrack from 2
    src = np.array([0, 1, 3, 2], np.int32)
    dst = np.array([1, 2, 1, 1], np.int32)
    kj, ji = build_triplets(src, dst, 4)
    pairs = set(zip(kj.tolist(), ji.tolist()))
    # edge ids: e0=(0->1), e1=(1->2), e2=(3->1), e3=(2->1)
    # triplets for e1=(1->2): k->1 with k != 2 -> {e0, e2}
    assert (0, 1) in pairs and (2, 1) in pairs
    assert (3, 1) not in pairs  # backtrack 2->1->2 excluded
    # triplets for e0=(0->1): incoming of 0: none
    assert not any(j == 0 for _, j in pairs)


def test_losses_finite_and_trainable(mol, key):
    batch = {
        "x": mol["x"], "coords": mol["coords"],
        "edge_src": mol["edge_src"], "edge_dst": mol["edge_dst"],
        "edge_mask": mol["edge_mask"], "y": mol["y"],
        "tri_kj": mol["tri_kj"], "tri_ji": mol["tri_ji"],
        "tri_mask": mol["tri_mask"],
    }
    for model, cfg in [
        (egnn, egnn.EGNNConfig(n_layers=2, d_hidden=24, d_in=10)),
        (mace, mace.MACEConfig(n_layers=2, d_hidden=12, d_in=10)),
        (dimenet, dimenet.DimeNetConfig(n_blocks=2, d_hidden=16,
                                        d_in=10, n_bilinear=4)),
    ]:
        p = model.init_params(key, cfg)
        loss, g = jax.value_and_grad(model.regression_loss)(p, batch, cfg)
        assert np.isfinite(float(loss))
        assert all(
            bool(jnp.all(jnp.isfinite(x)))
            for x in jax.tree_util.tree_leaves(g)
        )
