"""Checkpointing: atomic write, async overlap, elastic restore,
idempotent training resume."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.train import (
    AdamWConfig, Checkpointer, TrainConfig, build_train_step,
    init_train_state,
)


def tree_eq(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return all(
        np.array_equal(np.asarray(x, np.float64), np.asarray(y, np.float64))
        for x, y in zip(la, lb)
    )


def test_roundtrip_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 3), jnp.bfloat16)},
            "l": [jnp.zeros(2), jnp.ones(3)]}
    ck.save(3, tree)
    ck.save(7, tree)
    assert ck.latest_step() == 7
    out, man = ck.restore(step=3)
    assert tree_eq(tree, out)
    assert man["step"] == 3
    assert out["b"]["c"].dtype == jnp.bfloat16


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.arange(100)}
    ck.save_async(1, tree)
    ck.wait()
    out, _ = ck.restore()
    assert tree_eq(tree, out)


def test_no_partial_checkpoint_visible(tmp_path):
    """Tmp dirs never count as checkpoints."""
    ck = Checkpointer(str(tmp_path))
    os.makedirs(tmp_path / ".tmp-step_9")
    assert ck.latest_step() is None
    ck.save(1, {"w": jnp.zeros(2)})
    assert ck.latest_step() == 1


def test_elastic_restore_resharding(tmp_path):
    """Restore with explicit (different) shardings re-places leaves."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.arange(16.0)}
    ck.save(1, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data"))}
    out, _ = ck.restore(shardings=sh)
    assert tree_eq(tree, out)
    assert out["w"].sharding == sh["w"]


def test_resume_is_idempotent(tmp_path, key, topo1):
    """train 6 steps == train 3, checkpoint, restore, train 3 more —
    bitwise-identical params (deterministic data + optimizer)."""
    from repro.data import lm_batch
    from repro.models.lm import LMConfig, init_params, lm_loss

    cfg = LMConfig(name="t", n_layers=1, d_model=32, n_heads=2,
                   n_kv_heads=1, d_ff=64, vocab=61,
                   param_dtype="float32", loss_chunk=8)
    tc = TrainConfig(adamw=AdamWConfig(lr=1e-2), warmup_steps=2,
                     total_steps=6)
    fn = jax.jit(build_train_step(
        lambda pp, b: lm_loss(pp, b, cfg, topo1), tc
    ))

    def batch_at(i):
        return {k: jnp.asarray(v)
                for k, v in lm_batch(i, 4, 16, 61, seed=0).items()}

    # continuous run
    p = init_params(key, cfg)
    st = init_train_state(p, tc)
    for i in range(6):
        p, st, _ = fn(p, st, batch_at(i), jnp.int32(i))

    # interrupted run
    p2 = init_params(key, cfg)
    st2 = init_train_state(p2, tc)
    ck = Checkpointer(str(tmp_path))
    for i in range(3):
        p2, st2, _ = fn(p2, st2, batch_at(i), jnp.int32(i))
    ck.save(3, {"params": p2, "opt": st2})
    tree, man = ck.restore()
    p3, st3 = tree["params"], tree["opt"]
    for i in range(man["step"], 6):
        p3, st3, _ = fn(p3, st3, batch_at(i), jnp.int32(i))

    assert tree_eq(p, p3)
