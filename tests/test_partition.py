"""The pluggable relabeling-partitioner subsystem
(repro.graph.partition): strategy correctness host-side, the
@partition spec grammar, and engine equivalence — every partitioner
must produce bit-identical un-permuted final states, because the
engine's orderings are functions of workitem values, never of vertex
placement.  The 8-device equivalence gate lives in
tests/test_distributed_subprocess.py."""

import numpy as np
import pytest

from repro.api import Problem, SingleSource, EveryVertex, Solver, SolverConfig
from repro.core import dijkstra_reference
from repro.graph import (
    Graph,
    PARTITIONER_KINDS,
    canonical_partitioner,
    partition_1d,
    partition_graph,
    rmat1,
)

ALL_PARTS = ["block", "shuffle:3", "ebal", "degree"]


def edge_set(g: Graph):
    return set(zip(g.src.tolist(), g.dst.tolist(), g.weight.tolist()))


def reconstruct_edges(pg):
    """Edge set in original ids via the owner-mapping seam
    (to_global + inv_perm) — exercises exactly the translation the
    facade relies on."""
    mask = pg.row_src != pg.n_local
    ps, rs = np.nonzero(mask)
    gsrc = pg.to_global(ps, pg.row_src[ps, rs])
    cols = pg.col[ps, rs]
    wgts = pg.wgt[ps, rs]
    em = cols != pg.n_pad
    dsts = pg.inv_perm[cols[em]]
    srcs = np.repeat(gsrc, em.sum(axis=1))
    return set(zip(srcs.tolist(), dsts.tolist(), wgts[em].tolist()))


# ------------------------------------------------------- host-side


@pytest.mark.parametrize("part", ALL_PARTS)
@pytest.mark.parametrize("n_parts", [1, 2, 8])
def test_partitioner_preserves_edges(tiny_graphs, part, n_parts):
    for g in tiny_graphs[:2]:
        pg = partition_graph(g, n_parts, partitioner=part)
        assert pg.partitioner == canonical_partitioner(part)
        assert reconstruct_edges(pg) == edge_set(g)


@pytest.mark.parametrize("part", ALL_PARTS)
def test_owner_slot_to_global_inverse(tiny_graphs, part):
    g = tiny_graphs[0]
    pg = partition_graph(g, 4, partitioner=part)
    v = np.arange(g.n)
    rank, slot = pg.owner_slot(v)
    assert np.all((0 <= rank) & (rank < pg.n_parts))
    assert np.all((0 <= slot) & (slot < pg.n_local))
    assert np.array_equal(pg.to_global(rank, slot), v)
    # every real vertex appears exactly once in the padded space
    pid = rank * pg.n_local + slot
    assert len(set(pid.tolist())) == g.n
    # unpermute inverts the relabeling
    state = np.arange(pg.n_pad, dtype=np.float32)
    assert np.array_equal(pg.unpermute(state), pid.astype(np.float32))


def test_ebal_reduces_max_rows_on_skewed_rmat():
    """The acceptance-gate inequality, host-side: edge-balanced
    boundaries strictly shrink the stacked (max-rank) virtual row
    count that every rank's dense sweep pays."""
    g = rmat1(11, seed=5)
    block = partition_graph(g, 8, width=8, partitioner="block")
    ebal = partition_graph(g, 8, width=8, partitioner="ebal")
    assert ebal.rows_per_rank < block.rows_per_rank
    assert (
        ebal.load_stats()["straggler_rows"]
        < block.load_stats()["straggler_rows"]
    )


def test_load_stats_consistency(tiny_graphs):
    g = tiny_graphs[0]
    for part in ALL_PARTS:
        pg = partition_graph(g, 4, partitioner=part)
        st = pg.load_stats()
        assert sum(st["edges_per_rank"]) == g.m
        assert max(st["rows_per_rank"]) == st["max_rows"] == pg.rows_per_rank
        assert 0 < st["ell_occupancy"] <= 1
        assert st["straggler_rows"] >= 1.0
        assert st["straggler_edges"] >= 1.0
        assert pg.partitioner in pg.describe()


def test_block_is_identity_and_partition_1d_compatible(tiny_graphs):
    g = tiny_graphs[0]
    a = partition_1d(g, 4)
    b = partition_graph(g, 4, partitioner="block")
    assert a.perm is None and b.perm is None
    assert np.array_equal(a.row_src, b.row_src)
    assert np.array_equal(a.col, b.col)
    assert np.array_equal(a.wgt, b.wgt)
    # identity seam: owner_slot is the classic divmod
    v = np.arange(g.n)
    rank, slot = a.owner_slot(v)
    assert np.array_equal(rank, v // a.n_local)
    assert np.array_equal(slot, v % a.n_local)


def test_canonicalization_and_errors():
    assert canonical_partitioner("BLOCK") == "block"
    assert canonical_partitioner("shuffle") == "shuffle:0"
    assert canonical_partitioner("shuffle:42") == "shuffle:42"
    assert canonical_partitioner(" ebal ") == "ebal"
    with pytest.raises(ValueError, match="did you mean 'ebal'"):
        canonical_partitioner("ebl")
    with pytest.raises(ValueError, match="unknown partitioner"):
        canonical_partitioner("metis")
    with pytest.raises(ValueError, match="takes no argument"):
        canonical_partitioner("block:3")
    with pytest.raises(ValueError, match="seed must be an integer"):
        canonical_partitioner("shuffle:x")
    with pytest.raises(ValueError, match="empty partitioner"):
        canonical_partitioner("")
    assert set(PARTITIONER_KINDS) == {"block", "shuffle", "ebal", "degree"}


def test_shuffle_deterministic_per_seed(tiny_graphs):
    g = tiny_graphs[0]
    a = partition_graph(g, 4, partitioner="shuffle:9")
    b = partition_graph(g, 4, partitioner="shuffle:9")
    c = partition_graph(g, 4, partitioner="shuffle:10")
    assert np.array_equal(a.perm, b.perm)
    assert not np.array_equal(a.perm, c.perm)
    assert a.same_layout(b) and not a.same_layout(c)


# ------------------------------------------------- spec grammar


def test_spec_grammar_partition_segment():
    cfg = SolverConfig.from_spec("delta:5+threadq/sparse@ebal")
    assert cfg.partition == "ebal" and cfg.exchange == "sparse"
    assert SolverConfig.from_spec(cfg.name) == cfg
    # v2 hierarchy grammar composes with @ too
    cfg = SolverConfig.from_spec(
        "delta:5 > pod:dijkstra /sparse @shuffle:7"
    )
    assert cfg.partition == "shuffle:7"
    assert SolverConfig.from_spec(cfg.name) == cfg
    # defaults: block, omitted from the name
    assert SolverConfig.from_spec("delta:5").partition == "block"
    assert "@" not in SolverConfig.from_spec("delta:5").name
    # canonicalization makes configs hash-equal
    assert SolverConfig(partition="shuffle") == SolverConfig(
        partition="shuffle:0"
    )
    # explicit override beats the parsed segment
    cfg = SolverConfig.from_spec("delta:5@ebal", partition="degree")
    assert cfg.partition == "degree"


def test_spec_grammar_partition_errors():
    with pytest.raises(ValueError, match="did you mean"):
        SolverConfig.from_spec("delta:5@ebl")
    with pytest.raises(ValueError, match="unknown partitioner"):
        SolverConfig(partition="metis")
    with pytest.raises(ValueError, match="empty partition segment"):
        SolverConfig.from_spec("delta:5@")
    with pytest.raises(ValueError, match="empty ordering segment"):
        SolverConfig.from_spec("@ebal")


# ------------------------------------------- engine equivalence (P=1)


def _close(a, b):
    return np.allclose(
        np.where(np.isinf(a), -1, a), np.where(np.isinf(b), -1, b)
    )


@pytest.mark.parametrize(
    "spec", ["delta:5+threadq/a2a", "dijkstra/sparse", "chaotic+buffer"]
)
def test_single_device_equivalence(tiny_graphs, spec):
    """Un-permuted final distances are bit-identical across
    partitioners (the relabeling changes layout, never values)."""
    g = tiny_graphs[0]
    ref = dijkstra_reference(g, 0)
    base = None
    for part in ALL_PARTS:
        cfg = SolverConfig.from_spec(spec, partition=part, frontier_cap=32)
        sol = Solver(cfg).solve(Problem(g, SingleSource(0)))
        assert _close(ref, sol.state), part
        if base is None:
            base = sol.state
        assert np.array_equal(base, sol.state), (spec, part)


def test_cc_everyvertex_under_shuffle(tiny_graphs):
    g = tiny_graphs[0].symmetrized().deduplicated()
    a = Solver(SolverConfig(root="chaotic", partition="shuffle:5")).solve(
        Problem(g, EveryVertex(), processing="cc")
    )
    b = Solver(SolverConfig(root="chaotic")).solve(
        Problem(g, EveryVertex(), processing="cc")
    )
    assert np.array_equal(a.state, b.state)


def test_sswp_under_degree(tiny_graphs):
    g = tiny_graphs[0]
    a = Solver(SolverConfig(root="chaotic", partition="degree")).solve(
        Problem(g, SingleSource(0), processing="sswp")
    )
    b = Solver(SolverConfig(root="chaotic")).solve(
        Problem(g, SingleSource(0), processing="sswp")
    )
    assert np.array_equal(a.state, b.state)


# ------------------------------------------------- facade plumbing


def test_prepartitioned_graph_mismatch_raises(tiny_graphs):
    g = tiny_graphs[0]
    pg = partition_graph(g, 1, partitioner="shuffle:3")
    with pytest.raises(ValueError, match="pre-partitioned"):
        Solver("delta:5").solve(Problem(pg, SingleSource(0)))
    # matching partitioner is accepted
    sol = Solver(SolverConfig(partition="shuffle:3")).solve(
        Problem(pg, SingleSource(0))
    )
    assert _close(dijkstra_reference(g, 0), sol.state)


def test_resolve_composes_with_permutation(tiny_graphs):
    """perm composes with warm restarts: resolve under a non-identity
    partitioner seeds the relabeled slot space correctly."""
    g = tiny_graphs[0]
    solver = Solver(SolverConfig(partition="shuffle:3"))
    sol = solver.solve(Problem(g, SingleSource(0)))
    w2 = g.weight.copy()
    w2[np.random.default_rng(0).integers(0, g.m, 25)] *= 0.25
    g2 = Graph(g.n, g.src, g.dst, w2, name="cheap")
    warm = solver.resolve(sol, graph=g2)
    ref2 = dijkstra_reference(g2, 0)
    assert _close(ref2, warm.state)
    # adding a source through the permuted seam
    warm2 = solver.resolve(sol, new_sources=3)
    assert warm2.state[3] == 0.0


def test_resolve_layout_change_raises(tiny_graphs):
    g = tiny_graphs[0]
    sol = Solver(SolverConfig(partition="shuffle:3")).solve(
        Problem(g, SingleSource(0))
    )
    with pytest.raises(ValueError, match="partition layout changed"):
        Solver(SolverConfig(partition="shuffle:4")).resolve(sol, graph=g)


def test_selfstab_in_ell_cache(tiny_graphs):
    """Satellite: repeated sweeps re-chunk nothing; in-place mutation
    invalidates."""
    from repro.core import selfstab

    g = tiny_graphs[2]
    selfstab.in_ell_cache_clear()
    a = selfstab.in_ell(g)
    b = selfstab.in_ell(g)
    assert a is b  # memo hit, no rebuild
    ref = dijkstra_reference(g, 0)
    d0 = np.full(g.n, np.inf, np.float32)
    d = selfstab.synchronous_sweep(g, 0, d0, iters=3 * g.n, ell=a)
    assert _close(ref, d)
    old = g.weight.copy()
    try:
        g.weight *= 2.0  # in place: id(g) unchanged, content changed
        c = selfstab.in_ell(g)
        assert c is not a
        d2 = selfstab.synchronous_sweep(g, 0, d0, iters=3 * g.n)
        assert _close(2.0 * ref, d2)
    finally:
        g.weight[:] = old  # tiny_graphs is session-scoped
        selfstab.in_ell_cache_clear()
