"""HLO collective parser + roofline model."""

from repro.roofline import Roofline, collective_bytes
from repro.roofline.hlo import _shape_bytes


SNIPPET = """
HloModule m
ENTRY %main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %p1 = bf16[64]{0} parameter(1)
  %ag = f32[1024,256]{1,0} all-gather(%p0), dimensions={0}
  %ar = bf16[64]{0} all-reduce(%p1), to_apply=%add
  %rs = f32[16,256]{1,0} reduce-scatter(%ag), dimensions={0}
  %cp = bf16[64]{0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert _shape_bytes("bf16[64]") == 128
    assert _shape_bytes("(f32[2,2], s32[3])") == 16 + 12
    assert _shape_bytes("pred[]") == 1


def test_collective_parse_snippet():
    out = collective_bytes(SNIPPET)
    assert out["counts"] == {
        "all-gather": 1, "all-reduce": 1, "reduce-scatter": 1,
        "collective-permute": 1,
    }
    assert out["bytes"]["all-gather"] == 128 * 256 * 4   # operand %p0
    assert out["bytes"]["all-reduce"] == 128             # %p1 bf16[64]
    assert out["bytes"]["reduce-scatter"] == 1024 * 256 * 4
    assert out["bytes"]["collective-permute"] == 128


def test_collective_parse_real_module():
    """Cross-check against a real compiled psum: one all-reduce of a
    known payload size."""
    import subprocess
    import sys
    import os

    child = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
mesh = jax.make_mesh((4,), ("d",))
def f(x):
    from repro.compat import shard_map
    return shard_map(lambda y: jax.lax.psum(y, "d"), mesh=mesh,
                     in_specs=P("d"), out_specs=P())(x)
xs = jax.ShapeDtypeStruct((4096,), jnp.float32)
with mesh:
    comp = jax.jit(f, in_shardings=NamedSharding(mesh, P("d"))).lower(xs).compile()
from repro.roofline import collective_bytes
out = collective_bytes(comp.as_text())
assert out["counts"].get("all-reduce", 0) >= 1, out
assert out["bytes"]["all-reduce"] == 1024 * 4, out   # per-device shard
print("PARSE-OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", child], env=env,
                       capture_output=True, text=True, timeout=300,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "PARSE-OK" in r.stdout


def test_roofline_terms():
    r = Roofline(
        arch="a", cell="c", mesh="m", chips=256,
        hlo_flops=197e12,       # exactly 1s of compute
        hlo_bytes=819e9 * 2,    # 2s of HBM
        coll_bytes=50e9 * 0.5,  # 0.5s of ICI
        model_flops=197e12 * 256 * 0.5,
    )
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 2.0) < 1e-9
    assert abs(r.t_collective - 0.5) < 1e-9
    assert r.dominant == "memory"
    assert abs(r.useful_ratio - 0.5) < 1e-9
    # fraction = useful / (chips * peak * t_bound) = 0.25
    assert abs(r.roofline_fraction - 0.25) < 1e-9


def test_probe_correction():
    from repro.roofline import from_record

    rec = {
        "arch": "a", "cell": "c", "mesh": "m", "chips": 2,
        "cost": {"flops": 999.0, "bytes accessed": 999.0},
        "collectives": {"total_bytes": 999},
        "model_flops": 100.0,
        "probes": {
            "n_layers": 10,
            "L1": {"flops": 30.0, "bytes": 20.0, "collective_bytes": 4},
            "L2": {"flops": 40.0, "bytes": 25.0, "collective_bytes": 6},
        },
    }
    r = from_record(rec)
    assert r.hlo_flops == 30 + 9 * 10     # f1 + (L-1) * (f2-f1)
    assert r.hlo_bytes == 20 + 9 * 5
    assert r.coll_bytes == 4 + 9 * 2
