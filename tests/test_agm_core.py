"""Logical AGM engine (Definition 3 semantics) + self-stabilizing
kernel, against the textbook Dijkstra oracle."""

import numpy as np
import pytest

from repro.core import (
    dijkstra_reference, make_ordering, run_logical, sssp_agm,
)
from repro.core.selfstab import synchronous_sweep

SPECS = ["chaotic", "dijkstra", "delta:5", "delta:20", "kla:1", "kla:2"]


def close(a, b):
    return np.allclose(
        np.where(np.isinf(a), -1, a), np.where(np.isinf(b), -1, b)
    )


@pytest.mark.parametrize("spec", SPECS)
def test_logical_agm_matches_dijkstra(tiny_graphs, spec):
    for g in tiny_graphs:
        ref = dijkstra_reference(g, 0)
        dist, m = run_logical(sssp_agm(g, 0, make_ordering(spec)))
        assert close(ref, dist), f"{spec} on {g.name}"
        assert m.commits > 0 and m.relaxations >= m.commits


def test_ordering_reduces_work(tiny_graphs):
    """Paper §IV: Dijkstra ordering does the least redundant work;
    chaotic the most.  (commits = state updates actually applied.)"""
    g = tiny_graphs[0]
    _, m_dj = run_logical(sssp_agm(g, 0, make_ordering("dijkstra")))
    _, m_d5 = run_logical(sssp_agm(g, 0, make_ordering("delta:5")))
    _, m_ch = run_logical(sssp_agm(g, 0, make_ordering("chaotic")))
    assert m_dj.commits <= m_d5.commits <= m_ch.commits
    # and inversely for the number of equivalence classes (sync)
    assert m_dj.classes >= m_d5.classes >= m_ch.classes


def test_selfstab_sweep_from_zero_state(tiny_graphs):
    """Algorithm 1 under a synchronous demon from the standard init."""
    for g in tiny_graphs[:2]:
        ref = dijkstra_reference(g, 0)
        d0 = np.full(g.n, np.inf, np.float32)
        d = synchronous_sweep(g, 0, d0, iters=3 * g.n)
        assert close(ref, d), g.name


def test_selfstab_sweep_from_corrupted_state(tiny_graphs):
    """The self-stabilization property itself: convergence from an
    ARBITRARY corrupted state (R1 may raise distances)."""
    g = tiny_graphs[3]  # small-world: low diameter, converges fast
    ref = dijkstra_reference(g, 0)
    rng = np.random.default_rng(0)
    d0 = rng.uniform(0, 50, g.n).astype(np.float32)  # garbage state
    d = synchronous_sweep(g, 0, d0, iters=400)
    assert close(ref, d)


def test_selfstab_pallas_kernel_path(tiny_graphs):
    g = tiny_graphs[0]
    ref = dijkstra_reference(g, 0)
    d0 = np.full(g.n, np.inf, np.float32)
    d = synchronous_sweep(g, 0, d0, iters=3 * g.n,
                          impl="pallas_interpret")
    assert close(ref, d)
