"""Multi-device engine semantics, exercised in a subprocess with 8
placeholder host devices (the parent pytest process must keep seeing
one device, so the XLA flag lives only in the child env)."""

import os
import subprocess
import sys

import pytest

CHILD = r"""
import numpy as np, jax
assert len(jax.devices()) == 8, jax.devices()
from jax.sharding import Mesh
from repro.graph import rmat1, partition_1d
from repro.core import (EngineConfig, run_distributed, make_policy,
                        dijkstra_reference, sssp_sources)

g = rmat1(9, seed=5)
ref = dijkstra_reference(g, 0)
mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
pg = partition_1d(g, 8)
results = {}
for root in ['chaotic', 'delta:20', 'kla:2', 'dijkstra']:
    for variant in ['buffer', 'threadq', 'nodeq', 'numaq']:
        for ex in ['a2a', 'pmin']:
            pol = make_policy(root, variant, chunk_size=16)
            cfg = EngineConfig(policy=pol, exchange=ex)
            d, m = run_distributed(pg, mesh, cfg, sssp_sources(0))
            ok = np.allclose(np.where(np.isinf(ref), -1, ref),
                             np.where(np.isinf(d), -1, d))
            assert ok, (root, variant, ex)
            results[(root, variant, ex)] = m

# the two exchange paths must do identical work (same semantics)
for root in ['chaotic', 'delta:20']:
    a = results[(root, 'buffer', 'a2a')]
    b = results[(root, 'buffer', 'pmin')]
    assert a.relaxations == b.relaxations
    assert a.supersteps == b.supersteps
    # and the optimized exchange moves half the bytes
    assert a.exchange_bytes * 2 == b.exchange_bytes

# pod-scoped (nodeq) ordering does no more work than buffer
a = results[('chaotic', 'nodeq', 'a2a')]
b = results[('chaotic', 'buffer', 'a2a')]
assert a.relaxations <= b.relaxations
assert a.supersteps >= b.supersteps
print('MULTIDEV-OK')
"""


@pytest.mark.slow
def test_engine_8_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", CHILD], env=env, capture_output=True,
        text=True, timeout=900, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))
        ),
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MULTIDEV-OK" in r.stdout


CHILD_API = r"""
import numpy as np, jax
assert len(jax.devices()) == 8, jax.devices()
from repro.api import Problem, SingleSource, Solver
import repro.api as api
from repro.core import dijkstra_reference
from repro.graph import rmat1

g = rmat1(9, seed=5)
mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
for exchange in ['a2a', 'pmin']:
    solver = Solver(f'delta:5+threadq/{exchange}', mesh=mesh)
    # batched sources over the 8-device mesh
    vs = [0, 3, 40]
    sols = solver.solve_batch([Problem(g, SingleSource(v)) for v in vs])
    for v, s in zip(vs, sols):
        ref = dijkstra_reference(g, v)
        assert np.allclose(np.where(np.isinf(ref), -1, ref),
                           np.where(np.isinf(s.state), -1, s.state)), \
            (exchange, v)
    # compile-once: a second batch on the same shapes re-traces nothing
    before = api.trace_count()
    solver.solve_batch([Problem(g, SingleSource(v)) for v in (7, 9, 11)])
    assert api.trace_count() == before, exchange
    # warm restart after cheapening a few edges
    w2 = g.weight.copy()
    w2[np.random.default_rng(2).integers(0, g.m, 30)] *= 0.25
    g2 = type(g)(g.n, g.src, g.dst, w2, name='cheap')
    ref2 = dijkstra_reference(g2, 0)
    warm = solver.resolve(sols[0], graph=g2)
    cold = solver.solve(Problem(g2, SingleSource(0)))
    assert np.allclose(np.where(np.isinf(ref2), -1, ref2),
                       np.where(np.isinf(warm.state), -1, warm.state)), \
        exchange
    assert warm.metrics.supersteps < cold.metrics.supersteps, exchange
print('API-MULTIDEV-OK')
"""


@pytest.mark.slow
def test_api_facade_8_devices():
    """Batched sources + warm restart through repro.api on an 8-device
    (pod, data, model) mesh, both exchange paths."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", CHILD_API], env=env, capture_output=True,
        text=True, timeout=900, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))
        ),
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "API-MULTIDEV-OK" in r.stdout


CHILD_SPARSE = r"""
import numpy as np, jax
assert len(jax.devices()) == 8, jax.devices()
from repro.api import Problem, SingleSource, Solver, SolverConfig
from repro.core import dijkstra_reference
from repro.graph import rmat1

g = rmat1(9, seed=5)
ref = dijkstra_reference(g, 0)
mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))

def close(a, b):
    return np.allclose(np.where(np.isinf(a), -1, a),
                       np.where(np.isinf(b), -1, b))

for root in ['delta:5', 'dijkstra', 'kla:2']:
    sols = {}
    for ex in ['a2a', 'sparse', 'auto']:
        cfg = SolverConfig(root=root, variant='threadq', exchange=ex,
                           chunk_size=16, frontier_cap=4)
        sols[ex] = Solver(cfg, mesh=mesh).solve(Problem(g, SingleSource(0)))
        assert close(ref, sols[ex].state), (root, ex)
    a, s = sols['a2a'].metrics, sols['sparse'].metrics
    # identical schedules: the sparse path changes HOW candidates move,
    # never WHICH candidates exist
    assert s.supersteps == a.supersteps, root
    assert s.relaxations == a.relaxations, root
    # the point of the PR: with a tight frontier capacity the sparse
    # exchange moves fewer bytes than the dense reduce-scatter on the
    # supersteps it runs (dijkstra/delta frontiers are far below |V|)
    assert a.exchange_bytes > 0
    if s.sparse_fallbacks < s.supersteps:
        assert s.exchange_bytes < a.exchange_bytes, (
            root, s.exchange_bytes, a.exchange_bytes, s.sparse_fallbacks)

# overflow fallback on every superstep is still exact
cfg = SolverConfig(root='delta:5', exchange='sparse', frontier_cap=1)
sol = Solver(cfg, mesh=mesh).solve(Problem(g, SingleSource(0)))
assert close(ref, sol.state)
assert sol.metrics.sparse_fallbacks > 0

# batched sources ride the sparse path too
solver = Solver('delta:5+threadq/sparse', mesh=mesh)
vs = [0, 3, 40]
for v, s in zip(vs, solver.solve_batch(
        [Problem(g, SingleSource(v)) for v in vs])):
    r = dijkstra_reference(g, v)
    assert close(r, s.state), v
print('SPARSE-MULTIDEV-OK')
"""


@pytest.mark.slow
def test_sparse_exchange_8_devices():
    """/sparse and /auto on an 8-device mesh: states identical to the
    dense path, fewer exchanged bytes at a tight frontier capacity,
    exact under forced overflow fallback."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", CHILD_SPARSE], env=env,
        capture_output=True, text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SPARSE-MULTIDEV-OK" in r.stdout


CHILD_HIER = r"""
import numpy as np, jax
assert len(jax.devices()) == 8, jax.devices()
from repro.api import Problem, SingleSource, Solver, SolverConfig
from repro.core import dijkstra_reference
from repro.graph import rmat1

g = rmat1(9, seed=5)
ref = dijkstra_reference(g, 0)
mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))

def close(a, b):
    return np.allclose(np.where(np.isinf(a), -1, a),
                       np.where(np.isinf(b), -1, b))

# multi-level hierarchies on a mesh where pod/device/chunk scopes are
# genuinely distinct; each vs the reference solver, across exchanges
HIERS = [
    'delta:20 > pod:dijkstra > chunk:delta:1',
    'delta:20 > pod:delta:5 > device:dijkstra > chunk:topk:16',
    'chaotic > device:dijkstra > chunk:topk:8',
    'kla:2 > pod:dijkstra',
]
mets = {}
for spec in HIERS:
    for ex in ['a2a', 'pmin', 'sparse']:
        cfg = SolverConfig.from_spec(spec, exchange=ex, frontier_cap=8)
        sol = Solver(cfg, mesh=mesh).solve(Problem(g, SingleSource(0)))
        assert close(ref, sol.state), (spec, ex)
        mets[(spec, ex)] = sol.metrics
    # exchange modes keep identical schedules on hierarchies too
    assert mets[(spec, 'a2a')].supersteps == mets[(spec, 'pmin')].supersteps
    assert mets[(spec, 'sparse')].supersteps == mets[(spec, 'a2a')].supersteps
    assert mets[(spec, 'sparse')].relaxations == mets[(spec, 'a2a')].relaxations

# refinement narrows per-superstep work: the 2-level point does no
# more relaxations (and no fewer supersteps) than its root alone
base = Solver(SolverConfig.from_spec('delta:20'), mesh=mesh).solve(
    Problem(g, SingleSource(0))).metrics
ref2 = mets[('delta:20 > pod:dijkstra > chunk:delta:1', 'a2a')]
assert ref2.relaxations <= base.relaxations
assert ref2.supersteps >= base.supersteps

# legacy preset == equivalent hierarchy spec, bit-identical states
a = Solver('delta:20+nodeq', mesh=mesh).solve(Problem(g, SingleSource(0)))
b = Solver('delta:20 > pod:dijkstra', mesh=mesh).solve(
    Problem(g, SingleSource(0)))
assert np.array_equal(a.state, b.state)
assert a.metrics.supersteps == b.metrics.supersteps
print('HIER-MULTIDEV-OK')
"""


@pytest.mark.slow
def test_hierarchy_8_devices():
    """Composed per-level hierarchies on an 8-device (pod, data,
    model) mesh: correct vs the reference solver, identical schedules
    across exchange modes, refinement monotonicity, and legacy-preset
    equivalence."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", CHILD_HIER], env=env,
        capture_output=True, text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "HIER-MULTIDEV-OK" in r.stdout


CHILD_PARTITION = r"""
import numpy as np, jax
assert len(jax.devices()) == 8, jax.devices()
from repro.api import Problem, SingleSource, Solver, SolverConfig
from repro.core import dijkstra_reference
from repro.graph import rmat1, grid_road_graph, partition_graph

mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))

def close(a, b):
    return np.allclose(np.where(np.isinf(a), -1, a),
                       np.where(np.isinf(b), -1, b))

# the equivalence gate: every relabeling partitioner must produce
# BIT-identical un-permuted final distances vs the block baseline,
# across orderings (incl. pod/device/chunk-scoped ones, whose
# intermediate schedules DO depend on vertex placement), exchanges
# and graphs.  W=8 so fat-row chunking makes the RMAT skew visible.
SPECS = [
    'chaotic', 'dijkstra', 'delta:5', 'delta:20', 'kla:2',
    'delta:5+nodeq', 'chaotic+threadq',
    'delta:20 > pod:dijkstra > chunk:delta:1',
]
PARTS = ['block', 'shuffle:3', 'ebal', 'degree']
GRAPHS = [('rmat1', rmat1(8, seed=5)),
          ('road', grid_road_graph(12, seed=1))]
for gname, g in GRAPHS:
    ref = dijkstra_reference(g, 0)
    for spec in SPECS:
        for ex in ['a2a', 'sparse']:
            base = None
            for part in PARTS:
                cfg = SolverConfig.from_spec(
                    spec, exchange=ex, chunk_size=16, partition=part,
                    frontier_cap=16)
                pg = partition_graph(g, 8, width=8, partitioner=part)
                sol = Solver(cfg, mesh=mesh).solve(
                    Problem(pg, SingleSource(0)))
                assert close(ref, sol.state), (gname, spec, ex, part)
                if base is None:
                    base = sol.state
                assert np.array_equal(base, sol.state), \
                    (gname, spec, ex, part)

# and the load-balance payoff on the skewed RMAT: edge-balanced
# boundaries strictly shrink the stacked virtual-row count R
g = GRAPHS[0][1]
Rb = partition_graph(g, 8, width=8, partitioner='block').rows_per_rank
Re = partition_graph(g, 8, width=8, partitioner='ebal').rows_per_rank
assert Re < Rb, (Re, Rb)
print('PARTITION-MULTIDEV-OK')
"""


@pytest.mark.slow
def test_partition_equivalence_8_devices():
    """The partition equivalence gate on an 8-device (pod, data,
    model) mesh: 8 ordering specs x {a2a, sparse} x 4 partitioners x
    2 graphs, bit-identical un-permuted states vs the block baseline,
    plus the ebal row-count reduction on the skewed RMAT."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", CHILD_PARTITION], env=env,
        capture_output=True, text=True, timeout=1800,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "PARTITION-MULTIDEV-OK" in r.stdout


CHILD_PROBLEMS = r"""
import heapq
import numpy as np, jax
assert len(jax.devices()) == 8, jax.devices()
from repro.api import (EveryVertex, Problem, SingleSource, Solver,
                       SolverConfig)
from repro.graph import rmat1
from repro.graph.formats import coo_to_csr

mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
g = rmat1(8, seed=5)
gu = g.symmetrized().deduplicated()

def close(a, b):
    return np.allclose(np.where(np.isinf(a), -1, a),
                       np.where(np.isinf(b), -1, b))

# ---- CC oracle: union-find, canonical label = min id in component
parent = list(range(gu.n))
def find(a):
    while parent[a] != a:
        parent[a] = parent[parent[a]]
        a = parent[a]
    return a
for u, v in zip(gu.src, gu.dst):
    ra, rb = find(int(u)), find(int(v))
    if ra != rb:
        parent[ra] = rb
comp_min = {}
for v in range(gu.n):
    r = find(v)
    comp_min[r] = min(comp_min.get(r, v), v)
cc_ref = np.array([comp_min[find(v)] for v in range(gu.n)], np.int64)

# ---- SSWP oracle: max-min Dijkstra
csr = coo_to_csr(g)
width = np.full(g.n, -np.inf)
width[0] = np.inf
visited = np.zeros(g.n, bool)
heap = [(-np.float64(np.inf), 0)]
while heap:
    nw, v = heapq.heappop(heap)
    w = -nw
    if visited[v]:
        continue
    visited[v] = True
    nbrs, ws = csr.neighbors(v)
    for u, ew in zip(nbrs, ws):
        cand = min(w, float(ew))
        if cand > width[u]:
            width[u] = cand
            heapq.heappush(heap, (-cand, int(u)))

# CC (EveryVertex initial workitem set) and SSWP through the facade,
# under identity and non-identity relabeling partitioners, both
# exchange families — all bit-identical to block and oracle-correct
for ex in ['a2a', 'sparse']:
    cc_base = sswp_base = None
    for part in ['block', 'shuffle:3', 'ebal']:
        cfg = SolverConfig(root='chaotic', exchange=ex, partition=part,
                           frontier_cap=16)
        cc = Solver(cfg, mesh=mesh).solve(
            Problem(gu, EveryVertex(), processing='cc'))
        assert np.array_equal(cc.state.astype(np.int64), cc_ref), \
            ('cc', ex, part)
        sswp = Solver(cfg, mesh=mesh).solve(
            Problem(g, SingleSource(0), processing='sswp'))
        assert close(width, sswp.state), ('sswp', ex, part)
        if cc_base is None:
            cc_base, sswp_base = cc.state, sswp.state
        assert np.array_equal(cc_base, cc.state), ('cc', ex, part)
        assert np.array_equal(sswp_base, sswp.state), ('sswp', ex, part)
print('PROBLEMS-MULTIDEV-OK')
"""


@pytest.mark.slow
def test_cc_sswp_facade_8_devices():
    """CC (EveryVertex) and SSWP through the facade on the 8-device
    mesh, under identity and non-identity partitioners and both
    exchange families, vs host oracles."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", CHILD_PROBLEMS], env=env,
        capture_output=True, text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "PROBLEMS-MULTIDEV-OK" in r.stdout


CHILD_LM = r"""
import numpy as np, jax, jax.numpy as jnp
assert len(jax.devices()) == 8
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.common import Topology
from repro.models.lm import LMConfig, init_params, lm_loss, param_specs
from repro.models.moe import MoEConfig
from repro.models.common import single_device_topology

mesh = jax.make_mesh((2, 4), ('data', 'model'))
topo = Topology(mesh=mesh, dp_axes=('data',), tp_axis='model')
cfg = LMConfig(name='t', n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
               d_ff=64, vocab=96, param_dtype='float32', loss_chunk=8,
               moe=MoEConfig(n_experts=4, top_k=2, d_model=32, d_ff=64,
                             capacity_factor=2.0, min_capacity=64))
p = init_params(jax.random.PRNGKey(0), cfg)
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 96)
batch = {'tokens': toks, 'labels': toks}
specs = param_specs(cfg, topo)
ps = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P))
with mesh:
    p_sh = jax.tree_util.tree_map(jax.device_put, p, ps)
    loss_dist = jax.jit(lambda pp, b: lm_loss(pp, b, cfg, topo))(p_sh, batch)

topo1 = single_device_topology()
loss_1 = lm_loss(p, batch, cfg, topo1)
err = abs(float(loss_dist) - float(loss_1))
assert err < 2e-3, (float(loss_dist), float(loss_1))
print('LM-DIST-OK', err)
"""


@pytest.mark.slow
def test_lm_moe_distributed_matches_single_device():
    """TP=4 x DP=2 sharded MoE LM loss == single-device loss."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", CHILD_LM], env=env, capture_output=True,
        text=True, timeout=900, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))
        ),
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "LM-DIST-OK" in r.stdout


CHILD_ALIGNED = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.models.gnn.batch import align_segments
from repro.models.gnn.layers import (scatter_sum, scatter_sum_owner_aligned,
                                     aligned_scatter)
from repro.models.common import Topology

rng = np.random.default_rng(0)
E, T, d, P = 64, 200, 8, 8
seg = np.sort(rng.integers(0, E, T)).astype(np.int32)
payload = rng.integers(0, 1000, T).astype(np.int32)
vi, si, mk = align_segments(payload, seg, E, P)
vals = (rng.normal(size=(vi.shape[0], d)).astype(np.float32)
        * mk[:, None])
mesh = jax.make_mesh((2, 4), ("data", "model"))
topo = Topology(mesh=mesh, dp_axes=("data",), tp_axis="model")
with aligned_scatter(topo):
    out_a = jax.jit(lambda v, s: scatter_sum_owner_aligned(v, s, E))(
        jnp.asarray(vals), jnp.asarray(si))
out_p = scatter_sum(jnp.asarray(vals), jnp.asarray(si), E)
assert np.allclose(np.asarray(out_a), np.asarray(out_p), atol=1e-5)
# gradient path stays correct through the shard_map
g = jax.grad(lambda v: jnp.sum(
    scatter_sum_owner_aligned(v, jnp.asarray(si), E) ** 2))
with aligned_scatter(topo):
    ga = g(jnp.asarray(vals))
gp = jax.grad(lambda v: jnp.sum(scatter_sum(v, jnp.asarray(si), E) ** 2))(
    jnp.asarray(vals))
assert np.allclose(np.asarray(ga), np.asarray(gp), atol=1e-5)
print("ALIGNED-OK")
"""


@pytest.mark.slow
def test_aligned_scatter():
    """Owner-aligned shard_map segment-sum == plain segment-sum
    (values + gradients), on 8 devices (§Perf H2)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", CHILD_ALIGNED], env=env,
        capture_output=True, text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "ALIGNED-OK" in r.stdout
