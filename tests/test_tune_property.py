"""Property test: retuning safety of the adaptive controller
(hypothesis; skips itself when the optional dep is absent).

The paper's self-stabilization argument says the kernel's fixpoint is
unique and mid-solve retuning only reorders the schedule.  Machine-
check it: for ARBITRARY controller schedules (delta rescales, frontier
cap jumps, exchange forcing, any segment window), the adaptive solve
must land bit-identically on the static solve's state.
"""

import jax
import numpy as np
import pytest

hyp = pytest.importorskip(
    "hypothesis", reason="optional dev dependency (requirements-dev.txt)"
)
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

from repro.api import Problem, SingleSource, Solver, SolverConfig  # noqa: E402
from repro.tune import Decision, ScheduledPolicy  # noqa: E402
from repro.tune.controller import run_adaptive  # noqa: E402
from repro.graph import rmat1  # noqa: E402

MESH = jax.make_mesh((1,), ("data",))
GRAPH = rmat1(8, seed=3)

decisions = st.builds(
    Decision,
    delta=st.one_of(
        st.none(),
        st.sampled_from([1.0, 2.5, 5.0, 10.0, 40.0]),
    ),
    frontier_cap=st.one_of(
        st.none(), st.sampled_from([1, 2, 4, 8, 64])
    ),
    exchange_force=st.one_of(st.none(), st.sampled_from([0, 1, 2])),
)


@settings(
    max_examples=15,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
@given(
    schedule=st.lists(decisions, max_size=6),
    window=st.integers(min_value=1, max_value=5),
)
def test_any_retuning_schedule_is_bit_identical(schedule, window):
    static = Solver("delta:5/sparse", mesh=MESH).solve(
        Problem(GRAPH, SingleSource(0))
    )
    cfg = SolverConfig.from_spec(
        "delta:5/sparse", adapt="static", adapt_window=window,
        frontier_cap=2,
    )
    solver = Solver(cfg, mesh=MESH)
    pg = solver.partition(GRAPH)
    prob = Problem(GRAPH, SingleSource(0))
    ecfg = cfg.engine_config(prob.processing_fn)
    from repro.core.engine import initial_state

    D0, T0, L0 = initial_state(pg, prob.processing_fn,
                               prob.source_items())
    state, metrics, report = run_adaptive(
        MESH, ecfg, pg, ScheduledPolicy(schedule), D0, T0, L0
    )
    assert metrics.converged
    assert np.array_equal(
        state.reshape(-1)[: GRAPH.n], np.asarray(static.state)
    )
    assert report.segments >= 1
