"""Shared fixtures.  NOTE: no XLA device-count flags here — unit and
smoke tests must see the real (single) device; multi-device tests run
in subprocesses that set their own flags."""

import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def tiny_graphs():
    from repro.graph import rmat1, rmat2, grid_road_graph, small_world_graph

    return [
        rmat1(8, seed=3),
        rmat2(8, seed=5),
        grid_road_graph(12, seed=1),
        small_world_graph(300, seed=2),
    ]


@pytest.fixture(scope="session")
def topo1():
    from repro.models.common import single_device_topology

    return single_device_topology()
