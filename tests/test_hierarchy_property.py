"""Property-based spec round-tripping (satellite of the hierarchy
redesign): for every generated config, ``SolverConfig.from_spec(
cfg.name) == cfg`` — in the legacy ``root+variant/exchange`` grammar
AND the hierarchy ``>`` grammar — and every ``paper_variant_specs()``
string parses to a preset-equivalent hierarchy."""

import pytest

pytest.importorskip(
    "hypothesis", reason="optional dev dependency (requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st

from repro.api import SolverConfig
from repro.core import Hierarchy, make_hierarchy, paper_variant_specs

roots = st.sampled_from(
    ["chaotic", "dijkstra", "delta:3", "delta:5", "delta:12.5",
     "kla:1", "kla:3"]
)
variants = st.sampled_from(["buffer", "threadq", "nodeq", "numaq"])
exchanges = st.sampled_from(["a2a", "pmin", "sparse", "auto"])
chunks = st.sampled_from([1, 16, 64, 1024])
class_orderings = st.sampled_from(
    ["chaotic", "dijkstra", "delta:1", "delta:5", "kla:2"]
)
drains = st.sampled_from(["topk:4", "topk:64", "topk:16:delta:2"])


@given(root=roots, variant=variants, exchange=exchanges, chunk=chunks)
@settings(max_examples=80, deadline=None)
def test_legacy_grammar_round_trips(root, variant, exchange, chunk):
    cfg = SolverConfig(
        root=root, variant=variant, exchange=exchange, chunk_size=chunk
    )
    assert SolverConfig.from_spec(cfg.name) == cfg
    # parsing the explicit legacy string matches direct construction
    assert SolverConfig.from_spec(
        f"{root}+{variant}/{exchange}", chunk_size=chunk
    ) == cfg


@given(
    root=roots,
    pod=st.none() | class_orderings,
    device=st.none() | class_orderings,
    chunk=st.none() | class_orderings | drains,
    exchange=exchanges,
)
@settings(max_examples=120, deadline=None)
def test_hierarchy_grammar_round_trips(root, pod, device, chunk, exchange):
    parts = [root]
    for lvl, o in [("pod", pod), ("device", device), ("chunk", chunk)]:
        if o is not None:
            parts.append(f"{lvl}:{o}")
    spec = " > ".join(parts) + f"/{exchange}"
    cfg = SolverConfig.from_spec(spec)
    assert SolverConfig.from_spec(cfg.name) == cfg, spec
    assert cfg.hierarchy == Hierarchy.from_spec(" > ".join(parts)), spec


@given(chunk=chunks)
@settings(max_examples=10, deadline=None)
def test_paper_specs_parse_to_preset_hierarchies(chunk):
    for spec in paper_variant_specs():
        cfg = SolverConfig.from_spec(spec, chunk_size=chunk)
        root, variant = spec.split("+", 1)
        assert cfg.hierarchy == make_hierarchy(root, variant, chunk), spec
        assert SolverConfig.from_spec(cfg.name, chunk_size=chunk) == cfg
