"""Strict-weak-ordering laws (paper §III properties 1-4) for every
ordering, via hypothesis: the key-based representation makes
``w1 < w2  iff  key(w1) < key(w2)``, so the laws reduce to properties
of the key function — which we verify directly on sampled workitems.
"""

import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis", reason="optional dev dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import make_ordering

ORDERINGS = [
    "chaotic", "dijkstra", "delta:3", "delta:7", "kla:1", "kla:3",
    "topk:16", "topk:16:delta:3",
]

wi = st.tuples(
    st.floats(0, 1e6, allow_nan=False, width=32),  # distance
    st.integers(0, 1000),                          # level
)


def key_of(spec, w):
    o = make_ordering(spec)
    d = jnp.float32(w[0])
    l = jnp.float32(w[1])
    return float(o.class_key(d, l))


def less(spec, w1, w2):
    return key_of(spec, w1) < key_of(spec, w2)


@pytest.mark.parametrize("spec", ORDERINGS)
@given(w1=wi, w2=wi, w3=wi)
@settings(max_examples=60, deadline=None)
def test_strict_weak_ordering_laws(spec, w1, w2, w3):
    # 1) irreflexive
    assert not less(spec, w1, w1)
    # 2) asymmetric
    if less(spec, w1, w2):
        assert not less(spec, w2, w1)
    # 3) transitive
    if less(spec, w1, w2) and less(spec, w2, w3):
        assert less(spec, w1, w3)
    # 4) incomparability is transitive
    inc12 = not less(spec, w1, w2) and not less(spec, w2, w1)
    inc23 = not less(spec, w2, w3) and not less(spec, w3, w2)
    if inc12 and inc23:
        assert not less(spec, w1, w3) and not less(spec, w3, w1)


@given(w1=wi, w2=wi)
@settings(max_examples=30, deadline=None)
def test_chaotic_single_class(w1, w2):
    assert not less("chaotic", w1, w2)


@given(w=wi, dw=st.floats(0.0009765625, 100, width=32))
@settings(max_examples=30, deadline=None)
def test_monotone_keys_under_relaxation(w, dw):
    """Generated workitems (distance + positive weight) never land in
    a smaller equivalence class — the AGM execution invariant."""
    for spec in ["dijkstra", "delta:5"]:
        k1 = key_of(spec, w)
        k2 = key_of(spec, (w[0] + dw, w[1]))
        assert k2 >= k1
    k1 = key_of("kla:2", w)
    k2 = key_of("kla:2", (w[0] + dw, w[1] + 1))
    assert k2 >= k1


# Non-hypothesis coverage of the ordering registry, hierarchy grid and
# spec grammar lives in tests/test_hierarchy.py (it must run even when
# hypothesis is absent).
