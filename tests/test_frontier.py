"""Frontier-sparse execution path: compaction/bucketing primitives,
sparse/auto vs dense equivalence across the paper variant grid, and
overflow-fallback correctness (multi-device semantics run in
tests/test_distributed_subprocess.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Problem, SingleSource, Solver, SolverConfig
from repro.core import dijkstra_reference, paper_variant_specs
from repro.core.frontier import (
    bucket_slots,
    compact_rows,
    frontier_caps,
    scatter_plane,
    sparse_payload,
    unpack_combine,
)

rng = np.random.default_rng(11)


def close(a, b):
    return np.allclose(
        np.where(np.isinf(a), -1, a), np.where(np.isinf(b), -1, b)
    )


@pytest.fixture(scope="module")
def mesh1():
    return jax.make_mesh((1,), ("data",))


# ------------------------------------------------------------ primitives


def test_compact_rows_orders_and_flags_overflow():
    mask = jnp.array([False, True, False, True, True, False, True])
    idx, count, overflow = compact_rows(mask, 8)
    assert list(np.asarray(idx))[:4] == [1, 3, 4, 6]
    assert all(i == 7 for i in np.asarray(idx)[4:])  # sentinel = R
    assert int(count) == 4 and not bool(overflow)
    idx, count, overflow = compact_rows(mask, 2)
    assert list(np.asarray(idx)) == [1, 3]  # first-cap prefix, in order
    assert int(count) == 4 and bool(overflow)


def test_bucket_slots_and_scatter_plane():
    mask = jnp.array([[True, False, True, True], [False, False, False, True]])
    slot, overflow = bucket_slots(mask, 2)
    s = np.asarray(slot)
    assert s[0, 0] == 0 and s[0, 2] == 1
    assert s[0, 1] == 2 and s[0, 3] == 2  # non-candidate + spill -> dropped
    assert s[1, 3] == 0 and bool(overflow)  # row 0 holds 3 > 2 candidates
    vals = jnp.arange(8, dtype=jnp.float32).reshape(2, 4)
    buf = np.asarray(scatter_plane(vals, slot, 2, jnp.float32(-1.0)))
    assert buf.shape == (2, 2)
    assert buf[0, 0] == 0.0 and buf[0, 1] == 2.0
    assert buf[1, 0] == 7.0 and buf[1, 1] == -1.0


@pytest.mark.parametrize("is_min", [True, False])
def test_payload_roundtrip_matches_dense_combine(is_min):
    """pack -> (identity exchange) -> unpack == dense reduce over the
    candidate buffer, for both semirings, with room to spare."""
    P_, n_local = 4, 16
    worst = np.float32(np.inf if is_min else -np.inf)
    C = np.full(P_ * n_local, worst, np.float32)
    hot = rng.choice(P_ * n_local, 20, replace=False)
    C[hot] = rng.uniform(1, 50, 20).astype(np.float32)
    payload, overflow = sparse_payload(
        jnp.asarray(C), [], P_, 8, worst
    )
    assert not bool(overflow)
    # single-host stand-in for all_to_all: rank r's received row p is
    # what rank p built for destination r — here every "rank" holds the
    # same C, so combining any one rank's planes against segment r
    # suffices; use segment 1.
    recv = jnp.asarray(payload)
    mine, mineL = unpack_combine(recv, n_local, 8, is_min, worst, False)
    assert mineL is None
    # oracle: per-destination-segment reduce of C (segment r of each row)
    seg = C.reshape(P_, n_local)
    # unpack_combine scatters ALL P rows of the payload into one
    # (n_local,) buffer -> equals elementwise reduce over segments
    oracle = seg.min(0) if is_min else seg.max(0)
    assert np.allclose(np.where(np.isinf(mine), -1, np.asarray(mine)),
                       np.where(np.isinf(oracle), -1, oracle))


def test_frontier_caps_defaults_and_knob():
    row_cap, slot_cap = frontier_caps(1024, 16, 128, 8)
    assert row_cap == 128 and slot_cap == 64  # clamped at n_local/2
    row_cap, slot_cap = frontier_caps(1024, 16, 128, 8, frontier_cap=4)
    assert row_cap == 4 and slot_cap == 4
    # cap clamps to the row count
    row_cap, _ = frontier_caps(16, 16, 128, 8, frontier_cap=999)
    assert row_cap == 16


# ----------------------------------------------- dense/sparse equivalence


@pytest.mark.slow
@pytest.mark.parametrize("spec", paper_variant_specs())
def test_sparse_and_auto_match_dense_across_grid(tiny_graphs, mesh1, spec):
    """Acceptance: sparse and auto exchange produce states identical to
    the dense path for every member of the paper's variant grid."""
    g = tiny_graphs[0]
    sols = {}
    for exchange in ("a2a", "sparse", "auto"):
        solver = Solver(
            SolverConfig.from_spec(spec, exchange=exchange, chunk_size=64),
            mesh=mesh1,
        )
        sols[exchange] = solver.solve(Problem(g, SingleSource(0)))
    ref = dijkstra_reference(g, 0)
    assert close(ref, sols["a2a"].state), spec
    for exchange in ("sparse", "auto"):
        assert np.array_equal(sols["a2a"].state, sols[exchange].state), (
            spec, exchange
        )
        assert (
            sols[exchange].metrics.supersteps
            == sols["a2a"].metrics.supersteps
        ), (spec, exchange)


def test_overflow_fallback_is_correct(tiny_graphs, mesh1):
    """F smaller than the frontier: every superstep overflows into the
    dense path and the result is still exact."""
    g = tiny_graphs[1]
    ref = dijkstra_reference(g, 0)
    sol = Solver(
        SolverConfig(root="delta:5", exchange="sparse", frontier_cap=1),
        mesh=mesh1,
    ).solve(Problem(g, SingleSource(0)))
    assert close(ref, sol.state)
    dense = Solver(
        SolverConfig(root="delta:5", exchange="a2a"), mesh=mesh1
    ).solve(Problem(g, SingleSource(0)))
    assert sol.metrics.supersteps == dense.metrics.supersteps


def test_sparse_batched_sources(tiny_graphs, mesh1):
    solver = Solver("delta:5+threadq/sparse", mesh=mesh1)
    g = tiny_graphs[0]
    vs = [0, 5, 11]
    sols = solver.solve_batch([Problem(g, SingleSource(v)) for v in vs])
    for v, sol in zip(vs, sols):
        assert close(dijkstra_reference(g, v), sol.state), v


def test_sparse_other_processings(tiny_graphs, mesh1):
    """CC (min label, weightless) and SSWP (max semiring) ride the
    sparse path unchanged."""
    g = tiny_graphs[0]
    for processing in ("cc", "sswp"):
        from repro.api import EveryVertex

        src = EveryVertex() if processing == "cc" else SingleSource(0)
        dense = Solver("chaotic+buffer/a2a", mesh=mesh1).solve(
            Problem(g, src, processing=processing)
        )
        sparse = Solver("chaotic+buffer/sparse", mesh=mesh1).solve(
            Problem(g, src, processing=processing)
        )
        assert np.array_equal(dense.state, sparse.state), processing


def test_sparse_pallas_interpret_relax(tiny_graphs, mesh1):
    """The push-mode Pallas kernel (interpret mode) inside the engine
    agrees with the inline jnp path."""
    g = tiny_graphs[0]
    ref = dijkstra_reference(g, 0)
    sol = Solver(
        SolverConfig(
            root="delta:5", exchange="sparse",
            relax_impl="pallas_interpret",
        ),
        mesh=mesh1,
    ).solve(Problem(g, SingleSource(0)))
    assert close(ref, sol.state)


def test_consecutive_overflow_warns_actionably(tiny_graphs, mesh1):
    """A frontier_cap so small every superstep falls back dense must
    produce ONE RuntimeWarning naming the spec and suggesting both a
    larger cap and /adapt:rho — not a warning per superstep."""
    g = tiny_graphs[0]
    solver = Solver(
        SolverConfig(root="delta:5", exchange="sparse", frontier_cap=1),
        mesh=mesh1,
    )
    with pytest.warns(RuntimeWarning, match="frontier_cap") as rec:
        sol = solver.solve(Problem(g, SingleSource(0)))
    overflow = [w for w in rec
                if "consecutive supersteps" in str(w.message)]
    assert len(overflow) == 1
    msg = str(overflow[0].message)
    assert "delta:5+buffer/sparse" in msg  # names the spec
    assert "/adapt:rho" in msg             # names the adaptive cure
    assert sol.metrics.overflow_streak >= 3
    # a schedule whose frontier fits (dijkstra drains one class at a
    # time here) stays below the streak threshold and stays quiet
    import warnings as _w

    with _w.catch_warnings(record=True) as quiet:
        _w.simplefilter("always")
        sol2 = Solver(
            SolverConfig(root="dijkstra", exchange="sparse"), mesh=mesh1
        ).solve(Problem(g, SingleSource(0)))
    assert sol2.metrics.overflow_streak < 3
    assert not [w for w in quiet
                if "consecutive supersteps" in str(w.message)]


# Property-based sparse-vs-dense equivalence on arbitrary random
# graphs lives in tests/test_frontier_property.py (needs hypothesis).
